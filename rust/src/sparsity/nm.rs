//! Exact N:M semi-structured selection.
//!
//! Semantics (shared with the Pallas kernel — see
//! `python/compile/kernels/nm_sparse.py::nm_mask_ref`):
//! within each non-overlapping block of `m` consecutive elements along the
//! last dimension, keep the `n` elements with the highest score; the rank of
//! element `i` is `#{j : s_j > s_i} + #{j < i : s_j == s_i}` so ties resolve
//! toward lower indices and exactly `n` elements survive per block.

use crate::sparsity::pipeline::{self, Scratch};

/// Compute the keep-mask for one row of scores. `scores.len()` must be a
/// multiple of `m`.
///
/// Thin shim over the fused pipeline's partial selection (bit-identical
/// masks for NaN-free scores — the seed rank loop kept every NaN element,
/// the fused path treats NaN as an index-tie — O(m) per block instead of
/// the old O(m²) rank loop). Hot paths should hold a [`Scratch`] and call
/// [`pipeline::nm_mask_into`] directly.
#[deprecated(note = "use sparsity::pipeline::nm_mask_into with a reusable Scratch")]
pub fn nm_mask(scores: &[f32], n: usize, m: usize) -> Vec<bool> {
    let mut mask = vec![false; scores.len()];
    let mut scratch = Scratch::new();
    pipeline::nm_mask_into(scores, n, m, &mut mask, &mut scratch);
    mask
}

/// Apply an N:M mask in place: zero the dropped elements of `values` using
/// scores (which may differ from values — e.g. CLACT or Amber scores).
#[deprecated(note = "use sparsity::pipeline::nm_prune_by_scores with a reusable Scratch")]
pub fn nm_prune_by(values: &mut [f32], scores: &[f32], n: usize, m: usize) {
    let mut scratch = Scratch::new();
    pipeline::nm_prune_by_scores(values, scores, n, m, &mut scratch);
}

/// Magnitude-based N:M pruning (the paper's ACT criterion): score = |x|.
#[deprecated(note = "use sparsity::pipeline::Sparsifier::sparsify_row")]
pub fn nm_prune_magnitude(values: &mut [f32], n: usize, m: usize) {
    let sp = pipeline::Sparsifier::new(crate::sparsity::Pattern::NM {
        n: n as u32,
        m: m as u32,
    });
    sp.sparsify_row(values, &mut Scratch::new());
}

/// Check that a row satisfies the N:M constraint (≤ n non-zeros per block;
/// exactly n when the block had ≥ n non-zero scores).
pub fn satisfies_nm(values: &[f32], n: usize, m: usize) -> bool {
    values.len() % m == 0
        && values
            .chunks_exact(m)
            .all(|b| b.iter().filter(|x| **x != 0.0).count() <= n)
}

/// Count of non-zeros per block, for diagnostics.
pub fn block_occupancy(values: &[f32], m: usize) -> Vec<usize> {
    values
        .chunks_exact(m)
        .map(|b| b.iter().filter(|x| **x != 0.0).count())
        .collect()
}

#[cfg(test)]
#[allow(deprecated)] // the shims' semantics are exactly what these tests pin
mod tests {
    use super::*;
    use crate::util::miniprop::{forall_simple, gen_activations, Config};
    use crate::util::prng::Rng;

    #[test]
    fn keeps_top_n_simple() {
        let s = [1.0, 4.0, 3.0, 2.0];
        let mask = nm_mask(&s, 2, 4);
        assert_eq!(mask, vec![false, true, true, false]);
    }

    #[test]
    fn ties_break_low_index() {
        let s = [5.0, 5.0, 5.0, 5.0];
        let mask = nm_mask(&s, 2, 4);
        assert_eq!(mask, vec![true, true, false, false]);
    }

    #[test]
    fn multiple_blocks_independent() {
        let s = [9.0, 0.0, 0.0, 1.0, /* block 2 */ 0.0, 1.0, 2.0, 3.0];
        let mask = nm_mask(&s, 2, 4);
        assert_eq!(
            mask,
            vec![true, false, false, true, false, false, true, true]
        );
    }

    #[test]
    fn exactly_n_kept_always() {
        let cfg = Config::default();
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let m = *rng.choose(&[4usize, 8, 16, 32]);
                let n = rng.range(1, m + 1);
                let blocks = rng.range(1, 8);
                (gen_activations(rng, m * blocks), n, m)
            },
            |(xs, n, m)| {
                let mask = nm_mask(xs, *n, *m);
                mask.chunks_exact(*m)
                    .all(|b| b.iter().filter(|k| **k).count() == *n)
            },
        );
    }

    #[test]
    fn kept_scores_dominate_dropped() {
        let cfg = Config::default();
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let m = *rng.choose(&[4usize, 8, 16]);
                let n = rng.range(1, m);
                (gen_activations(rng, m * 4), n, m)
            },
            |(xs, n, m)| {
                let mask = nm_mask(xs, *n, *m);
                xs.chunks_exact(*m).zip(mask.chunks_exact(*m)).all(|(b, mk)| {
                    let min_kept = b
                        .iter()
                        .zip(mk)
                        .filter(|(_, k)| **k)
                        .map(|(x, _)| *x)
                        .fold(f32::INFINITY, f32::min);
                    let max_dropped = b
                        .iter()
                        .zip(mk)
                        .filter(|(_, k)| !**k)
                        .map(|(x, _)| *x)
                        .fold(f32::NEG_INFINITY, f32::max);
                    max_dropped <= min_kept
                })
            },
        );
    }

    #[test]
    fn n_equals_m_keeps_all() {
        let s = [1.0f32, -2.0, 3.0, -4.0];
        assert!(nm_mask(&s, 4, 4).iter().all(|k| *k));
    }

    #[test]
    fn prune_magnitude_zeroes_small() {
        let mut v = [0.1f32, -9.0, 0.2, 8.0];
        nm_prune_magnitude(&mut v, 2, 4);
        assert_eq!(v, [0.0, -9.0, 0.0, 8.0]);
        assert!(satisfies_nm(&v, 2, 4));
    }

    #[test]
    fn prune_by_external_scores() {
        // Values pruned according to someone else's scores (CLACT/Amber).
        let mut v = [10.0f32, 20.0, 30.0, 40.0];
        let scores = [4.0f32, 3.0, 2.0, 1.0];
        nm_prune_by(&mut v, &scores, 2, 4);
        assert_eq!(v, [10.0, 20.0, 0.0, 0.0]);
    }

    #[test]
    fn occupancy_counts() {
        let v = [1.0f32, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0, 5.0];
        assert_eq!(block_occupancy(&v, 4), vec![2, 1]);
    }

    #[test]
    #[should_panic]
    fn bad_length_panics() {
        nm_mask(&[1.0, 2.0, 3.0], 2, 4);
    }
}
