//! Weight pruning (the paper's WT baselines).
//!
//! Weight sparsity is *static*: we prune the checkpoint tensors rust-side
//! and run them through the **dense** HLO artifact, exactly how a deployment
//! would ship a pruned model. Supports unstructured global magnitude pruning
//! (Figure 1 / Table 10 WT rows) and semi-structured N:M pruning along the
//! input dimension (Table 2/11/12 WT rows), mirroring how 2:4 weight
//! sparsity is laid out for sparse tensor cores.

use crate::sparsity::pipeline::{Scratch, Sparsifier};
use crate::sparsity::{unstructured, Pattern};
use crate::util::tensor::{Tensor, TensorStore};
use anyhow::Result;

/// Names of sparsifiable linear-layer weights in the checkpoint: every
/// `layers.<i>.<proj>.w` 2-D tensor. Embedding/norm/head tensors are left
/// dense, matching the paper (only linear-layer inputs/weights sparsified).
pub fn prunable_weight_names(store: &TensorStore) -> Vec<String> {
    store
        .iter()
        .filter(|(name, t)| {
            t.rank() == 2 && name.starts_with("layers.") && name.ends_with(".w")
        })
        .map(|(name, _)| name.to_string())
        .collect()
}

/// Apply weight pruning with `pattern` to every prunable tensor in `store`.
/// Returns the number of tensors pruned.
///
/// The N:M path builds one fused [`Sparsifier`] + [`Scratch`] for the whole
/// store and reuses them across every tensor row — the bind-time cost for
/// the WT baselines is a single allocation-free sweep.
pub fn prune_weights(store: &mut TensorStore, pattern: Pattern) -> Result<usize> {
    let names = prunable_weight_names(store);
    let sparsifier = Sparsifier::new(pattern);
    let mut scratch = Scratch::new();
    for name in &names {
        let t = store.get_mut(name)?;
        prune_tensor_rows(t, &sparsifier, &mut scratch);
    }
    Ok(names.len())
}

/// Prune a single `[out, in]` weight tensor.
pub fn prune_weight_tensor(w: &mut Tensor, pattern: Pattern) {
    prune_tensor_rows(w, &Sparsifier::new(pattern), &mut Scratch::new());
}

fn prune_tensor_rows(w: &mut Tensor, sp: &Sparsifier, scratch: &mut Scratch) {
    match sp.pattern() {
        Pattern::Dense => {}
        Pattern::NM { m, .. } => {
            // N:M along the input dim: every row gets blockwise top-N by |w|.
            // Rows whose length is not a multiple of M keep a dense tail
            // (does not occur with our model dims; guarded for safety).
            let (rows, cols) = (w.rows(), w.cols());
            let main = cols - cols % m as usize;
            if main == 0 {
                return;
            }
            if main == cols {
                // Common case: the whole tensor is block-aligned — let the
                // row-parallel batch driver sweep it.
                sp.sparsify_batch(w, crate::util::threadpool::default_threads());
            } else {
                for r in 0..rows {
                    sp.sparsify_row(&mut w.row_mut(r)[..main], scratch);
                }
            }
        }
        Pattern::Unstructured { keep_pct } => {
            // Weight-side unstructured pruning is a *global* magnitude
            // threshold (not per-row top-k), so it stays on its own path.
            let sparsity = 1.0 - keep_pct as f64 / 100.0;
            unstructured::prune_global_magnitude(&mut w.data, sparsity);
        }
    }
}

/// Overall sparsity achieved across prunable tensors — for reporting and
/// sanity assertions in the harness.
pub fn achieved_sparsity(store: &TensorStore) -> f64 {
    let names = prunable_weight_names(store);
    let (mut zeros, mut total) = (0usize, 0usize);
    for name in &names {
        let t = store.get(name).unwrap();
        zeros += t.data.iter().filter(|x| **x == 0.0).count();
        total += t.len();
    }
    if total == 0 {
        0.0
    } else {
        zeros as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn store_with_layers(rng: &mut Rng) -> TensorStore {
        let mut s = TensorStore::new();
        for l in 0..2 {
            for proj in ["q", "k", "gate"] {
                let t = Tensor::from_vec(
                    &[16, 32],
                    (0..16 * 32).map(|_| rng.normal() as f32).collect(),
                );
                s.insert(&format!("layers.{l}.{proj}.w"), t);
            }
        }
        s.insert("embed.w", Tensor::from_vec(&[8, 4], vec![1.0; 32]));
        s.insert(
            "layers.0.norm.g",
            Tensor::from_vec(&[32], vec![1.0; 32]),
        );
        s
    }

    #[test]
    fn finds_only_linear_weights() {
        let mut rng = Rng::new(1);
        let s = store_with_layers(&mut rng);
        let names = prunable_weight_names(&s);
        assert_eq!(names.len(), 6);
        assert!(names.iter().all(|n| n.ends_with(".w") && n.starts_with("layers.")));
    }

    #[test]
    fn nm_prune_achieves_half_density() {
        let mut rng = Rng::new(2);
        let mut s = store_with_layers(&mut rng);
        let n = prune_weights(&mut s, Pattern::NM { n: 2, m: 4 }).unwrap();
        assert_eq!(n, 6);
        let sp = achieved_sparsity(&s);
        assert!((sp - 0.5).abs() < 1e-9, "sparsity {sp}");
        // Embedding untouched.
        assert_eq!(s.get("embed.w").unwrap().zero_fraction(), 0.0);
    }

    #[test]
    fn unstructured_prune_target() {
        let mut rng = Rng::new(3);
        let mut s = store_with_layers(&mut rng);
        prune_weights(&mut s, Pattern::Unstructured { keep_pct: 30 }).unwrap();
        let sp = achieved_sparsity(&s);
        assert!((sp - 0.7).abs() < 0.02, "sparsity {sp}");
    }

    #[test]
    fn each_row_satisfies_nm() {
        let mut rng = Rng::new(4);
        let mut w = Tensor::from_vec(
            &[8, 16],
            (0..128).map(|_| rng.normal() as f32).collect(),
        );
        prune_weight_tensor(&mut w, Pattern::NM { n: 8, m: 16 });
        for r in 0..8 {
            assert!(crate::sparsity::nm::satisfies_nm(w.row(r), 8, 16));
        }
    }

    #[test]
    fn dense_is_noop() {
        let mut rng = Rng::new(5);
        let mut s = store_with_layers(&mut rng);
        let before = s.get("layers.0.q.w").unwrap().clone();
        prune_weights(&mut s, Pattern::Dense).unwrap();
        assert_eq!(s.get("layers.0.q.w").unwrap(), &before);
    }
}
