//! Error-mitigation transformations (paper §2.3).
//!
//! Rust-native reference of the shift/variance corrections that wrap the
//! selection step. The accelerated path implements the same math inside the
//! Pallas kernel; these versions serve weight-side processing, analysis
//! binaries and cross-checks.
//!
//! Compensated forms (for a `[l, h]` activation matrix `X`, mask `M`):
//! - PTS (per-token shift):  `Y = ((X̂ ⊙ M) + η) Wᵀ` with `X̂ = X − η`;
//!   D-PTS uses the dynamic per-token mean, S-PTS/L-PTS use a stored
//!   per-channel vector.
//! - VAR: `Y = ν (X ⊙ M) Wᵀ`, `ν = sqrt(Var[X] / Var[X ⊙ M])` per token.

use crate::util::tensor::Tensor;

/// Per-token (row) mean — the D-PTS η.
pub fn row_means(x: &Tensor) -> Vec<f32> {
    (0..x.rows())
        .map(|i| {
            let row = x.row(i);
            (row.iter().map(|v| *v as f64).sum::<f64>() / row.len() as f64) as f32
        })
        .collect()
}

/// Per-channel (column) mean over the rows — the S-PTS η collected during
/// calibration.
pub fn col_means(x: &Tensor) -> Vec<f32> {
    let (l, h) = (x.rows(), x.cols());
    let mut sums = vec![0.0f64; h];
    for i in 0..l {
        for (j, v) in x.row(i).iter().enumerate() {
            sums[j] += *v as f64;
        }
    }
    sums.iter().map(|s| (*s / l as f64) as f32).collect()
}

/// Subtract a per-token scalar shift: `x̂_ij = x_ij − η_i`.
pub fn shift_rows(x: &Tensor, eta: &[f32]) -> Tensor {
    assert_eq!(eta.len(), x.rows());
    let h = x.cols();
    let mut out = x.clone();
    for i in 0..x.rows() {
        for v in out.row_mut(i) {
            *v -= eta[i];
        }
        let _ = h;
    }
    out
}

/// Subtract a per-channel shift: `x̂_ij = x_ij − η_j`.
pub fn shift_cols(x: &Tensor, eta: &[f32]) -> Tensor {
    assert_eq!(eta.len(), x.cols());
    let mut out = x.clone();
    for i in 0..x.rows() {
        for (v, e) in out.row_mut(i).iter_mut().zip(eta) {
            *v -= *e;
        }
    }
    out
}

/// Population variance of a row (shared with the fused pipeline so both
/// paths stay bit-identical).
pub(crate) fn row_var(row: &[f32]) -> f64 {
    let n = row.len() as f64;
    let mean = row.iter().map(|v| *v as f64).sum::<f64>() / n;
    row.iter().map(|v| (*v as f64 - mean).powi(2)).sum::<f64>() / n
}

/// Per-token VAR correction factors `ν_i = sqrt(Var[x_i] / Var[x̃_i])`
/// where `x̃` is the pruned row. Guards against a zero post-prune variance.
pub fn var_correction(x: &Tensor, pruned: &Tensor) -> Vec<f32> {
    assert_eq!(x.shape, pruned.shape);
    (0..x.rows())
        .map(|i| {
            let v_orig = row_var(x.row(i));
            let v_pruned = row_var(pruned.row(i));
            if v_pruned <= 1e-12 {
                1.0
            } else {
                (v_orig / v_pruned).sqrt() as f32
            }
        })
        .collect()
}

/// Scale each row by a per-token factor.
pub fn scale_rows(x: &mut Tensor, nu: &[f32]) {
    assert_eq!(nu.len(), x.rows());
    for i in 0..x.rows() {
        let f = nu[i];
        for v in x.row_mut(i) {
            *v *= f;
        }
    }
}

/// Full reference pipeline for one activation matrix: optional shift →
/// magnitude N:M prune → unshift → optional VAR.
///
/// Thin shim over the fused [`crate::sparsity::pipeline::Sparsifier`],
/// which executes the identical math in a single allocation-free pass per
/// row; kept because golden tests and analysis tools pin this signature.
#[deprecated(note = "use sparsity::pipeline::Sparsifier with .with_shift()/.with_var()")]
pub fn mitigated_nm_prune(
    x: &Tensor,
    n: usize,
    m: usize,
    shift: Shift,
    use_var: bool,
) -> Tensor {
    use crate::sparsity::pipeline::{Scratch, Sparsifier};
    let sp = Sparsifier::new(crate::sparsity::Pattern::NM {
        n: n as u32,
        m: m as u32,
    })
    .with_shift(shift)
    .with_var(use_var);
    let mut out = x.clone();
    let mut scratch = Scratch::new();
    sp.sparsify(&mut out, &mut scratch);
    out
}

/// Shift mode of the mitigation pipeline (paper §2.3).
#[derive(Clone, Debug)]
pub enum Shift {
    None,
    /// D-PTS: dynamic per-token mean.
    DynamicPerToken,
    /// S-PTS / L-PTS: a stored per-channel vector.
    PerChannel(Vec<f32>),
}

#[cfg(test)]
#[allow(deprecated)] // the shims' semantics are exactly what these tests pin
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_x(rng: &mut Rng, l: usize, h: usize, mean: f32) -> Tensor {
        Tensor::from_vec(
            &[l, h],
            (0..l * h).map(|_| rng.normal() as f32 + mean).collect(),
        )
    }

    #[test]
    fn row_means_exact() {
        let x = Tensor::from_vec(&[2, 2], vec![1.0, 3.0, -1.0, 1.0]);
        assert_eq!(row_means(&x), vec![2.0, 0.0]);
    }

    #[test]
    fn col_means_exact() {
        let x = Tensor::from_vec(&[2, 2], vec![1.0, 3.0, 3.0, 5.0]);
        assert_eq!(col_means(&x), vec![2.0, 4.0]);
    }

    #[test]
    fn shift_then_unshift_identity() {
        let mut rng = Rng::new(2);
        let x = rand_x(&mut rng, 4, 8, 1.5);
        let eta = row_means(&x);
        let shifted = shift_rows(&x, &eta);
        let mut back = shifted.clone();
        for i in 0..back.rows() {
            for v in back.row_mut(i) {
                *v += eta[i];
            }
        }
        assert!(x.max_abs_diff(&back) < 1e-5);
    }

    #[test]
    fn var_correction_restores_variance() {
        let mut rng = Rng::new(3);
        let x = rand_x(&mut rng, 8, 64, 0.0);
        let mut pruned = x.clone();
        for i in 0..pruned.rows() {
            crate::sparsity::nm::nm_prune_magnitude(pruned.row_mut(i), 2, 4);
        }
        let nu = var_correction(&x, &pruned);
        let mut corrected = pruned.clone();
        scale_rows(&mut corrected, &nu);
        for i in 0..x.rows() {
            let v0 = row_var(x.row(i));
            let v1 = row_var(corrected.row(i));
            // Variance ratio restored within tolerance (mean also moves, so
            // equality is approximate).
            assert!((v1 / v0 - 1.0).abs() < 0.35, "row {i}: {v1} vs {v0}");
        }
    }

    #[test]
    fn var_correction_handles_all_pruned() {
        let x = Tensor::from_vec(&[1, 4], vec![1.0, 1.0, 1.0, 1.0]);
        let pruned = Tensor::from_vec(&[1, 4], vec![0.0, 0.0, 0.0, 0.0]);
        assert_eq!(var_correction(&x, &pruned), vec![1.0]);
    }

    #[test]
    fn dpts_helps_shifted_distribution() {
        // The motivating case: activations centred far from zero. Plain
        // magnitude pruning keeps everything (all magnitudes similar), so
        // the pruned output loses the small-signal structure; centering
        // first prunes the *deviation* and reconstructs better.
        let mut rng = Rng::new(7);
        let l = 16;
        let h = 64;
        let x = rand_x(&mut rng, l, h, 10.0); // mean 10, sd 1
        let plain = mitigated_nm_prune(&x, 2, 4, Shift::None, false);
        let dpts = mitigated_nm_prune(&x, 2, 4, Shift::DynamicPerToken, false);
        let err = |a: &Tensor| {
            a.data
                .iter()
                .zip(&x.data)
                .map(|(p, o)| ((p - o) as f64).powi(2))
                .sum::<f64>()
        };
        assert!(
            err(&dpts) < err(&plain) * 0.5,
            "D-PTS reconstruction error should be much lower: {} vs {}",
            err(&dpts),
            err(&plain)
        );
    }

    #[test]
    fn spts_matches_dpts_when_stats_stationary() {
        // When per-channel means equal the true shift, S-PTS ≈ D-PTS.
        let mut rng = Rng::new(8);
        let x = rand_x(&mut rng, 32, 32, 5.0);
        let eta = col_means(&x);
        let spts = mitigated_nm_prune(&x, 8, 16, Shift::PerChannel(eta), false);
        let dpts = mitigated_nm_prune(&x, 8, 16, Shift::DynamicPerToken, false);
        let d = spts.max_abs_diff(&dpts);
        assert!(d < 2.0, "close but not identical: {d}");
    }

    #[test]
    fn mitigated_output_not_nm_sparse_after_compensation() {
        // After adding η back the output is dense again — the sparsity lives
        // in (X̂ ⊙ M); this mirrors the compensated matmul formulation.
        let mut rng = Rng::new(9);
        let x = rand_x(&mut rng, 2, 16, 3.0);
        let out = mitigated_nm_prune(&x, 2, 4, Shift::DynamicPerToken, false);
        let zeros = out.data.iter().filter(|v| **v == 0.0).count();
        assert!(zeros < out.len() / 2);
    }
}
