//! Sparsification core: patterns, selection criteria, masks and transforms.
//!
//! This is the rust-native reference implementation of everything the paper's
//! §2 defines. The Pallas kernel (L1) implements the same semantics for the
//! accelerated path; `python/tests/` checks kernel-vs-oracle in python and
//! `rust/tests/` checks this module against golden vectors exported from the
//! oracle, so all three implementations are pinned to one behaviour:
//!
//! - **N:M selection** keeps the top-N elements by score in each
//!   non-overlapping block of M along the last (hidden) dimension.
//!   Ties break toward the *lower index* (stable rank), matching the kernel.
//! - **Unstructured selection** keeps the top `keep_frac` fraction per row.
//! - Scores come from a [`Criterion`]: ACT, CLACT, Amber-Pruner, or WT.
//! - Error-mitigation [`transforms`] (D-PTS/S-PTS shift, VAR) wrap selection.

pub mod criteria;
pub mod nm;
pub mod packed;
pub mod pipeline;
pub mod transforms;
pub mod unstructured;
pub mod weightprune;

pub use packed::PackedNM;
pub use pipeline::{Scratch, Sparsifier};

use anyhow::{bail, Result};
use std::fmt;

/// A sparsity pattern from the paper's evaluation grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Pattern {
    /// No sparsification (the ORIG baseline).
    Dense,
    /// Semi-structured N:M — keep `n` of every `m` along the hidden dim.
    NM { n: u32, m: u32 },
    /// Unstructured — keep the top `keep_pct`% per token row.
    Unstructured { keep_pct: u32 },
}

impl Pattern {
    /// Parse `"dense" | "2:4" | "8:16" | "u50" | ...`. Whitespace around
    /// the string and around the `:`/`u` separators is tolerated
    /// (`"8 : 16"`, `"u 50"`); anything else is a descriptive error.
    pub fn parse(s: &str) -> Result<Pattern> {
        let s = s.trim();
        if s.is_empty() {
            bail!("empty sparsity pattern (expected 'dense', 'N:M' like '8:16', or 'u50')");
        }
        if s.eq_ignore_ascii_case("dense") || s.eq_ignore_ascii_case("orig") {
            return Ok(Pattern::Dense);
        }
        if s.starts_with('u') || s.starts_with('U') {
            let p = s[1..].trim();
            if p.is_empty() {
                bail!("unstructured pattern '{s}' missing the sparsity percentage, e.g. 'u50'");
            }
            let sparsity: u32 = p.parse().map_err(|_| {
                anyhow::anyhow!("unstructured pattern '{s}': '{p}' is not a percentage in 0..=99")
            })?;
            if sparsity >= 100 {
                bail!("unstructured sparsity {sparsity}% out of range (expected 0..=99)");
            }
            return Ok(Pattern::Unstructured { keep_pct: 100 - sparsity });
        }
        if let Some((n, m)) = s.split_once(':') {
            let (n_s, m_s) = (n.trim(), m.trim());
            if n_s.is_empty() || m_s.is_empty() {
                bail!(
                    "N:M pattern '{s}' is missing {} of the ':'",
                    if n_s.is_empty() { "N before" } else { "M after" }
                );
            }
            let n: u32 = n_s.parse().map_err(|_| {
                anyhow::anyhow!("N:M pattern '{s}': '{n_s}' is not a positive integer")
            })?;
            let m: u32 = m_s.parse().map_err(|_| {
                anyhow::anyhow!("N:M pattern '{s}': '{m_s}' is not a positive integer")
            })?;
            if n == 0 || m == 0 {
                bail!("N:M pattern '{s}': N and M must be positive");
            }
            if n > m {
                bail!("N:M pattern '{s}': N ({n}) cannot exceed M ({m})");
            }
            return Ok(Pattern::NM { n, m });
        }
        bail!("unrecognized sparsity pattern '{s}' (want 'dense', N:M like '8:16', or 'u50')")
    }

    /// Fraction of elements kept.
    pub fn density(&self) -> f64 {
        match self {
            Pattern::Dense => 1.0,
            Pattern::NM { n, m } => *n as f64 / *m as f64,
            Pattern::Unstructured { keep_pct } => *keep_pct as f64 / 100.0,
        }
    }

    /// Fraction of elements removed.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.density()
    }

    /// Number of elements selection keeps in a row of width `h` — the
    /// uniform per-row geometry `Sparsifier` and `PackedNM` share.
    pub fn kept_per_row(&self, h: usize) -> usize {
        match self {
            Pattern::Dense => h,
            Pattern::NM { n, m } => h / *m as usize * *n as usize,
            Pattern::Unstructured { keep_pct } => {
                (((h as f64) * (*keep_pct as f64 / 100.0)).round() as usize).min(h)
            }
        }
    }

    /// Number of valid layouts per block (`C(m, n)`), the paper's
    /// flexibility measure (§1: 2:4 has 6, 8:16 has 12870).
    pub fn layouts_per_block(&self) -> Option<u128> {
        match self {
            Pattern::NM { n, m } => Some(crate::metadata::binomial(*m as u64, *n as u64)),
            _ => None,
        }
    }

    /// Canonical artifact key: which HLO variant serves this pattern.
    pub fn artifact_key(&self) -> String {
        match self {
            Pattern::Dense => "dense".to_string(),
            Pattern::NM { n, m } => format!("{n}_{m}"),
            Pattern::Unstructured { keep_pct } => format!("u{}", 100 - keep_pct),
        }
    }
}

impl fmt::Display for Pattern {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Pattern::Dense => write!(f, "dense"),
            Pattern::NM { n, m } => write!(f, "{n}:{m}"),
            Pattern::Unstructured { keep_pct } => write!(f, "u{}", 100 - keep_pct),
        }
    }
}

/// The paper's full evaluated pattern grid (Figure 2 / Table 7).
pub fn paper_patterns() -> Vec<Pattern> {
    vec![
        Pattern::NM { n: 2, m: 4 },
        Pattern::NM { n: 4, m: 8 },
        Pattern::NM { n: 8, m: 16 },
        Pattern::NM { n: 16, m: 32 },
        Pattern::Unstructured { keep_pct: 50 },
        Pattern::Unstructured { keep_pct: 30 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_patterns() {
        assert_eq!(Pattern::parse("dense").unwrap(), Pattern::Dense);
        assert_eq!(Pattern::parse("2:4").unwrap(), Pattern::NM { n: 2, m: 4 });
        assert_eq!(
            Pattern::parse("16:32").unwrap(),
            Pattern::NM { n: 16, m: 32 }
        );
        assert_eq!(
            Pattern::parse("u70").unwrap(),
            Pattern::Unstructured { keep_pct: 30 }
        );
        assert!(Pattern::parse("5:4").is_err());
        assert!(Pattern::parse("0:4").is_err());
        assert!(Pattern::parse("u105").is_err());
        assert!(Pattern::parse("nonsense").is_err());
    }

    #[test]
    fn parse_tolerates_internal_whitespace() {
        assert_eq!(Pattern::parse("8 : 16").unwrap(), Pattern::NM { n: 8, m: 16 });
        assert_eq!(Pattern::parse("  2:4  ").unwrap(), Pattern::NM { n: 2, m: 4 });
        assert_eq!(
            Pattern::parse("u 50").unwrap(),
            Pattern::Unstructured { keep_pct: 50 }
        );
        assert_eq!(
            Pattern::parse("U70").unwrap(),
            Pattern::Unstructured { keep_pct: 30 }
        );
    }

    #[test]
    fn parse_negative_cases_have_descriptive_errors() {
        // Bare 'u' — previously a bare ParseIntError about an empty string.
        let e = Pattern::parse("u").unwrap_err().to_string();
        assert!(e.contains("missing the sparsity percentage"), "{e}");
        let e = Pattern::parse("").unwrap_err().to_string();
        assert!(e.contains("empty sparsity pattern"), "{e}");
        let e = Pattern::parse(":4").unwrap_err().to_string();
        assert!(e.contains("missing N before"), "{e}");
        let e = Pattern::parse("2:").unwrap_err().to_string();
        assert!(e.contains("missing M after"), "{e}");
        let e = Pattern::parse("2:4:8").unwrap_err().to_string();
        assert!(e.contains("not a positive integer"), "{e}");
        let e = Pattern::parse("5:4").unwrap_err().to_string();
        assert!(e.contains("cannot exceed"), "{e}");
        let e = Pattern::parse("0:4").unwrap_err().to_string();
        assert!(e.contains("must be positive"), "{e}");
        let e = Pattern::parse("ufifty").unwrap_err().to_string();
        assert!(e.contains("not a percentage"), "{e}");
        assert!(Pattern::parse("-2:4").is_err());
        assert!(Pattern::parse("u-5").is_err());
    }

    #[test]
    fn density_and_sparsity() {
        assert_eq!(Pattern::NM { n: 2, m: 4 }.density(), 0.5);
        assert_eq!(Pattern::Unstructured { keep_pct: 30 }.sparsity(), 0.7);
        assert_eq!(Pattern::Dense.density(), 1.0);
    }

    #[test]
    fn layout_counts_match_paper() {
        // §1: "a 2:4 block has only C(4,2) = 6 valid configurations" and
        // "8:16 provide ... C(16,8) = 12,870 possible layouts".
        assert_eq!(Pattern::NM { n: 2, m: 4 }.layouts_per_block(), Some(6));
        assert_eq!(
            Pattern::NM { n: 8, m: 16 }.layouts_per_block(),
            Some(12_870)
        );
        // "nearly 10x more than four concatenated 2:4 blocks (6^4 = 1296)".
        assert!(12_870f64 / 1296.0 > 9.0);
    }

    #[test]
    fn display_roundtrip() {
        for p in paper_patterns() {
            assert_eq!(Pattern::parse(&p.to_string()).unwrap(), p);
        }
    }

    #[test]
    fn artifact_keys() {
        assert_eq!(Pattern::NM { n: 8, m: 16 }.artifact_key(), "8_16");
        assert_eq!(
            Pattern::Unstructured { keep_pct: 50 }.artifact_key(),
            "u50"
        );
    }
}
