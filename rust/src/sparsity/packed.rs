//! Compressed-domain N:M activation streams (`PackedNM`).
//!
//! The paper's hardware case (Appendix A / Table 6) rests on N:M sparsity
//! cutting activation I/O nearly in half — which only materializes if the
//! system actually *moves* the compressed form. `PackedNM` is that form on
//! the rust side: per row, the kept f32 values stored contiguously in
//! ascending column order plus **one `u32` metadata word per block** (bit
//! `i` set ⇔ element `base+i` kept). The fused
//! [`Sparsifier`](crate::sparsity::Sparsifier) emits it directly during its
//! selection pass (`pack_row_into`/`pack`/`pack_batch`) — no dense
//! writeback, no per-block `Vec<bool>` — and the kernels here operate on
//! the stream without ever materializing the dense tensor:
//!
//! - [`PackedNM::row_dot`] / [`PackedNM::matvec_into`] /
//!   [`PackedNM::matmul_nt_into`]: packed·dense GEMV/GEMM — each row
//!   touches `kept_per_row` values instead of `cols` (the GEMM form is
//!   the per-site linear of the native decode engine, §2.9);
//! - [`PackedNM::decode_row_into`] / [`PackedNM::decode_into`]:
//!   scatter back to dense (zero-filled), row-parallel over
//!   `threadpool::par_chunks_mut`;
//! - [`PackedNM::row_l2`] / [`PackedNM::l2`] /
//!   [`PackedNM::fidelity_error_vs`]: reductions over the stream —
//!   `evalharness::sparsify_proxy_error` computes reconstruction fidelity
//!   this way, bit-identical to the dense formula.
//!
//! Metadata leaves the machine through `metadata::MaskCodec::encode_words`
//! (combinadic for N:M); [`PackedNM::measured_bytes_per_row`] reports the
//! *measured* encoded footprint that `BENCH_packed.json`, `table6` and
//! `examples/hw_breakeven.rs` cite in place of theoretical
//! `bits_per_element`.
//!
//! Geometry is uniform: every row keeps exactly `kept_per_row` elements
//! (N:M keeps n per block; unstructured keeps the same rounded count per
//! row), so row offsets are trivial and repacking into an existing
//! `PackedNM` of the same shape is allocation-free (buffers are resized in
//! place — scratch-owned steady state, like the `Sparsifier` itself).
//! Packing applies to *selection-only* pipelines (no shift, no VAR): those
//! drop elements to exactly `0.0` and keep values unchanged, which is what
//! a zero-fill scatter reconstructs — `rust/tests/packed_roundtrip.rs`
//! pins `decode(pack(x)) ≡ sparsify(x)` bitwise for every paper pattern.

use crate::metadata::MaskCodec;
use crate::sparsity::Pattern;
use crate::util::tensor::Tensor;
use crate::util::threadpool;
use crate::util::threadpool::{DisjointSliceMut, WorkerPool};

/// Metadata block width for patterns without a native block: one `u32`
/// word covers 32 columns.
const WORD_BLOCK: usize = 32;

/// A compressed activation tensor: `[rows, cols]` logically, stored as
/// contiguous kept values + one metadata word per block. See the module
/// docs for layout and invariants.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedNM {
    pattern: Pattern,
    rows: usize,
    cols: usize,
    /// Metadata block width: `m` for N:M, 32 otherwise.
    block: usize,
    /// Kept elements per row (uniform across rows).
    kept_per_row: usize,
    /// `rows * kept_per_row` kept values, row-major, ascending column
    /// order within each row.
    pub(crate) values: Vec<f32>,
    /// `rows * blocks_per_row` metadata words, row-major.
    pub(crate) meta: Vec<u32>,
}

impl PackedNM {
    /// Empty stream for rows of width `cols` under `pattern`. Panics on
    /// geometry the packed layout cannot hold (N:M with `m > 32` or
    /// `cols % m != 0` — the same rows the dense pipeline rejects).
    pub fn new(pattern: Pattern, cols: usize) -> PackedNM {
        let block = match pattern {
            Pattern::NM { n, m } => {
                let (n, m) = (n as usize, m as usize);
                assert!(n > 0 && n <= m, "invalid N:M {n}:{m}");
                assert!(m <= 32, "packed N:M supports M up to 32 (one u32 word per block)");
                assert_eq!(cols % m, 0, "row length {cols} not a multiple of M={m}");
                m
            }
            _ => WORD_BLOCK,
        };
        PackedNM {
            pattern,
            rows: 0,
            cols,
            block,
            kept_per_row: pattern.kept_per_row(cols),
            values: Vec::new(),
            meta: Vec::new(),
        }
    }

    /// Re-shape for a fresh pack of `rows` rows, reusing the existing
    /// allocations (no allocation when the new extent fits capacity).
    pub(crate) fn reset_for(&mut self, pattern: Pattern, cols: usize, rows: usize) {
        if self.pattern != pattern || self.cols != cols {
            let fresh = PackedNM::new(pattern, cols);
            self.pattern = fresh.pattern;
            self.cols = fresh.cols;
            self.block = fresh.block;
            self.kept_per_row = fresh.kept_per_row;
        }
        self.rows = rows;
        self.values.resize(rows * self.kept_per_row, 0.0);
        self.meta.resize(rows * self.blocks_per_row(), 0);
    }

    /// Append one (uninitialized) row, returning its index. The caller
    /// fills it through [`PackedNM::row_slots_mut`].
    pub(crate) fn append_row_slot(&mut self) -> usize {
        let r = self.rows;
        self.rows += 1;
        self.values.resize(self.rows * self.kept_per_row, 0.0);
        self.meta.resize(self.rows * self.blocks_per_row(), 0);
        r
    }

    /// Mutable (values, meta) slices of row `r` — the emitter's write
    /// window.
    pub(crate) fn row_slots_mut(&mut self, r: usize) -> (&mut [f32], &mut [u32]) {
        let kpr = self.kept_per_row;
        let bpr = self.blocks_per_row();
        (
            &mut self.values[r * kpr..(r + 1) * kpr],
            &mut self.meta[r * bpr..(r + 1) * bpr],
        )
    }

    /// Both output buffers at once — the parallel emitter splits them into
    /// lockstep row chunks.
    pub(crate) fn buffers_mut(&mut self) -> (&mut [f32], &mut [u32]) {
        (&mut self.values, &mut self.meta)
    }

    /// Drop all rows, keeping buffers for reuse.
    pub fn clear(&mut self) {
        self.rows = 0;
        self.values.clear();
        self.meta.clear();
    }

    pub fn pattern(&self) -> Pattern {
        self.pattern
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Metadata block width (`m` for N:M, 32 otherwise).
    pub fn block_width(&self) -> usize {
        self.block
    }

    pub fn kept_per_row(&self) -> usize {
        self.kept_per_row
    }

    pub fn blocks_per_row(&self) -> usize {
        (self.cols + self.block - 1) / self.block
    }

    /// All kept values, row-major.
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// All metadata words, row-major.
    pub fn meta_words(&self) -> &[u32] {
        &self.meta
    }

    /// Kept values of row `r`, ascending column order.
    pub fn row_values(&self, r: usize) -> &[f32] {
        &self.values[r * self.kept_per_row..(r + 1) * self.kept_per_row]
    }

    /// Metadata words of row `r`.
    pub fn meta_row(&self, r: usize) -> &[u32] {
        let bpr = self.blocks_per_row();
        &self.meta[r * bpr..(r + 1) * bpr]
    }

    // ------------------------------------------------------------- kernels

    /// Scatter row `r` into `out` (length `cols`): kept values land at
    /// their columns, everything else becomes `0.0` — exactly what the
    /// selection-only `Sparsifier` writes densely.
    pub fn decode_row_into(&self, r: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.cols, "decode row length mismatch");
        out.iter_mut().for_each(|v| *v = 0.0);
        let vals = self.row_values(r);
        let mut vi = 0usize;
        for (bi, &word) in self.meta_row(r).iter().enumerate() {
            let base = bi * self.block;
            let mut w = word;
            while w != 0 {
                let b = w.trailing_zeros() as usize;
                out[base + b] = vals[vi];
                vi += 1;
                w &= w - 1;
            }
        }
        debug_assert_eq!(vi, vals.len());
    }

    /// Scatter the whole stream into a `[rows, cols]` tensor, row-parallel
    /// over up to `threads` workers.
    pub fn decode_into(&self, x: &mut Tensor, threads: usize) {
        assert_eq!(x.shape, vec![self.rows, self.cols], "decode shape mismatch");
        if self.rows == 0 || self.cols == 0 {
            return;
        }
        let cols = self.cols;
        let threads = threads.max(1).min(self.rows);
        let rows_per_chunk = (self.rows + threads - 1) / threads;
        threadpool::par_chunks_mut(&mut x.data, rows_per_chunk * cols, threads, |ci, chunk| {
            for (i, row) in chunk.chunks_exact_mut(cols).enumerate() {
                self.decode_row_into(ci * rows_per_chunk + i, row);
            }
        });
    }

    /// Convenience dense materialization (allocates; tests and one-shots).
    pub fn to_dense(&self) -> Tensor {
        let mut t = Tensor::zeros(&[self.rows, self.cols]);
        self.decode_into(&mut t, 1);
        t
    }

    /// Dot product of packed row `r` with a dense vector (length `cols`)
    /// — touches `kept_per_row` elements instead of `cols`.
    pub fn row_dot(&self, r: usize, v: &[f32]) -> f32 {
        assert_eq!(v.len(), self.cols, "dot length mismatch");
        let vals = self.row_values(r);
        let mut acc = 0.0f32;
        let mut vi = 0usize;
        for (bi, &word) in self.meta_row(r).iter().enumerate() {
            let base = bi * self.block;
            let mut w = word;
            while w != 0 {
                let b = w.trailing_zeros() as usize;
                acc += vals[vi] * v[base + b];
                vi += 1;
                w &= w - 1;
            }
        }
        acc
    }

    /// Packed·dense GEMV: `out[r] = packed_row(r) · v`, row-parallel.
    pub fn matvec_into(&self, v: &[f32], out: &mut [f32], threads: usize) {
        assert_eq!(out.len(), self.rows, "matvec output length mismatch");
        if self.rows == 0 {
            return;
        }
        let threads = threads.max(1).min(self.rows);
        let rows_per_chunk = (self.rows + threads - 1) / threads;
        threadpool::par_chunks_mut(out, rows_per_chunk, threads, |ci, chunk| {
            for (i, o) in chunk.iter_mut().enumerate() {
                *o = self.row_dot(ci * rows_per_chunk + i, v);
            }
        });
    }

    /// Compressed-domain linear layer: `out[r * w.rows() + o] =
    /// row(r) · w.row(o)` — packed activations `[rows, cols]` times a
    /// dense `[w_rows, cols]` weight matrix transposed, the GEMM one
    /// decode step runs per sparsified site (`y = W · s(x)` with the
    /// packed operand the activation rows — one per batched lane in
    /// `NativeEngine::step_batch`). Same `row_dot` kernel as
    /// [`PackedNM::matvec_into`]; partitioned across the engine's
    /// [`WorkerPool`] by **weight-row ranges** (each worker owns output
    /// columns `o ∈ [lo, hi)` across every lane), and weight-row-major
    /// within a range so one weight row serves every lane while hot. Each
    /// output element is one whole ascending-column dot computed by
    /// exactly one worker, so the result is bitwise identical at any
    /// thread count — and to the single-row `matvec_into` (DESIGN.md
    /// §2.11). Lane-major output makes per-worker writes strided, hence
    /// the [`DisjointSliceMut`] shared view.
    pub fn matmul_nt_into(&self, w: &Tensor, out: &mut [f32], pool: &WorkerPool) {
        assert_eq!(w.cols(), self.cols, "matmul inner-dim mismatch");
        let w_rows = w.rows();
        assert_eq!(out.len(), self.rows * w_rows, "matmul output length mismatch");
        if self.rows == 0 || w_rows == 0 {
            return;
        }
        if pool.threads() == 1 || w_rows == 1 {
            for o in 0..w_rows {
                let wrow = w.row(o);
                for r in 0..self.rows {
                    out[r * w_rows + o] = self.row_dot(r, wrow);
                }
            }
            return;
        }
        let shared = DisjointSliceMut::new(out);
        pool.run_ranges(w_rows, |lo, hi| {
            for o in lo..hi {
                let wrow = w.row(o);
                for r in 0..self.rows {
                    // SAFETY: weight-row ranges are disjoint across parts,
                    // so element r*w_rows+o has exactly one writer.
                    unsafe { shared.write(r * w_rows + o, self.row_dot(r, wrow)) };
                }
            }
        });
    }

    /// L2 norm of row `r` (zeros contribute nothing, so this equals the
    /// dense row's norm).
    pub fn row_l2(&self, r: usize) -> f64 {
        self.row_values(r)
            .iter()
            .map(|x| (*x as f64) * (*x as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// L2 norm of the whole stream.
    pub fn l2(&self) -> f64 {
        self.values
            .iter()
            .map(|x| (*x as f64) * (*x as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// Relative L2 reconstruction error `‖x − decode(self)‖₂ / ‖x‖₂`
    /// computed from the stream alone: kept elements reconstruct exactly,
    /// so only *dropped* elements of `x` contribute — iterated in row-major
    /// order, making the f64 accumulation bit-identical to the dense
    /// formula over `x − sparsify(x)`.
    pub fn fidelity_error_vs(&self, x: &Tensor) -> f64 {
        assert_eq!(x.shape, vec![self.rows, self.cols], "fidelity shape mismatch");
        let mut sum = 0.0f64;
        for r in 0..self.rows {
            let row = x.row(r);
            for (bi, &word) in self.meta_row(r).iter().enumerate() {
                let base = bi * self.block;
                let width = self.block.min(self.cols - base);
                for b in 0..width {
                    if word >> b & 1 == 0 {
                        let d = row[base + b] as f64;
                        sum += d * d;
                    }
                }
            }
        }
        sum.sqrt() / x.l2().max(1e-12)
    }

    // ----------------------------------------------------------- footprint

    /// Bytes of the value payload (f32).
    pub fn payload_bytes(&self) -> usize {
        self.values.len() * 4
    }

    /// *Measured* metadata footprint in bits: the actual output size of
    /// `codec` over this stream's words (combinadic/index-list/bitmap for
    /// N:M). Patterns without a fixed per-block ones-count (unstructured,
    /// dense) are reported at the dense-bitmap floor of one bit per
    /// element.
    pub fn encoded_metadata_bits(&self, codec: MaskCodec) -> usize {
        match self.pattern {
            Pattern::NM { n, m } => {
                let (_, bits) = codec.encode_words(&self.meta, n as usize, m as usize);
                bits
            }
            _ => self.rows * self.cols,
        }
    }

    /// Measured compressed footprint per row: value payload plus encoded
    /// metadata, in bytes. The number `BENCH_packed.json` reports and
    /// `table6`/`hw_breakeven` cite against the dense `cols * 4`.
    pub fn measured_bytes_per_row(&self, codec: MaskCodec) -> f64 {
        if self.rows == 0 {
            return 0.0;
        }
        let meta_bytes = (self.encoded_metadata_bits(codec) + 7) / 8;
        (self.payload_bytes() + meta_bytes) as f64 / self.rows as f64
    }

    /// Dense footprint per row (f32), the baseline for the bandwidth
    /// ratio.
    pub fn dense_bytes_per_row(&self) -> f64 {
        (self.cols * 4) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::{paper_patterns, Scratch, Sparsifier};
    use crate::util::prng::Rng;

    fn rand_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Tensor {
        Tensor::from_vec(
            &[rows, cols],
            (0..rows * cols).map(|_| rng.normal() as f32).collect(),
        )
    }

    #[test]
    fn pack_decode_roundtrip_matches_dense_sparsify() {
        let mut rng = Rng::new(3);
        for pattern in paper_patterns() {
            let x = rand_matrix(&mut rng, 7, 64);
            let sp = Sparsifier::new(pattern);
            let mut packed = PackedNM::new(pattern, 64);
            let mut scratch = Scratch::new();
            sp.pack(&x, &mut packed, &mut scratch);
            assert_eq!(packed.rows(), 7);
            assert_eq!(packed.kept_per_row(), sp.kept_per_row(64));
            let mut dense = x.clone();
            sp.sparsify(&mut dense, &mut scratch);
            let decoded = packed.to_dense();
            assert_eq!(
                decoded.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                dense.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{pattern}"
            );
        }
    }

    #[test]
    fn matvec_matches_dense_gemv() {
        let mut rng = Rng::new(5);
        let x = rand_matrix(&mut rng, 33, 96);
        let v: Vec<f32> = (0..96).map(|_| rng.normal() as f32).collect();
        let sp = Sparsifier::new(Pattern::NM { n: 8, m: 16 });
        let mut packed = PackedNM::new(sp.pattern(), 96);
        let mut scratch = Scratch::new();
        sp.pack(&x, &mut packed, &mut scratch);
        let mut dense = x.clone();
        sp.sparsify(&mut dense, &mut scratch);
        for threads in [1usize, 4] {
            let mut out = vec![0.0f32; 33];
            packed.matvec_into(&v, &mut out, threads);
            for r in 0..33 {
                let expect: f32 = dense.row(r).iter().zip(&v).map(|(a, b)| a * b).sum();
                assert!(
                    (out[r] - expect).abs() <= 1e-4 * expect.abs().max(1.0),
                    "row {r}: {} vs {expect} (threads {threads})",
                    out[r]
                );
            }
        }
    }

    #[test]
    fn matmul_nt_matches_matvec_columns_and_dense_gemm() {
        let mut rng = Rng::new(7);
        let x = rand_matrix(&mut rng, 5, 64);
        let w = rand_matrix(&mut rng, 9, 64); // [w_rows, cols]
        let sp = Sparsifier::new(Pattern::NM { n: 2, m: 4 });
        let mut packed = PackedNM::new(sp.pattern(), 64);
        let mut scratch = Scratch::new();
        sp.pack(&x, &mut packed, &mut scratch);
        for threads in [1usize, 3] {
            let pool = WorkerPool::new(threads);
            let mut out = vec![0.0f32; 5 * 9];
            packed.matmul_nt_into(&w, &mut out, &pool);
            // Column o of the result is exactly matvec_into against w.row(o).
            for o in 0..9 {
                let mut col = vec![0.0f32; 5];
                packed.matvec_into(w.row(o), &mut col, 1);
                for r in 0..5 {
                    assert_eq!(out[r * 9 + o].to_bits(), col[r].to_bits(), "r{r} o{o}");
                }
            }
            // And bitwise equal to the dense GEMM over the sparsified rows
            // (ascending-column accumulation; ±0.0 terms never flip bits).
            let mut dense = x.clone();
            sp.sparsify(&mut dense, &mut scratch);
            for r in 0..5 {
                for o in 0..9 {
                    let expect: f32 =
                        dense.row(r).iter().zip(w.row(o)).map(|(a, b)| a * b).sum();
                    assert_eq!(out[r * 9 + o].to_bits(), expect.to_bits(), "r{r} o{o}");
                }
            }
        }
    }

    #[test]
    fn l2_and_fidelity_match_dense() {
        let mut rng = Rng::new(11);
        let x = rand_matrix(&mut rng, 9, 32);
        let sp = Sparsifier::new(Pattern::NM { n: 2, m: 4 });
        let mut packed = PackedNM::new(sp.pattern(), 32);
        let mut scratch = Scratch::new();
        sp.pack(&x, &mut packed, &mut scratch);
        let dense = packed.to_dense();
        assert!((packed.l2() - dense.l2()).abs() < 1e-9);
        for r in 0..9 {
            let row_norm = dense.row(r).iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
            assert!((packed.row_l2(r) - row_norm).abs() < 1e-9);
        }
        // Fidelity from the stream == fidelity from the dense difference.
        let denom = x.l2().max(1e-12);
        let diff = x
            .data
            .iter()
            .zip(&dense.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert_eq!(packed.fidelity_error_vs(&x), diff / denom);
    }

    #[test]
    fn reuse_is_allocation_stable() {
        // Repacking the same shape must not grow the buffers.
        let mut rng = Rng::new(13);
        let sp = Sparsifier::new(Pattern::NM { n: 4, m: 8 });
        let mut packed = PackedNM::new(sp.pattern(), 64);
        let mut scratch = Scratch::new();
        let x = rand_matrix(&mut rng, 16, 64);
        sp.pack(&x, &mut packed, &mut scratch);
        let cap_v = packed.values.capacity();
        let cap_m = packed.meta.capacity();
        for _ in 0..5 {
            let y = rand_matrix(&mut rng, 16, 64);
            sp.pack(&y, &mut packed, &mut scratch);
            assert_eq!(packed.values.capacity(), cap_v);
            assert_eq!(packed.meta.capacity(), cap_m);
        }
    }

    #[test]
    fn unstructured_tail_block_handled() {
        // cols not a multiple of 32: tail metadata word is partial.
        let mut rng = Rng::new(17);
        let x = rand_matrix(&mut rng, 4, 40);
        let sp = Sparsifier::new(Pattern::Unstructured { keep_pct: 50 });
        let mut packed = PackedNM::new(sp.pattern(), 40);
        let mut scratch = Scratch::new();
        sp.pack(&x, &mut packed, &mut scratch);
        assert_eq!(packed.blocks_per_row(), 2);
        assert_eq!(packed.kept_per_row(), 20);
        let mut dense = x.clone();
        sp.sparsify(&mut dense, &mut scratch);
        assert_eq!(packed.to_dense().data, dense.data);
        // No ghost bits beyond the tail width.
        for r in 0..4 {
            assert_eq!(packed.meta_row(r)[1] >> 8, 0, "bits past column 40");
        }
    }

    #[test]
    fn measured_footprint_orders_sensibly() {
        let mut rng = Rng::new(19);
        let x = rand_matrix(&mut rng, 8, 128);
        let sp = Sparsifier::new(Pattern::NM { n: 8, m: 16 });
        let mut packed = PackedNM::new(sp.pattern(), 128);
        let mut scratch = Scratch::new();
        sp.pack(&x, &mut packed, &mut scratch);
        let dense = packed.dense_bytes_per_row();
        let comb = packed.measured_bytes_per_row(MaskCodec::Combinadic);
        let bitmap = packed.measured_bytes_per_row(MaskCodec::Bitmap);
        // Half the values + metadata: well under dense, combinadic ≤ bitmap.
        assert!(comb < dense, "{comb} vs {dense}");
        assert!(comb <= bitmap, "{comb} vs {bitmap}");
        // 8 blocks/row * 14 bits = 112 bits -> 14 bytes; payload 64*4.
        assert_eq!(packed.payload_bytes(), 8 * 64 * 4);
        assert_eq!(
            packed.encoded_metadata_bits(MaskCodec::Combinadic),
            8 * 8 * 14
        );
    }

    #[test]
    #[should_panic(expected = "not a multiple of M")]
    fn misaligned_nm_rejected() {
        PackedNM::new(Pattern::NM { n: 2, m: 4 }, 30);
    }
}
