//! Selection criteria (paper §2.2): how to score each activation element.
//!
//! Scores feed [`crate::sparsity::nm::nm_mask`] / unstructured top-k. These
//! rust implementations mirror `python/compile/kernels/ref.py` exactly and
//! are exercised against golden vectors exported by the python oracle.

use crate::util::tensor::Tensor;
use anyhow::{bail, Result};
use std::fmt;

/// Which activation-scoring criterion to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Criterion {
    /// `S(x_ij) = |x_ij|` — plain magnitude (ACT).
    Act,
    /// Cosine-Loss ACTivation (CLACT, proposed in the paper):
    /// `S(x_ij) = |x_ij| / ||x_i,:||_2 * ||x_:,j||_2` — row-normalized
    /// magnitude re-weighted by column (channel) energy over the sequence.
    Clact,
    /// Amber-Pruner: `S(x_ij) = |x_ij| * L(ŵ_:,j)` where `L` is the
    /// channel-wise l2 norm of outlier-clipped, standardized weights.
    Amber,
}

impl Criterion {
    pub fn parse(s: &str) -> Result<Criterion> {
        match s.to_ascii_lowercase().as_str() {
            "act" | "magnitude" => Ok(Criterion::Act),
            "clact" => Ok(Criterion::Clact),
            "amber" | "amber-pruner" => Ok(Criterion::Amber),
            other => bail!("unknown criterion '{other}'"),
        }
    }
}

impl fmt::Display for Criterion {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Criterion::Act => write!(f, "act"),
            Criterion::Clact => write!(f, "clact"),
            Criterion::Amber => write!(f, "amber"),
        }
    }
}

/// Score a `[rows, h]` activation matrix with the ACT criterion.
pub fn score_act(x: &Tensor) -> Tensor {
    Tensor::from_vec(&x.shape, x.data.iter().map(|v| v.abs()).collect())
}

/// CLACT column energies `‖x_:,j‖₂` over the sequence — the data-dependent
/// per-channel scale the fused pipeline multiplies into `|x̂|`. (The per-row
/// `1/‖x_i,:‖₂` factor of eq. 4 is a positive constant within each row, so
/// it never changes which elements a block keeps; the pipeline omits it.)
pub fn clact_col_energy(x: &Tensor) -> Vec<f32> {
    let (l, h) = (x.rows(), x.cols());
    let mut col_energy = vec![0.0f64; h];
    for i in 0..l {
        for (j, v) in x.row(i).iter().enumerate() {
            col_energy[j] += (*v as f64) * (*v as f64);
        }
    }
    col_energy.iter().map(|e| (e.sqrt()) as f32).collect()
}

/// Score with CLACT (paper eq. 4). `x` is `[l, h]` — sequence by hidden.
pub fn score_clact(x: &Tensor) -> Tensor {
    let (l, h) = (x.rows(), x.cols());
    let col_energy = clact_col_energy(x);
    let mut out = Tensor::zeros(&x.shape);
    for i in 0..l {
        let row = x.row(i);
        let row_norm = (row.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>()).sqrt() as f32;
        let denom = if row_norm == 0.0 { 1.0 } else { row_norm };
        for j in 0..h {
            out.data[i * h + j] = row[j].abs() / denom * col_energy[j];
        }
    }
    out
}

/// Compute the Amber-Pruner channel scale vector `L(ŵ_:,j)` from a weight
/// matrix `w: [out, in]`: clip weights outside the [0.5, 99.5] percentiles,
/// standardize, then take the l2 norm of each *input-channel* column.
pub fn amber_channel_norms(w: &Tensor) -> Vec<f32> {
    let (o, i) = (w.rows(), w.cols());
    // Percentile clipping bounds over the whole matrix.
    let mut sorted: Vec<f32> = w.data.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lo = sorted[((sorted.len() as f64) * 0.005) as usize];
    let hi = sorted[(((sorted.len() as f64) * 0.995) as usize).min(sorted.len() - 1)];
    let clipped: Vec<f32> = w.data.iter().map(|v| v.clamp(lo, hi)).collect();
    // Standardize.
    let mean = clipped.iter().map(|v| *v as f64).sum::<f64>() / clipped.len() as f64;
    let var = clipped
        .iter()
        .map(|v| (*v as f64 - mean).powi(2))
        .sum::<f64>()
        / clipped.len() as f64;
    let std = var.sqrt().max(1e-8);
    // Channel-wise l2 over output rows for each input column j.
    let mut norms = vec![0.0f64; i];
    for r in 0..o {
        for j in 0..i {
            let z = (clipped[r * i + j] as f64 - mean) / std;
            norms[j] += z * z;
        }
    }
    norms.iter().map(|n| n.sqrt() as f32).collect()
}

/// Score with Amber-Pruner given precomputed channel norms.
pub fn score_amber(x: &Tensor, channel_norms: &[f32]) -> Tensor {
    let (l, h) = (x.rows(), x.cols());
    assert_eq!(channel_norms.len(), h);
    let mut out = Tensor::zeros(&x.shape);
    for i in 0..l {
        for j in 0..h {
            out.data[i * h + j] = x.data[i * h + j].abs() * channel_norms[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_tensor(rng: &mut Rng, l: usize, h: usize) -> Tensor {
        Tensor::from_vec(
            &[l, h],
            (0..l * h).map(|_| rng.normal() as f32).collect(),
        )
    }

    #[test]
    fn parse_criteria() {
        assert_eq!(Criterion::parse("act").unwrap(), Criterion::Act);
        assert_eq!(Criterion::parse("CLACT").unwrap(), Criterion::Clact);
        assert_eq!(Criterion::parse("amber-pruner").unwrap(), Criterion::Amber);
        assert!(Criterion::parse("wanda2").is_err());
    }

    #[test]
    fn act_is_abs() {
        let x = Tensor::from_vec(&[1, 3], vec![-1.0, 2.0, -3.0]);
        assert_eq!(score_act(&x).data, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn clact_reduces_to_l1_like_for_single_row() {
        // Paper: "for l=1 [CLACT] reduces to an l1-type criterion" — the
        // ordering matches plain magnitude for a single token.
        let x = Tensor::from_vec(&[1, 4], vec![0.5, -3.0, 1.0, 2.0]);
        let s = score_clact(&x);
        let order = |v: &[f32]| {
            let mut idx: Vec<usize> = (0..v.len()).collect();
            idx.sort_by(|&a, &b| v[b].partial_cmp(&v[a]).unwrap());
            idx
        };
        assert_eq!(order(&s.data), order(&score_act(&x).data));
    }

    #[test]
    fn clact_upweights_high_energy_columns() {
        // Two tokens; column 0 has much higher energy across the sequence.
        let x = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 10.0, 0.1]);
        let s = score_clact(&x);
        // For token 0 the equal-magnitude elements are separated by column
        // energy: col 0 score > col 1 score.
        assert!(s.data[0] > s.data[1]);
    }

    #[test]
    fn clact_col_energy_exact() {
        let x = Tensor::from_vec(&[2, 2], vec![3.0, 0.0, 4.0, 2.0]);
        assert_eq!(clact_col_energy(&x), vec![5.0, 2.0]);
    }

    #[test]
    fn clact_zero_row_safe() {
        let x = Tensor::from_vec(&[2, 2], vec![0.0, 0.0, 1.0, 2.0]);
        let s = score_clact(&x);
        assert!(s.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn amber_norms_shape_and_positivity() {
        let mut rng = Rng::new(5);
        let w = rand_tensor(&mut rng, 32, 16);
        let norms = amber_channel_norms(&w);
        assert_eq!(norms.len(), 16);
        assert!(norms.iter().all(|n| *n > 0.0));
    }

    #[test]
    fn amber_outlier_insensitive() {
        // A giant outlier in one weight should barely move the channel norms
        // because of percentile clipping.
        let mut rng = Rng::new(6);
        let w = rand_tensor(&mut rng, 64, 8);
        let base = amber_channel_norms(&w);
        let mut w2 = w.clone();
        w2.data[3] = 1e6;
        let spiked = amber_channel_norms(&w2);
        for (a, b) in base.iter().zip(&spiked) {
            assert!((a - b).abs() / a.max(1e-6) < 0.25, "clipping bounded the outlier");
        }
    }

    #[test]
    fn amber_score_scales_by_channel() {
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 1.0]);
        let s = score_amber(&x, &[2.0, 0.5]);
        assert_eq!(s.data, vec![2.0, 0.5]);
    }
}
