//! Unstructured (top-k) selection.
//!
//! The paper's unstructured baseline applies magnitude thresholding at a
//! target sparsity level; we implement the per-row exact top-k variant
//! (used for activations, matching the kernel's per-token semantics) and a
//! global-threshold variant over a whole tensor (used for weight pruning,
//! matching how magnitude weight pruning is usually done).

use crate::sparsity::pipeline::{self, Scratch};

/// Keep-mask retaining the `keep` highest-scoring elements of the row.
/// Ties break toward lower indices (same rank rule as N:M).
///
/// Thin shim over the fused pipeline's partial selection (bit-identical
/// masks for NaN-free scores, O(len) average instead of a full sort). Hot
/// paths should hold a [`Scratch`] and call [`pipeline::topk_mask_into`]
/// directly.
#[deprecated(note = "use sparsity::pipeline::topk_mask_into with a reusable Scratch")]
pub fn topk_mask(scores: &[f32], keep: usize) -> Vec<bool> {
    let mut mask = vec![false; scores.len()];
    let mut scratch = Scratch::new();
    pipeline::topk_mask_into(scores, keep, &mut mask, &mut scratch);
    mask
}

/// Prune a row in place, keeping the top `keep_frac` fraction by |x|.
#[deprecated(note = "use sparsity::pipeline::Sparsifier::sparsify_row or prune_row_topk_magnitude")]
pub fn prune_row_magnitude(values: &mut [f32], keep_frac: f64) {
    let keep = ((values.len() as f64) * keep_frac).round() as usize;
    let mut scratch = Scratch::new();
    pipeline::prune_row_topk_magnitude(values, keep, &mut scratch);
}

/// Global magnitude threshold that achieves `sparsity` over the whole slice
/// (used for weight tensors). Returns the threshold used.
pub fn prune_global_magnitude(values: &mut [f32], sparsity: f64) -> f32 {
    assert!((0.0..1.0).contains(&sparsity));
    if sparsity == 0.0 || values.is_empty() {
        return 0.0;
    }
    let mut mags: Vec<f32> = values.iter().map(|x| x.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let cut = ((values.len() as f64) * sparsity) as usize;
    let thresh = mags[cut.min(values.len() - 1)];
    // Strict `<` keeps elements equal to the threshold: removal count is
    // then <= target, erring toward keeping weight mass (matches jnp ref).
    for v in values.iter_mut() {
        if v.abs() < thresh {
            *v = 0.0;
        }
    }
    thresh
}

#[cfg(test)]
#[allow(deprecated)] // the shims' semantics are exactly what these tests pin
mod tests {
    use super::*;
    use crate::util::miniprop::{forall_simple, gen_activations, Config};
    use crate::util::prng::Rng;

    #[test]
    fn topk_keeps_largest() {
        let s = [0.5f32, 3.0, 1.0, 2.0];
        assert_eq!(topk_mask(&s, 2), vec![false, true, false, true]);
    }

    #[test]
    fn topk_tie_low_index() {
        let s = [1.0f32, 1.0, 1.0];
        assert_eq!(topk_mask(&s, 2), vec![true, true, false]);
    }

    #[test]
    fn topk_full_keep() {
        let s = [1.0f32, 2.0];
        assert_eq!(topk_mask(&s, 5), vec![true, true]);
    }

    #[test]
    fn prune_row_density() {
        let cfg = Config::default();
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let len = rng.range(10, 200);
                let keep = rng.range(1, 10) as f64 / 10.0;
                (gen_activations(rng, len), keep)
            },
            |(xs, keep_frac)| {
                let mut v = xs.clone();
                prune_row_magnitude(&mut v, *keep_frac);
                let nonzero = v.iter().filter(|x| **x != 0.0).count();
                let target = ((xs.len() as f64) * keep_frac).round() as usize;
                nonzero <= target // zeros in input may reduce the count
            },
        );
    }

    #[test]
    fn global_threshold_sparsity() {
        let mut v: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        let t = prune_global_magnitude(&mut v, 0.7);
        let zeros = v.iter().filter(|x| **x == 0.0).count();
        assert_eq!(zeros, 70);
        assert!(t > 0.0);
    }

    #[test]
    fn global_zero_sparsity_noop() {
        let mut v = vec![1.0f32, -2.0];
        prune_global_magnitude(&mut v, 0.0);
        assert_eq!(v, vec![1.0, -2.0]);
    }

    #[test]
    fn global_preserves_largest() {
        let cfg = Config::default();
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let len = rng.range(16, 256);
                gen_activations(rng, len)
            },
            |xs| {
                let mut v = xs.clone();
                prune_global_magnitude(&mut v, 0.5);
                // The max-|x| element always survives.
                let (argmax, _) = xs
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                    .unwrap();
                xs[argmax] == 0.0 || v[argmax] != 0.0
            },
        );
    }
}
