//! Fused, allocation-free sparsification pipeline.
//!
//! The paper's hot loop — score a row with a criterion, apply an
//! error-mitigation transform, keep the top-N per block — used to run as
//! three separate allocating passes with an O(m²) rank loop per block
//! (`nm::nm_mask`). At the flexible patterns the paper champions that
//! overhead dominates: an 8:16 block pays 256 comparisons where ~16 suffice.
//!
//! [`Sparsifier`] fuses the whole pipeline into a single pass over each row:
//!
//! ```text
//!   x ──┬─ η (shift: none / per-token mean / stored per-channel) ──┐
//!       │                                                          │
//!       └─► s_j = |x_j − η_j| · c_j   (c = CLACT col-energy /      │
//!                 │                    Amber channel norms / 1)    │
//!                 ▼                                                ▼
//!        per-block partial top-N       kept:    y_j = (x_j − η_j) + η_j
//!        (nth-element, O(m) avg)       dropped: y_j = η_j
//!                 │
//!                 ▼
//!        optional VAR: y ·= sqrt(Var[x] / Var[y])   (per row)
//! ```
//!
//! Selection uses `select_nth_unstable_by` over a reusable index buffer —
//! O(m) average per block instead of the O(m²) rank loop — with the same
//! total order `(score desc, index asc)`, so the keep-*set* (and therefore
//! the mask and the pruned values) is bit-identical to the seed free
//! functions: element `i` has seed-rank `#{j: s_j>s_i} + #{j<i: s_j==s_i}`,
//! which is exactly its position in that total order.
//!
//! All scratch space lives in a caller-owned [`Scratch`]; after the first
//! row of a given width no call allocates. [`Sparsifier::sparsify_batch`]
//! drives disjoint row chunks through `util::threadpool::par_chunks_mut`
//! with one `Scratch` per worker.
//!
//! The seed implementations are preserved verbatim as `reference_*`
//! oracles: property tests assert byte-identical masks, and
//! `rust/benches/substrate.rs` reports the fused-vs-seed throughput that
//! `BENCH_sparsity.json` captures.

use crate::sparsity::criteria::Criterion;
use crate::sparsity::transforms::{row_var, Shift};
use crate::sparsity::Pattern;
use crate::util::tensor::Tensor;
use crate::util::threadpool;
use crate::util::threadpool::{DisjointSliceMut, WorkerPool};
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::cmp::Ordering;

thread_local! {
    /// Per-thread scratch for the [`WorkerPool`]-driven batch entry points
    /// (`sparsify_rows_pool` / `pack_rows_pool`). Pool workers persist
    /// across decode ticks, so after the first tick of a given width the
    /// hot loop allocates nothing — unlike the scoped drivers below, which
    /// build a fresh `Scratch` per spawned worker per call.
    static POOL_SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::new());
}

/// Reusable scratch buffers for the fused pipeline. Create once, pass to
/// every per-row call; buffers grow to the widest row seen and are then
/// reused without further allocation.
#[derive(Debug, Default)]
pub struct Scratch {
    /// Fused criterion scores for the current row.
    scores: Vec<f32>,
    /// Index buffer for the partial selection (block- or row-sized).
    idx: Vec<u32>,
    /// Snapshot of the unmodified row, kept only when VAR needs it.
    orig: Vec<f32>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }
}

/// `(score desc, index asc)` — the seed tie-break order. NaN scores have no
/// total order; they are treated as equal (the seed rank loop kept NaN
/// elements unconditionally — scores here come from `abs()`/norms and are
/// never NaN in practice).
#[inline]
fn cmp_rank(scores: &[f32], a: u32, b: u32) -> Ordering {
    match scores[b as usize].partial_cmp(&scores[a as usize]) {
        Some(Ordering::Equal) | None => a.cmp(&b),
        Some(o) => o,
    }
}

/// Fill `idx` with `0..scores.len()` and partition it so that `idx[..keep]`
/// is exactly the seed keep-set (top `keep` by `(score desc, index asc)`).
/// Returns the clamped keep count. O(len) average via nth-element.
fn select_top(scores: &[f32], keep: usize, idx: &mut Vec<u32>) -> usize {
    let len = scores.len();
    debug_assert!(len <= u32::MAX as usize);
    idx.clear();
    idx.extend((0..len).map(|i| i as u32));
    let keep = keep.min(len);
    if keep == 0 || keep == len {
        return keep;
    }
    idx.select_nth_unstable_by(keep - 1, |&a, &b| cmp_rank(scores, a, b));
    keep
}

#[inline]
fn eta_at(eta_chan: Option<&[f32]>, eta_scalar: f32, j: usize) -> f32 {
    match eta_chan {
        Some(v) => v[j],
        None => eta_scalar,
    }
}

/// The fused pipeline object: pattern + criterion scale + transform hooks,
/// built once per (method × pattern) cell and reused across every row.
#[derive(Clone, Debug)]
pub struct Sparsifier {
    pattern: Pattern,
    criterion: Criterion,
    shift: Shift,
    use_var: bool,
    /// Per-channel score multiplier: CLACT column energies or Amber channel
    /// norms. `None` means plain magnitude (ACT). Multiplying by a positive
    /// per-channel constant is exactly how both criteria reorder a row —
    /// CLACT's per-row 1/‖x‖₂ factor is rank-invariant and is omitted here.
    channel_scale: Option<Vec<f32>>,
}

impl Sparsifier {
    /// Plain magnitude (ACT) sparsifier with no transforms.
    pub fn new(pattern: Pattern) -> Sparsifier {
        Sparsifier {
            pattern,
            criterion: Criterion::Act,
            shift: Shift::None,
            use_var: false,
            channel_scale: None,
        }
    }

    /// Build the sparsifier for a named criterion. CLACT derives its
    /// per-channel scale from a calibration activation matrix; Amber-Pruner
    /// derives it from the layer's weight matrix.
    pub fn for_criterion(
        pattern: Pattern,
        criterion: Criterion,
        calib_activations: Option<&Tensor>,
        weights: Option<&Tensor>,
    ) -> Result<Sparsifier> {
        let mut sp = Sparsifier::new(pattern);
        sp.criterion = criterion;
        match criterion {
            Criterion::Act => {}
            Criterion::Clact => {
                let x = calib_activations
                    .context("CLACT needs a calibration activation matrix")?;
                sp.channel_scale = Some(crate::sparsity::criteria::clact_col_energy(x));
            }
            Criterion::Amber => {
                let w = weights.context("Amber-Pruner needs the layer weight matrix")?;
                sp.channel_scale = Some(crate::sparsity::criteria::amber_channel_norms(w));
            }
        }
        Ok(sp)
    }

    /// Set the shift transform (D-PTS dynamic per-token mean, or a stored
    /// S-PTS/L-PTS per-channel vector).
    pub fn with_shift(mut self, shift: Shift) -> Sparsifier {
        self.shift = shift;
        self
    }

    /// Enable/disable the per-token VAR variance correction.
    pub fn with_var(mut self, on: bool) -> Sparsifier {
        self.use_var = on;
        self
    }

    /// Set an explicit per-channel score scale (e.g. a stored
    /// `amber_cscale` calibration vector).
    pub fn with_channel_scale(mut self, scale: Vec<f32>) -> Sparsifier {
        self.channel_scale = Some(scale);
        self
    }

    /// Label the criterion this pipeline's channel scale realizes (CLACT
    /// column energies vs Amber channel norms are indistinguishable once
    /// baked into `channel_scale`; the label keeps reports honest). Prefer
    /// [`Sparsifier::for_criterion`], which derives scale + label together.
    pub fn with_criterion(mut self, criterion: Criterion) -> Sparsifier {
        self.criterion = criterion;
        self
    }

    pub fn pattern(&self) -> Pattern {
        self.pattern
    }

    pub fn criterion(&self) -> Criterion {
        self.criterion
    }

    pub fn uses_var(&self) -> bool {
        self.use_var
    }

    pub fn shift(&self) -> &Shift {
        &self.shift
    }

    /// Number of elements the selection keeps for a row of width `h`.
    pub fn kept_per_row(&self, h: usize) -> usize {
        self.pattern.kept_per_row(h)
    }

    /// Does this pipeline only *select* (no shift, no VAR)? Selection-only
    /// pipelines drop elements to exactly `0.0` and leave kept values
    /// untouched, which is what the packed compressed representation
    /// ([`PackedNM`]) can carry — a per-channel criterion scale is fine
    /// (it reorders selection without changing values).
    pub fn is_selection_only(&self) -> bool {
        matches!(self.shift, Shift::None) && !self.use_var
    }

    /// Can this pipeline emit a [`PackedNM`](crate::sparsity::PackedNM)
    /// stream? Selection-only (see [`Sparsifier::is_selection_only`]) and
    /// within the packed layout's geometry (one `u32` word per block ⇒
    /// N:M blocks up to M = 32). The single predicate both
    /// `evalharness::sparsify_proxy_error` and `quant` consult before
    /// taking the compressed-domain path.
    pub fn is_packable(&self) -> bool {
        self.is_selection_only()
            && match self.pattern {
                Pattern::NM { m, .. } => m <= 32,
                _ => true,
            }
    }

    /// Fused single pass over one row, in place: shift → score → per-block
    /// top-N → compensate → optional VAR. Bit-identical to the seed
    /// composition (`shift_*` → `nm_prune_magnitude`/`topk` → unshift →
    /// `var_correction` + `scale_rows`).
    ///
    /// Panics (like the seed) if the row length is not a multiple of M for
    /// an N:M pattern, or if a stored vector's length mismatches the row.
    pub fn sparsify_row(&self, row: &mut [f32], scratch: &mut Scratch) {
        let h = row.len();
        if matches!(self.pattern, Pattern::Dense) || h == 0 {
            return;
        }
        if let Pattern::NM { n, m } = self.pattern {
            let (n, m) = (n as usize, m as usize);
            assert!(n > 0 && n <= m, "invalid N:M {n}:{m}");
            assert_eq!(h % m, 0, "row length {h} not a multiple of M={m}");
        }

        // Snapshot for VAR (the correction compares against the original x).
        if self.use_var {
            scratch.orig.clear();
            scratch.orig.extend_from_slice(row);
        }

        let (eta_scalar, eta_chan, shifted) = self.shift_params(row);
        self.fill_scores(row, eta_scalar, eta_chan, scratch);

        // Partial selection + compensated writeback. Kept elements replay
        // the seed's (x−η)+η rounding; dropped elements become 0+η = η.
        match self.pattern {
            Pattern::Dense => unreachable!(),
            Pattern::NM { n, m } => {
                let (n, m) = (n as usize, m as usize);
                for base in (0..h).step_by(m) {
                    let keep = select_top(&scratch.scores[base..base + m], n, &mut scratch.idx);
                    writeback(row, base, &scratch.idx, keep, shifted, eta_chan, eta_scalar);
                }
            }
            Pattern::Unstructured { .. } => {
                let keep = select_top(&scratch.scores, self.kept_per_row(h), &mut scratch.idx);
                writeback(row, 0, &scratch.idx, keep, shifted, eta_chan, eta_scalar);
            }
        }

        // VAR: ν = sqrt(Var[x] / Var[y]), identical guard and f64 math to
        // the seed `var_correction` + `scale_rows`.
        if self.use_var {
            let v_orig = row_var(&scratch.orig[..h]);
            let v_pruned = row_var(row);
            let nu = if v_pruned <= 1e-12 {
                1.0
            } else {
                (v_orig / v_pruned).sqrt() as f32
            };
            for v in row.iter_mut() {
                *v *= nu;
            }
        }
    }

    /// Shift parameters for one row: `(η_scalar, η_per_channel, shifted?)`.
    /// The per-token mean matches the seed's `row_means` bit-for-bit (f64
    /// accumulate, f32 cast).
    fn shift_params<'a>(&'a self, row: &[f32]) -> (f32, Option<&'a [f32]>, bool) {
        let eta_scalar: f32 = match self.shift {
            Shift::DynamicPerToken => {
                (row.iter().map(|v| *v as f64).sum::<f64>() / row.len() as f64) as f32
            }
            _ => 0.0,
        };
        let eta_chan: Option<&[f32]> = match &self.shift {
            Shift::PerChannel(v) => {
                assert_eq!(v.len(), row.len(), "per-channel eta length mismatch");
                Some(v.as_slice())
            }
            _ => None,
        };
        (eta_scalar, eta_chan, !matches!(self.shift, Shift::None))
    }

    /// Fused criterion scores into scratch: `s_j = |x_j − η_j| · c_j`.
    fn fill_scores(
        &self,
        row: &[f32],
        eta_scalar: f32,
        eta_chan: Option<&[f32]>,
        scratch: &mut Scratch,
    ) {
        scratch.scores.clear();
        match &self.channel_scale {
            None => {
                for (j, v) in row.iter().enumerate() {
                    scratch
                        .scores
                        .push((*v - eta_at(eta_chan, eta_scalar, j)).abs());
                }
            }
            Some(cs) => {
                assert_eq!(cs.len(), row.len(), "channel scale length mismatch");
                for (j, v) in row.iter().enumerate() {
                    scratch
                        .scores
                        .push((*v - eta_at(eta_chan, eta_scalar, j)).abs() * cs[j]);
                }
            }
        }
    }

    /// Compute the keep-mask of one row without modifying values.
    /// `mask.len()` must equal `values.len()`.
    pub fn mask_row_into(&self, values: &[f32], mask: &mut [bool], scratch: &mut Scratch) {
        let h = values.len();
        assert_eq!(mask.len(), h, "mask length mismatch");
        if matches!(self.pattern, Pattern::Dense) {
            mask.iter_mut().for_each(|b| *b = true);
            return;
        }
        if h == 0 {
            return;
        }
        // Same shift + score computation as sparsify_row, selection only.
        let (eta_scalar, eta_chan, _shifted) = self.shift_params(values);
        self.fill_scores(values, eta_scalar, eta_chan, scratch);
        mask.iter_mut().for_each(|b| *b = false);
        match self.pattern {
            Pattern::Dense => unreachable!(),
            Pattern::NM { n, m } => {
                let (n, m) = (n as usize, m as usize);
                assert!(n > 0 && n <= m, "invalid N:M {n}:{m}");
                assert_eq!(h % m, 0, "row length {h} not a multiple of M={m}");
                for base in (0..h).step_by(m) {
                    let keep = select_top(&scratch.scores[base..base + m], n, &mut scratch.idx);
                    for &i in &scratch.idx[..keep] {
                        mask[base + i as usize] = true;
                    }
                }
            }
            Pattern::Unstructured { .. } => {
                let keep = select_top(&scratch.scores, self.kept_per_row(h), &mut scratch.idx);
                for &i in &scratch.idx[..keep] {
                    mask[i as usize] = true;
                }
            }
        }
    }

    /// Sparsify every row of a `[rows, h]` matrix in place (single thread,
    /// caller-owned scratch).
    pub fn sparsify(&self, x: &mut Tensor, scratch: &mut Scratch) {
        let h = x.cols();
        for row in x.data.chunks_exact_mut(h) {
            self.sparsify_row(row, scratch);
        }
    }

    /// Row-parallel batch driver: splits the matrix into contiguous row
    /// chunks and runs each through the fused pass on
    /// `util::threadpool::par_chunks_mut`, one `Scratch` per worker.
    /// Results are identical to [`Sparsifier::sparsify`] regardless of
    /// `threads` (rows are independent).
    pub fn sparsify_batch(&self, x: &mut Tensor, threads: usize) {
        let h = x.cols();
        let rows = x.rows();
        if rows == 0 || h == 0 || matches!(self.pattern, Pattern::Dense) {
            return;
        }
        let threads = threads.max(1).min(rows);
        let rows_per_chunk = (rows + threads - 1) / threads;
        threadpool::par_chunks_mut(&mut x.data, rows_per_chunk * h, threads, |_chunk, span| {
            let mut scratch = Scratch::new();
            for row in span.chunks_exact_mut(h) {
                self.sparsify_row(row, &mut scratch);
            }
        });
    }

    /// [`WorkerPool`]-driven row-parallel sparsification over a lane-major
    /// slice (`xs.len() == rows * cols`), in place. The hot-loop twin of
    /// [`Sparsifier::sparsify_batch`]: same per-row kernel over disjoint
    /// row ranges, but on persistent parked workers with per-thread
    /// reusable scratch (no spawn, no steady-state allocation). Rows are
    /// independent, so results are bitwise identical to a serial
    /// [`Sparsifier::sparsify_row`] loop at any pool width.
    pub fn sparsify_rows_pool(&self, xs: &mut [f32], cols: usize, pool: &WorkerPool) {
        if cols == 0 || xs.is_empty() || matches!(self.pattern, Pattern::Dense) {
            return;
        }
        assert_eq!(xs.len() % cols, 0, "lane-major input not rectangular");
        let rows = xs.len() / cols;
        let shared = DisjointSliceMut::new(xs);
        pool.run_ranges(rows, |lo, hi| {
            POOL_SCRATCH.with(|s| {
                let mut scratch = s.borrow_mut();
                for r in lo..hi {
                    // SAFETY: row ranges are disjoint across parts.
                    let row = unsafe { shared.slice_mut(r * cols, cols) };
                    self.sparsify_row(row, &mut scratch);
                }
            });
        });
    }

    /// Tensor wrapper over [`Sparsifier::sparsify_rows_pool`].
    pub fn sparsify_batch_pool(&self, x: &mut Tensor, pool: &WorkerPool) {
        let h = x.cols();
        self.sparsify_rows_pool(&mut x.data, h, pool);
    }

    // ------------------------------------------------- compressed emission

    /// Emit one row straight into the packed stream during the selection
    /// pass: score → per-block top-N → metadata word + kept values, with
    /// no dense writeback and no per-block mask allocation. Requires a
    /// selection-only pipeline (see [`Sparsifier::is_selection_only`]);
    /// `decode` of the emitted row is bit-identical to
    /// [`Sparsifier::sparsify_row`] on the same data.
    pub fn pack_row_into(
        &self,
        row: &[f32],
        packed: &mut crate::sparsity::PackedNM,
        scratch: &mut Scratch,
    ) {
        assert_eq!(packed.pattern(), self.pattern, "packed stream pattern mismatch");
        assert_eq!(packed.cols(), row.len(), "packed stream width mismatch");
        let r = packed.append_row_slot();
        let (vals, meta) = packed.row_slots_mut(r);
        self.pack_row_to(row, vals, meta, scratch);
    }

    /// Pack every row of a `[rows, h]` matrix into `packed` (single
    /// thread, caller-owned scratch). The stream is re-shaped in place —
    /// repacking a same-shaped matrix allocates nothing.
    pub fn pack(
        &self,
        x: &Tensor,
        packed: &mut crate::sparsity::PackedNM,
        scratch: &mut Scratch,
    ) {
        let (rows, h) = (x.rows(), x.cols());
        packed.reset_for(self.pattern, h, rows);
        for r in 0..rows {
            let (vals, meta) = packed.row_slots_mut(r);
            // Borrow dance: row_slots_mut holds `packed`; re-borrow x only.
            self.pack_row_to(x.row(r), vals, meta, scratch);
        }
    }

    /// Row-parallel packed emission: kept-values and metadata outputs are
    /// split into lockstep row chunks on `threadpool::par_chunks2_mut`,
    /// one `Scratch` per worker. Identical to [`Sparsifier::pack`] at any
    /// thread count.
    pub fn pack_batch(&self, x: &Tensor, packed: &mut crate::sparsity::PackedNM, threads: usize) {
        let (rows, h) = (x.rows(), x.cols());
        packed.reset_for(self.pattern, h, rows);
        if rows == 0 || h == 0 {
            return;
        }
        let kpr = packed.kept_per_row();
        let bpr = packed.blocks_per_row();
        if kpr == 0 {
            // Nothing is kept (tiny unstructured keep fractions): the
            // stream is all-zero metadata and an empty value payload.
            let (_, meta) = packed.buffers_mut();
            meta.iter_mut().for_each(|w| *w = 0);
            return;
        }
        let threads = threads.max(1).min(rows);
        let rows_per_chunk = (rows + threads - 1) / threads;
        let (values, meta) = packed.buffers_mut();
        threadpool::par_chunks2_mut(
            values,
            rows_per_chunk * kpr,
            meta,
            rows_per_chunk * bpr,
            threads,
            |ci, vspan, mspan| {
                let mut scratch = Scratch::new();
                for (i, (vals, mw)) in vspan
                    .chunks_exact_mut(kpr)
                    .zip(mspan.chunks_exact_mut(bpr))
                    .enumerate()
                {
                    self.pack_row_to(x.row(ci * rows_per_chunk + i), vals, mw, &mut scratch);
                }
            },
        );
    }

    /// [`WorkerPool`]-driven packed emission over a lane-major slice
    /// (`xs.len() == rows * cols`): the hot-loop twin of
    /// [`Sparsifier::pack_batch`], used by `NativeEngine` so per-tick lane
    /// packing shares the engine's one worker set. Each worker packs a
    /// disjoint row range straight into its exact value/metadata slots
    /// (uniform geometry makes the offsets trivial), with per-thread
    /// reusable scratch. The emitted stream is bitwise identical to a
    /// serial [`Sparsifier::pack_row_into`] loop at any pool width.
    pub fn pack_rows_pool(
        &self,
        xs: &[f32],
        cols: usize,
        packed: &mut crate::sparsity::PackedNM,
        pool: &WorkerPool,
    ) {
        let rows = if cols == 0 { 0 } else { xs.len() / cols };
        assert_eq!(xs.len(), rows * cols, "lane-major input not rectangular");
        packed.reset_for(self.pattern, cols, rows);
        if rows == 0 || cols == 0 {
            return;
        }
        let kpr = packed.kept_per_row();
        let bpr = packed.blocks_per_row();
        if kpr == 0 {
            let (_, meta) = packed.buffers_mut();
            meta.iter_mut().for_each(|w| *w = 0);
            return;
        }
        let (values, meta) = packed.buffers_mut();
        let vals = DisjointSliceMut::new(values);
        let mws = DisjointSliceMut::new(meta);
        pool.run_ranges(rows, |lo, hi| {
            POOL_SCRATCH.with(|s| {
                let mut scratch = s.borrow_mut();
                for r in lo..hi {
                    // SAFETY: row slots are disjoint across the disjoint
                    // row ranges (kpr values + bpr words per row).
                    let (v, m) = unsafe {
                        (vals.slice_mut(r * kpr, kpr), mws.slice_mut(r * bpr, bpr))
                    };
                    self.pack_row_to(&xs[r * cols..(r + 1) * cols], v, m, &mut scratch);
                }
            });
        });
    }

    /// Selection + compressed emission for one row into exact-size output
    /// slots (`vals.len() == kept_per_row`, `meta.len() == blocks_per_row`).
    fn pack_row_to(&self, row: &[f32], vals: &mut [f32], meta: &mut [u32], scratch: &mut Scratch) {
        assert!(
            self.is_selection_only(),
            "packed emission requires a selection-only pipeline (no shift/VAR)"
        );
        let h = row.len();
        if h == 0 {
            return;
        }
        self.fill_scores(row, 0.0, None, scratch);
        let mut vi = 0usize;
        match self.pattern {
            Pattern::Dense => {
                vals.copy_from_slice(row);
                vi = h;
                for (bi, word) in meta.iter_mut().enumerate() {
                    let width = 32usize.min(h - bi * 32);
                    *word = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
                }
            }
            Pattern::NM { n, m } => {
                let (n, m) = (n as usize, m as usize);
                assert!(n > 0 && n <= m, "invalid N:M {n}:{m}");
                assert_eq!(h % m, 0, "row length {h} not a multiple of M={m}");
                for (bi, base) in (0..h).step_by(m).enumerate() {
                    let keep = select_top(&scratch.scores[base..base + m], n, &mut scratch.idx);
                    let mut word = 0u32;
                    for &i in &scratch.idx[..keep] {
                        word |= 1 << i;
                    }
                    meta[bi] = word;
                    // Walking the word's set bits yields the keep-set in
                    // ascending column order without sorting the indices.
                    let mut w = word;
                    while w != 0 {
                        let b = w.trailing_zeros() as usize;
                        vals[vi] = row[base + b];
                        vi += 1;
                        w &= w - 1;
                    }
                }
            }
            Pattern::Unstructured { .. } => {
                let keep = select_top(&scratch.scores, self.kept_per_row(h), &mut scratch.idx);
                meta.iter_mut().for_each(|w| *w = 0);
                for &i in &scratch.idx[..keep] {
                    meta[i as usize / 32] |= 1 << (i % 32);
                }
                for (bi, &word) in meta.iter().enumerate() {
                    let base = bi * 32;
                    let mut w = word;
                    while w != 0 {
                        let b = w.trailing_zeros() as usize;
                        vals[vi] = row[base + b];
                        vi += 1;
                        w &= w - 1;
                    }
                }
            }
        }
        debug_assert_eq!(vi, vals.len(), "kept-count / slot-size mismatch");
    }
}

#[inline]
fn writeback(
    row: &mut [f32],
    base: usize,
    idx: &[u32],
    keep: usize,
    shifted: bool,
    eta_chan: Option<&[f32]>,
    eta_scalar: f32,
) {
    for &i in &idx[keep..] {
        let j = base + i as usize;
        row[j] = eta_at(eta_chan, eta_scalar, j);
    }
    if shifted {
        for &i in &idx[..keep] {
            let j = base + i as usize;
            let e = eta_at(eta_chan, eta_scalar, j);
            row[j] = (row[j] - e) + e;
        }
    }
}

// ------------------------------------------------------------------ free fns
// Selection-only entry points used by the deprecated shims and by callers
// that bring their own scores (e.g. metadata encoding).

/// Write the N:M keep-mask for `scores` into `mask` (pre-sized, any
/// contents). Fused-path equivalent of the seed `nm::nm_mask`.
pub fn nm_mask_into(
    scores: &[f32],
    n: usize,
    m: usize,
    mask: &mut [bool],
    scratch: &mut Scratch,
) {
    assert!(n > 0 && n <= m, "invalid N:M {n}:{m}");
    assert_eq!(
        scores.len() % m,
        0,
        "row length {} not a multiple of M={m}",
        scores.len()
    );
    assert_eq!(mask.len(), scores.len(), "mask length mismatch");
    mask.iter_mut().for_each(|b| *b = false);
    for base in (0..scores.len()).step_by(m) {
        let keep = select_top(&scores[base..base + m], n, &mut scratch.idx);
        for &i in &scratch.idx[..keep] {
            mask[base + i as usize] = true;
        }
    }
}

/// Write the top-`keep` mask for `scores` into `mask`. Fused-path
/// equivalent of the seed `unstructured::topk_mask`.
pub fn topk_mask_into(scores: &[f32], keep: usize, mask: &mut [bool], scratch: &mut Scratch) {
    assert_eq!(mask.len(), scores.len(), "mask length mismatch");
    mask.iter_mut().for_each(|b| *b = false);
    let keep = select_top(scores, keep, &mut scratch.idx);
    for &i in &scratch.idx[..keep] {
        mask[i as usize] = true;
    }
}

/// Zero the elements of `values` outside the per-block top-N of `scores`
/// (which may differ from `values` — CLACT/Amber). Fused-path equivalent of
/// the seed `nm::nm_prune_by`.
pub fn nm_prune_by_scores(
    values: &mut [f32],
    scores: &[f32],
    n: usize,
    m: usize,
    scratch: &mut Scratch,
) {
    assert_eq!(values.len(), scores.len());
    assert!(n > 0 && n <= m, "invalid N:M {n}:{m}");
    assert_eq!(
        scores.len() % m,
        0,
        "row length {} not a multiple of M={m}",
        scores.len()
    );
    for base in (0..scores.len()).step_by(m) {
        let keep = select_top(&scores[base..base + m], n, &mut scratch.idx);
        for &i in &scratch.idx[keep..] {
            values[base + i as usize] = 0.0;
        }
    }
}

/// Keep the top-`keep` elements of `values` by magnitude, zeroing the rest.
/// Fused-path equivalent of the seed `unstructured::prune_row_magnitude`.
pub fn prune_row_topk_magnitude(values: &mut [f32], keep: usize, scratch: &mut Scratch) {
    scratch.scores.clear();
    scratch.scores.extend(values.iter().map(|x| x.abs()));
    let keep = select_top(&scratch.scores, keep, &mut scratch.idx);
    for &i in &scratch.idx[keep..] {
        values[i as usize] = 0.0;
    }
}

// ---------------------------------------------------------------- reference
// The seed implementations, preserved verbatim as oracles. Property tests
// pin the fused path byte-identical to these; `benches/substrate.rs` reports
// the fused-vs-seed throughput ratio captured in BENCH_sparsity.json.

/// The seed O(m²) rank-loop N:M mask (oracle; do not use on hot paths).
pub fn reference_nm_mask(scores: &[f32], n: usize, m: usize) -> Vec<bool> {
    assert!(n > 0 && n <= m, "invalid N:M {n}:{m}");
    assert_eq!(
        scores.len() % m,
        0,
        "row length {} not a multiple of M={m}",
        scores.len()
    );
    let mut mask = vec![false; scores.len()];
    for (b, block) in scores.chunks_exact(m).enumerate() {
        let base = b * m;
        for i in 0..m {
            let si = block[i];
            let mut rank = 0usize;
            for (j, &sj) in block.iter().enumerate() {
                if sj > si || (sj == si && j < i) {
                    rank += 1;
                }
            }
            if rank < n {
                mask[base + i] = true;
            }
        }
    }
    mask
}

/// The seed sort-based top-k mask (oracle).
pub fn reference_topk_mask(scores: &[f32], keep: usize) -> Vec<bool> {
    let keep = keep.min(scores.len());
    if keep == scores.len() {
        return vec![true; scores.len()];
    }
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut mask = vec![false; scores.len()];
    for &i in idx.iter().take(keep) {
        mask[i] = true;
    }
    mask
}

/// The seed allocating per-row magnitude prune for any pattern (oracle).
pub fn reference_row_prune(values: &mut [f32], pattern: Pattern) {
    match pattern {
        Pattern::Dense => {}
        Pattern::NM { n, m } => {
            let scores: Vec<f32> = values.iter().map(|x| x.abs()).collect();
            let mask = reference_nm_mask(&scores, n as usize, m as usize);
            for (v, keep) in values.iter_mut().zip(mask) {
                if !keep {
                    *v = 0.0;
                }
            }
        }
        Pattern::Unstructured { keep_pct } => {
            let keep = ((values.len() as f64) * (keep_pct as f64 / 100.0)).round() as usize;
            let scores: Vec<f32> = values.iter().map(|x| x.abs()).collect();
            let mask = reference_topk_mask(&scores, keep);
            for (v, k) in values.iter_mut().zip(mask) {
                if !k {
                    *v = 0.0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::paper_patterns;
    use crate::sparsity::transforms::{
        col_means, row_means, scale_rows, shift_cols, shift_rows, var_correction,
    };
    use crate::util::miniprop::{forall_simple, gen_activations, Config};
    use crate::util::prng::Rng;

    fn rand_matrix(rng: &mut Rng, l: usize, h: usize, mean: f32) -> Tensor {
        Tensor::from_vec(
            &[l, h],
            (0..l * h).map(|_| rng.normal() as f32 + mean).collect(),
        )
    }

    /// Seed composition oracle for the full mitigated pipeline: shift →
    /// per-row reference prune → unshift → VAR, exactly the seed
    /// `mitigated_nm_prune` generalized to any pattern.
    fn reference_mitigated(x: &Tensor, pattern: Pattern, shift: &Shift, use_var: bool) -> Tensor {
        let (shifted, eta_rows, eta_cols): (Tensor, Option<Vec<f32>>, Option<Vec<f32>>) =
            match shift {
                Shift::None => (x.clone(), None, None),
                Shift::DynamicPerToken => {
                    let eta = row_means(x);
                    (shift_rows(x, &eta), Some(eta), None)
                }
                Shift::PerChannel(eta) => (shift_cols(x, eta), None, Some(eta.clone())),
            };
        let mut pruned = shifted;
        for i in 0..pruned.rows() {
            reference_row_prune(pruned.row_mut(i), pattern);
        }
        let mut restored = pruned;
        if let Some(eta) = &eta_rows {
            for i in 0..restored.rows() {
                let e = eta[i];
                for v in restored.row_mut(i) {
                    *v += e;
                }
            }
        }
        if let Some(eta) = &eta_cols {
            for i in 0..restored.rows() {
                for (v, e) in restored.row_mut(i).iter_mut().zip(eta) {
                    *v += *e;
                }
            }
        }
        if use_var {
            let nu = var_correction(x, &restored);
            scale_rows(&mut restored, &nu);
        }
        restored
    }

    #[test]
    fn fused_nm_mask_matches_seed_oracle() {
        // Satellite: random rows × all paper N:M patterns, byte-identical
        // masks including tie-break-toward-lower-index on duplicate scores
        // (gen_activations seeds exact ±1.0 ties and zeros).
        let cfg = Config::default();
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let m = *rng.choose(&[4usize, 8, 16, 32]);
                let n = rng.range(1, m + 1);
                let blocks = rng.range(1, 8);
                (gen_activations(rng, m * blocks), n, m)
            },
            |(xs, n, m)| {
                let mut mask = vec![false; xs.len()];
                let mut scratch = Scratch::new();
                nm_mask_into(xs, *n, *m, &mut mask, &mut scratch);
                mask == reference_nm_mask(xs, *n, *m)
            },
        );
    }

    #[test]
    fn fused_topk_matches_seed_oracle() {
        let cfg = Config::default();
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let len = rng.range(1, 300);
                let keep = rng.range(0, len + 2); // includes 0 and > len
                (gen_activations(rng, len), keep)
            },
            |(xs, keep)| {
                let mut mask = vec![false; xs.len()];
                let mut scratch = Scratch::new();
                topk_mask_into(xs, *keep, &mut mask, &mut scratch);
                mask == reference_topk_mask(xs, *keep)
            },
        );
    }

    #[test]
    fn fused_row_prune_matches_seed_all_paper_patterns() {
        let cfg = Config::default();
        let patterns = paper_patterns();
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let pattern = *rng.choose(&patterns);
                // All paper patterns have M | 32, so 32·k rows fit all.
                let xs = gen_activations(rng, 32 * rng.range(1, 6));
                (xs, pattern)
            },
            |(xs, pattern)| {
                let mut fused = xs.clone();
                let mut scratch = Scratch::new();
                Sparsifier::new(*pattern).sparsify_row(&mut fused, &mut scratch);
                let mut seed = xs.clone();
                reference_row_prune(&mut seed, *pattern);
                // Bit-identical, not approximately equal.
                fused.iter().zip(&seed).all(|(a, b)| a.to_bits() == b.to_bits())
            },
        );
    }

    #[test]
    fn fused_mitigated_matches_seed_composition_bitwise() {
        let mut rng = Rng::new(0xF00D);
        let patterns = [
            Pattern::NM { n: 2, m: 4 },
            Pattern::NM { n: 8, m: 16 },
            Pattern::Unstructured { keep_pct: 50 },
        ];
        for pattern in patterns {
            for shift_kind in 0..3 {
                for use_var in [false, true] {
                    let x = rand_matrix(&mut rng, 6, 32, 3.0);
                    let shift = match shift_kind {
                        0 => Shift::None,
                        1 => Shift::DynamicPerToken,
                        _ => Shift::PerChannel(col_means(&x)),
                    };
                    let expected = reference_mitigated(&x, pattern, &shift, use_var);
                    let mut got = x.clone();
                    let sp = Sparsifier::new(pattern)
                        .with_shift(shift.clone())
                        .with_var(use_var);
                    let mut scratch = Scratch::new();
                    sp.sparsify(&mut got, &mut scratch);
                    assert_eq!(
                        got.data
                            .iter()
                            .map(|v| v.to_bits())
                            .collect::<Vec<_>>(),
                        expected
                            .data
                            .iter()
                            .map(|v| v.to_bits())
                            .collect::<Vec<_>>(),
                        "pattern {pattern} shift {shift:?} var {use_var}"
                    );
                }
            }
        }
    }

    #[test]
    fn channel_scale_reorders_like_external_scores() {
        // Pruning values by |x|·c must equal pruning by precomputed scores.
        let mut rng = Rng::new(11);
        let h = 32;
        let xs: Vec<f32> = (0..h).map(|_| rng.normal() as f32).collect();
        let cs: Vec<f32> = (0..h).map(|_| rng.normal().abs() as f32 + 0.1).collect();
        let scores: Vec<f32> = xs.iter().zip(&cs).map(|(x, c)| x.abs() * c).collect();
        let mut scratch = Scratch::new();
        let mut a = xs.clone();
        Sparsifier::new(Pattern::NM { n: 2, m: 4 })
            .with_channel_scale(cs)
            .sparsify_row(&mut a, &mut scratch);
        let mut b = xs.clone();
        nm_prune_by_scores(&mut b, &scores, 2, 4, &mut scratch);
        assert_eq!(a, b);
    }

    #[test]
    fn batch_matches_row_loop_any_thread_count() {
        let mut rng = Rng::new(21);
        let x = rand_matrix(&mut rng, 37, 64, 1.0); // odd row count on purpose
        let sp = Sparsifier::new(Pattern::NM { n: 8, m: 16 })
            .with_shift(Shift::DynamicPerToken)
            .with_var(true);
        let mut serial = x.clone();
        let mut scratch = Scratch::new();
        sp.sparsify(&mut serial, &mut scratch);
        for threads in [1, 2, 3, 8, 64] {
            let mut par = x.clone();
            sp.sparsify_batch(&mut par, threads);
            assert_eq!(par.data, serial.data, "threads={threads}");
        }
    }

    #[test]
    fn pool_batch_matches_row_loop_any_pool_width() {
        let mut rng = Rng::new(23);
        let x = rand_matrix(&mut rng, 31, 64, 1.0); // odd row count on purpose
        let sp = Sparsifier::new(Pattern::NM { n: 8, m: 16 })
            .with_shift(Shift::DynamicPerToken)
            .with_var(true);
        let mut serial = x.clone();
        let mut scratch = Scratch::new();
        sp.sparsify(&mut serial, &mut scratch);
        for threads in [1usize, 2, 4, 7] {
            let pool = WorkerPool::new(threads);
            let mut par = x.clone();
            sp.sparsify_batch_pool(&mut par, &pool);
            assert_eq!(par.data, serial.data, "pool threads={threads}");
        }
    }

    #[test]
    fn pack_rows_pool_matches_serial_any_pool_width() {
        use crate::sparsity::PackedNM;
        let mut rng = Rng::new(95);
        let x = rand_matrix(&mut rng, 29, 64, 0.0); // odd row count on purpose
        for pattern in [
            Pattern::NM { n: 8, m: 16 },
            Pattern::Unstructured { keep_pct: 30 },
            Pattern::Dense,
        ] {
            let sp = Sparsifier::new(pattern);
            let mut serial = PackedNM::new(pattern, 64);
            let mut scratch = Scratch::new();
            sp.pack(&x, &mut serial, &mut scratch);
            for threads in [1usize, 2, 4, 7] {
                let pool = WorkerPool::new(threads);
                let mut par = PackedNM::new(pattern, 64);
                sp.pack_rows_pool(&x.data, 64, &mut par, &pool);
                assert_eq!(par, serial, "{pattern} pool threads={threads}");
            }
        }
    }

    #[test]
    fn pack_batch_matches_serial_any_thread_count() {
        use crate::sparsity::PackedNM;
        let mut rng = Rng::new(91);
        let x = rand_matrix(&mut rng, 29, 64, 0.0); // odd row count on purpose
        for pattern in [
            Pattern::NM { n: 8, m: 16 },
            Pattern::Unstructured { keep_pct: 30 },
        ] {
            let sp = Sparsifier::new(pattern);
            let mut serial = PackedNM::new(pattern, 64);
            let mut scratch = Scratch::new();
            sp.pack(&x, &mut serial, &mut scratch);
            for threads in [1usize, 2, 3, 8, 64] {
                let mut par = PackedNM::new(pattern, 64);
                sp.pack_batch(&x, &mut par, threads);
                assert_eq!(par, serial, "{pattern} threads={threads}");
            }
        }
    }

    #[test]
    fn pack_with_channel_scale_matches_sparsify_zeros() {
        use crate::sparsity::PackedNM;
        let mut rng = Rng::new(93);
        let h = 32;
        let xs: Vec<f32> = (0..h).map(|_| rng.normal() as f32).collect();
        let cs: Vec<f32> = (0..h).map(|_| rng.normal().abs() as f32 + 0.1).collect();
        let sp = Sparsifier::new(Pattern::NM { n: 2, m: 4 }).with_channel_scale(cs);
        assert!(sp.is_selection_only());
        let mut scratch = Scratch::new();
        let mut packed = PackedNM::new(sp.pattern(), h);
        sp.pack_row_into(&xs, &mut packed, &mut scratch);
        let mut dense = xs.clone();
        sp.sparsify_row(&mut dense, &mut scratch);
        let mut decoded = vec![0.0f32; h];
        packed.decode_row_into(0, &mut decoded);
        assert_eq!(decoded, dense);
    }

    #[test]
    #[should_panic(expected = "selection-only")]
    fn packed_emission_rejects_shifted_pipelines() {
        use crate::sparsity::PackedNM;
        let sp = Sparsifier::new(Pattern::NM { n: 2, m: 4 }).with_shift(Shift::DynamicPerToken);
        assert!(!sp.is_selection_only());
        let mut packed = PackedNM::new(sp.pattern(), 4);
        let mut scratch = Scratch::new();
        sp.pack_row_into(&[1.0, 2.0, 3.0, 4.0], &mut packed, &mut scratch);
    }

    #[test]
    fn scratch_reuse_is_stateless() {
        // One scratch across rows of different widths and patterns.
        let mut scratch = Scratch::new();
        let mut a = vec![1.0f32, -2.0, 3.0, -4.0];
        Sparsifier::new(Pattern::NM { n: 2, m: 4 }).sparsify_row(&mut a, &mut scratch);
        assert_eq!(a, vec![0.0, 0.0, 3.0, -4.0]);
        let mut b: Vec<f32> = (0..32).map(|i| i as f32).collect();
        Sparsifier::new(Pattern::NM { n: 16, m: 32 }).sparsify_row(&mut b, &mut scratch);
        assert_eq!(b.iter().filter(|v| **v != 0.0).count(), 16);
        let mut c = vec![5.0f32; 4];
        Sparsifier::new(Pattern::Unstructured { keep_pct: 50 })
            .sparsify_row(&mut c, &mut scratch);
        assert_eq!(c, vec![5.0, 5.0, 0.0, 0.0]); // ties break low-index
    }

    #[test]
    fn mask_row_matches_prune_zeros() {
        let mut rng = Rng::new(31);
        let xs: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let sp = Sparsifier::new(Pattern::NM { n: 4, m: 8 });
        let mut scratch = Scratch::new();
        let mut mask = vec![false; xs.len()];
        sp.mask_row_into(&xs, &mut mask, &mut scratch);
        let mut pruned = xs.clone();
        sp.sparsify_row(&mut pruned, &mut scratch);
        for (j, keep) in mask.iter().enumerate() {
            assert_eq!(*keep, pruned[j] != 0.0 || xs[j] == 0.0, "col {j}");
        }
        assert_eq!(mask.iter().filter(|k| **k).count(), 32);
    }

    #[test]
    fn dense_is_identity() {
        let mut v = vec![1.0f32, -0.0, 2.0];
        let before = v.clone();
        let mut scratch = Scratch::new();
        Sparsifier::new(Pattern::Dense)
            .with_var(true)
            .sparsify_row(&mut v, &mut scratch);
        assert_eq!(
            v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            before.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn kept_per_row_counts() {
        assert_eq!(Sparsifier::new(Pattern::NM { n: 8, m: 16 }).kept_per_row(64), 32);
        assert_eq!(
            Sparsifier::new(Pattern::Unstructured { keep_pct: 30 }).kept_per_row(100),
            30
        );
        assert_eq!(Sparsifier::new(Pattern::Dense).kept_per_row(7), 7);
    }

    #[test]
    fn for_criterion_requires_inputs() {
        let p = Pattern::NM { n: 2, m: 4 };
        assert!(Sparsifier::for_criterion(p, Criterion::Clact, None, None).is_err());
        assert!(Sparsifier::for_criterion(p, Criterion::Amber, None, None).is_err());
        let x = Tensor::from_vec(&[2, 4], vec![1.0; 8]);
        let sp = Sparsifier::for_criterion(p, Criterion::Clact, Some(&x), None).unwrap();
        assert_eq!(sp.criterion(), Criterion::Clact);
        assert!(sp.channel_scale.is_some());
    }
}
