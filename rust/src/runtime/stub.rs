//! CPU stub for the `xla` crate, compiled when the default-off `pjrt`
//! feature is disabled.
//!
//! CI machines (and fresh checkouts) have no PJRT plugin and no AOT
//! artifacts, but the crate must still build, run its tests, and serve
//! synthetic traffic (`nmsparse loadgen`, `ServerCore` +
//! `SyntheticBackend`). This module mirrors exactly the slice of the
//! `xla` API that `runtime::mod` touches: constructors succeed so
//! `Runtime::cpu()` / `EnginePool::open` work artifact-free code paths,
//! and the first call that would actually need XLA (`compile`,
//! `execute_b`) fails with a descriptive error pointing at the feature
//! flag. Rebuild with `--features pjrt` for the real engine.

use std::path::Path;

/// Error type standing in for `xla::Error`; call sites only format it
/// with `{:?}`.
#[derive(Debug)]
pub struct Error(pub String);

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what} requires the PJRT runtime, but nmsparse was built without the \
         default-off `pjrt` feature (cargo build --features pjrt)"
    )))
}

/// Stand-in for `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (pjrt feature disabled)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("compiling an HLO variant")
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Ok(PjRtBuffer)
    }
}

/// Stand-in for `xla::HloModuleProto` (text parse is a file-existence
/// check; real parsing needs XLA).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, Error> {
        if Path::new(path).exists() {
            Ok(HloModuleProto)
        } else {
            Err(Error(format!("no HLO text at {path}")))
        }
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("executing a bound engine")
    }
}

/// Stand-in for `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("fetching a device buffer")
    }
}

/// Stand-in for `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn to_tuple2(&self) -> Result<(Literal, Literal), Error> {
        unavailable("untupling a literal")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("reading a literal")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_builds_and_fails_descriptively() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let buf = client.buffer_from_host_buffer(&[1.0f32], &[1], None).unwrap();
        let err = PjRtLoadedExecutable.execute_b(&[&buf]).unwrap_err();
        assert!(format!("{err:?}").contains("pjrt"));
        assert!(HloModuleProto::from_text_file("/definitely/not/here.hlo.txt").is_err());
        assert!(Literal.to_vec::<f32>().is_err());
    }
}
