//! PJRT runtime: load AOT artifacts and execute them from the rust hot path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `compile` → `execute_b`. A [`Variant`] is one compiled sparsity-pattern
//! executable; an [`Engine`] is a variant *bound* to concrete weights and
//! method parameters (pre-uploaded device buffers, so the per-request cost
//! is tokens/lens upload + execution + two small output transfers).
//!
//! Input binding is driven by `io_manifest.json` (written by `aot.py`): an
//! ordered list of named inputs. Names are resolved by a caller-supplied
//! [`InputResolver`] — `w.<tensor>` from the checkpoint store, `m.<...>`
//! from the method configuration. This keeps the runtime generic over
//! variants (standard vs R-Sparse) and methods.

use crate::util::json::{self, Json};
use crate::util::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

// The real `xla` crate only exists behind the default-off `pjrt` feature
// (CI machines have no PJRT plugin). Without it, `stub` provides the same
// API surface: constructors succeed, and the first call that would need
// XLA fails with an error pointing at `--features pjrt`. The module is
// `pub` (doc-hidden) because stub types appear in public signatures
// (`Runtime::upload` returns a buffer) — a private module would trip the
// `private_interfaces` lint.
#[cfg(not(feature = "pjrt"))]
#[doc(hidden)]
pub mod stub;
#[cfg(not(feature = "pjrt"))]
use stub as xla;

/// One named input of a variant executable.
#[derive(Clone, Debug)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl InputSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Static model dimensions recorded by `aot.py`.
#[derive(Clone, Debug)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn: usize,
    pub batch: usize,
    pub seq: usize,
    pub num_params: usize,
    pub sites: Vec<String>,
}

/// Metadata for one lowered variant.
#[derive(Clone, Debug)]
pub struct VariantMeta {
    pub key: String,
    pub file: String,
    pub pattern: String,
    pub rank: Option<usize>,
    pub inputs: Vec<InputSpec>,
}

/// Parsed `io_manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dims: ModelDims,
    pub variants: BTreeMap<String, VariantMeta>,
    pub train_final_loss: f64,
    pub train_valid_ppl: f64,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `io_manifest.json` from the artifacts directory.
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("io_manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {} — run `make artifacts` first", path.display())
        })?;
        let j = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let c = j.req("config")?;
        let as_usize = |k: &str| -> Result<usize> {
            c.req(k)?.as_usize().with_context(|| format!("config.{k}"))
        };
        let dims = ModelDims {
            vocab: as_usize("vocab")?,
            d_model: as_usize("d_model")?,
            n_layers: as_usize("n_layers")?,
            n_heads: as_usize("n_heads")?,
            ffn: as_usize("ffn")?,
            batch: as_usize("eval_batch")?,
            seq: as_usize("eval_seq")?,
            num_params: as_usize("num_params")?,
            sites: c
                .req("sites")?
                .as_arr()
                .context("config.sites")?
                .iter()
                .map(|s| s.as_str().unwrap_or("").to_string())
                .collect(),
        };
        let mut variants = BTreeMap::new();
        if let Some(Json::Obj(vs)) = j.get("variants") {
            for (key, v) in vs {
                let inputs = v
                    .req("inputs")?
                    .as_arr()
                    .context("variant inputs")?
                    .iter()
                    .map(|i| -> Result<InputSpec> {
                        Ok(InputSpec {
                            name: i.req("name")?.as_str().context("input name")?.to_string(),
                            shape: i
                                .req("shape")?
                                .as_arr()
                                .context("input shape")?
                                .iter()
                                .map(|x| x.as_usize().unwrap_or(0))
                                .collect(),
                            dtype: i
                                .req("dtype")?
                                .as_str()
                                .context("input dtype")?
                                .to_string(),
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                variants.insert(
                    key.clone(),
                    VariantMeta {
                        key: key.clone(),
                        file: v.req("file")?.as_str().context("file")?.to_string(),
                        pattern: v.req("pattern")?.as_str().context("pattern")?.to_string(),
                        rank: v.get("rank").and_then(|r| r.as_usize()),
                        inputs,
                    },
                );
            }
        }
        let train = j.req("train")?;
        Ok(Manifest {
            dims,
            variants,
            train_final_loss: train
                .get("final_loss")
                .and_then(|x| x.as_f64())
                .unwrap_or(f64::NAN),
            train_valid_ppl: train
                .get("valid_ppl")
                .and_then(|x| x.as_f64())
                .unwrap_or(f64::NAN),
            dir: artifacts_dir.to_path_buf(),
        })
    }

    pub fn variant(&self, key: &str) -> Result<&VariantMeta> {
        self.variants.get(key).with_context(|| {
            format!(
                "variant '{key}' not in manifest (have: {})",
                self.variants.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }
}

/// The PJRT client wrapper.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one variant's HLO text.
    pub fn load_variant(&self, manifest: &Manifest, key: &str) -> Result<Arc<Variant>> {
        let meta = manifest.variant(key)?.clone();
        let path = manifest.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {key}: {e:?}"))?;
        Ok(Arc::new(Variant {
            exe,
            meta,
            dims: manifest.dims.clone(),
        }))
    }

    /// Upload an f32 tensor to the device.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let shape: &[usize] = if t.shape.is_empty() { &[] } else { &t.shape };
        self.client
            .buffer_from_host_buffer(&t.data, shape, None)
            .map_err(|e| anyhow::anyhow!("upload: {e:?}"))
    }

    /// Upload an i32 array to the device.
    pub fn upload_i32(&self, data: &[i32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(|e| anyhow::anyhow!("upload i32: {e:?}"))
    }
}

/// A compiled variant executable (unbound).
pub struct Variant {
    exe: xla::PjRtLoadedExecutable,
    pub meta: VariantMeta,
    pub dims: ModelDims,
}

/// Resolves a fixed (non-token) input name to its tensor value.
pub type InputResolver<'a> = dyn Fn(&InputSpec) -> Result<Tensor> + 'a;

impl Variant {
    /// Bind weights + method parameters: resolve and upload every input
    /// after `tokens`/`lens` once. The same variant can be bound many times
    /// (e.g. dense weights vs pruned weights vs quantized weights).
    pub fn bind(self: &Arc<Self>, rt: &Runtime, resolver: &InputResolver) -> Result<Engine> {
        anyhow::ensure!(
            self.meta.inputs.len() >= 2
                && self.meta.inputs[0].name == "tokens"
                && self.meta.inputs[1].name == "lens",
            "variant {} manifest must start with tokens, lens",
            self.meta.key
        );
        let mut fixed = Vec::with_capacity(self.meta.inputs.len() - 2);
        for spec in &self.meta.inputs[2..] {
            let t = resolver(spec).with_context(|| format!("resolving input '{}'", spec.name))?;
            anyhow::ensure!(
                t.shape == spec.shape,
                "input '{}': resolver produced shape {:?}, manifest wants {:?}",
                spec.name,
                t.shape,
                spec.shape
            );
            fixed.push(rt.upload(&t)?);
        }
        Ok(Engine {
            variant: Arc::clone(self),
            fixed,
        })
    }
}

/// Output of one forward execution.
#[derive(Clone, Debug)]
pub struct ForwardOut {
    /// `[batch * seq]` — `tgt_lp[b*T + t]` = log p(token[t+1] | prefix) for
    /// t < T-1 (last column is 0).
    pub tgt_logprobs: Vec<f32>,
    /// `[batch * vocab]` — next-token logits at each row's last valid
    /// position.
    pub last_logits: Vec<f32>,
}

/// A variant bound to weights + method params, ready to serve.
pub struct Engine {
    variant: Arc<Variant>,
    fixed: Vec<xla::PjRtBuffer>,
}

impl Engine {
    pub fn dims(&self) -> &ModelDims {
        &self.variant.dims
    }

    pub fn key(&self) -> &str {
        &self.variant.meta.key
    }

    /// Execute one batch. `tokens` is `[batch * seq]` row-major; `lens` is
    /// `[batch]` valid lengths.
    pub fn run(&self, rt: &Runtime, tokens: &[i32], lens: &[i32]) -> Result<ForwardOut> {
        let d = self.dims().clone();
        anyhow::ensure!(
            tokens.len() == d.batch * d.seq && lens.len() == d.batch,
            "bad batch shape: tokens {} (want {}), lens {} (want {})",
            tokens.len(),
            d.batch * d.seq,
            lens.len(),
            d.batch
        );
        let tok_buf = rt.upload_i32(tokens, &[d.batch, d.seq])?;
        let len_buf = rt.upload_i32(lens, &[d.batch])?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(2 + self.fixed.len());
        args.push(&tok_buf);
        args.push(&len_buf);
        for b in &self.fixed {
            args.push(b);
        }
        let result = self
            .variant
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.key()))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        let (lp, ll) = lit
            .to_tuple2()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        let tgt_logprobs = lp
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("tgt_lp: {e:?}"))?;
        let last_logits = ll
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("last_logits: {e:?}"))?;
        anyhow::ensure!(tgt_logprobs.len() == d.batch * d.seq, "tgt_lp size");
        anyhow::ensure!(last_logits.len() == d.batch * d.vocab, "last_logits size");
        Ok(ForwardOut {
            tgt_logprobs,
            last_logits,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full runtime round-trips are exercised by `rust/tests/` integration
    // tests (they need artifacts); here we test manifest parsing only.

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join(format!("nmsparse-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let text = r#"{
          "config": {"vocab": 160, "d_model": 64, "n_layers": 2, "n_heads": 2,
                     "ffn": 128, "eval_batch": 4, "eval_seq": 16,
                     "num_params": 1000, "sites": ["q","k"]},
          "train": {"final_loss": 0.5, "valid_ppl": 1.7, "steps": 10},
          "variants": {
            "dense": {"file": "model_dense.hlo.txt", "pattern": "dense", "rank": null,
              "inputs": [
                {"name": "tokens", "shape": [4, 16], "dtype": "i32"},
                {"name": "lens", "shape": [4], "dtype": "i32"},
                {"name": "w.embed.w", "shape": [160, 64], "dtype": "f32"}
              ]}
          }
        }"#;
        std::fs::write(dir.join("io_manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.dims.vocab, 160);
        assert_eq!(m.dims.batch, 4);
        let v = m.variant("dense").unwrap();
        assert_eq!(v.inputs.len(), 3);
        assert_eq!(v.inputs[2].name, "w.embed.w");
        assert_eq!(v.inputs[2].elements(), 160 * 64);
        assert!(m.variant("nope").is_err());
        assert!((m.train_valid_ppl - 1.7).abs() < 1e-9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_missing_dir_errors() {
        let m = Manifest::load(Path::new("/definitely/not/here"));
        assert!(m.is_err());
    }
}
