//! Int8 weight quantization baseline (Appendix E / Table 14 comparator).
//!
//! Per-output-channel symmetric int8 quantization with round-to-nearest.
//! This is the retraining-free analog of the paper's 8-bit comparison row;
//! it quantizes the checkpoint rust-side and runs through the dense HLO
//! artifact (weights are dequantized to f32 on load — we measure the
//! *accuracy* effect of quantization, as the paper does, not kernel speed).

use crate::metadata::MaskCodec;
use crate::sparsity::pipeline::{Scratch, Sparsifier};
use crate::sparsity::PackedNM;
use crate::util::tensor::{Tensor, TensorStore};
use anyhow::Result;

/// Quantization statistics for reporting.
#[derive(Clone, Debug, Default)]
pub struct QuantStats {
    pub tensors: usize,
    pub params: usize,
    pub max_abs_err: f64,
    pub mean_abs_err: f64,
    pub compressed_bytes: usize,
    pub original_bytes: usize,
    /// Bytes of the packed sparse+quant representation (kept values at the
    /// quantized width, *measured* combinadic metadata, dense tails and
    /// per-row scales) — populated when `quantize_store_with` ran with a
    /// selection-only sparsifier and packed each tensor post-prune.
    pub packed_bytes: usize,
}

impl QuantStats {
    pub fn compression_ratio(&self) -> f64 {
        if self.compressed_bytes == 0 {
            return 0.0;
        }
        self.original_bytes as f64 / self.compressed_bytes as f64
    }

    /// original / packed — what dense f32 shrinks to once pruning's zeros
    /// stop being stored at all (vs [`QuantStats::compression_ratio`],
    /// which still pays for them at the quantized width).
    pub fn sparse_compression_ratio(&self) -> f64 {
        if self.packed_bytes == 0 {
            return 0.0;
        }
        self.original_bytes as f64 / self.packed_bytes as f64
    }
}

/// Fake-quantize one row in place; returns (scale, max abs err over the row).
#[inline]
fn fake_quant_row(row: &mut [f32], qmax: f32) -> (f32, f64) {
    let amax = row.iter().fold(0.0f32, |a, x| a.max(x.abs()));
    let scale = if amax == 0.0 { 1.0 } else { amax / qmax };
    let mut max_err = 0.0f64;
    for v in row.iter_mut() {
        let q = (*v / scale).round().clamp(-qmax - 1.0, qmax);
        let deq = q * scale;
        max_err = max_err.max((deq - *v).abs() as f64);
        *v = deq;
    }
    (scale, max_err)
}

/// Quantize one `[out, in]` weight matrix to int8 per-output-channel and
/// immediately dequantize (fake-quant). Returns (per-channel scales, max err).
pub fn fake_quant_int8(w: &mut Tensor, bits: u32) -> (Vec<f32>, f64) {
    assert!(w.rank() == 2, "fake_quant_int8 expects 2-D weights");
    assert!((2..=8).contains(&bits));
    let qmax = ((1i32 << (bits - 1)) - 1) as f32; // e.g. 127 for int8
    let rows = w.rows();
    let mut scales = Vec::with_capacity(rows);
    let mut max_err = 0.0f64;
    for r in 0..rows {
        let (scale, err) = fake_quant_row(w.row_mut(r), qmax);
        max_err = max_err.max(err);
        scales.push(scale);
    }
    (scales, max_err)
}

/// Fake-quantize every prunable linear weight in the checkpoint.
pub fn quantize_store(store: &mut TensorStore, bits: u32) -> Result<QuantStats> {
    quantize_store_with(store, bits, None)
}

/// Fused weight transform: optionally run the [`Sparsifier`] over every
/// prunable row and fake-quantize it in the same sweep (the WT+quant combo
/// baseline — prune and quantize touch each row once instead of two
/// allocating store passes). `mean_abs_err`/`max_abs_err` measure the
/// quantization step only, relative to the (possibly sparsified) row.
///
/// Like `weightprune`, N:M rows whose width is not a multiple of M keep a
/// dense tail; unstructured sparsifiers here are *per-row* top-k (the
/// weight-side global-threshold variant does not fuse — use
/// `weightprune::prune_weights` followed by [`quantize_store`] for that).
pub fn quantize_store_with(
    store: &mut TensorStore,
    bits: u32,
    sparsifier: Option<&Sparsifier>,
) -> Result<QuantStats> {
    assert!((2..=8).contains(&bits));
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let names = crate::sparsity::weightprune::prunable_weight_names(store);
    let mut stats = QuantStats::default();
    let mut abs_err_sum = 0.0f64;
    let mut scratch = Scratch::new();
    let mut pre_quant: Vec<f32> = Vec::new();
    // Pack post-prune: the quantized sparse rows re-emitted as a PackedNM
    // stream so `packed_bytes` reports the *measured* compressed footprint.
    // Re-selection uses plain magnitude (not the caller's criterion, whose
    // channel scale could zero-score a surviving value): top-|q| keeps
    // every nonzero of an already-pruned row, so the stream decodes to
    // exactly the stored dense row.
    let pack_sp = sparsifier
        .filter(|sp| sp.is_packable())
        .map(|sp| Sparsifier::new(sp.pattern()));
    for name in &names {
        let t = store.get_mut(name)?;
        let (rows, cols) = (t.rows(), t.cols());
        // Dense-tail guard, mirroring weightprune::prune_tensor_rows.
        let sparsify_cols = match sparsifier.map(|sp| sp.pattern()) {
            Some(crate::sparsity::Pattern::NM { m, .. }) => cols - cols % m as usize,
            _ => cols,
        };
        let mut packed = match &pack_sp {
            Some(ps) if sparsify_cols > 0 => Some(PackedNM::new(ps.pattern(), sparsify_cols)),
            _ => None,
        };
        for r in 0..rows {
            let row = t.row_mut(r);
            if let Some(sp) = sparsifier {
                if sparsify_cols > 0 {
                    sp.sparsify_row(&mut row[..sparsify_cols], &mut scratch);
                }
            }
            pre_quant.clear();
            pre_quant.extend_from_slice(row);
            let (_scale, err) = fake_quant_row(row, qmax);
            stats.max_abs_err = stats.max_abs_err.max(err);
            abs_err_sum += row
                .iter()
                .zip(&pre_quant)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>();
            if let Some(p) = packed.as_mut() {
                pack_sp.as_ref().unwrap().pack_row_into(&row[..sparsify_cols], p, &mut scratch);
            }
        }
        stats.tensors += 1;
        stats.params += rows * cols;
        stats.original_bytes += rows * cols * 4;
        stats.compressed_bytes += rows * cols * (bits as usize) / 8 + rows * 4;
        if let Some(p) = &packed {
            let values_bytes = p.values().len() * bits as usize / 8;
            let meta_bytes = (p.encoded_metadata_bits(MaskCodec::Combinadic) + 7) / 8;
            let tail_bytes = rows * (cols - sparsify_cols) * bits as usize / 8;
            stats.packed_bytes += values_bytes + meta_bytes + tail_bytes + rows * 4;
        }
    }
    stats.mean_abs_err = if stats.params > 0 {
        abs_err_sum / stats.params as f64
    } else {
        0.0
    };
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_w(rng: &mut Rng, o: usize, i: usize) -> Tensor {
        Tensor::from_vec(&[o, i], (0..o * i).map(|_| rng.normal() as f32 * 0.1).collect())
    }

    #[test]
    fn int8_error_bounded_by_half_step() {
        let mut rng = Rng::new(1);
        let mut w = rand_w(&mut rng, 16, 64);
        let orig = w.clone();
        let (scales, max_err) = fake_quant_int8(&mut w, 8);
        assert_eq!(scales.len(), 16);
        for r in 0..16 {
            let bound = scales[r] as f64 * 0.5 + 1e-7;
            for (a, b) in w.row(r).iter().zip(orig.row(r)) {
                assert!(((a - b).abs() as f64) <= bound);
            }
        }
        assert!(max_err > 0.0);
    }

    #[test]
    fn quant_idempotent() {
        let mut rng = Rng::new(2);
        let mut w = rand_w(&mut rng, 8, 32);
        fake_quant_int8(&mut w, 8);
        let once = w.clone();
        fake_quant_int8(&mut w, 8);
        assert!(w.max_abs_diff(&once) < 1e-6, "quantizing twice is stable");
    }

    #[test]
    fn lower_bits_more_error() {
        let mut rng = Rng::new(3);
        let base = rand_w(&mut rng, 8, 128);
        let mut w8 = base.clone();
        let mut w4 = base.clone();
        let (_, e8) = fake_quant_int8(&mut w8, 8);
        let (_, e4) = fake_quant_int8(&mut w4, 4);
        assert!(e4 > e8 * 4.0, "4-bit err {e4} vs 8-bit err {e8}");
    }

    #[test]
    fn zero_row_safe() {
        let mut w = Tensor::from_vec(&[1, 4], vec![0.0; 4]);
        let (scales, err) = fake_quant_int8(&mut w, 8);
        assert_eq!(scales, vec![1.0]);
        assert_eq!(err, 0.0);
        assert_eq!(w.data, vec![0.0; 4]);
    }

    #[test]
    fn fused_sparse_quant_matches_sequential() {
        use crate::sparsity::Pattern;
        let mut rng = Rng::new(7);
        let mut seq = TensorStore::new();
        seq.insert("layers.0.q.w", rand_w(&mut rng, 8, 32));
        seq.insert("layers.1.down.w", rand_w(&mut rng, 16, 16));
        // Width not a multiple of M: the last 2 columns keep a dense tail.
        seq.insert("layers.2.odd.w", rand_w(&mut rng, 4, 10));
        let mut fused = seq.clone();
        let pattern = Pattern::NM { n: 2, m: 4 };
        // Sequential: two store passes.
        crate::sparsity::weightprune::prune_weights(&mut seq, pattern).unwrap();
        quantize_store(&mut seq, 8).unwrap();
        // Fused: one pass per row.
        let sp = Sparsifier::new(pattern);
        let stats = quantize_store_with(&mut fused, 8, Some(&sp)).unwrap();
        assert_eq!(stats.tensors, 3);
        for name in ["layers.0.q.w", "layers.1.down.w", "layers.2.odd.w"] {
            assert_eq!(fused.get(name).unwrap(), seq.get(name).unwrap(), "{name}");
        }
        // Block-aligned tensors stay N:M sparse after quantization (zeros
        // quantize to zero).
        for name in ["layers.0.q.w", "layers.1.down.w"] {
            for r in 0..fused.get(name).unwrap().rows() {
                assert!(crate::sparsity::nm::satisfies_nm(
                    fused.get(name).unwrap().row(r),
                    2,
                    4
                ));
            }
        }
    }

    #[test]
    fn packed_accounting_reflects_sparse_storage() {
        use crate::sparsity::{Pattern, Scratch};
        let mut rng = Rng::new(8);
        let mut s = TensorStore::new();
        s.insert("layers.0.q.w", rand_w(&mut rng, 16, 64));
        s.insert("layers.2.odd.w", rand_w(&mut rng, 4, 10)); // dense tail of 2
        let sp = Sparsifier::new(Pattern::NM { n: 2, m: 4 });
        let stats = quantize_store_with(&mut s, 8, Some(&sp)).unwrap();
        // Packed: half the values at int8 + ~3 bits/block metadata — well
        // under the dense-int8 footprint, well over nothing.
        assert!(stats.packed_bytes > 0);
        assert!(
            stats.packed_bytes < stats.compressed_bytes,
            "{} vs {}",
            stats.packed_bytes,
            stats.compressed_bytes
        );
        assert!(stats.sparse_compression_ratio() > stats.compression_ratio());
        // Re-packing the stored (quantized) rows reconstructs them exactly:
        // selection on the quantized row keeps every nonzero.
        let t = s.get("layers.0.q.w").unwrap();
        let mut packed = crate::sparsity::PackedNM::new(sp.pattern(), 64);
        let mut scratch = Scratch::new();
        sp.pack(t, &mut packed, &mut scratch);
        assert_eq!(packed.to_dense().data, t.data);
        // Without a sparsifier there is nothing to pack.
        let mut dense_store = TensorStore::new();
        dense_store.insert("layers.0.q.w", rand_w(&mut rng, 8, 16));
        let dense_stats = quantize_store(&mut dense_store, 8).unwrap();
        assert_eq!(dense_stats.packed_bytes, 0);
        assert_eq!(dense_stats.sparse_compression_ratio(), 0.0);
    }

    #[test]
    fn store_quantization_stats() {
        let mut rng = Rng::new(4);
        let mut s = TensorStore::new();
        s.insert("layers.0.q.w", rand_w(&mut rng, 16, 16));
        s.insert("layers.0.gate.w", rand_w(&mut rng, 16, 16));
        s.insert("embed.w", rand_w(&mut rng, 4, 4)); // untouched
        let stats = quantize_store(&mut s, 8).unwrap();
        assert_eq!(stats.tensors, 2);
        assert_eq!(stats.params, 512);
        assert!(stats.compression_ratio() > 3.0); // ~4x minus scale overhead
        assert!(stats.mean_abs_err > 0.0);
    }
}
