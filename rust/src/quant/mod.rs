//! Int8 weight quantization baseline (Appendix E / Table 14 comparator).
//!
//! Per-output-channel symmetric int8 quantization with round-to-nearest.
//! This is the retraining-free analog of the paper's 8-bit comparison row;
//! it quantizes the checkpoint rust-side and runs through the dense HLO
//! artifact (weights are dequantized to f32 on load — we measure the
//! *accuracy* effect of quantization, as the paper does, not kernel speed).

use crate::util::tensor::{Tensor, TensorStore};
use anyhow::Result;

/// Quantization statistics for reporting.
#[derive(Clone, Debug, Default)]
pub struct QuantStats {
    pub tensors: usize,
    pub params: usize,
    pub max_abs_err: f64,
    pub mean_abs_err: f64,
    pub compressed_bytes: usize,
    pub original_bytes: usize,
}

impl QuantStats {
    pub fn compression_ratio(&self) -> f64 {
        if self.compressed_bytes == 0 {
            return 0.0;
        }
        self.original_bytes as f64 / self.compressed_bytes as f64
    }
}

/// Quantize one `[out, in]` weight matrix to int8 per-output-channel and
/// immediately dequantize (fake-quant). Returns (per-channel scales, max err).
pub fn fake_quant_int8(w: &mut Tensor, bits: u32) -> (Vec<f32>, f64) {
    assert!(w.rank() == 2, "fake_quant_int8 expects 2-D weights");
    assert!((2..=8).contains(&bits));
    let qmax = ((1i32 << (bits - 1)) - 1) as f32; // e.g. 127 for int8
    let rows = w.rows();
    let mut scales = Vec::with_capacity(rows);
    let mut max_err = 0.0f64;
    for r in 0..rows {
        let row = w.row_mut(r);
        let amax = row.iter().fold(0.0f32, |a, x| a.max(x.abs()));
        let scale = if amax == 0.0 { 1.0 } else { amax / qmax };
        for v in row.iter_mut() {
            let q = (*v / scale).round().clamp(-qmax - 1.0, qmax);
            let deq = q * scale;
            max_err = max_err.max((deq - *v).abs() as f64);
            *v = deq;
        }
        scales.push(scale);
    }
    (scales, max_err)
}

/// Fake-quantize every prunable linear weight in the checkpoint.
pub fn quantize_store(store: &mut TensorStore, bits: u32) -> Result<QuantStats> {
    let names = crate::sparsity::weightprune::prunable_weight_names(store);
    let mut stats = QuantStats::default();
    let mut abs_err_sum = 0.0f64;
    for name in &names {
        let t = store.get_mut(name)?;
        let before: Vec<f32> = t.data.clone();
        let (scales, max_err) = fake_quant_int8(t, bits);
        stats.tensors += 1;
        stats.params += t.len();
        stats.max_abs_err = stats.max_abs_err.max(max_err);
        abs_err_sum += t
            .data
            .iter()
            .zip(&before)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>();
        stats.original_bytes += t.len() * 4;
        stats.compressed_bytes += t.len() * (bits as usize) / 8 + scales.len() * 4;
    }
    stats.mean_abs_err = if stats.params > 0 {
        abs_err_sum / stats.params as f64
    } else {
        0.0
    };
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_w(rng: &mut Rng, o: usize, i: usize) -> Tensor {
        Tensor::from_vec(&[o, i], (0..o * i).map(|_| rng.normal() as f32 * 0.1).collect())
    }

    #[test]
    fn int8_error_bounded_by_half_step() {
        let mut rng = Rng::new(1);
        let mut w = rand_w(&mut rng, 16, 64);
        let orig = w.clone();
        let (scales, max_err) = fake_quant_int8(&mut w, 8);
        assert_eq!(scales.len(), 16);
        for r in 0..16 {
            let bound = scales[r] as f64 * 0.5 + 1e-7;
            for (a, b) in w.row(r).iter().zip(orig.row(r)) {
                assert!(((a - b).abs() as f64) <= bound);
            }
        }
        assert!(max_err > 0.0);
    }

    #[test]
    fn quant_idempotent() {
        let mut rng = Rng::new(2);
        let mut w = rand_w(&mut rng, 8, 32);
        fake_quant_int8(&mut w, 8);
        let once = w.clone();
        fake_quant_int8(&mut w, 8);
        assert!(w.max_abs_diff(&once) < 1e-6, "quantizing twice is stable");
    }

    #[test]
    fn lower_bits_more_error() {
        let mut rng = Rng::new(3);
        let base = rand_w(&mut rng, 8, 128);
        let mut w8 = base.clone();
        let mut w4 = base.clone();
        let (_, e8) = fake_quant_int8(&mut w8, 8);
        let (_, e4) = fake_quant_int8(&mut w4, 4);
        assert!(e4 > e8 * 4.0, "4-bit err {e4} vs 8-bit err {e8}");
    }

    #[test]
    fn zero_row_safe() {
        let mut w = Tensor::from_vec(&[1, 4], vec![0.0; 4]);
        let (scales, err) = fake_quant_int8(&mut w, 8);
        assert_eq!(scales, vec![1.0]);
        assert_eq!(err, 0.0);
        assert_eq!(w.data, vec![0.0; 4]);
    }

    #[test]
    fn store_quantization_stats() {
        let mut rng = Rng::new(4);
        let mut s = TensorStore::new();
        s.insert("layers.0.q.w", rand_w(&mut rng, 16, 16));
        s.insert("layers.0.gate.w", rand_w(&mut rng, 16, 16));
        s.insert("embed.w", rand_w(&mut rng, 4, 4)); // untouched
        let stats = quantize_store(&mut s, 8).unwrap();
        assert_eq!(stats.tensors, 2);
        assert_eq!(stats.params, 512);
        assert!(stats.compression_ratio() > 3.0); // ~4x minus scale overhead
        assert!(stats.mean_abs_err > 0.0);
    }
}
