//! Length-prefixed compact binary framing (DESIGN.md §2.15).
//!
//! Frame grammar (all integers little-endian):
//!
//! ```text
//! frame    := u32 len | u8 tag | body          -- len covers tag + body
//! str      := u32 n | n UTF-8 bytes
//! opt_str  := u8 present | str?                -- present in {0, 1}
//! toks     := u32 n | n x u32
//! ```
//!
//! Request tags: `0x01` ping, `0x02` stats, `0x03` score, `0x04` generate,
//! `0x05` score_tokens, `0x06` generate_tokens. Reply tags: `0x81` blob
//! (JSON payload verbatim — stats/ping are cold-path), `0x82` score,
//! `0x83` generate, `0x84` chunk, `0x85` end, `0x86` error.
//!
//! A connection opens with a 6-byte hello (`NMSW` magic + u16 version) so
//! a JSON client talking to a binary port fails loudly instead of
//! garbling. Malformed frames are rejected frame-local: the decoder
//! reports how many bytes to skip (the whole delimited frame) and the
//! connection keeps serving — only a frame too corrupt to delimit (bad
//! length prefix) forfeits resynchronization.

use super::codec::{Codec, DecodeResult, FrameError, StreamOutcome, WireReply, WireRequest};
use crate::util::json::{self, Json};

pub const MAGIC: [u8; 4] = *b"NMSW";
pub const VERSION: u16 = 1;
pub const HELLO_LEN: usize = 6;

/// Frames larger than this are rejected before allocation — nothing the
/// protocol carries legitimately approaches it.
pub const MAX_FRAME: usize = 1 << 24;

const TAG_PING: u8 = 0x01;
const TAG_STATS: u8 = 0x02;
const TAG_SCORE: u8 = 0x03;
const TAG_GENERATE: u8 = 0x04;
const TAG_SCORE_TOKENS: u8 = 0x05;
const TAG_GENERATE_TOKENS: u8 = 0x06;
const TAG_BLOB: u8 = 0x81;
const TAG_R_SCORE: u8 = 0x82;
const TAG_R_GENERATE: u8 = 0x83;
const TAG_CHUNK: u8 = 0x84;
const TAG_END: u8 = 0x85;
const TAG_ERROR: u8 = 0x86;

const FLAG_STREAM: u8 = 0x01;
const FLAG_MAX_NEW: u8 = 0x02;

/// The 6-byte connect preamble a binary client must send first.
pub fn hello() -> [u8; HELLO_LEN] {
    let mut h = [0u8; HELLO_LEN];
    h[..4].copy_from_slice(&MAGIC);
    h[4..].copy_from_slice(&VERSION.to_le_bytes());
    h
}

/// Validate a peer's hello. The error string is sent back as the final
/// frame before the server closes the connection.
pub fn check_hello(buf: &[u8]) -> Result<(), String> {
    if buf.len() < HELLO_LEN {
        return Err(format!("short hello ({} of {HELLO_LEN} bytes)", buf.len()));
    }
    if buf[..4] != MAGIC {
        return Err("bad magic (expected NMSW)".to_string());
    }
    let peer = u16::from_le_bytes([buf[4], buf[5]]);
    if peer != VERSION {
        return Err(format!("codec version mismatch: peer {peer}, host {VERSION}"));
    }
    Ok(())
}

// ---- encoding ------------------------------------------------------------

struct FrameWriter {
    buf: Vec<u8>,
}

impl FrameWriter {
    fn new(tag: u8) -> FrameWriter {
        // Length placeholder patched in finish().
        let mut buf = vec![0u8; 4];
        buf.push(tag);
        FrameWriter { buf }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn opt_str(&mut self, s: &Option<String>) {
        match s {
            None => self.u8(0),
            Some(s) => {
                self.u8(1);
                self.str(s);
            }
        }
    }

    fn toks(&mut self, ts: &[u32]) {
        self.u32(ts.len() as u32);
        for t in ts {
            self.u32(*t);
        }
    }

    fn finish(mut self, out: &mut Vec<u8>) {
        let len = (self.buf.len() - 4) as u32;
        self.buf[..4].copy_from_slice(&len.to_le_bytes());
        out.extend_from_slice(&self.buf);
    }
}

// ---- decoding ------------------------------------------------------------

struct FrameReader<'a> {
    body: &'a [u8],
    pos: usize,
}

impl<'a> FrameReader<'a> {
    fn u8(&mut self) -> Result<u8, String> {
        let v = *self.body.get(self.pos).ok_or("truncated frame body")?;
        self.pos += 1;
        Ok(v)
    }

    fn u32(&mut self) -> Result<u32, String> {
        let end = self.pos + 4;
        let b = self.body.get(self.pos..end).ok_or("truncated frame body")?;
        self.pos = end;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f64(&mut self) -> Result<f64, String> {
        let end = self.pos + 8;
        let b = self.body.get(self.pos..end).ok_or("truncated frame body")?;
        self.pos = end;
        let mut raw = [0u8; 8];
        raw.copy_from_slice(b);
        Ok(f64::from_bits(u64::from_le_bytes(raw)))
    }

    fn str(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        let end = self.pos + n;
        let b = self.body.get(self.pos..end).ok_or("truncated string")?;
        self.pos = end;
        String::from_utf8(b.to_vec()).map_err(|_| "invalid utf8 in string".to_string())
    }

    fn opt_str(&mut self) -> Result<Option<String>, String> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.str()?)),
            t => Err(format!("bad option tag {t}")),
        }
    }

    fn toks(&mut self) -> Result<Vec<u32>, String> {
        let n = self.u32()? as usize;
        if n > self.body.len().saturating_sub(self.pos) / 4 {
            return Err("token count exceeds frame".to_string());
        }
        (0..n).map(|_| self.u32()).collect()
    }

    fn done(&self) -> Result<(), String> {
        if self.pos == self.body.len() {
            Ok(())
        } else {
            Err(format!("{} trailing bytes in frame", self.body.len() - self.pos))
        }
    }
}

/// Delimit one frame: `Ok(None)` = need more bytes; `Ok(Some((tag, body,
/// consumed)))` = one whole frame; `Err` = unrecoverable length prefix.
fn delimit(buf: &[u8]) -> Result<Option<(u8, &[u8], usize)>, FrameError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if len == 0 || len > MAX_FRAME {
        // Nothing to resynchronize on — skip the prefix and let the caller
        // decide whether the connection is worth keeping.
        return Err(FrameError {
            consumed: 4,
            message: format!("bad frame length {len}"),
        });
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    Ok(Some((buf[4], &buf[5..4 + len], 4 + len)))
}

fn decode_with<T>(
    buf: &[u8],
    parse: impl FnOnce(u8, &mut FrameReader<'_>) -> Result<T, String>,
) -> DecodeResult<T> {
    let Some((tag, body, consumed)) = delimit(buf)? else {
        return Ok(None);
    };
    let mut r = FrameReader { body, pos: 0 };
    match parse(tag, &mut r).and_then(|v| r.done().map(|()| v)) {
        Ok(v) => Ok(Some((v, consumed))),
        Err(message) => Err(FrameError { consumed, message }),
    }
}

pub struct BinaryCodec;

impl Codec for BinaryCodec {
    fn name(&self) -> &'static str {
        "binary"
    }

    fn encode_request(&self, req: &WireRequest, out: &mut Vec<u8>) {
        match req {
            WireRequest::Ping => FrameWriter::new(TAG_PING).finish(out),
            WireRequest::Stats => FrameWriter::new(TAG_STATS).finish(out),
            WireRequest::Score { text, choice, tenant } => {
                let mut w = FrameWriter::new(TAG_SCORE);
                w.opt_str(tenant);
                w.str(text);
                w.str(choice);
                w.finish(out);
            }
            WireRequest::Generate { text, max_new, tenant, stream } => {
                let mut w = FrameWriter::new(TAG_GENERATE);
                let mut flags = 0u8;
                if *stream {
                    flags |= FLAG_STREAM;
                }
                if max_new.is_some() {
                    flags |= FLAG_MAX_NEW;
                }
                w.u8(flags);
                w.u32(max_new.unwrap_or(0) as u32);
                w.opt_str(tenant);
                w.str(text);
                w.finish(out);
            }
            WireRequest::ScoreTokens { tokens, span, tenant } => {
                let mut w = FrameWriter::new(TAG_SCORE_TOKENS);
                w.u32(*tenant);
                w.u32(span.0);
                w.u32(span.1);
                w.toks(tokens);
                w.finish(out);
            }
            WireRequest::GenerateTokens { tokens, max_new, tenant, stream } => {
                let mut w = FrameWriter::new(TAG_GENERATE_TOKENS);
                w.u32(*tenant);
                w.u8(if *stream { FLAG_STREAM } else { 0 });
                w.u32(*max_new);
                w.toks(tokens);
                w.finish(out);
            }
        }
    }

    fn encode_reply(&self, rep: &WireReply, out: &mut Vec<u8>) {
        match rep {
            WireReply::Blob(j) => {
                let mut w = FrameWriter::new(TAG_BLOB);
                w.str(&j.dump());
                w.finish(out);
            }
            WireReply::Score { score } => {
                let mut w = FrameWriter::new(TAG_R_SCORE);
                w.f64(*score);
                w.finish(out);
            }
            WireReply::Generate { tokens, text } => {
                let mut w = FrameWriter::new(TAG_R_GENERATE);
                w.toks(tokens);
                w.str(text);
                w.finish(out);
            }
            WireReply::Chunk { index, token } => {
                let mut w = FrameWriter::new(TAG_CHUNK);
                w.u32(*index);
                w.u32(*token);
                w.finish(out);
            }
            WireReply::End { outcome, tokens, text } => {
                let mut w = FrameWriter::new(TAG_END);
                w.u8(match outcome {
                    StreamOutcome::End => 0,
                    StreamOutcome::Timeout => 1,
                    StreamOutcome::ReplicaFailed => 2,
                });
                w.toks(tokens);
                w.str(text);
                w.finish(out);
            }
            WireReply::Error { message } => {
                let mut w = FrameWriter::new(TAG_ERROR);
                w.str(message);
                w.finish(out);
            }
        }
    }

    fn decode_request(&self, buf: &[u8]) -> DecodeResult<WireRequest> {
        decode_with(buf, |tag, r| match tag {
            TAG_PING => Ok(WireRequest::Ping),
            TAG_STATS => Ok(WireRequest::Stats),
            TAG_SCORE => {
                let tenant = r.opt_str()?;
                let text = r.str()?;
                let choice = r.str()?;
                Ok(WireRequest::Score { text, choice, tenant })
            }
            TAG_GENERATE => {
                let flags = r.u8()?;
                let raw_max = r.u32()?;
                let tenant = r.opt_str()?;
                let text = r.str()?;
                let max_new = (flags & FLAG_MAX_NEW != 0).then_some(raw_max as usize);
                Ok(WireRequest::Generate {
                    text,
                    max_new,
                    tenant,
                    stream: flags & FLAG_STREAM != 0,
                })
            }
            TAG_SCORE_TOKENS => {
                let tenant = r.u32()?;
                let span = (r.u32()?, r.u32()?);
                let tokens = r.toks()?;
                Ok(WireRequest::ScoreTokens { tokens, span, tenant })
            }
            TAG_GENERATE_TOKENS => {
                let tenant = r.u32()?;
                let flags = r.u8()?;
                let max_new = r.u32()?;
                let tokens = r.toks()?;
                Ok(WireRequest::GenerateTokens {
                    tokens,
                    max_new,
                    tenant,
                    stream: flags & FLAG_STREAM != 0,
                })
            }
            t => Err(format!("unknown request tag 0x{t:02x}")),
        })
    }

    fn decode_reply(&self, buf: &[u8]) -> DecodeResult<WireReply> {
        decode_with(buf, |tag, r| match tag {
            TAG_BLOB => {
                let raw = r.str()?;
                let j = json::parse(&raw).map_err(|e| format!("bad blob payload: {e}"))?;
                Ok(WireReply::Blob(j))
            }
            TAG_R_SCORE => Ok(WireReply::Score { score: r.f64()? }),
            TAG_R_GENERATE => {
                let tokens = r.toks()?;
                let text = r.str()?;
                Ok(WireReply::Generate { tokens, text })
            }
            TAG_CHUNK => Ok(WireReply::Chunk { index: r.u32()?, token: r.u32()? }),
            TAG_END => {
                let outcome = match r.u8()? {
                    0 => StreamOutcome::End,
                    1 => StreamOutcome::Timeout,
                    2 => StreamOutcome::ReplicaFailed,
                    t => return Err(format!("bad outcome tag {t}")),
                };
                let tokens = r.toks()?;
                let text = r.str()?;
                Ok(WireReply::End { outcome, tokens, text })
            }
            TAG_ERROR => Ok(WireReply::Error { message: r.str()? }),
            t => Err(format!("unknown reply tag 0x{t:02x}")),
        })
    }
}
