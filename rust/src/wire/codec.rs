//! Codec-neutral message model for the serving wire protocol.
//!
//! A [`Codec`] turns [`WireRequest`] / [`WireReply`] values into transport
//! bytes and back, incrementally: `decode_*` consumes from a growing byte
//! buffer and either yields one message plus the byte count it consumed,
//! reports that more bytes are needed, or rejects a malformed frame while
//! telling the caller how many bytes to skip so the connection survives.
//!
//! Two implementations exist (DESIGN.md §2.15): [`super::json::JsonCodec`]
//! — the newline-delimited JSON protocol serve has always spoken, kept as
//! the default and as the compatibility oracle — and
//! [`super::binary::BinaryCodec`], a length-prefixed compact framing for
//! token streaming at serving scale.

use crate::util::json::Json;

/// One client -> server message.
///
/// `Score`/`Generate` mirror the original text-level JSON ops byte-for-byte;
/// the `*Tokens` twins carry raw token ids for clients that already hold the
/// vocab (loadgen, tests) and for the codec-equivalence harness.
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    Ping,
    Stats,
    Score {
        text: String,
        choice: String,
        tenant: Option<String>,
    },
    Generate {
        text: String,
        max_new: Option<usize>,
        tenant: Option<String>,
        stream: bool,
    },
    ScoreTokens {
        tokens: Vec<u32>,
        span: (u32, u32),
        tenant: u32,
    },
    GenerateTokens {
        tokens: Vec<u32>,
        max_new: u32,
        tenant: u32,
        stream: bool,
    },
}

/// Terminal-frame taxonomy for a streamed generate — the PR 7 outcome set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamOutcome {
    End,
    Timeout,
    ReplicaFailed,
}

impl StreamOutcome {
    pub fn as_str(self) -> &'static str {
        match self {
            StreamOutcome::End => "end",
            StreamOutcome::Timeout => "timeout",
            StreamOutcome::ReplicaFailed => "replica_failed",
        }
    }

    pub fn parse(s: &str) -> Option<StreamOutcome> {
        match s {
            "end" => Some(StreamOutcome::End),
            "timeout" => Some(StreamOutcome::Timeout),
            "replica_failed" => Some(StreamOutcome::ReplicaFailed),
            _ => None,
        }
    }
}

/// One server -> client frame.
#[derive(Clone, Debug, PartialEq)]
pub enum WireReply {
    /// Prebuilt JSON object passed through verbatim (ping banner, stats op).
    /// The JSON codec dumps it unchanged — this is what keeps the default
    /// codec byte-identical to the historical protocol.
    Blob(Json),
    Score {
        score: f64,
    },
    Generate {
        tokens: Vec<u32>,
        text: String,
    },
    /// Incremental streamed token. Best-effort under backpressure; the
    /// terminal `End` frame is the authoritative transcript.
    Chunk {
        index: u32,
        token: u32,
    },
    /// Terminal frame of a streamed generate.
    End {
        outcome: StreamOutcome,
        tokens: Vec<u32>,
        text: String,
    },
    Error {
        message: String,
    },
}

/// A frame the decoder rejected. `consumed` is how many buffer bytes the
/// caller must drop to resynchronize — the connection itself stays usable.
#[derive(Clone, Debug, PartialEq)]
pub struct FrameError {
    pub consumed: usize,
    pub message: String,
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for FrameError {}

/// `Ok(Some((msg, consumed)))` — one message decoded; `Ok(None)` — need
/// more bytes; `Err(e)` — malformed frame, skip `e.consumed` bytes.
pub type DecodeResult<T> = Result<Option<(T, usize)>, FrameError>;

pub trait Codec: Send + Sync {
    fn name(&self) -> &'static str;
    fn encode_request(&self, req: &WireRequest, out: &mut Vec<u8>);
    fn encode_reply(&self, rep: &WireReply, out: &mut Vec<u8>);
    fn decode_request(&self, buf: &[u8]) -> DecodeResult<WireRequest>;
    fn decode_reply(&self, buf: &[u8]) -> DecodeResult<WireReply>;
}

/// Which codec a connection speaks. Parsed from `--codec`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecKind {
    Json,
    Binary,
}

impl CodecKind {
    pub fn parse(s: &str) -> Option<CodecKind> {
        match s {
            "json" => Some(CodecKind::Json),
            "binary" => Some(CodecKind::Binary),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            CodecKind::Json => "json",
            CodecKind::Binary => "binary",
        }
    }

    pub fn codec(self) -> &'static dyn Codec {
        match self {
            CodecKind::Json => &super::json::JsonCodec,
            CodecKind::Binary => &super::binary::BinaryCodec,
        }
    }
}
