//! Wire subsystem: codecs, framing, and streamed-token lanes for the
//! serving front (DESIGN.md §2.15).
//!
//! The [`codec::Codec`] trait abstracts the transport encoding behind
//! serve/loadgen. Two implementations ship: [`json::JsonCodec`] — the
//! historical newline-delimited JSON protocol, kept as the default and
//! as the compatibility oracle — and [`binary::BinaryCodec`], a
//! length-prefixed compact framing with a versioned connect handshake.
//! [`stream`] provides the bounded per-session lanes that carry
//! incremental tokens from the replica tick loop to a streaming client
//! without ever letting a slow socket stall decode.

pub mod binary;
pub mod codec;
pub mod json;
pub mod stream;

pub use codec::{Codec, CodecKind, DecodeResult, FrameError, StreamOutcome, WireReply, WireRequest};
pub use stream::{stream_channel, StreamPoll, StreamReceiver, StreamSender, LANE_CAP};
