//! The newline-delimited JSON codec — the protocol `nmsparse serve` has
//! always spoken, now behind the [`Codec`] trait.
//!
//! This impl is the compatibility oracle (DESIGN.md §2.15): for the ops
//! that existed before the wire subsystem (`ping`/`stats`/`score`/
//! `generate`, buffered replies) it produces byte-identical lines to the
//! historical hand-rolled path, because it reuses the same `util::json`
//! writer with the same BTreeMap key ordering. Anything the binary codec
//! claims about a message's meaning must agree with what this codec says.

use super::codec::{Codec, DecodeResult, FrameError, StreamOutcome, WireReply, WireRequest};
use crate::util::json::{self, Json};

pub struct JsonCodec;

/// Scan to the next newline, skipping blank lines the way the old
/// `BufReader::lines()` loop did. Returns (line, consumed) where
/// `consumed` covers the skipped blanks and the terminator.
fn next_line(buf: &[u8]) -> Option<(&[u8], usize)> {
    let mut start = 0;
    loop {
        let nl = buf[start..].iter().position(|&b| b == b'\n')? + start;
        let mut line = &buf[start..nl];
        if let [rest @ .., b'\r'] = line {
            line = rest;
        }
        if line.iter().all(|b| b.is_ascii_whitespace()) {
            start = nl + 1;
            continue;
        }
        return Some((line, nl + 1));
    }
}

fn bad(consumed: usize, message: String) -> FrameError {
    FrameError { consumed, message }
}

fn parse_line(line: &[u8], consumed: usize) -> Result<Json, FrameError> {
    let text = std::str::from_utf8(line).map_err(|_| bad(consumed, "invalid utf8".into()))?;
    json::parse(text).map_err(|e| bad(consumed, format!("{e}")))
}

fn str_field(j: &Json, key: &str, consumed: usize) -> Result<String, FrameError> {
    match j.get(key) {
        None => Err(bad(consumed, format!("missing json key '{key}'"))),
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| bad(consumed, key.to_string())),
    }
}

fn tokens_field(j: &Json, key: &str, consumed: usize) -> Result<Vec<u32>, FrameError> {
    let arr = j
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| bad(consumed, format!("missing json key '{key}'")))?;
    arr.iter()
        .map(|t| t.as_usize().map(|v| v as u32))
        .collect::<Option<Vec<u32>>>()
        .ok_or_else(|| bad(consumed, format!("non-integer token in '{key}'")))
}

fn tokens_json(tokens: &[u32]) -> Json {
    Json::Arr(tokens.iter().map(|t| Json::Num(*t as f64)).collect())
}

fn decode_request_json(j: &Json, consumed: usize) -> Result<WireRequest, FrameError> {
    let op = match j.get("op") {
        None => return Err(bad(consumed, "missing json key 'op'".into())),
        Some(v) => v.as_str().ok_or_else(|| bad(consumed, "op".to_string()))?,
    };
    let tenant_name = j.get("tenant").and_then(Json::as_str).map(str::to_string);
    let tenant_id = j.get("tenant").and_then(Json::as_usize).unwrap_or(0) as u32;
    let stream = j.get("stream").and_then(Json::as_bool).unwrap_or(false);
    match op {
        "ping" => Ok(WireRequest::Ping),
        "stats" => Ok(WireRequest::Stats),
        "score" => Ok(WireRequest::Score {
            text: str_field(j, "text", consumed)?,
            choice: str_field(j, "choice", consumed)?,
            tenant: tenant_name,
        }),
        "generate" => Ok(WireRequest::Generate {
            text: str_field(j, "text", consumed)?,
            max_new: j.get("max_new").and_then(Json::as_usize),
            tenant: tenant_name,
            stream,
        }),
        "score_tokens" => {
            let span = j
                .get("span")
                .and_then(Json::as_arr)
                .filter(|a| a.len() == 2)
                .and_then(|a| Some((a[0].as_usize()? as u32, a[1].as_usize()? as u32)))
                .ok_or_else(|| bad(consumed, "missing json key 'span'".into()))?;
            Ok(WireRequest::ScoreTokens {
                tokens: tokens_field(j, "tokens", consumed)?,
                span,
                tenant: tenant_id,
            })
        }
        "generate_tokens" => Ok(WireRequest::GenerateTokens {
            tokens: tokens_field(j, "tokens", consumed)?,
            max_new: j
                .get("max_new")
                .and_then(Json::as_usize)
                .ok_or_else(|| bad(consumed, "missing json key 'max_new'".into()))?
                as u32,
            tenant: tenant_id,
            stream,
        }),
        other => Err(bad(consumed, format!("unknown op '{other}'"))),
    }
}

fn decode_reply_json(j: &Json, consumed: usize) -> Result<WireReply, FrameError> {
    if j.get("chunk").and_then(Json::as_bool) == Some(true) {
        let index = j
            .get("index")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad(consumed, "missing json key 'index'".into()))? as u32;
        let token = j
            .get("token")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad(consumed, "missing json key 'token'".into()))? as u32;
        return Ok(WireReply::Chunk { index, token });
    }
    if j.get("done").and_then(Json::as_bool) == Some(true) {
        let outcome = j
            .get("outcome")
            .and_then(Json::as_str)
            .and_then(StreamOutcome::parse)
            .ok_or_else(|| bad(consumed, "bad stream outcome".into()))?;
        return Ok(WireReply::End {
            outcome,
            tokens: tokens_field(j, "tokens", consumed)?,
            text: str_field(j, "text", consumed)?,
        });
    }
    match j.get("ok").and_then(Json::as_bool) {
        Some(true) if j.get("score").is_some() => Ok(WireReply::Score {
            score: j
                .get("score")
                .and_then(Json::as_f64)
                .ok_or_else(|| bad(consumed, "score".into()))?,
        }),
        Some(true) if j.get("tokens").is_some() && j.get("text").is_some() => {
            Ok(WireReply::Generate {
                tokens: tokens_field(j, "tokens", consumed)?,
                text: str_field(j, "text", consumed)?,
            })
        }
        Some(false) if j.get("error").is_some() => Ok(WireReply::Error {
            message: str_field(j, "error", consumed)?,
        }),
        _ => Ok(WireReply::Blob(j.clone())),
    }
}

impl Codec for JsonCodec {
    fn name(&self) -> &'static str {
        "json"
    }

    fn encode_request(&self, req: &WireRequest, out: &mut Vec<u8>) {
        let mut r = Json::obj();
        match req {
            WireRequest::Ping => r.insert("op", "ping".into()),
            WireRequest::Stats => r.insert("op", "stats".into()),
            WireRequest::Score { text, choice, tenant } => {
                r.insert("op", "score".into());
                r.insert("text", text.as_str().into());
                r.insert("choice", choice.as_str().into());
                if let Some(t) = tenant {
                    r.insert("tenant", t.as_str().into());
                }
            }
            WireRequest::Generate { text, max_new, tenant, stream } => {
                r.insert("op", "generate".into());
                r.insert("text", text.as_str().into());
                if let Some(m) = max_new {
                    r.insert("max_new", (*m).into());
                }
                if let Some(t) = tenant {
                    r.insert("tenant", t.as_str().into());
                }
                if *stream {
                    r.insert("stream", true.into());
                }
            }
            WireRequest::ScoreTokens { tokens, span, tenant } => {
                r.insert("op", "score_tokens".into());
                r.insert("tokens", tokens_json(tokens));
                let span = vec![(span.0 as usize).into(), (span.1 as usize).into()];
                r.insert("span", Json::Arr(span));
                r.insert("tenant", (*tenant as usize).into());
            }
            WireRequest::GenerateTokens { tokens, max_new, tenant, stream } => {
                r.insert("op", "generate_tokens".into());
                r.insert("tokens", tokens_json(tokens));
                r.insert("max_new", (*max_new as usize).into());
                r.insert("tenant", (*tenant as usize).into());
                if *stream {
                    r.insert("stream", true.into());
                }
            }
        }
        out.extend_from_slice(r.dump().as_bytes());
        out.push(b'\n');
    }

    fn encode_reply(&self, rep: &WireReply, out: &mut Vec<u8>) {
        let dumped = match rep {
            WireReply::Blob(j) => j.dump(),
            WireReply::Score { score } => {
                let mut r = Json::obj();
                r.insert("ok", true.into());
                r.insert("score", (*score).into());
                r.dump()
            }
            WireReply::Generate { tokens, text } => {
                let mut r = Json::obj();
                r.insert("ok", true.into());
                r.insert("tokens", tokens_json(tokens));
                r.insert("text", text.as_str().into());
                r.dump()
            }
            WireReply::Chunk { index, token } => {
                let mut r = Json::obj();
                r.insert("chunk", true.into());
                r.insert("index", (*index as usize).into());
                r.insert("token", (*token as usize).into());
                r.dump()
            }
            WireReply::End { outcome, tokens, text } => {
                let mut r = Json::obj();
                r.insert("done", true.into());
                r.insert("outcome", outcome.as_str().into());
                r.insert("tokens", tokens_json(tokens));
                r.insert("text", text.as_str().into());
                r.dump()
            }
            WireReply::Error { message } => {
                let mut r = Json::obj();
                r.insert("ok", false.into());
                r.insert("error", message.as_str().into());
                r.dump()
            }
        };
        out.extend_from_slice(dumped.as_bytes());
        out.push(b'\n');
    }

    fn decode_request(&self, buf: &[u8]) -> DecodeResult<WireRequest> {
        let Some((line, consumed)) = next_line(buf) else {
            return Ok(None);
        };
        let j = parse_line(line, consumed)?;
        decode_request_json(&j, consumed).map(|req| Some((req, consumed)))
    }

    fn decode_reply(&self, buf: &[u8]) -> DecodeResult<WireReply> {
        let Some((line, consumed)) = next_line(buf) else {
            return Ok(None);
        };
        let j = parse_line(line, consumed)?;
        decode_reply_json(&j, consumed).map(|rep| Some((rep, consumed)))
    }
}
