//! Bounded per-session token lanes for streamed generates.
//!
//! Backpressure contract (DESIGN.md §2.15): the replica tick loop calls
//! [`StreamSender::offer`], which never blocks — the lane is a bounded
//! `sync_channel` fed with `try_send`. A slow client stops draining its
//! own lane; once the lane is full, that session's *incremental* frames
//! are dropped (counted in `wire.stream_lagged`) while decode, the other
//! sessions, and the terminal reply all proceed untouched. The terminal
//! frame carries the full token sequence, so the transcript a client
//! observes is identical to the buffered path regardless of how many
//! incremental frames backpressure suppressed.
//!
//! End-of-stream is signalled by hangup, not by an in-band event: the
//! core drops the [`StreamSender`] when the session reaches a terminal
//! outcome, the receiver observes disconnect, and the IO thread then
//! reads the authoritative terminal response from the ordinary reply
//! ticket (which is unbounded and therefore cannot be wedged by a full
//! lane).

use crate::util::trace;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::time::Duration;

/// Default lane capacity: deeper than any one tick's emissions, shallow
/// enough that a stalled client stops costing memory almost immediately.
pub const LANE_CAP: usize = 32;

/// Producer half, held by the replica worker inside its pending-reply
/// table. Dropping it closes the lane.
pub struct StreamSender {
    tx: SyncSender<u32>,
}

impl StreamSender {
    /// Non-blocking offer of one decoded token. Returns false when the
    /// lane is full (client lagging) or the client hung up; the caller
    /// never retries — the terminal frame is authoritative.
    pub fn offer(&self, token: u32) -> bool {
        match self.tx.try_send(token) {
            Ok(()) => true,
            Err(TrySendError::Full(_)) => {
                trace::counter("wire.stream_lagged").inc();
                false
            }
            Err(TrySendError::Disconnected(_)) => false,
        }
    }
}

/// What one bounded wait on the lane produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamPoll {
    /// One incremental token.
    Token(u32),
    /// Nothing yet — keep waiting (bounded by the caller's deadline).
    Idle,
    /// Sender dropped: the session reached a terminal outcome and the
    /// reply ticket now holds the authoritative response.
    Closed,
}

/// Consumer half, held by the client/IO side.
pub struct StreamReceiver {
    rx: Receiver<u32>,
}

impl StreamReceiver {
    pub fn poll(&self, wait: Duration) -> StreamPoll {
        match self.rx.recv_timeout(wait) {
            Ok(tok) => StreamPoll::Token(tok),
            Err(RecvTimeoutError::Timeout) => StreamPoll::Idle,
            Err(RecvTimeoutError::Disconnected) => StreamPoll::Closed,
        }
    }

    /// Drain whatever is already buffered without waiting.
    pub fn drain(&self) -> Vec<u32> {
        let mut out = Vec::new();
        while let Ok(tok) = self.rx.try_recv() {
            out.push(tok);
        }
        out
    }
}

/// One lane. `cap` is clamped to at least 1.
pub fn stream_channel(cap: usize) -> (StreamSender, StreamReceiver) {
    let (tx, rx) = sync_channel(cap.max(1));
    (StreamSender { tx }, StreamReceiver { rx })
}
