//! Corpus generation: verbalizing the world into a training token stream.
//!
//! The corpus plays WikiText-2's role: it is the training distribution, the
//! held-out perplexity set, and the calibration set for S-PTS/L-PTS and
//! R-Sparse. Sentences come from a fixed template family that *includes the
//! eval-task formats* (QA, true/false, instruction-following), so the dense
//! model learns both the facts and the answer formats.

use crate::synthlang::vocab::{Vocab, EOS};
use crate::synthlang::world::{Entity, World};
use crate::util::prng::Rng;
use anyhow::Result;

/// Number words used by instruction templates (count 2..=4).
pub const COUNT_WORDS: [(usize, &str); 3] = [(2, "two"), (3, "three"), (4, "four")];

/// Render one fact/QA/instruction sentence about `e`. The template mix is
/// the training distribution; eval tasks reuse the same surface forms.
pub fn render_sentence(world: &World, e: &Entity, rng: &mut Rng) -> String {
    let name = e.name();
    let loc = e.location_word();
    let food = e.food_word();
    let size = e.size_word();
    match rng.below(14) {
        0 => format!("the {name} lives in the {loc} ."),
        1 => format!("the {name} eats {food} ."),
        2 => format!("the {name} is {size} ."),
        3 => format!("there is a {size} {name} in the {loc} ."),
        4 => format!("does the {name} live in the {loc} ? yes ."),
        5 => {
            let wrong = world.wrong_location(e, rng);
            format!(
                "does the {name} live in the {} ? no .",
                crate::synthlang::vocab::LOCATIONS[wrong]
            )
        }
        6 => format!("where does the {name} live ? in the {loc} ."),
        7 => format!("what does the {name} eat ? {food} ."),
        8 => format!("is it true that the {name} eats {food} ? true ."),
        9 => {
            let wrong = world.wrong_food(e, rng);
            format!(
                "is it true that the {name} eats {} ? false .",
                crate::synthlang::vocab::FOODS[wrong]
            )
        }
        10 => {
            // Two-entity reference resolution (winogrande-style).
            let other = world.other_entity(e, rng);
            format!(
                "the {name} and the {} . who eats {food} ? the {name} .",
                other.name()
            )
        }
        11 => {
            // Multi-sentence continuation (hellaswag-style narrative).
            format!("the {name} is {size} . it lives in the {loc} . it eats {food} .")
        }
        12 => {
            // Instruction: repeat-k (ifeval-style, verifiable).
            let (count, count_word) = *rng.choose(&COUNT_WORDS);
            let word = crate::synthlang::vocab::ANIMALS[e.animal];
            let reps = vec![word; count].join(" ");
            format!("repeat the word {word} {count_word} times : {reps} .")
        }
        _ => {
            // Instruction: answer-with-N-words.
            if rng.chance(0.5) {
                format!("answer with one word . what does the {name} eat ? {food} .")
            } else {
                format!("answer with two words . who lives in the {loc} ? {name} .")
            }
        }
    }
}

/// Build a token stream of approximately `target_tokens` tokens: documents
/// of 3–8 sentences about random entities, separated by EOS.
pub fn build_stream(
    world: &World,
    vocab: &Vocab,
    rng: &mut Rng,
    target_tokens: usize,
) -> Result<Vec<u32>> {
    let mut stream: Vec<u32> = Vec::with_capacity(target_tokens + 256);
    while stream.len() < target_tokens {
        let sentences = rng.range(3, 9);
        for _ in 0..sentences {
            let e = world.entity(rng.below(world.len()));
            let text = render_sentence(world, e, rng);
            stream.extend(vocab.encode(&text)?);
        }
        stream.push(EOS);
    }
    stream.truncate(target_tokens);
    Ok(stream)
}

/// The three corpus splits written by `datagen`.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub train: Vec<u32>,
    pub valid: Vec<u32>,
    pub calib: Vec<u32>,
}

impl Corpus {
    /// Generate all splits with decorrelated streams over the same world.
    pub fn generate(
        world: &World,
        vocab: &Vocab,
        seed: u64,
        train_tokens: usize,
        valid_tokens: usize,
        calib_tokens: usize,
    ) -> Result<Corpus> {
        let mut base = Rng::new(seed);
        let mut r_train = base.fork("corpus-train");
        let mut r_valid = base.fork("corpus-valid");
        let mut r_calib = base.fork("corpus-calib");
        Ok(Corpus {
            train: build_stream(world, vocab, &mut r_train, train_tokens)?,
            valid: build_stream(world, vocab, &mut r_valid, valid_tokens)?,
            calib: build_stream(world, vocab, &mut r_calib, calib_tokens)?,
        })
    }

    /// Write a split as little-endian u32 (the format `train.py` mmaps).
    pub fn write_tokens(path: &std::path::Path, tokens: &[u32]) -> Result<()> {
        let mut bytes = Vec::with_capacity(tokens.len() * 4);
        for t in tokens {
            bytes.extend_from_slice(&t.to_le_bytes());
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    /// Read a split back (used by tests and the perplexity harness).
    pub fn read_tokens(path: &std::path::Path) -> Result<Vec<u32>> {
        let bytes = std::fs::read(path)?;
        anyhow::ensure!(bytes.len() % 4 == 0, "token file not u32-aligned");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (World, Vocab) {
        (World::generate(42, 40), Vocab::synthlang())
    }

    #[test]
    fn sentences_tokenize_cleanly() {
        let (world, vocab) = setup();
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let e = world.entity(rng.below(world.len()));
            let s = render_sentence(&world, e, &mut rng);
            let ids = vocab.encode(&s).expect(&s);
            assert!(!ids.is_empty());
            assert_eq!(vocab.decode(&ids), s);
        }
    }

    #[test]
    fn stream_reaches_target_and_contains_eos() {
        let (world, vocab) = setup();
        let mut rng = Rng::new(2);
        let stream = build_stream(&world, &vocab, &mut rng, 5000).unwrap();
        assert_eq!(stream.len(), 5000);
        assert!(stream.iter().any(|t| *t == EOS));
        assert!(stream.iter().all(|t| (*t as usize) < vocab.len()));
    }

    #[test]
    fn corpus_deterministic() {
        let (world, vocab) = setup();
        let a = Corpus::generate(&world, &vocab, 9, 2000, 500, 500).unwrap();
        let b = Corpus::generate(&world, &vocab, 9, 2000, 500, 500).unwrap();
        assert_eq!(a.train, b.train);
        assert_eq!(a.valid, b.valid);
    }

    #[test]
    fn splits_differ() {
        let (world, vocab) = setup();
        let c = Corpus::generate(&world, &vocab, 9, 2000, 2000, 2000).unwrap();
        assert_ne!(c.train, c.valid);
        assert_ne!(c.valid, c.calib);
    }

    #[test]
    fn token_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("nmsparse-corpus-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.tokens");
        let tokens: Vec<u32> = (0..1000).map(|i| i % 97).collect();
        Corpus::write_tokens(&path, &tokens).unwrap();
        assert_eq!(Corpus::read_tokens(&path).unwrap(), tokens);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn repeat_instruction_is_verifiable() {
        // The repeat-k template must contain the word exactly k+1 times
        // (once in the instruction + k in the answer).
        let (world, vocab) = setup();
        let mut rng = Rng::new(3);
        let mut found = 0;
        for _ in 0..2000 {
            let e = world.entity(rng.below(world.len()));
            let s = render_sentence(&world, e, &mut rng);
            if s.starts_with("repeat the word") {
                found += 1;
                let word = s.split_whitespace().nth(3).unwrap();
                let count_word = s.split_whitespace().nth(4).unwrap();
                let expect = COUNT_WORDS
                    .iter()
                    .find(|(_, w)| *w == count_word)
                    .unwrap()
                    .0;
                let occurrences =
                    s.split_whitespace().filter(|w| *w == word).count();
                assert_eq!(occurrences, expect + 1, "{s}");
                let _ = vocab.encode(&s).unwrap();
            }
        }
        assert!(found > 20);
    }
}
