//! Evaluation task generators — the synthetic analogs of the paper's
//! benchmark suite (§2.4: Core + Extended datasets, IFEval).
//!
//! Every multiple-choice task is a set of (context, choices, label) tuples
//! scored by continuation loglikelihood, exactly like LM Eval Harness. The
//! IFEval analog stores verifiable constraints checked on greedy decodes.
//! All tasks are generated from the same [`World`] the corpus verbalized.

use crate::synthlang::vocab::{Vocab, FOODS, LOCATIONS};
use crate::synthlang::world::World;
use crate::util::json::{self, Json};
use crate::util::prng::Rng;
use anyhow::{Context, Result};

/// One multiple-choice example.
#[derive(Clone, Debug, PartialEq)]
pub struct Example {
    /// Context (prompt) token ids.
    pub context: Vec<u32>,
    /// Candidate continuations (token ids); the harness scores each.
    pub choices: Vec<Vec<u32>>,
    /// Index of the correct choice.
    pub label: usize,
    /// Human-readable rendering for debugging.
    pub text: String,
}

/// A named set of examples.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskSet {
    pub name: String,
    pub examples: Vec<Example>,
}

/// Verifiable constraint for the IFEval analog.
#[derive(Clone, Debug, PartialEq)]
pub enum Constraint {
    /// Output must contain `word` exactly `count` times before the period.
    RepeatWord { word: u32, count: usize },
    /// Answer (tokens before the first period) must be exactly `count`
    /// words; `valid_answers` lists the factually correct ones.
    ExactWords { count: usize, valid_answers: Vec<Vec<u32>> },
}

/// One generative instruction-following example.
#[derive(Clone, Debug, PartialEq)]
pub struct IfevalExample {
    pub prompt: Vec<u32>,
    pub constraint: Constraint,
    pub text: String,
}

/// The IFEval-analog task set.
#[derive(Clone, Debug, PartialEq)]
pub struct IfevalSet {
    pub name: String,
    pub examples: Vec<IfevalExample>,
}

/// Names of the core multiple-choice tasks (paper's screening suite).
pub const CORE_TASKS: &[&str] = &["synth_boolq", "synth_arce", "synth_piqa", "synth_wino"];

/// Names of the extended tasks.
pub const EXTENDED_TASKS: &[&str] = &[
    "synth_hellaswag",
    "synth_openbookqa",
    "synth_rte",
    "synth_mmlu",
    "synth_lambada",
];

/// Generate a task set by name.
pub fn generate(
    name: &str,
    world: &World,
    vocab: &Vocab,
    n: usize,
    seed: u64,
) -> Result<TaskSet> {
    let mut rng = Rng::new(seed).fork(name);
    let mut examples = Vec::with_capacity(n);
    for i in 0..n {
        let e = world.entity(rng.below(world.len()));
        let ex = match name {
            "synth_boolq" => {
                // "does the {name} live in the {loc} ?" -> yes/no
                let positive = i % 2 == 0;
                let loc = if positive {
                    e.location
                } else {
                    world.wrong_location(e, &mut rng)
                };
                let ctx = format!("does the {} live in the {} ?", e.name(), LOCATIONS[loc]);
                mc_example(vocab, &ctx, &["yes", "no"], if positive { 0 } else { 1 })?
            }
            "synth_arce" => {
                // "where does the {name} live ? in the" -> 4 locations
                let ctx = format!("where does the {} live ? in the", e.name());
                let mut opts = world.distractor_locations(e, 3, &mut rng);
                let label = rng.below(4);
                opts.insert(label, e.location);
                let words: Vec<&str> = opts.iter().map(|l| LOCATIONS[*l]).collect();
                mc_example(vocab, &ctx, &words, label)?
            }
            "synth_piqa" => {
                // Plausibility: "the {name}" -> "eats {food} ." vs corrupted
                let ctx = format!("the {}", e.name());
                let wrong = world.wrong_food(e, &mut rng);
                let good = format!("eats {} .", e.food_word());
                let bad = format!("eats {} .", FOODS[wrong]);
                let label = rng.below(2);
                let (a, b) = if label == 0 { (good, bad) } else { (bad, good) };
                mc_example(vocab, &ctx, &[a.as_str(), b.as_str()], label)?
            }
            "synth_wino" => {
                // Referent resolution between two entities.
                let other = world.other_entity(e, &mut rng);
                let ctx = format!(
                    "the {} and the {} . who eats {} ? the",
                    e.name(),
                    other.name(),
                    e.food_word()
                );
                let label = rng.below(2);
                let (a, b) = if label == 0 {
                    (e.name(), other.name())
                } else {
                    (other.name(), e.name())
                };
                mc_example(vocab, &ctx, &[a.as_str(), b.as_str()], label)?
            }
            "synth_hellaswag" => {
                // Narrative continuation, 4-way over foods.
                let ctx = format!(
                    "the {} is {} . it lives in the {} . it eats",
                    e.name(),
                    e.size_word(),
                    e.location_word()
                );
                let mut opts = world.distractor_foods(e, 3, &mut rng);
                let label = rng.below(4);
                opts.insert(label, e.food);
                let words: Vec<&str> = opts.iter().map(|f| FOODS[*f]).collect();
                mc_example(vocab, &ctx, &words, label)?
            }
            "synth_openbookqa" => {
                let ctx = format!("what does the {} eat ?", e.name());
                let mut opts = world.distractor_foods(e, 3, &mut rng);
                let label = rng.below(4);
                opts.insert(label, e.food);
                let words: Vec<&str> = opts.iter().map(|f| FOODS[*f]).collect();
                mc_example(vocab, &ctx, &words, label)?
            }
            "synth_rte" => {
                let positive = i % 2 == 0;
                let food = if positive {
                    e.food
                } else {
                    world.wrong_food(e, &mut rng)
                };
                let ctx = format!(
                    "is it true that the {} eats {} ?",
                    e.name(),
                    FOODS[food]
                );
                mc_example(vocab, &ctx, &["true", "false"], if positive { 0 } else { 1 })?
            }
            "synth_mmlu" => {
                // Mixed-domain 4-way with a distinct "question:/answer:" form.
                if rng.chance(0.5) {
                    let ctx = format!(
                        "question : where does the {} live ? answer : in the",
                        e.name()
                    );
                    let mut opts = world.distractor_locations(e, 3, &mut rng);
                    let label = rng.below(4);
                    opts.insert(label, e.location);
                    let words: Vec<&str> = opts.iter().map(|l| LOCATIONS[*l]).collect();
                    mc_example(vocab, &ctx, &words, label)?
                } else {
                    let ctx = format!(
                        "question : what does the {} eat ? answer :",
                        e.name()
                    );
                    let mut opts = world.distractor_foods(e, 3, &mut rng);
                    let label = rng.below(4);
                    opts.insert(label, e.food);
                    let words: Vec<&str> = opts.iter().map(|f| FOODS[*f]).collect();
                    mc_example(vocab, &ctx, &words, label)?
                }
            }
            "synth_lambada" => {
                // Final-word prediction over a long discourse context.
                let ctx = format!(
                    "the {} lives in the {} . the {} is {} . so there is a {} {} in the",
                    e.name(),
                    e.location_word(),
                    e.name(),
                    e.size_word(),
                    e.size_word(),
                    crate::synthlang::vocab::ANIMALS[e.animal],
                );
                let mut opts = world.distractor_locations(e, 3, &mut rng);
                let label = rng.below(4);
                opts.insert(label, e.location);
                let words: Vec<&str> = opts.iter().map(|l| LOCATIONS[*l]).collect();
                mc_example(vocab, &ctx, &words, label)?
            }
            other => anyhow::bail!("unknown task '{other}'"),
        };
        examples.push(ex);
    }
    Ok(TaskSet {
        name: name.to_string(),
        examples,
    })
}

fn mc_example(
    vocab: &Vocab,
    ctx: &str,
    choices: &[impl AsRef<str>],
    label: usize,
) -> Result<Example> {
    let context = vocab.encode(ctx)?;
    let mut enc = Vec::with_capacity(choices.len());
    let mut txt = format!("{ctx} => [");
    for (i, c) in choices.iter().enumerate() {
        enc.push(vocab.encode(c.as_ref())?);
        if i > 0 {
            txt.push_str(" | ");
        }
        if i == label {
            txt.push('*');
        }
        txt.push_str(c.as_ref());
    }
    txt.push(']');
    Ok(Example {
        context,
        choices: enc,
        label,
        text: txt,
    })
}

/// Generate the IFEval analog.
pub fn generate_ifeval(world: &World, vocab: &Vocab, n: usize, seed: u64) -> Result<IfevalSet> {
    let mut rng = Rng::new(seed).fork("synth_ifeval");
    let mut examples = Vec::with_capacity(n);
    for _ in 0..n {
        let e = world.entity(rng.below(world.len()));
        let ex = match rng.below(3) {
            0 => {
                let (count, count_word) = *rng.choose(&crate::synthlang::corpus::COUNT_WORDS);
                let word = crate::synthlang::vocab::ANIMALS[e.animal];
                let prompt = format!("repeat the word {word} {count_word} times :");
                IfevalExample {
                    prompt: vocab.encode(&prompt)?,
                    constraint: Constraint::RepeatWord {
                        word: vocab.id(word)?,
                        count,
                    },
                    text: prompt,
                }
            }
            1 => {
                let prompt = format!("answer with one word . what does the {} eat ?", e.name());
                IfevalExample {
                    prompt: vocab.encode(&prompt)?,
                    constraint: Constraint::ExactWords {
                        count: 1,
                        valid_answers: vec![vocab.encode(e.food_word())?],
                    },
                    text: prompt,
                }
            }
            _ => {
                let prompt = format!(
                    "answer with two words . who lives in the {} ?",
                    e.location_word()
                );
                // Every entity in that location is a factually valid answer.
                let valid: Vec<Vec<u32>> = world
                    .entities
                    .iter()
                    .filter(|x| x.location == e.location)
                    .map(|x| vocab.encode(&x.name()))
                    .collect::<Result<_>>()?;
                IfevalExample {
                    prompt: vocab.encode(&prompt)?,
                    constraint: Constraint::ExactWords {
                        count: 2,
                        valid_answers: valid,
                    },
                    text: prompt,
                }
            }
        };
        examples.push(ex);
    }
    Ok(IfevalSet {
        name: "synth_ifeval".to_string(),
        examples,
    })
}

// ---------------- JSON (de)serialization ----------------

fn ids_to_json(ids: &[u32]) -> Json {
    Json::Arr(ids.iter().map(|i| Json::Num(*i as f64)).collect())
}

fn ids_from_json(j: &Json) -> Result<Vec<u32>> {
    Ok(j.as_arr()
        .context("expected id array")?
        .iter()
        .map(|x| x.as_f64().unwrap_or(0.0) as u32)
        .collect())
}

impl TaskSet {
    pub fn to_json(&self) -> Json {
        let mut t = Json::obj();
        t.insert("name", self.name.as_str().into());
        t.insert(
            "examples",
            Json::Arr(
                self.examples
                    .iter()
                    .map(|e| {
                        let mut o = Json::obj();
                        o.insert("context", ids_to_json(&e.context));
                        o.insert(
                            "choices",
                            Json::Arr(e.choices.iter().map(|c| ids_to_json(c)).collect()),
                        );
                        o.insert("label", e.label.into());
                        o.insert("text", e.text.as_str().into());
                        o
                    })
                    .collect(),
            ),
        );
        t
    }

    pub fn from_json(j: &Json) -> Result<TaskSet> {
        let name = j.req("name")?.as_str().context("name")?.to_string();
        let mut examples = Vec::new();
        for e in j.req("examples")?.as_arr().context("examples")? {
            examples.push(Example {
                context: ids_from_json(e.req("context")?)?,
                choices: e
                    .req("choices")?
                    .as_arr()
                    .context("choices")?
                    .iter()
                    .map(ids_from_json)
                    .collect::<Result<_>>()?,
                label: e.req("label")?.as_usize().context("label")?,
                text: e
                    .get("text")
                    .and_then(|t| t.as_str())
                    .unwrap_or("")
                    .to_string(),
            });
        }
        Ok(TaskSet { name, examples })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().dump())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<TaskSet> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading task file {}", path.display()))?;
        let j = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        TaskSet::from_json(&j)
    }
}

impl Constraint {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        match self {
            Constraint::RepeatWord { word, count } => {
                o.insert("type", "repeat_word".into());
                o.insert("word", (*word as usize).into());
                o.insert("count", (*count).into());
            }
            Constraint::ExactWords { count, valid_answers } => {
                o.insert("type", "exact_words".into());
                o.insert("count", (*count).into());
                o.insert(
                    "valid_answers",
                    Json::Arr(valid_answers.iter().map(|a| ids_to_json(a)).collect()),
                );
            }
        }
        o
    }

    pub fn from_json(j: &Json) -> Result<Constraint> {
        match j.req("type")?.as_str() {
            Some("repeat_word") => Ok(Constraint::RepeatWord {
                word: j.req("word")?.as_usize().context("word")? as u32,
                count: j.req("count")?.as_usize().context("count")?,
            }),
            Some("exact_words") => Ok(Constraint::ExactWords {
                count: j.req("count")?.as_usize().context("count")?,
                valid_answers: j
                    .req("valid_answers")?
                    .as_arr()
                    .context("valid_answers")?
                    .iter()
                    .map(ids_from_json)
                    .collect::<Result<_>>()?,
            }),
            other => anyhow::bail!("unknown constraint type {other:?}"),
        }
    }
}

impl IfevalSet {
    pub fn to_json(&self) -> Json {
        let mut t = Json::obj();
        t.insert("name", self.name.as_str().into());
        t.insert(
            "examples",
            Json::Arr(
                self.examples
                    .iter()
                    .map(|e| {
                        let mut o = Json::obj();
                        o.insert("prompt", ids_to_json(&e.prompt));
                        o.insert("constraint", e.constraint.to_json());
                        o.insert("text", e.text.as_str().into());
                        o
                    })
                    .collect(),
            ),
        );
        t
    }

    pub fn from_json(j: &Json) -> Result<IfevalSet> {
        let name = j.req("name")?.as_str().context("name")?.to_string();
        let mut examples = Vec::new();
        for e in j.req("examples")?.as_arr().context("examples")? {
            examples.push(IfevalExample {
                prompt: ids_from_json(e.req("prompt")?)?,
                constraint: Constraint::from_json(e.req("constraint")?)?,
                text: e
                    .get("text")
                    .and_then(|t| t.as_str())
                    .unwrap_or("")
                    .to_string(),
            });
        }
        Ok(IfevalSet { name, examples })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().dump())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<IfevalSet> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading ifeval file {}", path.display()))?;
        let j = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        IfevalSet::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (World, Vocab) {
        (World::generate(11, 40), Vocab::synthlang())
    }

    #[test]
    fn all_tasks_generate() {
        let (world, vocab) = setup();
        for name in CORE_TASKS.iter().chain(EXTENDED_TASKS) {
            let t = generate(name, &world, &vocab, 32, 5).unwrap();
            assert_eq!(t.examples.len(), 32, "{name}");
            for ex in &t.examples {
                assert!(!ex.context.is_empty());
                assert!(ex.choices.len() >= 2);
                assert!(ex.label < ex.choices.len());
                assert!(ex.choices.iter().all(|c| !c.is_empty()));
            }
        }
    }

    #[test]
    fn generation_deterministic() {
        let (world, vocab) = setup();
        let a = generate("synth_boolq", &world, &vocab, 16, 7).unwrap();
        let b = generate("synth_boolq", &world, &vocab, 16, 7).unwrap();
        assert_eq!(a, b);
        let c = generate("synth_boolq", &world, &vocab, 16, 8).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn boolq_labels_balanced() {
        let (world, vocab) = setup();
        let t = generate("synth_boolq", &world, &vocab, 100, 3).unwrap();
        let yes = t.examples.iter().filter(|e| e.label == 0).count();
        assert_eq!(yes, 50);
    }

    #[test]
    fn labels_not_positionally_biased() {
        // 4-way tasks should place the answer at varied positions.
        let (world, vocab) = setup();
        let t = generate("synth_arce", &world, &vocab, 200, 3).unwrap();
        let mut counts = [0usize; 4];
        for e in &t.examples {
            counts[e.label] += 1;
        }
        assert!(counts.iter().all(|c| *c > 20), "{counts:?}");
    }

    #[test]
    fn choices_are_distinct() {
        let (world, vocab) = setup();
        for name in CORE_TASKS.iter().chain(EXTENDED_TASKS) {
            let t = generate(name, &world, &vocab, 64, 9).unwrap();
            for ex in &t.examples {
                let mut c = ex.choices.clone();
                c.sort();
                c.dedup();
                assert_eq!(c.len(), ex.choices.len(), "{name}: {}", ex.text);
            }
        }
    }

    #[test]
    fn taskset_json_roundtrip() {
        let (world, vocab) = setup();
        let t = generate("synth_wino", &world, &vocab, 8, 2).unwrap();
        let j = t.to_json();
        let back = TaskSet::from_json(&json::parse(&j.dump()).unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn ifeval_generates_and_roundtrips() {
        let (world, vocab) = setup();
        let t = generate_ifeval(&world, &vocab, 48, 4).unwrap();
        assert_eq!(t.examples.len(), 48);
        for ex in &t.examples {
            assert!(!ex.prompt.is_empty());
            match &ex.constraint {
                Constraint::RepeatWord { count, .. } => assert!((2..=4).contains(count)),
                Constraint::ExactWords { count, valid_answers } => {
                    assert!((1..=2).contains(count));
                    assert!(!valid_answers.is_empty());
                    for a in valid_answers {
                        assert_eq!(a.len(), *count, "answer length matches constraint");
                    }
                }
            }
        }
        let back = IfevalSet::from_json(&json::parse(&t.to_json().dump()).unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn unknown_task_rejected() {
        let (world, vocab) = setup();
        assert!(generate("synth_nonsense", &world, &vocab, 1, 0).is_err());
    }
}
