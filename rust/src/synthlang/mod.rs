//! SynthLang — the synthetic data substrate.
//!
//! Stands in for the gated real data (WikiText-2, BoolQ/ARC/PIQA/WinoGrande,
//! HellaSwag/OpenBookQA/RTE/MMLU/Lambada, IFEval) per DESIGN.md §1. A seeded
//! [`world::World`] defines facts; [`corpus`] verbalizes them into training/
//! validation/calibration token streams; [`tasks`] derives the evaluation
//! suites. `nmsparse datagen` writes everything under `artifacts/data/`.

pub mod corpus;
pub mod tasks;
pub mod vocab;
pub mod world;

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

/// Generation knobs for `datagen`.
#[derive(Clone, Debug)]
pub struct DatagenConfig {
    pub seed: u64,
    pub entities: usize,
    pub train_tokens: usize,
    pub valid_tokens: usize,
    pub calib_tokens: usize,
    /// Examples per multiple-choice task.
    pub task_examples: usize,
    /// Examples in the IFEval analog.
    pub ifeval_examples: usize,
}

impl Default for DatagenConfig {
    fn default() -> Self {
        DatagenConfig {
            seed: 20250710,
            entities: 48,
            train_tokens: 300_000,
            valid_tokens: 24_000,
            calib_tokens: 24_000,
            task_examples: 200,
            ifeval_examples: 150,
        }
    }
}

/// Generate the complete data directory. Layout:
/// ```text
/// <out>/
///   vocab.json            words + sizes
///   world.json            entity table (debugging)
///   corpus_train.tokens   u32-LE stream
///   corpus_valid.tokens
///   corpus_calib.tokens
///   tasks/<name>.json     multiple-choice suites
///   tasks/synth_ifeval.json
/// ```
pub fn generate_all(cfg: &DatagenConfig, out: &Path) -> Result<()> {
    std::fs::create_dir_all(out.join("tasks"))?;
    let vocab = vocab::Vocab::synthlang();
    let world = world::World::generate(cfg.seed, cfg.entities);

    // vocab.json
    let mut vj = Json::obj();
    vj.insert("size", vocab.len().into());
    vj.insert("padded_size", vocab.padded_len().into());
    vj.insert("words", vocab.words().to_vec().into());
    std::fs::write(out.join("vocab.json"), vj.pretty())?;

    // world.json (debug / provenance)
    let mut entities = Vec::new();
    for e in &world.entities {
        let mut o = Json::obj();
        o.insert("name", e.name().into());
        o.insert("location", e.location_word().into());
        o.insert("food", e.food_word().into());
        o.insert("size", e.size_word().into());
        entities.push(o);
    }
    let mut wj = Json::obj();
    wj.insert("seed", (cfg.seed as usize).into());
    wj.insert("entities", Json::Arr(entities));
    std::fs::write(out.join("world.json"), wj.pretty())?;

    // Corpus splits.
    let corpus = corpus::Corpus::generate(
        &world,
        &vocab,
        cfg.seed,
        cfg.train_tokens,
        cfg.valid_tokens,
        cfg.calib_tokens,
    )?;
    corpus::Corpus::write_tokens(&out.join("corpus_train.tokens"), &corpus.train)?;
    corpus::Corpus::write_tokens(&out.join("corpus_valid.tokens"), &corpus.valid)?;
    corpus::Corpus::write_tokens(&out.join("corpus_calib.tokens"), &corpus.calib)?;

    // Task suites.
    for name in tasks::CORE_TASKS.iter().chain(tasks::EXTENDED_TASKS) {
        let t = tasks::generate(name, &world, &vocab, cfg.task_examples, cfg.seed)?;
        t.save(&out.join("tasks").join(format!("{name}.json")))?;
    }
    let ifeval = tasks::generate_ifeval(&world, &vocab, cfg.ifeval_examples, cfg.seed)?;
    ifeval.save(&out.join("tasks").join("synth_ifeval.json"))?;

    Ok(())
}

/// Load the vocab recorded by `datagen` (checks it matches the built-in).
pub fn load_vocab(data_dir: &Path) -> Result<vocab::Vocab> {
    let text = std::fs::read_to_string(data_dir.join("vocab.json"))
        .with_context(|| format!("reading vocab from {}", data_dir.display()))?;
    let j = crate::util::json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let v = vocab::Vocab::synthlang();
    let recorded = j.req("size")?.as_usize().context("size")?;
    anyhow::ensure!(
        recorded == v.len(),
        "vocab size mismatch: data dir has {recorded}, binary has {}; regenerate artifacts",
        v.len()
    );
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_all_writes_everything() {
        let dir = std::env::temp_dir().join(format!("nmsparse-datagen-{}", std::process::id()));
        let cfg = DatagenConfig {
            train_tokens: 4000,
            valid_tokens: 1000,
            calib_tokens: 1000,
            task_examples: 8,
            ifeval_examples: 8,
            ..Default::default()
        };
        generate_all(&cfg, &dir).unwrap();
        assert!(dir.join("vocab.json").exists());
        assert!(dir.join("world.json").exists());
        assert!(dir.join("corpus_train.tokens").exists());
        for name in tasks::CORE_TASKS.iter().chain(tasks::EXTENDED_TASKS) {
            let t = tasks::TaskSet::load(&dir.join("tasks").join(format!("{name}.json"))).unwrap();
            assert_eq!(t.examples.len(), 8);
        }
        let ife =
            tasks::IfevalSet::load(&dir.join("tasks").join("synth_ifeval.json")).unwrap();
        assert_eq!(ife.examples.len(), 8);
        let v = load_vocab(&dir).unwrap();
        assert!(v.len() > 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
