//! SynthLang vocabulary and word-level tokenizer.
//!
//! The vocabulary is a *closed*, deterministic word list so the token↔id
//! mapping is identical across runs and languages: rust builds it from the
//! constant lists below; python never needs a tokenizer because the corpus
//! is shipped to training as raw token ids (`*.tokens`, little-endian u32).

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Special token ids (fixed positions at the head of the vocab).
pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const UNK: u32 = 3;

/// Colors used in entity names.
pub const COLORS: &[&str] = &[
    "red", "blue", "green", "golden", "silver", "black", "white", "brown",
];

/// Animal nouns.
pub const ANIMALS: &[&str] = &[
    "fox", "owl", "bear", "wolf", "deer", "hare", "otter", "crow", "lynx", "mole", "swan",
    "toad", "stork", "badger", "weasel", "heron",
];

/// Locations entities live in.
pub const LOCATIONS: &[&str] = &[
    "forest", "den", "river", "meadow", "cave", "marsh", "valley", "burrow", "cliff", "grove",
];

/// Foods entities eat.
pub const FOODS: &[&str] = &[
    "berries", "fish", "seeds", "roots", "insects", "honey", "leaves", "acorns", "grass",
    "mushrooms",
];

/// Size adjectives.
pub const SIZES: &[&str] = &["big", "small"];

/// Function words, question scaffolding and instruction vocabulary.
pub const FUNCTION_WORDS: &[&str] = &[
    "the", "a", "is", "are", "was", "it", "that", "and", "or", "not", "does", "do", "what",
    "where", "which", "who", "how", "lives", "live", "eats", "eat", "likes", "like", "in",
    "yes", "no", "true", "false", "color", "size", "animal", "place", "food", "question",
    "answer", "with", "exactly", "one", "two", "three", "four", "times", "word", "words",
    "repeat", "say", "end", "statement", "story", "then", "so", "because", "there", "of",
    "this", "same", "different", "but", "also", "only", "very", "every", "both",
];

/// Punctuation tokens (kept as standalone words).
pub const PUNCT: &[&str] = &[".", "?", ":", ","];

/// The deterministic vocabulary: id ↔ word.
#[derive(Clone, Debug)]
pub struct Vocab {
    words: Vec<String>,
    index: HashMap<String, u32>,
}

impl Vocab {
    /// Build the canonical SynthLang vocabulary. Order is fixed:
    /// specials, punctuation, function words, colors, sizes, animals,
    /// locations, foods.
    pub fn synthlang() -> Vocab {
        let mut words: Vec<String> =
            vec!["<pad>".into(), "<bos>".into(), "<eos>".into(), "<unk>".into()];
        for group in [FUNCTION_WORDS, PUNCT, COLORS, SIZES, ANIMALS, LOCATIONS, FOODS] {
            for w in group {
                words.push((*w).to_string());
            }
        }
        let mut index = HashMap::new();
        for (i, w) in words.iter().enumerate() {
            let prev = index.insert(w.clone(), i as u32);
            assert!(prev.is_none(), "duplicate vocab word '{w}'");
        }
        Vocab { words, index }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Vocab size rounded up for the model's embedding table (multiple of
    /// 32 so N:M blocks tile the unembedding cleanly).
    pub fn padded_len(&self) -> usize {
        (self.len() + 31) / 32 * 32
    }

    /// Id for a word; errors on unknown (the corpus generator must never
    /// produce out-of-vocab text).
    pub fn id(&self, word: &str) -> Result<u32> {
        match self.index.get(word) {
            Some(id) => Ok(*id),
            None => bail!("word '{word}' not in SynthLang vocab"),
        }
    }

    /// Word for an id (`<unk>` for out-of-range).
    pub fn word(&self, id: u32) -> &str {
        self.words
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<unk>")
    }

    /// Tokenize a whitespace-separated SynthLang sentence.
    pub fn encode(&self, text: &str) -> Result<Vec<u32>> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }

    /// Render ids back to text.
    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|id| self.word(*id))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// All words (for JSON export to `artifacts/data/vocab.json`).
    pub fn words(&self) -> &[String] {
        &self.words
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_is_stable_and_small() {
        let v = Vocab::synthlang();
        let v2 = Vocab::synthlang();
        assert_eq!(v.words(), v2.words());
        assert!(v.len() < 256, "vocab size {}", v.len());
        assert_eq!(v.padded_len() % 32, 0);
        assert!(v.padded_len() >= v.len());
    }

    #[test]
    fn specials_at_fixed_ids() {
        let v = Vocab::synthlang();
        assert_eq!(v.word(PAD), "<pad>");
        assert_eq!(v.word(BOS), "<bos>");
        assert_eq!(v.word(EOS), "<eos>");
        assert_eq!(v.word(UNK), "<unk>");
    }

    #[test]
    fn encode_decode_roundtrip() {
        let v = Vocab::synthlang();
        let text = "the red fox lives in the forest .";
        let ids = v.encode(text).unwrap();
        assert_eq!(v.decode(&ids), text);
    }

    #[test]
    fn unknown_word_is_error() {
        let v = Vocab::synthlang();
        assert!(v.encode("the purple dinosaur").is_err());
    }

    #[test]
    fn no_duplicate_words() {
        let v = Vocab::synthlang();
        let mut sorted = v.words().to_vec();
        sorted.sort();
        let before = sorted.len();
        sorted.dedup();
        assert_eq!(before, sorted.len());
    }

    #[test]
    fn out_of_range_id_is_unk() {
        let v = Vocab::synthlang();
        assert_eq!(v.word(9999), "<unk>");
    }
}
