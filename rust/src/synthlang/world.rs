//! The SynthLang world: a deterministic set of entities and facts.
//!
//! A world is a seeded sample of entities ("the red fox") with attributes
//! (habitat, diet, size). The corpus generator verbalizes these facts; the
//! task generators query them. Because both read the *same* world, eval
//! answers are consistent with the training text — the model's task is
//! memorization + format following, which a few hundred training steps on a
//! small transformer handles, giving the sparsification experiments a
//! meaningful dense baseline to degrade from.

use crate::synthlang::vocab::{ANIMALS, COLORS, FOODS, LOCATIONS, SIZES};
use crate::util::prng::Rng;

/// One entity and its attributes. Attribute values are indices into the
/// vocab constant lists, not strings, so worlds serialize compactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Entity {
    pub color: usize,
    pub animal: usize,
    pub location: usize,
    pub food: usize,
    pub size: usize,
}

impl Entity {
    /// "red fox" — the unique two-word name.
    pub fn name(&self) -> String {
        format!("{} {}", COLORS[self.color], ANIMALS[self.animal])
    }

    pub fn location_word(&self) -> &'static str {
        LOCATIONS[self.location]
    }

    pub fn food_word(&self) -> &'static str {
        FOODS[self.food]
    }

    pub fn size_word(&self) -> &'static str {
        SIZES[self.size]
    }
}

/// A generated world.
#[derive(Clone, Debug)]
pub struct World {
    pub seed: u64,
    pub entities: Vec<Entity>,
}

impl World {
    /// Sample `n` entities with unique (color, animal) names. Panics if `n`
    /// exceeds the number of distinct names.
    pub fn generate(seed: u64, n: usize) -> World {
        let max = COLORS.len() * ANIMALS.len();
        assert!(n <= max, "cannot generate {n} unique entities (max {max})");
        let mut rng = Rng::new(seed).fork("world");
        // Enumerate all (color, animal) pairs, shuffle, take n — guarantees
        // uniqueness without rejection sampling.
        let mut pairs: Vec<(usize, usize)> = (0..COLORS.len())
            .flat_map(|c| (0..ANIMALS.len()).map(move |a| (c, a)))
            .collect();
        rng.shuffle(&mut pairs);
        let entities = pairs
            .into_iter()
            .take(n)
            .map(|(color, animal)| Entity {
                color,
                animal,
                location: rng.below(LOCATIONS.len()),
                food: rng.below(FOODS.len()),
                size: rng.below(SIZES.len()),
            })
            .collect();
        World { seed, entities }
    }

    /// Does any entity live in `location`? (for boolq distractor filtering)
    pub fn entity(&self, i: usize) -> &Entity {
        &self.entities[i]
    }

    pub fn len(&self) -> usize {
        self.entities.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entities.is_empty()
    }

    /// A location index different from the entity's true one.
    pub fn wrong_location(&self, e: &Entity, rng: &mut Rng) -> usize {
        loop {
            let l = rng.below(LOCATIONS.len());
            if l != e.location {
                return l;
            }
        }
    }

    /// A food index different from the entity's true one.
    pub fn wrong_food(&self, e: &Entity, rng: &mut Rng) -> usize {
        loop {
            let f = rng.below(FOODS.len());
            if f != e.food {
                return f;
            }
        }
    }

    /// `k` distinct distractor locations (never the true one), for k-way
    /// multiple choice.
    pub fn distractor_locations(&self, e: &Entity, k: usize, rng: &mut Rng) -> Vec<usize> {
        assert!(k < LOCATIONS.len());
        let mut opts: Vec<usize> = (0..LOCATIONS.len()).filter(|l| *l != e.location).collect();
        rng.shuffle(&mut opts);
        opts.truncate(k);
        opts
    }

    /// `k` distinct distractor foods.
    pub fn distractor_foods(&self, e: &Entity, k: usize, rng: &mut Rng) -> Vec<usize> {
        assert!(k < FOODS.len());
        let mut opts: Vec<usize> = (0..FOODS.len()).filter(|f| *f != e.food).collect();
        rng.shuffle(&mut opts);
        opts.truncate(k);
        opts
    }

    /// Another entity with a different animal noun (for reference tasks).
    pub fn other_entity<'a>(&'a self, e: &Entity, rng: &mut Rng) -> &'a Entity {
        loop {
            let cand = &self.entities[rng.below(self.entities.len())];
            if cand.animal != e.animal {
                return cand;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = World::generate(7, 40);
        let b = World::generate(7, 40);
        assert_eq!(a.entities, b.entities);
        let c = World::generate(8, 40);
        assert_ne!(a.entities, c.entities);
    }

    #[test]
    fn names_unique() {
        let w = World::generate(1, 60);
        let mut names: Vec<String> = w.entities.iter().map(|e| e.name()).collect();
        names.sort();
        let before = names.len();
        names.dedup();
        assert_eq!(before, names.len());
    }

    #[test]
    fn attributes_in_range() {
        let w = World::generate(2, 50);
        for e in &w.entities {
            assert!(e.location < LOCATIONS.len());
            assert!(e.food < FOODS.len());
            assert!(e.size < SIZES.len());
        }
    }

    #[test]
    fn wrong_location_is_wrong() {
        let w = World::generate(3, 10);
        let mut rng = Rng::new(0);
        for e in &w.entities {
            for _ in 0..20 {
                assert_ne!(w.wrong_location(e, &mut rng), e.location);
            }
        }
    }

    #[test]
    fn distractors_distinct_and_wrong() {
        let w = World::generate(4, 10);
        let mut rng = Rng::new(1);
        let e = w.entity(0);
        let d = w.distractor_locations(e, 3, &mut rng);
        assert_eq!(d.len(), 3);
        let mut u = d.clone();
        u.sort();
        u.dedup();
        assert_eq!(u.len(), 3);
        assert!(!d.contains(&e.location));
    }

    #[test]
    fn other_entity_differs() {
        let w = World::generate(5, 20);
        let mut rng = Rng::new(2);
        let e = w.entity(0);
        for _ in 0..10 {
            assert_ne!(w.other_entity(e, &mut rng).animal, e.animal);
        }
    }

    #[test]
    #[should_panic]
    fn too_many_entities_panics() {
        World::generate(0, 10_000);
    }
}
