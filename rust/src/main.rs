//! `nmsparse` — launcher for the N:M activation-sparsity reproduction.
//!
//! Subcommands:
//!   datagen   generate the SynthLang data directory (runs before aot.py)
//!   smoke     verify the PJRT client + artifacts load end to end
//!   info      print manifest/config/training summary
//!   eval      evaluate one (pattern, method) cell on chosen tasks
//!   ppl       perplexity of a configuration on the validation corpus
//!   ifeval    instruction-following (strict/loose) for a configuration
//!   table     regenerate a paper table/figure (fig1, fig2, table2, ...)
//!   serve     run the TCP scoring/generation server (multi-replica;
//!             --backend coordinator|native)
//!   loadgen   drive a multi-replica ServerCore; emits BENCH_serving.json
//!             (--sweep emits BENCH_serving_sweep.json)
//!   decode    run the native KV-cached decode engine (--check pins
//!             KV-cached == full-context)
//!
//! Run `nmsparse <cmd> --help` for options.

use anyhow::Result;
use nmsparse::launcher;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    launcher::dispatch(&args)
}
