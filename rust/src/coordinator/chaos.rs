//! Deterministic fault injection for the serving core.
//!
//! [`ChaosBackend`] wraps any [`ReplicaBackend`] and executes a
//! [`FaultPlan`] against it: at engine-op tick `N` it panics, returns an
//! error, or stalls for a fixed number of milliseconds. Every decision is
//! a pure function of the plan and the op counter — no clocks, no OS
//! randomness — so a failure schedule replays bit-for-bit and the
//! supervision tests in `rust/tests/server_core.rs` can pin exact restart
//! and retry counts.
//!
//! Three pieces:
//!
//! - [`FaultPlan`]: a sorted list of one-shot faults, written in a tiny
//!   spec grammar (`panic@3;err@7;stall@5:20` — panic at op 3, error at
//!   op 7, 20 ms stall at op 5) or drawn from a seed
//!   ([`FaultPlan::seeded`], which always includes at least one panic so
//!   chaos runs always exercise the restart path).
//! - [`ChaosHandle`]: the *shared* tick counter + unfired faults. It
//!   lives outside the replica factory, so a rebuilt backend wrapped
//!   around the same handle continues the tick sequence instead of
//!   replaying fault 1 — a `panic@3` fires exactly once per plan, not
//!   once per restart.
//! - [`ChaosArg`]: the `--chaos` CLI argument — an integer seed (each
//!   replica derives its own plan) or an explicit spec string (every
//!   replica runs the same plan).
//!
//! Faults fire on the two engine ops (`score_rows`,
//! `decode_step_sessions`); the passthrough surface (`batch`,
//! `stop_tokens`, `end_session`) is never faulted, so capacity probing
//! and cleanup stay reliable even mid-plan.

use crate::coordinator::server::{ReplicaBackend, StepOutcome};
use crate::util::prng::Rng;
use anyhow::{bail, Result};
use std::collections::BTreeSet;
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

/// One scheduled fault, keyed by the 1-based engine-op tick it fires at.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Panic inside the backend call (the supervisor must catch it).
    Panic { tick: u64 },
    /// Return `Err` from the backend call.
    Error { tick: u64 },
    /// Sleep `ms` milliseconds, then run the op normally.
    Stall { tick: u64, ms: u64 },
}

impl Fault {
    pub fn tick(&self) -> u64 {
        match self {
            Fault::Panic { tick } | Fault::Error { tick } | Fault::Stall { tick, .. } => *tick,
        }
    }
}

/// A reproducible failure schedule: one-shot faults at distinct ticks.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct FaultPlan {
    faults: Vec<Fault>,
}

impl FaultPlan {
    /// Parse the spec grammar: `;`-separated terms of `panic@N`, `err@N`
    /// or `stall@N:D` (D in milliseconds), ticks 1-based and distinct.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut faults = Vec::new();
        let mut ticks = BTreeSet::new();
        for term in spec.split(';').map(str::trim).filter(|t| !t.is_empty()) {
            let (kind, rest) = term
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("chaos term '{term}' is missing '@tick'"))?;
            let fault = match kind {
                "panic" | "err" => {
                    let tick: u64 = rest
                        .parse()
                        .map_err(|_| anyhow::anyhow!("chaos term '{term}': bad tick '{rest}'"))?;
                    if kind == "panic" {
                        Fault::Panic { tick }
                    } else {
                        Fault::Error { tick }
                    }
                }
                "stall" => {
                    let (t, d) = rest.split_once(':').ok_or_else(|| {
                        anyhow::anyhow!("chaos term '{term}' needs 'stall@tick:ms'")
                    })?;
                    let tick: u64 = t
                        .parse()
                        .map_err(|_| anyhow::anyhow!("chaos term '{term}': bad tick '{t}'"))?;
                    let ms: u64 = d
                        .parse()
                        .map_err(|_| anyhow::anyhow!("chaos term '{term}': bad ms '{d}'"))?;
                    Fault::Stall { tick, ms }
                }
                other => bail!("unknown chaos fault kind '{other}' (panic|err|stall)"),
            };
            if fault.tick() == 0 {
                bail!("chaos term '{term}': ticks are 1-based");
            }
            if !ticks.insert(fault.tick()) {
                bail!("chaos spec '{spec}': duplicate tick {}", fault.tick());
            }
            faults.push(fault);
        }
        faults.sort_by_key(Fault::tick);
        Ok(FaultPlan { faults })
    }

    /// Draw a plan from a seed: 1–2 panics (always at least one, early
    /// enough that a bounded run reaches them even with full batches
    /// shrinking the op count), plus 0–2 errors and 0–2 short stalls.
    /// `horizon` is roughly the number of requests the plan should span.
    pub fn seeded(seed: u64, horizon: u64) -> FaultPlan {
        let mut rng = Rng::new(seed);
        let hi = horizon.max(8);
        let early = ((hi / 12).max(4)) as usize; // panic ticks in [1, early]
        let late = ((hi / 3).max(8)) as usize; // other ticks in [1, late]
        let mut ticks = BTreeSet::new();
        let mut faults = Vec::new();
        for _ in 0..1 + rng.below(2) {
            faults.push(Fault::Panic { tick: draw_tick(&mut rng, &mut ticks, early) });
        }
        for _ in 0..rng.below(3) {
            faults.push(Fault::Error { tick: draw_tick(&mut rng, &mut ticks, late) });
        }
        for _ in 0..rng.below(3) {
            let ms = 1 + rng.below(8) as u64;
            faults.push(Fault::Stall { tick: draw_tick(&mut rng, &mut ticks, late), ms });
        }
        faults.sort_by_key(Fault::tick);
        FaultPlan { faults }
    }

    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// Render back to the spec grammar (sorted by tick; parseable).
    pub fn to_spec(&self) -> String {
        let terms: Vec<String> = self
            .faults
            .iter()
            .map(|f| match f {
                Fault::Panic { tick } => format!("panic@{tick}"),
                Fault::Error { tick } => format!("err@{tick}"),
                Fault::Stall { tick, ms } => format!("stall@{tick}:{ms}"),
            })
            .collect();
        terms.join(";")
    }
}

/// Distinct 1-based tick in `[1, hi]`. The draw ranges are far larger
/// than the fault counts, so the rejection loop terminates fast.
fn draw_tick(rng: &mut Rng, ticks: &mut BTreeSet<u64>, hi: usize) -> u64 {
    loop {
        let t = rng.range(1, hi + 1) as u64;
        if ticks.insert(t) {
            return t;
        }
    }
}

struct ChaosInner {
    /// Engine ops observed so far (across backend rebuilds).
    tick: u64,
    /// Faults that have not fired yet.
    pending: Vec<Fault>,
}

/// Shared fault state for one replica: the op counter and the unfired
/// remainder of its plan. Clone it into every [`ChaosBackend`] built for
/// that replica — the state survives rebuilds, so each fault is one-shot
/// for the plan's lifetime, not per backend instance.
#[derive(Clone)]
pub struct ChaosHandle {
    inner: Arc<Mutex<ChaosInner>>,
}

impl ChaosHandle {
    pub fn new(plan: FaultPlan) -> ChaosHandle {
        let inner = ChaosInner { tick: 0, pending: plan.faults };
        ChaosHandle { inner: Arc::new(Mutex::new(inner)) }
    }

    /// Shorthand for `ChaosHandle::new(FaultPlan::seeded(..))`.
    pub fn seeded(seed: u64, horizon: u64) -> ChaosHandle {
        ChaosHandle::new(FaultPlan::seeded(seed, horizon))
    }

    /// Engine ops observed so far.
    pub fn ticks(&self) -> u64 {
        self.lock().tick
    }

    /// Faults still waiting to fire.
    pub fn remaining(&self) -> usize {
        self.lock().pending.len()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, ChaosInner> {
        // An injected panic unwinds *after* the guard is dropped, so the
        // mutex is never poisoned by design — recovery here is belt and
        // braces against future faults that fire under the lock.
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Advance the op counter and act out the fault scheduled for this
    /// tick, if any. The decision happens under the lock; the action
    /// (sleep / `Err` / panic) happens after the guard is dropped, so a
    /// panic can never poison the shared state.
    fn before_op(&self) -> Result<()> {
        let fired = {
            let mut g = self.lock();
            g.tick += 1;
            let t = g.tick;
            match g.pending.iter().position(|f| f.tick() == t) {
                Some(i) => Some(g.pending.remove(i)),
                None => None,
            }
        };
        match fired {
            None => Ok(()),
            Some(Fault::Stall { ms, .. }) => {
                std::thread::sleep(Duration::from_millis(ms));
                Ok(())
            }
            Some(Fault::Error { tick }) => bail!("chaos: injected error at tick {tick}"),
            Some(Fault::Panic { tick }) => panic!("chaos: injected panic at tick {tick}"),
        }
    }
}

/// A [`ReplicaBackend`] that runs its inner backend's ops through a
/// [`ChaosHandle`]. With `chaos: None` it is a pure passthrough, so all
/// launcher backend arms can wrap unconditionally and a no-chaos run
/// stays bitwise identical to an unwrapped one.
pub struct ChaosBackend<B> {
    inner: B,
    chaos: Option<ChaosHandle>,
}

impl<B: ReplicaBackend> ChaosBackend<B> {
    pub fn new(inner: B, chaos: Option<ChaosHandle>) -> ChaosBackend<B> {
        ChaosBackend { inner, chaos }
    }

    fn tick(&self) -> Result<()> {
        match &self.chaos {
            Some(h) => h.before_op(),
            None => Ok(()),
        }
    }
}

impl<B: ReplicaBackend> ReplicaBackend for ChaosBackend<B> {
    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn score_rows(&mut self, rows: &[(Vec<u32>, (usize, usize))]) -> Result<Vec<f64>> {
        self.tick()?;
        self.inner.score_rows(rows)
    }

    fn decode_step_sessions(&mut self, rows: &[(u64, &[u32])]) -> Result<Vec<StepOutcome>> {
        self.tick()?;
        self.inner.decode_step_sessions(rows)
    }

    fn end_session(&mut self, id: u64) {
        self.inner.end_session(id);
    }

    fn stop_tokens(&self) -> Vec<u32> {
        self.inner.stop_tokens()
    }
}

/// The `--chaos` CLI argument: a bare integer is a seed (each replica
/// derives its own [`FaultPlan`]); anything else is a spec every replica
/// runs verbatim.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ChaosArg {
    Seed(u64),
    Spec(FaultPlan),
}

impl ChaosArg {
    pub fn parse(s: &str) -> Result<ChaosArg> {
        let s = s.trim();
        if s.is_empty() {
            bail!("empty --chaos argument (want a seed or a fault spec)");
        }
        if s.bytes().all(|b| b.is_ascii_digit()) {
            return Ok(ChaosArg::Seed(s.parse()?));
        }
        Ok(ChaosArg::Spec(FaultPlan::parse(s)?))
    }

    /// Build replica `r`'s handle. Seeds are decorrelated per replica
    /// (golden-ratio stride); explicit specs replay identically on every
    /// replica.
    pub fn handle_for(&self, replica: usize, horizon: u64) -> ChaosHandle {
        match self {
            ChaosArg::Seed(seed) => {
                let stride = 0x9e37_79b9_7f4a_7c15u64;
                let sub = seed.wrapping_add(stride.wrapping_mul(replica as u64 + 1));
                ChaosHandle::seeded(sub, horizon)
            }
            ChaosArg::Spec(plan) => ChaosHandle::new(plan.clone()),
        }
    }

    /// Human-readable form for run banners.
    pub fn describe(&self) -> String {
        match self {
            ChaosArg::Seed(seed) => format!("seed {seed}"),
            ChaosArg::Spec(plan) => format!("spec '{}'", plan.to_spec()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    /// Counts calls; never fails on its own.
    struct CountBackend {
        calls: usize,
    }

    impl ReplicaBackend for CountBackend {
        fn batch(&self) -> usize {
            2
        }

        fn score_rows(&mut self, rows: &[(Vec<u32>, (usize, usize))]) -> Result<Vec<f64>> {
            self.calls += 1;
            Ok(vec![0.0; rows.len()])
        }

        fn decode_step_sessions(&mut self, rows: &[(u64, &[u32])]) -> Result<Vec<StepOutcome>> {
            self.calls += 1;
            Ok(vec![StepOutcome::Token(3); rows.len()])
        }

        fn stop_tokens(&self) -> Vec<u32> {
            vec![1]
        }
    }

    const ROW: (Vec<u32>, (usize, usize)) = (Vec::new(), (0, 0));

    #[test]
    fn spec_grammar_roundtrips_and_sorts() {
        let plan = FaultPlan::parse("err@7; panic@3 ;stall@5:20").unwrap();
        assert_eq!(plan.to_spec(), "panic@3;stall@5:20;err@7");
        assert_eq!(FaultPlan::parse(&plan.to_spec()).unwrap(), plan);
        assert_eq!(plan.faults().len(), 3);
        assert_eq!(FaultPlan::parse("").unwrap().faults().len(), 0);
    }

    #[test]
    fn bad_specs_are_rejected() {
        for bad in ["panic@", "boom@3", "panic@3;err@3", "stall@3", "stall@x:5", "panic@0"] {
            assert!(FaultPlan::parse(bad).is_err(), "spec '{bad}' must be rejected");
        }
    }

    #[test]
    fn seeded_plans_are_deterministic_and_always_panic() {
        for seed in [0u64, 7, 9, 0xBEEF, u64::MAX] {
            let a = FaultPlan::seeded(seed, 100);
            assert_eq!(a, FaultPlan::seeded(seed, 100), "seed {seed} must replay");
            assert!(
                a.faults().iter().any(|f| matches!(f, Fault::Panic { .. })),
                "seed {seed} plan has no panic: {a:?}"
            );
            let mut seen = BTreeSet::new();
            for f in a.faults() {
                assert!(f.tick() >= 1);
                assert!(seen.insert(f.tick()), "seed {seed}: duplicate tick");
            }
        }
        assert_ne!(FaultPlan::seeded(1, 100), FaultPlan::seeded(2, 100));
    }

    #[test]
    fn faults_fire_once_and_ticks_survive_rebuild() {
        let h = ChaosHandle::new(FaultPlan::parse("err@2;panic@3").unwrap());
        let mut b1 = ChaosBackend::new(CountBackend { calls: 0 }, Some(h.clone()));
        assert!(b1.score_rows(&[ROW]).is_ok()); // tick 1
        assert!(b1.score_rows(&[ROW]).is_err()); // tick 2: injected error
        drop(b1);
        // Rebuild around the SAME handle: the plan continues at tick 3.
        let mut b2 = ChaosBackend::new(CountBackend { calls: 0 }, Some(h.clone()));
        let panicked = catch_unwind(AssertUnwindSafe(|| {
            let _ = b2.decode_step_sessions(&[(0, &[4u32][..])]);
        }))
        .is_err();
        assert!(panicked, "tick 3 must panic");
        assert!(b2.score_rows(&[ROW]).is_ok()); // tick 4: plan exhausted
        assert_eq!(h.ticks(), 4);
        assert_eq!(h.remaining(), 0);
    }

    #[test]
    fn stall_sleeps_then_succeeds() {
        let h = ChaosHandle::new(FaultPlan::parse("stall@1:5").unwrap());
        let mut b = ChaosBackend::new(CountBackend { calls: 0 }, Some(h.clone()));
        let t0 = std::time::Instant::now();
        assert!(b.score_rows(&[ROW]).is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(5));
        assert_eq!(h.remaining(), 0);
    }

    #[test]
    fn passthrough_without_handle() {
        let mut b = ChaosBackend::new(CountBackend { calls: 0 }, None);
        for _ in 0..10 {
            assert!(b.score_rows(&[ROW]).is_ok());
        }
        assert_eq!(b.batch(), 2);
        assert_eq!(b.stop_tokens(), vec![1]);
    }

    #[test]
    fn chaos_arg_parses_seed_or_spec() {
        assert_eq!(ChaosArg::parse("42").unwrap(), ChaosArg::Seed(42));
        let spec = ChaosArg::parse("panic@2;stall@4:3").unwrap();
        assert!(matches!(spec, ChaosArg::Spec(_)));
        assert!(ChaosArg::parse("").is_err());
        assert!(ChaosArg::parse("nope@1").is_err());
        // Per-replica seed plans are decorrelated but individually stable.
        let arg = ChaosArg::Seed(7);
        let h0 = arg.handle_for(0, 96);
        let h1 = arg.handle_for(0, 96);
        assert_eq!(h0.remaining(), h1.remaining());
        assert_eq!(arg.describe(), "seed 7");
    }
}
