//! Engine pool: compiled-variant and bound-engine caches.
//!
//! Loading + PJRT-compiling an HLO variant takes seconds; binding uploads
//! ~11 MB of weights. Both are cached so table harnesses that sweep dozens
//! of (pattern × method) cells pay each cost once. Single-threaded by
//! design: PJRT wrapper types hold raw pointers (not `Send`), and XLA
//! already parallelizes execution internally. Multi-replica serving
//! therefore opens one pool *per replica thread* — see
//! [`crate::coordinator::server::CoordinatorBackend`], whose factory runs
//! inside each worker so no `Rc<Engine>` ever crosses a thread boundary.

use crate::coordinator::methods::MethodConfig;
use crate::engine::{EngineConfig, NativeEngine, NativeModel, NativeSparsity};
use crate::runtime::{Engine, Manifest, Runtime, Variant};
use crate::util::tensor::TensorStore;
use crate::util::trace::{self, Phase};
use anyhow::{Context, Result};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;

/// Owns the runtime, the artifact manifest, the checkpoint and the
/// calibration products; hands out bound engines on demand.
pub struct EnginePool {
    pub rt: Runtime,
    pub manifest: Manifest,
    pub weights: TensorStore,
    pub methodparams: TensorStore,
    variants: RefCell<HashMap<String, Arc<Variant>>>,
    engines: RefCell<HashMap<String, Rc<Engine>>>,
    /// Native (KV-cached, PJRT-free) engines, same cache key space as the
    /// bound PJRT engines.
    natives: RefCell<HashMap<String, Rc<RefCell<NativeEngine>>>>,
    /// Worker-pool width applied to native engines as they are built
    /// (see [`EnginePool::set_native_threads`]). Default 1 = inline.
    native_threads: Cell<usize>,
    /// Compile + bind wall-times, for the perf report.
    pub load_log: RefCell<Vec<(String, f64)>>,
}

impl EnginePool {
    /// Open an artifacts directory produced by `make artifacts`.
    pub fn open(artifacts_dir: &Path) -> Result<EnginePool> {
        let rt = Runtime::cpu()?;
        let manifest = Manifest::load(artifacts_dir)?;
        let weights = TensorStore::load(&artifacts_dir.join("ckpt"))
            .context("loading checkpoint (ckpt.bin/.json)")?;
        let methodparams = TensorStore::load(&artifacts_dir.join("methodparams"))
            .context("loading methodparams")?;
        Ok(EnginePool {
            rt,
            manifest,
            weights,
            methodparams,
            variants: RefCell::new(HashMap::new()),
            engines: RefCell::new(HashMap::new()),
            natives: RefCell::new(HashMap::new()),
            native_threads: Cell::new(1),
            load_log: RefCell::new(Vec::new()),
        })
    }

    /// Get (compile-caching) a variant executable.
    pub fn variant(&self, key: &str) -> Result<Arc<Variant>> {
        if let Some(v) = self.variants.borrow().get(key) {
            return Ok(Arc::clone(v));
        }
        let (v, dt) =
            trace::timed(Phase::EngineBuild, || self.rt.load_variant(&self.manifest, key));
        let v = v?;
        self.load_log
            .borrow_mut()
            .push((format!("compile:{key}"), dt.as_secs_f64()));
        self.variants
            .borrow_mut()
            .insert(key.to_string(), Arc::clone(&v));
        Ok(v)
    }

    /// Get (bind-caching) an engine for a method configuration.
    pub fn engine(&self, cfg: &MethodConfig) -> Result<Rc<Engine>> {
        let ekey = cfg.engine_key();
        if let Some(e) = self.engines.borrow().get(&ekey) {
            return Ok(Rc::clone(e));
        }
        let variant = self.variant(&cfg.variant_key)?;
        let (engine, dt) = trace::timed(Phase::EngineBuild, || -> Result<Rc<Engine>> {
            let weights = cfg.transformed_weights(&self.weights)?;
            let resolver = cfg.resolver(&weights, &self.methodparams);
            Ok(Rc::new(variant.bind(&self.rt, &resolver)?))
        });
        let engine = engine?;
        self.load_log
            .borrow_mut()
            .push((format!("bind:{}", cfg.id), dt.as_secs_f64()));
        self.engines.borrow_mut().insert(ekey, Rc::clone(&engine));
        Ok(engine)
    }

    /// Get (build-caching) a *native* engine for a method configuration:
    /// the artifacts checkpoint (after this config's weight transform)
    /// loaded into a pure-rust KV-cached [`NativeEngine`] at the
    /// manifest's dimensions, with per-site calibration vectors
    /// (S-PTS/L-PTS eta, Amber channel norms) drawn from the methodparams
    /// store. No PJRT compile or device upload — the native path works
    /// with the default-off `pjrt` feature.
    pub fn native_engine(&self, cfg: &MethodConfig) -> Result<Rc<RefCell<NativeEngine>>> {
        let ekey = cfg.engine_key();
        if let Some(e) = self.natives.borrow().get(&ekey) {
            return Ok(Rc::clone(e));
        }
        let (native, dt) = trace::timed(Phase::EngineBuild, || -> Result<NativeEngine> {
            let engine_cfg = EngineConfig::from_dims(&self.manifest.dims);
            let sparsity =
                NativeSparsity::from_method_with_params(cfg, &self.methodparams, &engine_cfg)?;
            let weights = cfg.transformed_weights(&self.weights)?;
            let model = NativeModel::from_store(&weights, &engine_cfg)
                .context("building native model from the artifacts checkpoint")?;
            let mut native = NativeEngine::new(model, sparsity)?;
            native.set_threads(self.native_threads.get());
            Ok(native)
        });
        let engine = Rc::new(RefCell::new(native?));
        self.load_log
            .borrow_mut()
            .push((format!("native:{}", cfg.id), dt.as_secs_f64()));
        self.natives.borrow_mut().insert(ekey, Rc::clone(&engine));
        Ok(engine)
    }

    /// Worker-pool width for native engines built *after* this call (min
    /// 1; already-cached engines keep their pool — evict first to rebuild
    /// wider). Threading never changes native decode bits, so mixing
    /// widths across cached engines is safe, just unannounced.
    pub fn set_native_threads(&self, threads: usize) {
        self.native_threads.set(threads.max(1));
    }

    /// Number of distinct engines bound so far.
    pub fn engines_bound(&self) -> usize {
        self.engines.borrow().len()
    }

    /// Drop cached engines (frees device buffers) but keep compiled variants.
    pub fn evict_engines(&self) {
        self.engines.borrow_mut().clear();
    }
}
