//! L3 coordinator: the serving/evaluation brain on top of the PJRT runtime.
//!
//! - [`methods`]: the paper's (criterion × transform) grid as runtime
//!   configurations;
//! - [`pool`]: compiled-variant + bound-engine caches;
//! - [`batcher`]: dynamic batching and fixed-shape packing;
//! - [`scheduler`]: continuous batching of mixed score/generate traffic;
//! - [`server`]: the socket-free multi-replica serving core (bounded
//!   admission, session-affine routing, deadline-driven batching,
//!   supervised replica restarts, per-request deadlines, latency stats)
//!   behind `nmsparse serve` / `loadgen`;
//! - [`chaos`]: deterministic fault injection ([`chaos::ChaosBackend`] +
//!   seeded [`chaos::FaultPlan`]s) so the failure paths above replay
//!   bit-for-bit under test and `loadgen --chaos`;
//! - [`Coordinator`]: the high-level API the eval harness, tables, server
//!   and examples use — score rows, measure perplexity, greedy-generate
//!   (full-context PJRT by default; KV-cached native decode via
//!   [`Coordinator::set_native`] / `EnginePool::native_engine`).

pub mod batcher;
pub mod chaos;
pub mod methods;
pub mod pool;
pub mod scheduler;
pub mod server;

use crate::coordinator::batcher::pack_rows;
use crate::coordinator::methods::MethodConfig;
use crate::coordinator::pool::EnginePool;
use anyhow::Result;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Atomic run counters for throughput reporting. Shared references can bump
/// these from parallel serving paths, and the counters themselves no longer
/// block `Coordinator: Sync` the way the old `Cell<usize>` trio did (the
/// engine pool's PJRT handles remain the only single-thread constraint).
/// Loads/stores use `Ordering::Relaxed` — they are statistics, not
/// synchronization.
#[derive(Debug, Default)]
pub struct CoordStats {
    forwards: AtomicUsize,
    rows_scored: AtomicUsize,
    tokens_generated: AtomicUsize,
}

impl CoordStats {
    pub fn new() -> CoordStats {
        CoordStats::default()
    }

    pub fn add_forwards(&self, by: usize) {
        self.forwards.fetch_add(by, Ordering::Relaxed);
    }

    pub fn add_rows_scored(&self, by: usize) {
        self.rows_scored.fetch_add(by, Ordering::Relaxed);
    }

    pub fn add_tokens_generated(&self, by: usize) {
        self.tokens_generated.fetch_add(by, Ordering::Relaxed);
    }

    pub fn forwards(&self) -> usize {
        self.forwards.load(Ordering::Relaxed)
    }

    pub fn rows_scored(&self) -> usize {
        self.rows_scored.load(Ordering::Relaxed)
    }

    pub fn tokens_generated(&self) -> usize {
        self.tokens_generated.load(Ordering::Relaxed)
    }

    /// One-line human summary for logs and bench footers.
    pub fn summary(&self) -> String {
        format!(
            "{} forwards, {} rows scored, {} tokens generated",
            self.forwards(),
            self.rows_scored(),
            self.tokens_generated()
        )
    }
}

/// High-level entry point owning the engine pool.
pub struct Coordinator {
    pub pool: EnginePool,
    /// Running counts for throughput reporting.
    pub stats: CoordStats,
    /// Route generation through the native KV-cached engine
    /// (`EnginePool::native_engine`) instead of full-context PJRT
    /// forwards. Scoring/perplexity stay on the PJRT path — the native
    /// engine's win is the decode loop.
    use_native: bool,
}

impl Coordinator {
    /// Open the artifacts directory (`make artifacts` output).
    pub fn open(artifacts_dir: &Path) -> Result<Coordinator> {
        Ok(Coordinator {
            pool: EnginePool::open(artifacts_dir)?,
            stats: CoordStats::new(),
            use_native: false,
        })
    }

    /// Open with native KV-cached decode selected (see
    /// [`Coordinator::set_native`]).
    pub fn open_native(artifacts_dir: &Path) -> Result<Coordinator> {
        let mut c = Coordinator::open(artifacts_dir)?;
        c.set_native(true);
        Ok(c)
    }

    /// Select (or deselect) the native decode engine for generation. The
    /// full-context PJRT path stays available and is the equivalence
    /// oracle (`rust/tests/integration.rs`).
    pub fn set_native(&mut self, on: bool) {
        self.use_native = on;
    }

    pub fn uses_native(&self) -> bool {
        self.use_native
    }

    /// Sum of continuation logprobs for each `(row, span)`:
    /// `sum_{t in [start,end)} log p(row[t] | row[:t])`.
    ///
    /// Rows longer than the artifact's sequence length are left-cropped
    /// (keeping the most recent context) with the span re-based.
    pub fn score_rows(
        &self,
        cfg: &MethodConfig,
        rows: &[(Vec<u32>, (usize, usize))],
    ) -> Result<Vec<f64>> {
        let engine = self.pool.engine(cfg)?;
        let dims = engine.dims().clone();
        let (batch, seq) = (dims.batch, dims.seq);

        // Crop + re-base spans.
        let mut cropped: Vec<Vec<u32>> = Vec::with_capacity(rows.len());
        let mut spans: Vec<(usize, usize)> = Vec::with_capacity(rows.len());
        for (row, (s, e)) in rows {
            anyhow::ensure!(*s >= 1, "span must start at >= 1 (token 0 has no context)");
            anyhow::ensure!(
                *e <= row.len() && s < e,
                "bad span ({s},{e}) for row len {}",
                row.len()
            );
            if row.len() > seq {
                let cut = row.len() - seq;
                anyhow::ensure!(
                    *s > cut,
                    "row of {} tokens cannot be scored: continuation span starts \
                     inside the cropped prefix (seq={seq})",
                    row.len()
                );
                cropped.push(row[cut..].to_vec());
                spans.push((*s - cut, *e - cut));
            } else {
                cropped.push(row.clone());
                spans.push((*s, *e));
            }
        }

        let packed = pack_rows(&cropped, batch, seq);
        let mut scores = Vec::with_capacity(rows.len());
        let mut idx = 0;
        for pb in &packed {
            let out = engine.run(&self.pool.rt, &pb.tokens, &pb.lens)?;
            self.stats.add_forwards(1);
            for r in 0..pb.rows {
                let (s, e) = spans[idx];
                // log p(row[t]) lives at tgt_lp[t-1].
                let base = r * seq;
                let mut total = 0.0f64;
                for t in s..e {
                    total += out.tgt_logprobs[base + t - 1] as f64;
                }
                scores.push(total);
                idx += 1;
            }
        }
        self.stats.add_rows_scored(rows.len());
        Ok(scores)
    }

    /// Perplexity over a token stream, using non-overlapping windows of the
    /// artifact's sequence length.
    pub fn perplexity(
        &self,
        cfg: &MethodConfig,
        stream: &[u32],
        max_windows: usize,
    ) -> Result<f64> {
        let engine = self.pool.engine(cfg)?;
        let dims = engine.dims().clone();
        let (batch, seq) = (dims.batch, dims.seq);
        let n_windows = (stream.len() / seq).min(max_windows.max(1));
        anyhow::ensure!(
            n_windows > 0,
            "token stream too short for perplexity: {} tokens < one {seq}-token window",
            stream.len()
        );
        let rows: Vec<Vec<u32>> = (0..n_windows)
            .map(|i| stream[i * seq..(i + 1) * seq].to_vec())
            .collect();
        let packed = pack_rows(&rows, batch, seq);
        let mut nll = 0.0f64;
        let mut count = 0usize;
        for pb in &packed {
            let out = engine.run(&self.pool.rt, &pb.tokens, &pb.lens)?;
            self.stats.add_forwards(1);
            for r in 0..pb.rows {
                let len = pb.lens[r] as usize;
                for t in 0..len.saturating_sub(1) {
                    nll -= out.tgt_logprobs[r * seq + t] as f64;
                    count += 1;
                }
            }
        }
        anyhow::ensure!(count > 0, "no tokens scored for perplexity");
        Ok((nll / count as f64).exp())
    }

    /// Greedy generation: extend each prompt until a stop token or
    /// `max_new` tokens. Allocating wrapper over
    /// [`Coordinator::generate_refs`].
    pub fn generate(
        &self,
        cfg: &MethodConfig,
        prompts: &[Vec<u32>],
        max_new: usize,
        stop: &[u32],
    ) -> Result<Vec<Vec<u32>>> {
        let refs: Vec<&[u32]> = prompts.iter().map(|p| p.as_slice()).collect();
        self.generate_refs(cfg, &refs, max_new, stop)
    }

    /// Greedy generation over borrowed prompt rows: extend each prompt
    /// until a stop token or `max_new` tokens.
    ///
    /// Two execution paths share these semantics:
    /// - **PJRT (default):** prompts are processed in fixed-size groups;
    ///   each step runs one full-context forward (the artifact shape is
    ///   static).
    /// - **Native ([`Coordinator::set_native`]):** each prompt prefills
    ///   once and then decodes one token per step against a KV cache
    ///   (`engine::NativeEngine`), with the configured N:M activation
    ///   sparsification applied in the compressed domain at every step.
    ///
    /// Takes `&[&[u32]]` so per-token callers (the serve decode loop, which
    /// borrows each session's incrementally-maintained row) don't clone
    /// every prompt on every step just to call in; the one working copy per
    /// group below is the only token copy on the path.
    pub fn generate_refs(
        &self,
        cfg: &MethodConfig,
        prompts: &[&[u32]],
        max_new: usize,
        stop: &[u32],
    ) -> Result<Vec<Vec<u32>>> {
        if self.use_native {
            return self.generate_refs_native(cfg, prompts, max_new, stop);
        }
        let engine = self.pool.engine(cfg)?;
        let dims = engine.dims().clone();
        let (batch, seq, vocab) = (dims.batch, dims.seq, dims.vocab);
        let mut outputs: Vec<Vec<u32>> = vec![Vec::new(); prompts.len()];

        for group_start in (0..prompts.len()).step_by(batch) {
            let group: Vec<usize> =
                (group_start..(group_start + batch).min(prompts.len())).collect();
            let mut rows: Vec<Vec<u32>> =
                group.iter().map(|&i| prompts[i].to_vec()).collect();
            let mut done: Vec<bool> = vec![false; group.len()];
            for _ in 0..max_new {
                if done.iter().all(|d| *d) {
                    break;
                }
                let packed = pack_rows(&rows, batch, seq);
                debug_assert_eq!(packed.len(), 1);
                let pb = &packed[0];
                let out = engine.run(&self.pool.rt, &pb.tokens, &pb.lens)?;
                self.stats.add_forwards(1);
                for (r, gi) in group.iter().enumerate() {
                    if done[r] {
                        continue;
                    }
                    let logits = &out.last_logits[r * vocab..(r + 1) * vocab];
                    let tok = argmax(logits) as u32;
                    rows[r].push(tok);
                    outputs[*gi].push(tok);
                    self.stats.add_tokens_generated(1);
                    if stop.contains(&tok) || rows[r].len() >= seq {
                        done[r] = true;
                    }
                }
            }
        }
        Ok(outputs)
    }

    /// The KV-cached generation loop behind [`Coordinator::generate_refs`]
    /// when the native engine is selected. One prefill per prompt, then
    /// one step per token; `forwards` counts engine steps (a step *is* a
    /// forward on this path), so throughput reports stay honest.
    fn generate_refs_native(
        &self,
        cfg: &MethodConfig,
        prompts: &[&[u32]],
        max_new: usize,
        stop: &[u32],
    ) -> Result<Vec<Vec<u32>>> {
        let engine = self.pool.native_engine(cfg)?;
        let mut engine = engine.borrow_mut();
        let mut kv_pool = engine.new_kv_pool();
        let mut kv = kv_pool.new_cache();
        let mut outputs = Vec::with_capacity(prompts.len());
        for prompt in prompts {
            let steps_before = engine.stats().steps;
            let out = engine.generate_greedy(&mut kv, &mut kv_pool, prompt, max_new, stop)?;
            self.stats.add_forwards((engine.stats().steps - steps_before) as usize);
            self.stats.add_tokens_generated(out.len());
            outputs.push(out);
        }
        Ok(outputs)
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn coord_stats_count_across_threads() {
        let stats = CoordStats::new();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..250 {
                        stats.add_forwards(1);
                        stats.add_rows_scored(2);
                        stats.add_tokens_generated(3);
                    }
                });
            }
        });
        assert_eq!(stats.forwards(), 1000);
        assert_eq!(stats.rows_scored(), 2000);
        assert_eq!(stats.tokens_generated(), 3000);
        assert_eq!(
            stats.summary(),
            "1000 forwards, 2000 rows scored, 3000 tokens generated"
        );
        // The whole struct is shareable by reference across threads.
        fn assert_sync<T: Sync>(_: &T) {}
        assert_sync(&stats);
    }
}
