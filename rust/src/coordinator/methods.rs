//! Method configurations: the paper's (criterion × transform) grid mapped
//! to runtime inputs of the AOT variants.
//!
//! A [`MethodConfig`] names a variant artifact (pattern), the flag settings,
//! which calibration families feed the per-site vectors, which sites are
//! exempt from sparsification, and an optional *weight transform* (WT
//! pruning / int8 quantization run through the dense artifact). The
//! [`MethodConfig::resolver`] closes over the checkpoint + methodparams
//! stores and satisfies the runtime's input manifest.

use crate::quant;
use crate::runtime::InputSpec;
use crate::sparsity::criteria::Criterion;
use crate::sparsity::transforms::Shift;
use crate::sparsity::{weightprune, Pattern, Sparsifier};
use crate::util::tensor::{Tensor, TensorStore};
use anyhow::{bail, Context, Result};

/// Static transform applied to the checkpoint before binding.
#[derive(Clone, Debug, PartialEq)]
pub enum WeightTransform {
    None,
    /// Magnitude weight pruning (the paper's WT rows).
    Prune(Pattern),
    /// Per-channel symmetric fake-quantization (Table 14 comparator).
    Quant(u32),
}

/// A fully-specified evaluation configuration.
#[derive(Clone, Debug)]
pub struct MethodConfig {
    /// Display name, e.g. "S-PTS", "CLACT+VAR".
    pub id: String,
    /// Artifact key, e.g. "dense", "8_16", "rsparse64_8_16".
    pub variant_key: String,
    pub shift_mode: f32,
    pub use_clact: f32,
    pub use_var: f32,
    /// Method-param family for eta, e.g. "spts_eta" or "lpts_eta.8_16".
    pub eta_family: Option<String>,
    /// Family for the channel score scale, e.g. "amber_cscale".
    pub cscale_family: Option<String>,
    /// Family for the learnable diagonal scale, e.g. "ls_scale.8_16".
    pub lsw_family: Option<String>,
    /// Site names (q/k/v/o/gate/up/down) with sparsification disabled.
    pub disabled_sites: Vec<String>,
    /// R-Sparse rank when the variant is an rsparse artifact.
    pub rank: Option<usize>,
    pub weight_transform: WeightTransform,
}

impl MethodConfig {
    /// Plain magnitude activation pruning for a pattern.
    pub fn act(pattern: Pattern) -> MethodConfig {
        MethodConfig {
            id: "ACT".into(),
            variant_key: pattern.artifact_key(),
            shift_mode: 0.0,
            use_clact: 0.0,
            use_var: 0.0,
            eta_family: None,
            cscale_family: None,
            lsw_family: None,
            disabled_sites: vec![],
            rank: None,
            weight_transform: WeightTransform::None,
        }
    }

    /// The dense (ORIG) baseline.
    pub fn dense() -> MethodConfig {
        let mut m = MethodConfig::act(Pattern::Dense);
        m.id = "ORIG".into();
        m
    }

    /// Weight pruning baseline: dense artifact + pruned checkpoint.
    pub fn wt(pattern: Pattern) -> MethodConfig {
        let mut m = MethodConfig::dense();
        m.id = "WT".into();
        m.weight_transform = WeightTransform::Prune(pattern);
        m
    }

    /// Int8 quantization comparator (Table 14).
    pub fn quant8() -> MethodConfig {
        let mut m = MethodConfig::dense();
        m.id = "INT8".into();
        m.weight_transform = WeightTransform::Quant(8);
        m
    }

    /// Look up a named method for a pattern. Names follow the paper's
    /// abbreviations (case-insensitive): act, wt, clact, amber, d-pts,
    /// s-pts, l-pts, var, ls+l-pts, r-sparse(64|128), combos with '+'.
    pub fn by_name(name: &str, pattern: Pattern) -> Result<MethodConfig> {
        let pat_key = pattern.artifact_key();
        let canon = name.to_ascii_lowercase().replace(['_', ' '], "-");
        let mut m = MethodConfig::act(pattern);
        m.id = name.to_string();
        match canon.as_str() {
            "orig" | "dense" => return Ok(MethodConfig::dense()),
            "act" => {}
            "wt" => return Ok(MethodConfig::wt(pattern)),
            "int8" | "quant8" => return Ok(MethodConfig::quant8()),
            "clact" => m.use_clact = 1.0,
            "amber" | "amber-pruner" => m.cscale_family = Some("amber_cscale".into()),
            "d-pts" | "dpts" => m.shift_mode = 1.0,
            "s-pts" | "spts" => {
                m.shift_mode = 2.0;
                m.eta_family = Some("spts_eta".into());
            }
            "l-pts" | "lpts" => {
                m.shift_mode = 2.0;
                m.eta_family = Some(format!("lpts_eta.{pat_key}"));
            }
            "var" => m.use_var = 1.0,
            "ls+l-pts" | "ls-l-pts" => {
                m.shift_mode = 2.0;
                m.eta_family = Some(format!("ls_eta.{pat_key}"));
                m.lsw_family = Some(format!("ls_scale.{pat_key}"));
            }
            "ls+l-pts+var" => {
                m.shift_mode = 2.0;
                m.eta_family = Some(format!("ls_eta.{pat_key}"));
                m.lsw_family = Some(format!("ls_scale.{pat_key}"));
                m.use_var = 1.0;
            }
            "l-pts+var" | "lpts+var" => {
                m.shift_mode = 2.0;
                m.eta_family = Some(format!("lpts_eta.{pat_key}"));
                m.use_var = 1.0;
            }
            "clact+pts" | "clact+s-pts" => {
                m.use_clact = 1.0;
                m.shift_mode = 2.0;
                m.eta_family = Some("spts_eta".into());
            }
            "clact+var" => {
                m.use_clact = 1.0;
                m.use_var = 1.0;
            }
            "amber+pts" | "amber-pruner+pts" => {
                m.cscale_family = Some("amber_cscale".into());
                m.shift_mode = 2.0;
                m.eta_family = Some("spts_eta".into());
            }
            "amber+var" | "amber-pruner+var" => {
                m.cscale_family = Some("amber_cscale".into());
                m.use_var = 1.0;
            }
            "r-sparse(64)" | "rsparse64" | "r-sparse-64" => {
                m.variant_key = format!("rsparse64_{pat_key}");
                m.rank = Some(64);
            }
            "r-sparse(128)" | "rsparse128" | "r-sparse-128" => {
                m.variant_key = format!("rsparse128_{pat_key}");
                m.rank = Some(128);
            }
            other => bail!("unknown method '{other}'"),
        }
        Ok(m)
    }

    /// Disable sparsification on the given sites (e.g. Qwen-style q/k/v
    /// exemption, or Table 5 layer subsets).
    pub fn with_disabled_sites(mut self, sites: &[&str]) -> MethodConfig {
        self.disabled_sites = sites.iter().map(|s| s.to_string()).collect();
        self
    }

    /// Recover the sparsity pattern this cell's artifact serves from its
    /// variant key (`"8_16"`, `"u50"`, `"dense"`, `"rsparse64_8_16"`).
    pub fn pattern(&self) -> Result<Pattern> {
        let key: &str = self
            .variant_key
            .strip_prefix("rsparse")
            .and_then(|r| r.split_once('_').map(|(_rank, rest)| rest))
            .unwrap_or(&self.variant_key);
        Pattern::parse(&key.replace('_', ":"))
            .with_context(|| format!("variant key '{}'", self.variant_key))
    }

    /// The rust-native fused pipeline equivalent of this cell's kernel
    /// flags, built **once per (method × pattern) cell** and reused across
    /// every row (the old path rebuilt per-row scoring closures).
    ///
    /// Per-*site* data lives in the methodparams store, so the caller
    /// supplies it: `eta` for `shift_mode == 2` (S-PTS/L-PTS), `cscale` for
    /// Amber (`cscale_family`) or CLACT (`use_clact` — pass this matrix's
    /// `criteria::clact_col_energy`, it is data-dependent). Missing
    /// required vectors are errors, never silent downgrades to ACT; cells
    /// with an LS diagonal scale (`lsw_family`) are kernel-only and are
    /// rejected here.
    pub fn sparsifier(&self, eta: Option<&[f32]>, cscale: Option<&[f32]>) -> Result<Sparsifier> {
        if self.lsw_family.is_some() {
            bail!(
                "method '{}' uses a learnable diagonal scale (lsw) — kernel-only, \
                 not representable in the host-side Sparsifier",
                self.id
            );
        }
        let mut sp = Sparsifier::new(self.pattern()?).with_var(self.use_var != 0.0);
        sp = match self.shift_mode as i64 {
            0 => sp,
            1 => sp.with_shift(Shift::DynamicPerToken),
            2 => {
                let e = eta.context(
                    "shift_mode 2 (S-PTS/L-PTS) needs this site's eta vector from methodparams",
                )?;
                sp.with_shift(Shift::PerChannel(e.to_vec()))
            }
            other => bail!("unknown shift_mode {other}"),
        };
        match (self.use_clact != 0.0, self.cscale_family.is_some(), cscale) {
            (true, _, Some(cs)) => {
                sp = sp
                    .with_channel_scale(cs.to_vec())
                    .with_criterion(Criterion::Clact);
            }
            (true, _, None) => bail!(
                "CLACT needs this activation matrix's column energies \
                 (criteria::clact_col_energy) passed as cscale"
            ),
            (false, true, Some(cs)) => {
                sp = sp
                    .with_channel_scale(cs.to_vec())
                    .with_criterion(Criterion::Amber);
            }
            (false, true, None) => bail!(
                "method '{}' scores with Amber channel norms — pass this site's \
                 cscale vector from methodparams",
                self.id
            ),
            (false, false, Some(_)) => bail!(
                "method '{}' defines no channel scale — refusing a cscale that \
                 would silently change its scoring criterion",
                self.id
            ),
            (false, false, None) => {}
        }
        Ok(sp)
    }

    /// Cache key distinguishing bound engines.
    pub fn engine_key(&self) -> String {
        format!(
            "{}|{}|{}|{}|{}|{:?}|{:?}|{:?}|{:?}|{:?}",
            self.variant_key,
            self.shift_mode,
            self.use_clact,
            self.use_var,
            self.disabled_sites.join(","),
            self.eta_family,
            self.cscale_family,
            self.lsw_family,
            self.rank,
            self.weight_transform,
        )
    }

    /// Checkpoint after this config's weight transform. Both transforms run
    /// through the fused pipeline: WT pruning builds one `Sparsifier` for
    /// the whole store, and quantization is a single fused sweep.
    pub fn transformed_weights(&self, weights: &TensorStore) -> Result<TensorStore> {
        let mut w = weights.clone();
        match &self.weight_transform {
            WeightTransform::None => {}
            WeightTransform::Prune(p) => {
                weightprune::prune_weights(&mut w, *p)?;
            }
            WeightTransform::Quant(bits) => {
                quant::quantize_store_with(&mut w, *bits, None)?;
            }
        }
        Ok(w)
    }

    /// Resolve one manifest input name to its tensor value.
    pub fn resolve(
        &self,
        spec: &InputSpec,
        weights: &TensorStore,
        methodparams: &TensorStore,
    ) -> Result<Tensor> {
        let name = spec.name.as_str();
        if let Some(wname) = name.strip_prefix("w.") {
            return weights.get(wname).cloned();
        }
        if let Some(rest) = name.strip_prefix("m.") {
            // rest examples: "eta.l0.q", "enable.l3.down", "flag.use_var",
            // "u.l1.gate" (rsparse).
            let parts: Vec<&str> = rest.split('.').collect();
            match parts.as_slice() {
                ["flag", "shift_mode"] => return Ok(Tensor::scalar(self.shift_mode)),
                ["flag", "use_clact"] => return Ok(Tensor::scalar(self.use_clact)),
                ["flag", "use_var"] => return Ok(Tensor::scalar(self.use_var)),
                ["enable", _l, site] => {
                    let on = !self.disabled_sites.iter().any(|d| d == site);
                    return Ok(Tensor::scalar(if on { 1.0 } else { 0.0 }));
                }
                ["eta", l, s] => {
                    return family_or(
                        &self.eta_family,
                        methodparams,
                        l,
                        s,
                        || Tensor::zeros(&spec.shape),
                    );
                }
                ["cscale", l, s] => {
                    return family_or(&self.cscale_family, methodparams, l, s, || {
                        ones(&spec.shape)
                    });
                }
                ["lsw", l, s] => {
                    return family_or(&self.lsw_family, methodparams, l, s, || {
                        ones(&spec.shape)
                    });
                }
                ["u", l, s] => {
                    let r = self.rank.context("rsparse input without rank")?;
                    return methodparams
                        .get(&format!("rsparse{r}_u.{l}.{s}"))
                        .cloned();
                }
                ["v", l, s] => {
                    let r = self.rank.context("rsparse input without rank")?;
                    return methodparams
                        .get(&format!("rsparse{r}_v.{l}.{s}"))
                        .cloned();
                }
                _ => bail!("unrecognized method input '{name}'"),
            }
        }
        bail!("unrecognized input '{name}'")
    }

    /// Build a boxed resolver closure for `Variant::bind`.
    pub fn resolver<'a>(
        &'a self,
        weights: &'a TensorStore,
        methodparams: &'a TensorStore,
    ) -> impl Fn(&InputSpec) -> Result<Tensor> + 'a {
        move |spec| self.resolve(spec, weights, methodparams)
    }
}

fn ones(shape: &[usize]) -> Tensor {
    let mut t = Tensor::zeros(shape);
    t.data.iter_mut().for_each(|x| *x = 1.0);
    t
}

fn family_or(
    family: &Option<String>,
    methodparams: &TensorStore,
    l: &str,
    s: &str,
    default: impl FnOnce() -> Tensor,
) -> Result<Tensor> {
    match family {
        None => Ok(default()),
        Some(f) => methodparams
            .get(&format!("{f}.{l}.{s}"))
            .cloned()
            .with_context(|| format!("method family '{f}' missing entry for {l}.{s}")),
    }
}

/// The method names evaluated in Table 2 (per pattern).
pub fn table2_methods() -> Vec<&'static str> {
    vec![
        "ACT", "CLACT", "Amber-Pruner", "VAR", "D-PTS", "S-PTS", "L-PTS",
        "R-Sparse(64)", "R-Sparse(128)",
    ]
}

/// The combination methods of Table 8.
pub fn table8_methods() -> Vec<&'static str> {
    vec![
        "CLACT+PTS", "CLACT+VAR", "Amber-Pruner+PTS", "Amber-Pruner+VAR", "L-PTS+VAR",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p816() -> Pattern {
        Pattern::NM { n: 8, m: 16 }
    }

    #[test]
    fn catalog_parses_all_table_methods() {
        for name in table2_methods().into_iter().chain(table8_methods()) {
            let m = MethodConfig::by_name(name, p816()).unwrap();
            assert_eq!(m.id, name);
        }
        assert!(MethodConfig::by_name("bogus", p816()).is_err());
    }

    #[test]
    fn spts_sets_eta_family_and_mode() {
        let m = MethodConfig::by_name("S-PTS", p816()).unwrap();
        assert_eq!(m.shift_mode, 2.0);
        assert_eq!(m.eta_family.as_deref(), Some("spts_eta"));
        assert_eq!(m.variant_key, "8_16");
    }

    #[test]
    fn lpts_family_is_pattern_specific() {
        let m = MethodConfig::by_name("L-PTS", Pattern::NM { n: 2, m: 4 }).unwrap();
        assert_eq!(m.eta_family.as_deref(), Some("lpts_eta.2_4"));
    }

    #[test]
    fn rsparse_variant_key() {
        let m = MethodConfig::by_name("R-Sparse(64)", p816()).unwrap();
        assert_eq!(m.variant_key, "rsparse64_8_16");
        assert_eq!(m.rank, Some(64));
    }

    #[test]
    fn resolve_flags_and_enables() {
        let m = MethodConfig::by_name("VAR", p816())
            .unwrap()
            .with_disabled_sites(&["q", "k", "v"]);
        let w = TensorStore::new();
        let mp = TensorStore::new();
        let flag = InputSpec {
            name: "m.flag.use_var".into(),
            shape: vec![],
            dtype: "f32".into(),
        };
        assert_eq!(m.resolve(&flag, &w, &mp).unwrap().data, vec![1.0]);
        let en_q = InputSpec {
            name: "m.enable.l2.q".into(),
            shape: vec![],
            dtype: "f32".into(),
        };
        assert_eq!(m.resolve(&en_q, &w, &mp).unwrap().data, vec![0.0]);
        let en_gate = InputSpec {
            name: "m.enable.l2.gate".into(),
            shape: vec![],
            dtype: "f32".into(),
        };
        assert_eq!(m.resolve(&en_gate, &w, &mp).unwrap().data, vec![1.0]);
    }

    #[test]
    fn resolve_defaults_and_families() {
        let mut mp = TensorStore::new();
        mp.insert("spts_eta.l0.q", Tensor::from_vec(&[4], vec![1., 2., 3., 4.]));
        let w = TensorStore::new();
        let spec = InputSpec {
            name: "m.eta.l0.q".into(),
            shape: vec![4],
            dtype: "f32".into(),
        };
        // ACT: zeros default.
        let act = MethodConfig::by_name("ACT", p816()).unwrap();
        assert_eq!(act.resolve(&spec, &w, &mp).unwrap().data, vec![0.0; 4]);
        // S-PTS: from family.
        let spts = MethodConfig::by_name("S-PTS", p816()).unwrap();
        assert_eq!(
            spts.resolve(&spec, &w, &mp).unwrap().data,
            vec![1., 2., 3., 4.]
        );
        // Missing family entry is an error.
        let spec_missing = InputSpec {
            name: "m.eta.l1.q".into(),
            shape: vec![4],
            dtype: "f32".into(),
        };
        assert!(spts.resolve(&spec_missing, &w, &mp).is_err());
        // cscale default is ones.
        let cspec = InputSpec {
            name: "m.cscale.l0.q".into(),
            shape: vec![4],
            dtype: "f32".into(),
        };
        assert_eq!(act.resolve(&cspec, &w, &mp).unwrap().data, vec![1.0; 4]);
    }

    #[test]
    fn weight_transforms_apply() {
        let mut w = TensorStore::new();
        w.insert(
            "layers.0.q.w",
            Tensor::from_vec(&[4, 8], (0..32).map(|i| i as f32 - 16.0).collect()),
        );
        let wt = MethodConfig::wt(Pattern::NM { n: 2, m: 4 });
        let pruned = wt.transformed_weights(&w).unwrap();
        assert!((pruned.get("layers.0.q.w").unwrap().zero_fraction() - 0.5).abs() < 0.1);
        let q = MethodConfig::quant8();
        let quanted = q.transformed_weights(&w).unwrap();
        let qdiff =
            quanted.get("layers.0.q.w").unwrap().max_abs_diff(w.get("layers.0.q.w").unwrap());
        assert!(qdiff > 0.0);
        // None leaves weights untouched.
        let act = MethodConfig::dense();
        assert_eq!(
            act.transformed_weights(&w).unwrap().get("layers.0.q.w").unwrap(),
            w.get("layers.0.q.w").unwrap()
        );
    }

    #[test]
    fn pattern_roundtrips_from_variant_key() {
        assert_eq!(
            MethodConfig::by_name("ACT", p816()).unwrap().pattern().unwrap(),
            p816()
        );
        assert_eq!(
            MethodConfig::dense().pattern().unwrap(),
            Pattern::Dense
        );
        assert_eq!(
            MethodConfig::act(Pattern::Unstructured { keep_pct: 50 })
                .pattern()
                .unwrap(),
            Pattern::Unstructured { keep_pct: 50 }
        );
        assert_eq!(
            MethodConfig::by_name("R-Sparse(64)", p816())
                .unwrap()
                .pattern()
                .unwrap(),
            p816()
        );
    }

    #[test]
    fn sparsifier_built_once_per_cell_reflects_flags() {
        let dpts = MethodConfig::by_name("D-PTS", p816()).unwrap();
        let sp = dpts.sparsifier(None, None).unwrap();
        assert_eq!(sp.pattern(), p816());
        assert!(matches!(sp.shift(), Shift::DynamicPerToken));
        assert!(!sp.uses_var());

        let var = MethodConfig::by_name("VAR", p816()).unwrap();
        assert!(var.sparsifier(None, None).unwrap().uses_var());

        // S-PTS needs the site's eta vector.
        let spts = MethodConfig::by_name("S-PTS", p816()).unwrap();
        assert!(spts.sparsifier(None, None).is_err());
        let sp = spts.sparsifier(Some(&[0.5; 16]), None).unwrap();
        assert!(matches!(sp.shift(), Shift::PerChannel(v) if v.len() == 16));

        // CLACT / Amber require their channel scales — never a silent ACT.
        let clact = MethodConfig::by_name("CLACT", p816()).unwrap();
        assert!(clact.sparsifier(None, None).is_err());
        let sp = clact.sparsifier(None, Some(&[1.0; 16])).unwrap();
        assert_eq!(sp.criterion(), Criterion::Clact);
        let amber = MethodConfig::by_name("Amber-Pruner", p816()).unwrap();
        assert!(amber.sparsifier(None, None).is_err());
        assert_eq!(
            amber
                .sparsifier(None, Some(&[1.0; 16]))
                .unwrap()
                .criterion(),
            Criterion::Amber
        );

        // A cscale for a method that defines none is rejected, not applied.
        assert!(dpts.sparsifier(None, Some(&[1.0; 16])).is_err());

        // LS cells are kernel-only.
        let ls = MethodConfig::by_name("LS+L-PTS", p816()).unwrap();
        assert!(ls.sparsifier(Some(&[0.0; 16]), None).is_err());

        // The built pipeline actually sparsifies at the cell's pattern.
        let act = MethodConfig::by_name("ACT", p816()).unwrap();
        let sp = act.sparsifier(None, None).unwrap();
        let mut row: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        sp.sparsify_row(&mut row, &mut crate::sparsity::Scratch::new());
        assert_eq!(row.iter().filter(|v| **v != 0.0).count(), 8);
    }

    #[test]
    fn engine_keys_distinguish_configs() {
        let a = MethodConfig::by_name("ACT", p816()).unwrap();
        let b = MethodConfig::by_name("VAR", p816()).unwrap();
        let c = MethodConfig::by_name("ACT", Pattern::NM { n: 2, m: 4 }).unwrap();
        assert_ne!(a.engine_key(), b.engine_key());
        assert_ne!(a.engine_key(), c.engine_key());
    }
}
