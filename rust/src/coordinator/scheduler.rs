//! Continuous-batching scheduler for mixed score/generate workloads.
//!
//! Miniature of a vLLM-style loop specialized to fixed-shape executables:
//! *score* requests are prefill-only (one forward), *generate* requests are
//! sessions that need one forward per emitted token. The scheduler decides,
//! each step, which rows ride the next fixed-size batch:
//!
//! - decode-priority (default): active sessions first — keeps per-token
//!   latency low, matching the paper's observation that decode is the
//!   latency-sensitive stage;
//! - a fairness counter prevents prefill starvation under decode load.
//!
//! Pure logic (no engine handle), so invariants are property-tested.

use std::collections::{HashMap, VecDeque};

/// A prefill-only scoring job.
#[derive(Clone, Debug, PartialEq)]
pub struct ScoreJob {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Span `[start, end)` of the continuation to score.
    pub span: (usize, usize),
}

/// An autoregressive generation session.
///
/// The full engine row (prompt + generated) is kept incrementally in a
/// private buffer: `row()` is a borrow, and each decode step appends one
/// token instead of re-cloning the whole prompt (the seed rebuilt an
/// O(len) `Vec` per token per session). Mutate generation state only
/// through [`Session::push_token`] so the buffer stays in sync.
#[derive(Clone, Debug, PartialEq)]
pub struct Session {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub generated: Vec<u32>,
    pub max_new: usize,
    pub done: bool,
    /// `tokens ++ generated`, maintained incrementally by `push_token`.
    row: Vec<u32>,
}

impl Session {
    pub fn new(id: u64, tokens: Vec<u32>, max_new: usize) -> Session {
        Session {
            id,
            row: tokens.clone(),
            tokens,
            generated: Vec::new(),
            max_new: max_new.max(1),
            done: false,
        }
    }

    /// Current full row (prompt + generated so far) — a borrow of the
    /// incrementally-maintained buffer, not a fresh allocation.
    pub fn row(&self) -> &[u32] {
        &self.row
    }

    /// Record one generated token; mark done on stop token or budget.
    pub fn push_token(&mut self, tok: u32, stop: &[u32]) {
        self.generated.push(tok);
        self.row.push(tok);
        if stop.contains(&tok) || self.generated.len() >= self.max_new {
            self.done = true;
        }
    }
}

/// What the engine should run next.
#[derive(Clone, Debug, PartialEq)]
pub enum Work {
    /// Run these scoring rows (ids refer to submitted jobs).
    Score(Vec<u64>),
    /// Advance these sessions one token.
    Decode(Vec<u64>),
    /// Nothing queued.
    Idle,
}

/// Scheduling policy.
#[derive(Clone, Copy, Debug)]
pub struct SchedPolicy {
    /// Decode batches dispatched before a queued prefill is forced through.
    pub max_decode_streak: usize,
    /// Prefer decode over prefill when both are queued.
    pub decode_priority: bool,
}

impl Default for SchedPolicy {
    fn default() -> Self {
        SchedPolicy {
            max_decode_streak: 4,
            decode_priority: true,
        }
    }
}

/// The scheduler state.
pub struct Scheduler {
    policy: SchedPolicy,
    batch: usize,
    scores: VecDeque<ScoreJob>,
    sessions: Vec<Session>,
    /// session id → index in `sessions` — O(1) lookup for the per-token
    /// `session_mut` calls in the decode loop (the seed scanned linearly).
    session_idx: HashMap<u64, usize>,
    decode_streak: usize,
    next_id: u64,
}

impl Scheduler {
    pub fn new(batch: usize, policy: SchedPolicy) -> Scheduler {
        Scheduler {
            policy,
            batch,
            scores: VecDeque::new(),
            sessions: Vec::new(),
            session_idx: HashMap::new(),
            decode_streak: 0,
            next_id: 1,
        }
    }

    /// Submit a scoring job; returns its id.
    pub fn submit_score(&mut self, tokens: Vec<u32>, span: (usize, usize)) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.scores.push_back(ScoreJob { id, tokens, span });
        id
    }

    /// Submit a generation session; returns its id.
    pub fn submit_generate(&mut self, tokens: Vec<u32>, max_new: usize) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.session_idx.insert(id, self.sessions.len());
        self.sessions.push(Session::new(id, tokens, max_new));
        id
    }

    pub fn score_job(&self, id: u64) -> Option<&ScoreJob> {
        self.scores.iter().find(|j| j.id == id)
    }

    pub fn session(&self, id: u64) -> Option<&Session> {
        self.session_idx.get(&id).map(|&i| &self.sessions[i])
    }

    pub fn session_mut(&mut self, id: u64) -> Option<&mut Session> {
        match self.session_idx.get(&id) {
            Some(&i) => Some(&mut self.sessions[i]),
            None => None,
        }
    }

    /// Remove finished sessions, returning them. Rebuilds the id→index
    /// map (O(live) once per reap, vs O(live) per lookup before).
    pub fn reap_done(&mut self) -> Vec<Session> {
        let (done, live): (Vec<_>, Vec<_>) =
            self.sessions.drain(..).partition(|s| s.done);
        self.sessions = live;
        self.session_idx.clear();
        for (i, s) in self.sessions.iter().enumerate() {
            self.session_idx.insert(s.id, i);
        }
        done
    }

    /// Remove a completed score job.
    pub fn complete_score(&mut self, id: u64) {
        self.scores.retain(|j| j.id != id);
    }

    pub fn pending(&self) -> (usize, usize) {
        (
            self.scores.len(),
            self.sessions.iter().filter(|s| !s.done).count(),
        )
    }

    /// Decide the next batch of work.
    pub fn next_work(&mut self) -> Work {
        let live: Vec<u64> = self
            .sessions
            .iter()
            .filter(|s| !s.done)
            .map(|s| s.id)
            .take(self.batch)
            .collect();
        let have_decode = !live.is_empty();
        let have_score = !self.scores.is_empty();
        let choose_decode = match (have_decode, have_score) {
            (false, false) => return Work::Idle,
            (true, false) => true,
            (false, true) => false,
            (true, true) => {
                if self.policy.decode_priority
                    && self.decode_streak < self.policy.max_decode_streak
                {
                    true
                } else {
                    false
                }
            }
        };
        if choose_decode {
            self.decode_streak += 1;
            Work::Decode(live)
        } else {
            self.decode_streak = 0;
            let ids = self
                .scores
                .iter()
                .take(self.batch)
                .map(|j| j.id)
                .collect();
            Work::Score(ids)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop::{forall_simple, Config};
    use crate::util::prng::Rng;

    #[test]
    fn idle_when_empty() {
        let mut s = Scheduler::new(4, SchedPolicy::default());
        assert_eq!(s.next_work(), Work::Idle);
    }

    #[test]
    fn decode_priority_with_fairness() {
        let mut s = Scheduler::new(2, SchedPolicy::default());
        let g = s.submit_generate(vec![1, 2], 100);
        s.submit_score(vec![3], (0, 1));
        // Decode wins max_decode_streak times, then prefill is forced.
        let mut decode_count = 0;
        for _ in 0..4 {
            match s.next_work() {
                Work::Decode(ids) => {
                    assert_eq!(ids, vec![g]);
                    decode_count += 1;
                }
                Work::Score(_) => break,
                Work::Idle => panic!("not idle"),
            }
        }
        assert_eq!(decode_count, SchedPolicy::default().max_decode_streak);
        assert!(matches!(s.next_work(), Work::Score(_)));
        // After the prefill, the streak resets and decode resumes.
        assert!(matches!(s.next_work(), Work::Decode(_)));
    }

    #[test]
    fn sessions_finish_on_stop_or_budget() {
        let mut sess = Session::new(1, vec![1], 3);
        sess.push_token(7, &[99]);
        assert!(!sess.done);
        sess.push_token(99, &[99]);
        assert!(sess.done); // stop token
        let mut sess2 = Session::new(2, vec![1], 2);
        sess2.push_token(5, &[99]);
        sess2.push_token(6, &[99]);
        assert!(sess2.done); // budget
        assert_eq!(sess2.row(), &[1, 5, 6][..]);
    }

    #[test]
    fn incremental_row_tracks_prompt_plus_generated() {
        // The row buffer stays in sync with tokens ++ generated across
        // many pushes — the invariant the O(1) row() borrow rests on.
        let mut sess = Session::new(7, vec![10, 11, 12], 100);
        assert_eq!(sess.row(), &[10, 11, 12][..]);
        for t in 0..50u32 {
            sess.push_token(t, &[]);
            let mut expect = sess.tokens.clone();
            expect.extend(&sess.generated);
            assert_eq!(sess.row(), expect.as_slice());
        }
    }

    #[test]
    fn session_lookup_survives_reap() {
        // The id→index map must be rebuilt when reap_done compacts the
        // session vec, or lookups would hit the wrong session.
        let mut s = Scheduler::new(8, SchedPolicy::default());
        let a = s.submit_generate(vec![1], 1);
        let b = s.submit_generate(vec![2], 5);
        let c = s.submit_generate(vec![3], 5);
        s.session_mut(a).unwrap().push_token(9, &[]); // a done
        s.reap_done();
        assert!(s.session(a).is_none());
        assert_eq!(s.session(b).unwrap().tokens, vec![2]);
        assert_eq!(s.session_mut(c).unwrap().tokens, vec![3]);
        // New submissions after a reap keep ids and indices consistent.
        let d = s.submit_generate(vec![4], 5);
        assert_eq!(s.session(d).unwrap().tokens, vec![4]);
        s.session_mut(b).unwrap().push_token(9, &[9]); // b done via stop
        let done = s.reap_done();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, b);
        assert_eq!(s.session(c).unwrap().tokens, vec![3]);
        assert_eq!(s.session(d).unwrap().tokens, vec![4]);
    }

    #[test]
    fn reap_done_removes_only_finished() {
        let mut s = Scheduler::new(4, SchedPolicy::default());
        let a = s.submit_generate(vec![1], 1);
        let b = s.submit_generate(vec![2], 5);
        s.session_mut(a).unwrap().push_token(9, &[]);
        let done = s.reap_done();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, a);
        assert!(s.session(b).is_some());
    }

    #[test]
    fn batch_size_respected() {
        let mut s = Scheduler::new(3, SchedPolicy::default());
        for i in 0..10 {
            s.submit_generate(vec![i], 5);
        }
        match s.next_work() {
            Work::Decode(ids) => assert_eq!(ids.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn prop_no_starvation_and_progress() {
        // Whatever mix is submitted, repeatedly servicing next_work makes
        // everything complete.
        let cfg = Config { cases: 64, ..Config::default() };
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let scores = rng.range(0, 10);
                let gens = rng.range(0, 6);
                let max_new = rng.range(1, 5);
                (scores, gens, max_new)
            },
            |(scores, gens, max_new)| {
                let mut s = Scheduler::new(4, SchedPolicy::default());
                for i in 0..*scores {
                    s.submit_score(vec![i as u32], (0, 1));
                }
                for i in 0..*gens {
                    s.submit_generate(vec![i as u32], *max_new);
                }
                for _ in 0..1000 {
                    match s.next_work() {
                        Work::Idle => break,
                        Work::Score(ids) => {
                            for id in ids {
                                s.complete_score(id);
                            }
                        }
                        Work::Decode(ids) => {
                            for id in ids {
                                s.session_mut(id).unwrap().push_token(1, &[]);
                            }
                            s.reap_done();
                        }
                    }
                }
                s.pending() == (0, 0)
            },
        );
    }
}
