//! Dynamic batching: accumulate work items until the batch is full or the
//! oldest item has waited too long — the standard latency/throughput knob
//! of LLM serving, applied to our fixed-shape PJRT executables.
//!
//! [`Batcher`] is a pure policy structure (easy to property-test); the
//! server and eval harness wire it to an [`crate::runtime::Engine`].
//! [`pack_rows`] turns variable-length token rows into the engine's fixed
//! `[batch, seq]` layout, padding the tail with dummy rows.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Flush policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max items per batch (the artifact's static batch dimension).
    pub capacity: usize,
    /// Max time the oldest item may wait before a partial flush.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            capacity: 16,
            max_wait: Duration::from_millis(20),
        }
    }
}

/// FIFO accumulator with deadline-based partial flushing.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    queue: VecDeque<(T, Instant)>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        Batcher {
            policy,
            queue: VecDeque::new(),
        }
    }

    pub fn push(&mut self, item: T) {
        self.push_at(item, Instant::now())
    }

    pub fn push_at(&mut self, item: T, now: Instant) {
        self.queue.push_back((item, now));
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should a batch be dispatched now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.capacity {
            return true;
        }
        match self.queue.front() {
            Some((_, t0)) => now.duration_since(*t0) >= self.policy.max_wait,
            None => false,
        }
    }

    /// When will the oldest item's deadline expire? (for timed waits)
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queue.front().map(|(_, t0)| *t0 + self.policy.max_wait)
    }

    /// Remove up to `capacity` items in FIFO order into a caller-owned
    /// buffer (cleared first) — the serve loop reuses one buffer across
    /// flushes instead of allocating a fresh `Vec` per batch.
    pub fn drain_batch_into(&mut self, out: &mut Vec<T>) {
        out.clear();
        let n = self.queue.len().min(self.policy.capacity);
        out.extend(self.queue.drain(..n).map(|(t, _)| t));
    }

    /// Remove up to `capacity` items in FIFO order (allocating wrapper
    /// over [`Batcher::drain_batch_into`]).
    pub fn drain_batch(&mut self) -> Vec<T> {
        let mut out = Vec::with_capacity(self.queue.len().min(self.policy.capacity));
        self.drain_batch_into(&mut out);
        out
    }
}

/// One packed fixed-shape batch.
#[derive(Clone, Debug)]
pub struct PackedBatch {
    /// `[batch * seq]` i32, padded with 0 (`<pad>`).
    pub tokens: Vec<i32>,
    /// `[batch]` valid lengths (dummy rows get 1).
    pub lens: Vec<i32>,
    /// How many leading rows are real.
    pub rows: usize,
}

/// Pack variable-length rows into `[batch, seq]` batches. Rows longer than
/// `seq` are left-truncated (keep the most recent context) — mirrors
/// LM-eval's context cropping.
pub fn pack_rows(rows: &[Vec<u32>], batch: usize, seq: usize) -> Vec<PackedBatch> {
    let mut out = Vec::new();
    for chunk in rows.chunks(batch.max(1)) {
        let mut tokens = vec![0i32; batch * seq];
        let mut lens = vec![1i32; batch];
        for (r, row) in chunk.iter().enumerate() {
            let cropped: &[u32] = if row.len() > seq {
                &row[row.len() - seq..]
            } else {
                row
            };
            for (t, tok) in cropped.iter().enumerate() {
                tokens[r * seq + t] = *tok as i32;
            }
            lens[r] = cropped.len().max(1) as i32;
        }
        out.push(PackedBatch {
            tokens,
            lens,
            rows: chunk.len(),
        });
    }
    out
}

/// How much of the packed compute is useful — diagnostics for the batching
/// policy (padding waste). Degenerate inputs (no batches, or a zero batch
/// dimension, which `pack_rows` itself guards with `batch.max(1)`) report
/// 1.0 instead of dividing by zero.
pub fn packing_efficiency(batches: &[PackedBatch], batch: usize) -> f64 {
    let used: usize = batches.iter().map(|b| b.rows).sum();
    occupancy(used, batches.len(), batch)
}

/// The `packing_efficiency` formula over raw counts: `rows` useful rows
/// dispatched across `batches` fixed-shape launches of `capacity` slots.
/// Used by [`crate::coordinator::server::ServerCore`] to report batch
/// occupancy without materializing `PackedBatch`es. Returns 1.0 when no
/// batch was dispatched (nothing was wasted).
pub fn occupancy(rows: usize, batches: usize, capacity: usize) -> f64 {
    if batches == 0 {
        return 1.0;
    }
    rows as f64 / (batches * capacity.max(1)) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop::{forall_simple, Config};
    use crate::util::prng::Rng;

    fn policy(cap: usize, ms: u64) -> BatchPolicy {
        BatchPolicy {
            capacity: cap,
            max_wait: Duration::from_millis(ms),
        }
    }

    #[test]
    fn flushes_when_full() {
        let mut b = Batcher::new(policy(4, 1000));
        let now = Instant::now();
        for i in 0..3 {
            b.push_at(i, now);
        }
        assert!(!b.ready(now));
        b.push_at(3, now);
        assert!(b.ready(now));
        assert_eq!(b.drain_batch(), vec![0, 1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = Batcher::new(policy(100, 10));
        let t0 = Instant::now();
        b.push_at(42, t0);
        assert!(!b.ready(t0));
        assert!(b.ready(t0 + Duration::from_millis(11)));
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(10)));
    }

    #[test]
    fn drain_respects_capacity_and_order() {
        let mut b = Batcher::new(policy(3, 10));
        let now = Instant::now();
        for i in 0..8 {
            b.push_at(i, now);
        }
        assert_eq!(b.drain_batch(), vec![0, 1, 2]);
        assert_eq!(b.drain_batch(), vec![3, 4, 5]);
        assert_eq!(b.drain_batch(), vec![6, 7]);
    }

    #[test]
    fn drain_into_reuses_buffer_and_matches_wrapper() {
        let mut a = Batcher::new(policy(3, 10));
        let mut b = Batcher::new(policy(3, 10));
        let now = Instant::now();
        for i in 0..8 {
            a.push_at(i, now);
            b.push_at(i, now);
        }
        let mut buf: Vec<i32> = Vec::new();
        let mut cap_after_first = 0usize;
        for round in 0..3 {
            a.drain_batch_into(&mut buf);
            assert_eq!(buf, b.drain_batch(), "round {round}");
            if round == 0 {
                cap_after_first = buf.capacity();
            } else {
                // The reused buffer never re-allocates: batches are capped
                // at `capacity`, which the first round already fit.
                assert_eq!(buf.capacity(), cap_after_first, "round {round}");
            }
        }
        assert!(a.is_empty() && b.is_empty());
        a.drain_batch_into(&mut buf);
        assert!(buf.is_empty(), "empty batcher clears the buffer");
    }

    #[test]
    fn prop_all_items_drain_in_fifo_order() {
        let cfg = Config::default();
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let cap = rng.range(1, 9);
                let n = rng.range(0, 50);
                (cap, (0..n).collect::<Vec<usize>>())
            },
            |(cap, items)| {
                let mut b = Batcher::new(policy(*cap, 0));
                let now = Instant::now();
                for &i in items {
                    b.push_at(i, now);
                }
                let mut got = Vec::new();
                while !b.is_empty() {
                    let batch = b.drain_batch();
                    if batch.len() > *cap {
                        return false;
                    }
                    got.extend(batch);
                }
                got == *items
            },
        );
    }

    #[test]
    fn pack_rows_shapes_and_crop() {
        let rows = vec![
            vec![5u32, 6, 7],
            vec![1; 20], // longer than seq: left-truncated
            vec![9],
        ];
        let packed = pack_rows(&rows, 2, 8);
        assert_eq!(packed.len(), 2);
        assert_eq!(packed[0].rows, 2);
        assert_eq!(packed[1].rows, 1);
        assert_eq!(packed[0].tokens.len(), 16);
        assert_eq!(packed[0].lens, vec![3, 8]);
        assert_eq!(packed[1].lens, vec![1, 1]); // dummy row len 1
        assert_eq!(&packed[0].tokens[0..3], &[5, 6, 7]);
    }

    #[test]
    fn prop_packing_preserves_tokens() {
        let cfg = Config::default();
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let n = rng.range(1, 40);
                let rows: Vec<Vec<u32>> = (0..n)
                    .map(|_| {
                        let len = rng.range(1, 12);
                        (0..len).map(|_| rng.below(100) as u32).collect()
                    })
                    .collect();
                rows
            },
            |rows| {
                let (batch, seq) = (4usize, 16usize);
                let packed = pack_rows(rows, batch, seq);
                let mut idx = 0;
                for pb in &packed {
                    for r in 0..pb.rows {
                        let len = pb.lens[r] as usize;
                        let got: Vec<u32> = pb.tokens[r * seq..r * seq + len]
                            .iter()
                            .map(|t| *t as u32)
                            .collect();
                        if got != rows[idx] {
                            return false;
                        }
                        idx += 1;
                    }
                }
                idx == rows.len()
            },
        );
    }

    #[test]
    fn efficiency_metric() {
        let rows = vec![vec![1u32]; 6];
        let packed = pack_rows(&rows, 4, 8);
        // 6 rows over 2 batches of 4 = 0.75.
        assert!((packing_efficiency(&packed, 4) - 0.75).abs() < 1e-12);
        assert_eq!(packing_efficiency(&[], 4), 1.0);
    }

    #[test]
    fn efficiency_degenerate_batch_dim() {
        // batch == 0 used to divide by zero (pack_rows guards with
        // batch.max(1) but the efficiency denominator did not).
        let rows = vec![vec![1u32]; 3];
        let packed = pack_rows(&rows, 0, 8);
        let e = packing_efficiency(&packed, 0);
        assert!(e.is_finite());
        assert!((e - 1.0).abs() < 1e-12); // 3 rows over 3 batches of max(0,1)=1 slot
        assert_eq!(occupancy(0, 0, 16), 1.0);
        assert!((occupancy(12, 1, 16) - 0.75).abs() < 1e-12);
        assert!(occupancy(5, 5, 0).is_finite());
    }
}
