//! Multi-replica serving core — the socket-free engine room behind
//! `nmsparse serve` and `nmsparse loadgen`.
//!
//! The seed server was one engine thread with unbounded admission and a
//! 2 ms busy-poll idle loop. [`ServerCore`] scales that loop to N engine
//! replicas and makes its behavior measurable:
//!
//! - **Replica-per-thread.** PJRT handles are not `Send`
//!   (`EnginePool::engine` returns `Rc<Engine>`), so each worker thread
//!   builds its *own* backend via the caller's factory — for the real
//!   path that means each replica opens its own `Coordinator`/engine
//!   pool ([`CoordinatorBackend`]); `--backend native` runs the
//!   KV-cached [`NativeBackend`] (artifacts checkpoint when present,
//!   seeded synthetic model otherwise); tests and CI use the
//!   artifact-free [`SyntheticBackend`].
//! - **Batched session stepping.** `ReplicaBackend::decode_step_sessions`
//!   is THE decode op: each worker tick hands every live session to the
//!   backend at once, and the native backend turns the tick into one
//!   `StepBatch` — each sparsified site one packed multi-row matmul over
//!   all lanes, paged KV per session, page-granular sliding windows for
//!   context-exhausted sessions (DESIGN.md §2.10).
//! - **Session-affine routing.** [`ServerHandle::submit_with_key`] pins a
//!   session key (e.g. one TCP connection) to a replica, so decode
//!   sessions and their follow-up traffic stay on the engine that holds
//!   them; keyless traffic goes to the least-loaded replica.
//! - **Work stealing.** Staged requests live in per-replica injection
//!   queues; an *idle* replica steals the oldest staged request from the
//!   deepest other queue (skewed session keys no longer serialize on one
//!   engine). Affinity still governs placement — stealing only moves
//!   work that has not started, and a submit into a backlogged replica
//!   wakes a potential thief.
//! - **Bounded admission.** Each replica admits at most `queue_cap`
//!   in-flight requests; beyond that [`SubmitError::Overloaded`] is
//!   returned *synchronously* and the protocol layer replies
//!   `{"ok":false,"error":"overloaded"}` instead of queueing without
//!   bound.
//! - **Deadline-driven waits.** Requests stage in per-tenant queues
//!   (`TenantStage`); an idle replica blocks on its wake channel until
//!   the oldest staged request must flush (or a new request arrives)
//!   instead of the seed's fixed 2 ms sleep — full batches dispatch
//!   immediately, partial batches after `max_wait`.
//! - **Multi-tenant fairness.** Admission is two-gated (per-tenant
//!   quota, then the global `queue_cap`) and each flush round drains
//!   tenants deficit-round-robin by weight, so a 10:1 traffic skew
//!   cannot starve the light tenant ([`SubmitOpts::tenant`],
//!   [`TenantStats`], DESIGN.md §2.15).
//! - **Streamed generates.** A submit may carry a bounded
//!   `wire::stream` lane; the decode loop offers each accepted token
//!   non-blocking (a slow client lags its own lane, never the tick),
//!   and dropping the lane on terminal reply is the end-of-stream
//!   signal.
//! - **Graceful drain.** [`ServerCore::shutdown`] stops admission, wakes
//!   every replica, and joins them only after all admitted work has been
//!   answered — no ticket is left dangling.
//! - **Supervised replicas.** Backend calls run under `catch_unwind`; a
//!   panic (or an `Err`) fails the replica, not the server. Every
//!   request the dead engine held gets a terminal answer — stateless
//!   `score`s are transparently retried on a live sibling (bounded by
//!   [`MAX_SCORE_RETRIES`]), stateful `generate` sessions fail fast with
//!   [`ERR_REPLICA_FAILED`] — and the backend is rebuilt via the same
//!   factory with capped exponential backoff ([`ReplicaStats::restarts`]
//!   counts successful rebuilds). Work staged behind the failure stays
//!   queued and is served after the rebuild; stealing and least-loaded
//!   routing both avoid dead replicas (DESIGN.md §2.12).
//! - **Per-request deadlines.** [`ServerHandle::submit_with`] carries an
//!   optional absolute deadline; an expired request is shed from the
//!   staged queue with a terminal [`ERR_TIMEOUT`] error instead of
//!   occupying a batch lane (`--request-timeout-ms` on serve/loadgen).
//! - **Measured, not asserted.** Every request's submit→reply latency is
//!   recorded into a [`Histogram`] (p50/p95/p99), and batch occupancy
//!   uses the `packing_efficiency` formula over dispatched rows vs
//!   slots. `{"op":"stats"}` and `BENCH_serving.json` read these.

use crate::coordinator::batcher::occupancy;
use crate::coordinator::methods::MethodConfig;
use crate::coordinator::scheduler::{SchedPolicy, Scheduler, Work};
use crate::coordinator::Coordinator;
use crate::engine::{
    EngineConfig, KvCache, KvPagePool, NativeEngine, NativeModel, NativeSparsity, SessionKvPool,
    StepBatch,
};
use crate::sparsity::Pattern;
use crate::util::stats::Histogram;
use crate::util::trace::{self, Phase};
use crate::wire::stream::StreamSender;
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Terminal error message for requests on a replica whose backend
/// panicked or errored (the `{"ok":false,"error":"replica_failed"}` the
/// wire layer forwards verbatim).
pub const ERR_REPLICA_FAILED: &str = "replica_failed";

/// Terminal error message for requests whose deadline expired while
/// staged (shed before occupying a batch lane).
pub const ERR_TIMEOUT: &str = "timeout";

/// Cross-replica retry budget for idempotent (score) requests whose
/// replica failed mid-flight. Generates are never retried — a session's
/// KV state died with its engine, and silently replaying a stateful
/// request is worse than a fast, distinguishable failure.
pub const MAX_SCORE_RETRIES: u32 = 2;

/// Lock that survives poisoning: a replica thread that panics inside a
/// backend call is caught by the supervisor, but if any future unwind
/// path does poison a stats/inject mutex, healthy replicas and the
/// `stats` op must keep working — the plain data under these locks is
/// never left mid-update across an unwind boundary.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

// ---------------------------------------------------------------- requests

/// A parsed request, ready for a replica's scheduler.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Score the continuation span `[start, end)` of `tokens`.
    Score { tokens: Vec<u32>, span: (usize, usize) },
    /// Greedy-generate up to `max_new` tokens after the prompt.
    Generate { tokens: Vec<u32>, max_new: usize },
}

/// A terminal reply for one request.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Score { score: f64 },
    Generate { tokens: Vec<u32> },
    Error { message: String },
}

/// Why a submit was refused before reaching a replica.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The replica's `queue_cap` is full — shed load instead of queueing.
    Overloaded { replica: usize },
    /// The core is shutting down (or the replica is gone).
    Closed,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Overloaded { .. } => write!(f, "overloaded"),
            SubmitError::Closed => write!(f, "shutting down"),
        }
    }
}

/// Handle to one in-flight request: which replica took it, and where its
/// terminal [`Response`] will arrive. A stolen request answers from the
/// thief; `replica` records the admission target.
pub struct Ticket {
    pub replica: usize,
    rx: mpsc::Receiver<Response>,
}

impl Ticket {
    /// Block until the reply arrives. The supervised core answers every
    /// admitted request terminally — success, [`ERR_TIMEOUT`], or
    /// [`ERR_REPLICA_FAILED`] — so `None` (sender dropped without a
    /// reply) indicates the core itself was torn down ungracefully.
    pub fn recv(&self) -> Option<Response> {
        self.rx.recv().ok()
    }

    pub fn recv_timeout(&self, d: Duration) -> Option<Response> {
        self.rx.recv_timeout(d).ok()
    }

    pub fn try_recv(&self) -> Option<Response> {
        self.rx.try_recv().ok()
    }
}

// ---------------------------------------------------------------- backends

/// One lane's result from [`ReplicaBackend::decode_step_sessions`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepOutcome {
    /// The lane advanced and emitted its next token.
    Token(u32),
    /// The lane made bounded progress (a resumable prefill block) but has
    /// no token yet — the scheduler re-ticks it with the unchanged row on
    /// the next dispatch (continuous batching).
    Pending,
    /// The backend ended the session (degenerate row, backend policy).
    End,
}

impl StepOutcome {
    /// The emitted token, if any (`Token(t)` → `Some(t)`).
    pub fn token(self) -> Option<u32> {
        match self {
            StepOutcome::Token(t) => Some(t),
            _ => None,
        }
    }
}

/// What one replica thread needs from its engine. Implementations own all
/// non-`Send` state (they are *built inside* the replica thread by the
/// factory passed to [`ServerCore::start`]). The surface is deliberately
/// lean — three ops and a release hook; the per-prompt `decode_step` of
/// earlier revisions is gone, batched session stepping IS the primary
/// decode op.
pub trait ReplicaBackend {
    /// Fixed batch capacity — scheduler slots per dispatch.
    fn batch(&self) -> usize;

    /// Score each `(tokens, span)` row: sum of continuation logprobs.
    fn score_rows(&mut self, rows: &[(Vec<u32>, (usize, usize))]) -> Result<Vec<f64>>;

    /// THE decode op: advance every `(session id, full row)` lane. The id
    /// is stable for the life of a generate session on this replica —
    /// KV-cached backends key incremental state by it and batch all lanes
    /// through one `StepBatch` per call; stateless backends just read the
    /// rows. A lane normally yields [`StepOutcome::Token`];
    /// [`StepOutcome::Pending`] defers it to the next tick with its row
    /// unchanged (bounded prefill of a long prompt), and
    /// [`StepOutcome::End`] ends the session early. The shipped backends
    /// emit until the scheduler ends sessions via stop tokens or the
    /// `max_new` budget (the native backend slides past the context edge,
    /// the coordinator backend left-crops).
    fn decode_step_sessions(&mut self, rows: &[(u64, &[u32])]) -> Result<Vec<StepOutcome>>;

    /// A generate session finished (stop/budget/context/error) — release
    /// any per-session state. Default: nothing to release.
    fn end_session(&mut self, _id: u64) {}

    /// Tokens that terminate a generate session.
    fn stop_tokens(&self) -> Vec<u32>;
}

/// The production PJRT backend: one [`Coordinator`] (engine pool, PJRT
/// client, bound engine) owned wholesale by one replica thread. Every
/// decode step is a full-context forward (the artifact executables are
/// fixed-shape) — [`NativeBackend`] is the KV-cached alternative.
pub struct CoordinatorBackend {
    coord: Coordinator,
    cfg: MethodConfig,
    stop: Vec<u32>,
    batch: usize,
}

impl CoordinatorBackend {
    /// Open the artifacts directory and bind the configured engine before
    /// taking traffic. Call this from inside the replica factory — the
    /// pool's PJRT handles must never cross threads.
    pub fn open(artifacts: &Path, cfg: MethodConfig, stop: Vec<u32>) -> Result<CoordinatorBackend> {
        let coord = Coordinator::open(artifacts)?;
        let batch = {
            let engine = coord.pool.engine(&cfg)?;
            engine.dims().batch
        };
        Ok(CoordinatorBackend { coord, cfg, stop, batch })
    }
}

impl ReplicaBackend for CoordinatorBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn score_rows(&mut self, rows: &[(Vec<u32>, (usize, usize))]) -> Result<Vec<f64>> {
        self.coord.score_rows(&self.cfg, rows)
    }

    /// Stateless: one full-context forward per row (the artifact
    /// executables are fixed-shape); session ids are irrelevant. Rows at
    /// or past the context edge are left-cropped by `pack_rows`, so this
    /// backend always emits a token — its sessions end at the scheduler
    /// level via stop tokens or the `max_new` budget.
    fn decode_step_sessions(&mut self, rows: &[(u64, &[u32])]) -> Result<Vec<StepOutcome>> {
        let prompts: Vec<&[u32]> = rows.iter().map(|(_, p)| *p).collect();
        let outs = self.coord.generate_refs(&self.cfg, &prompts, 1, &self.stop)?;
        Ok(outs
            .into_iter()
            .map(|o| match o.into_iter().next() {
                Some(t) => StepOutcome::Token(t),
                None => StepOutcome::End,
            })
            .collect())
    }

    fn stop_tokens(&self) -> Vec<u32> {
        self.stop.clone()
    }
}

/// The native KV-cached backend (`--backend native`): a pure-rust
/// [`NativeEngine`] whose generate sessions decode against per-session
/// paged caches ([`SessionKvPool`] slots over a shared [`KvPagePool`]) —
/// no full-context re-forward per token, no PJRT, no artifacts required
/// (weights come from the artifacts checkpoint when present, otherwise a
/// seeded deterministic synthetic model; calibrated per-site S-PTS/L-PTS/
/// Amber vectors load from the artifacts methodparams store).
///
/// Every scheduler tick becomes **one [`StepBatch`]** across all live
/// lanes (chunked to the session-cache cap so an LRU eviction can never
/// rob a lane mid-batch): each sparsified site runs as one packed
/// multi-row matmul. Context-exhausted sessions **slide** instead of
/// ending — the page-granular window rule
/// ([`KvPagePool::window_start`]) drops the oldest page block and
/// re-anchors (crop + re-prefill, the native twin of the PJRT crop
/// path), so generation continues to the session's `max_new` budget. The
/// rule is a pure function of the row length, so an evicted session
/// re-prefills its window transparently on its next step — slower, never
/// wrong (`rust/tests/step_batch.rs` pins cap-1 interleaving).
pub struct NativeBackend {
    engine: NativeEngine,
    /// Shared page storage for every cache below.
    pages: KvPagePool,
    /// Scratch cache for prefill-only work (scoring).
    score_kv: KvCache,
    /// Per-session incremental cache slots, keyed by scheduler session
    /// id; each slot records the window anchor its cache is built at.
    sessions: SessionKvPool,
    /// Reusable batched-step plan — one per tick.
    batch: StepBatch,
    stop: Vec<u32>,
    batch_cap: usize,
    /// Resumable-prefill block budget per session per tick (0 = feed a
    /// lane's whole backlog in one tick, the pre-existing behavior).
    prefill_block: usize,
    /// "artifacts" or "synthetic" — where the weights came from.
    pub origin: &'static str,
}

impl NativeBackend {
    /// Resident per-session KV slots per replica; an evicted session is
    /// re-prefilled from its row on its next step (slower, never wrong).
    pub const DEFAULT_SESSION_CAP: usize = 64;

    /// Artifacts checkpoint when `io_manifest.json` exists under
    /// `artifacts` (with this method's weight transform applied, and
    /// per-site calibration vectors from the methodparams store), else a
    /// seeded synthetic model at [`EngineConfig::tiny`] dimensions.
    pub fn open(
        artifacts: &Path,
        pattern: Pattern,
        method: &str,
        stop: Vec<u32>,
        batch: usize,
        seed: u64,
    ) -> Result<NativeBackend> {
        let mcfg = MethodConfig::by_name(method, pattern)?;
        let (model, sparsity, origin) =
            crate::engine::decode::load_native_parts(artifacts, &mcfg, seed)?;
        NativeBackend::from_model(model, sparsity, stop, batch, origin)
    }

    /// Purely synthetic backend (tests, loadgen, CI smoke).
    pub fn synthetic(
        cfg: &EngineConfig,
        seed: u64,
        sparsity: NativeSparsity,
        stop: Vec<u32>,
        batch: usize,
    ) -> Result<NativeBackend> {
        let model = NativeModel::synthetic(cfg, seed);
        NativeBackend::from_model(model, sparsity, stop, batch, "synthetic")
    }

    fn from_model(
        model: NativeModel,
        sparsity: NativeSparsity,
        stop: Vec<u32>,
        batch: usize,
        origin: &'static str,
    ) -> Result<NativeBackend> {
        let engine = NativeEngine::new(model, sparsity)?;
        let mut backend = NativeBackend::from_engine(engine, stop, batch);
        backend.origin = origin;
        Ok(backend)
    }

    /// Wrap an already-built engine (e.g. `nmsparse decode --lanes`
    /// reusing its loaded model) in a serving backend. The session-slot
    /// pool is sized to at least the scheduler tick width (`batch`): a
    /// cap below it would make each tick's chunks evict each other's
    /// slots, silently degrading every token to a full-window re-prefill
    /// (`with_session_cap` remains the explicit override for tests).
    pub fn from_engine(engine: NativeEngine, stop: Vec<u32>, batch: usize) -> NativeBackend {
        let pages = engine.new_kv_pool();
        let batch_cap = batch.max(1);
        NativeBackend {
            score_kv: pages.new_cache(),
            sessions: SessionKvPool::new(Self::DEFAULT_SESSION_CAP.max(batch_cap)),
            batch: StepBatch::new(),
            pages,
            engine,
            stop,
            batch_cap,
            prefill_block: 0,
            origin: "prebuilt",
        }
    }

    /// Bound prompt ingestion to at most one `block`-position blocked
    /// prefill chunk per session per tick (the `--prefill-block` flag):
    /// a lane more than one token behind its row catches up through the
    /// no-logits blocked kernel and returns [`StepOutcome::Pending`]
    /// until its final token is next, so a long prompt admits
    /// incrementally instead of stalling the tick's decode lanes
    /// (continuous batching). `0` (the default) keeps the pre-existing
    /// feed-to-completion tick — the sequential oracle the bounded path
    /// is pinned against.
    pub fn with_prefill_block(mut self, block: usize) -> NativeBackend {
        self.prefill_block = block;
        self
    }

    /// Resize the engine's worker pool (the `--threads` flag on
    /// `nmsparse serve`/`loadgen --backend native`). Weight-row
    /// partitioning keeps every lane's logits bitwise identical at any
    /// width, so this only changes tick wall time.
    pub fn with_threads(mut self, threads: usize) -> NativeBackend {
        self.engine.set_threads(threads);
        self
    }

    /// Override the LRU session-slot bound (tests pin eviction safety at
    /// cap 1 — batched steps chunk lanes to this bound).
    pub fn with_session_cap(mut self, cap: usize) -> NativeBackend {
        self.sessions = SessionKvPool::new(cap);
        self
    }

    /// Override the KV page granularity (tests pin page-boundary and
    /// sliding-window behavior with tiny pages).
    pub fn with_page_tokens(mut self, page_tokens: usize) -> NativeBackend {
        self.pages = self.engine.new_kv_pool_with(page_tokens);
        self.score_kv = self.pages.new_cache();
        self.sessions = SessionKvPool::new(self.sessions.cap());
        self
    }

    pub fn engine(&self) -> &NativeEngine {
        &self.engine
    }

    /// The shared page pool (tests read peak/outstanding byte counters).
    pub fn pages(&self) -> &KvPagePool {
        &self.pages
    }
}

impl ReplicaBackend for NativeBackend {
    fn batch(&self) -> usize {
        self.batch_cap
    }

    fn score_rows(&mut self, rows: &[(Vec<u32>, (usize, usize))]) -> Result<Vec<f64>> {
        let max_seq = self.engine.config().max_seq;
        let mut out = Vec::with_capacity(rows.len());
        for (tokens, (s, e)) in rows {
            // Left-crop long rows and re-base the span, exactly like
            // `Coordinator::score_rows`.
            let (row, span) = if tokens.len() > max_seq {
                let cut = tokens.len() - max_seq;
                anyhow::ensure!(
                    *s > cut,
                    "row of {} tokens cannot be scored: continuation span starts \
                     inside the cropped prefix (max_seq={max_seq})",
                    tokens.len()
                );
                (&tokens[cut..], (*s - cut, *e - cut))
            } else {
                (&tokens[..], (*s, *e))
            };
            out.push(self.engine.score_span(&mut self.score_kv, &mut self.pages, row, span)?);
        }
        // Scoring is prefill-only scratch work — recycle its pages now
        // rather than pinning them until the next score request (they
        // would distort the live-context page counters).
        self.score_kv.reset(&mut self.pages);
        Ok(out)
    }

    /// One batched step across every lane. Each session feeds only the
    /// window tokens its cache has not seen (normally exactly one; a
    /// fresh, evicted or freshly-slid session catches up over several
    /// ragged batched steps — or, with a `prefill_block` budget, over
    /// several Pending ticks of bounded blocked-prefill chunks), and a
    /// lane's final token loads the logits its next token is read from.
    /// Sessions never end on context here — the sliding window keeps
    /// them alive until stop/budget.
    fn decode_step_sessions(&mut self, rows: &[(u64, &[u32])]) -> Result<Vec<StepOutcome>> {
        let mut out = vec![StepOutcome::Pending; rows.len()];
        let cap = self.sessions.cap();
        let vocab = self.engine.config().vocab as u32;
        // Bounded resumable prefill needs lane slots to survive between
        // ticks; when the tick itself overflows the slot cap, chunks
        // would evict each other's in-flight prefill (livelock), so fall
        // back to feed-to-completion. In the serving loop this never
        // triggers: the pool is sized to at least the tick width.
        let bounded = self.prefill_block > 0 && rows.len() <= cap;
        for (chunk_idx, chunk) in rows.chunks(cap).enumerate() {
            let base = chunk_idx * cap;
            // A degenerate lane (empty row, out-of-vocab prompt token)
            // must not poison the shared batch: it ends its OWN session
            // (`End`, slot released) while healthy concurrent lanes keep
            // decoding — `Err` from here would abort every session in
            // the tick.
            let mut dead = vec![false; chunk.len()];
            // Reconcile each lane's cache with its current window. The
            // window start is a pure function of the row length, so a
            // rebound (evicted) slot simply re-prefills. `>=` (not `>`):
            // a cache already fed through the whole row means the caller
            // re-ticked an unchanged row (its emitted token was never
            // appended) — rebuild and re-emit deterministically instead
            // of returning a session-ending End. In the normal flow the
            // row has grown past the fed prefix, so equality never
            // triggers a rebuild there.
            for (j, (id, row)) in chunk.iter().enumerate() {
                if row.is_empty() {
                    dead[j] = true;
                    out[base + j] = StepOutcome::End;
                    self.sessions.remove(&mut self.pages, *id);
                    continue;
                }
                let ws = self.pages.window_start(row.len());
                let slot = self.sessions.get_or_create(&mut self.pages, *id);
                if slot.anchor != ws || ws + slot.kv.len() >= row.len() {
                    slot.kv.reset(&mut self.pages);
                    slot.anchor = ws;
                }
            }
            // Bounded prefill (continuous batching): each lane more than
            // one token behind its row catches up by at most one blocked
            // chunk — the no-logits body kernel — per tick. Lanes whose
            // final token becomes next join the shared step below and
            // emit; the rest return Pending and resume next tick from
            // their cursor (= anchor + kv.len(), persisted in the slot).
            if bounded {
                for (j, (id, row)) in chunk.iter().enumerate() {
                    if dead[j] {
                        continue;
                    }
                    let slot = self.sessions.get_mut(*id).expect("reconciled above");
                    let fed = slot.anchor + slot.kv.len();
                    let remaining = row.len() - fed;
                    if remaining <= 1 {
                        continue;
                    }
                    let budget = self.prefill_block.min(remaining - 1);
                    let body = &row[fed..fed + budget];
                    if body.iter().any(|t| *t >= vocab) {
                        dead[j] = true;
                        out[base + j] = StepOutcome::End;
                        self.sessions.remove(&mut self.pages, *id);
                        continue;
                    }
                    // Infallible here: tokens pre-checked, and the window
                    // rule caps `kv.len() + budget` under max_seq.
                    self.engine.prefill_body(
                        &mut slot.kv,
                        &mut self.pages,
                        body,
                        self.prefill_block,
                    )?;
                }
            }
            loop {
                self.batch.clear();
                for (j, (id, row)) in chunk.iter().enumerate() {
                    if dead[j] {
                        continue;
                    }
                    let slot = self.sessions.get_mut(*id).expect("reconciled above");
                    let fed = slot.anchor + slot.kv.len();
                    if fed < row.len() {
                        // Still mid-prefill under a bounded budget: hold
                        // the lane at Pending for this tick.
                        if bounded && row.len() - fed > 1 {
                            continue;
                        }
                        if row[fed] >= vocab {
                            dead[j] = true;
                            out[base + j] = StepOutcome::End;
                            self.sessions.remove(&mut self.pages, *id);
                            continue;
                        }
                        self.batch.push(*id, row[fed]);
                    }
                }
                if self.batch.is_empty() {
                    break;
                }
                self.engine.step_batch(&mut self.batch, &mut self.sessions, &mut self.pages)?;
                // Lanes whose step consumed their final row token emit.
                let mut lane = 0usize;
                for (j, (id, row)) in chunk.iter().enumerate() {
                    if lane < self.batch.len() && self.batch.lanes()[lane].session == *id {
                        let slot = self.sessions.get_mut(*id).expect("still resident");
                        if slot.anchor + slot.kv.len() == row.len() {
                            out[base + j] = StepOutcome::Token(self.batch.argmax(lane));
                        }
                        lane += 1;
                    }
                }
            }
        }
        trace::gauge("engine.kv_live_pages").set(self.pages.outstanding_pages() as u64);
        Ok(out)
    }

    fn end_session(&mut self, id: u64) {
        self.sessions.remove(&mut self.pages, id);
    }

    fn stop_tokens(&self) -> Vec<u32> {
        self.stop.clone()
    }
}

/// Deterministic artifact-free backend for tests, benches and the CI
/// loadgen smoke: scores and tokens are pure functions of the input, and
/// an optional per-forward sleep models engine latency (paid once per
/// dispatched batch, so batching amortizes it exactly like the real
/// engine would).
pub struct SyntheticBackend {
    batch: usize,
    forward_cost: Duration,
}

impl SyntheticBackend {
    /// The stop token [`SyntheticBackend::next_token`] occasionally emits.
    pub const STOP: u32 = 1;

    pub fn new(batch: usize, forward_cost: Duration) -> SyntheticBackend {
        SyntheticBackend { batch: batch.max(1), forward_cost }
    }

    /// The deterministic score formula — loopback tests assert against it.
    pub fn score_of(tokens: &[u32], span: (usize, usize)) -> f64 {
        let e = span.1.min(tokens.len());
        let s = span.0.min(e);
        let sum: u64 = tokens[s..e].iter().map(|t| *t as u64).sum();
        -((sum % 1000) as f64) / 100.0 - tokens.len() as f64 * 0.01
    }

    /// Deterministic next token (FNV over the prompt), sometimes the stop
    /// token so sessions end by stop as well as by budget.
    pub fn next_token(prompt: &[u32]) -> u32 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for t in prompt {
            h = (h ^ *t as u64).wrapping_mul(0x0100_0000_01b3);
        }
        let tok = (h % 96) as u32 + 2;
        if tok % 13 == 0 {
            Self::STOP
        } else {
            tok
        }
    }

    fn forward(&self) {
        if !self.forward_cost.is_zero() {
            std::thread::sleep(self.forward_cost);
        }
    }
}

impl ReplicaBackend for SyntheticBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn score_rows(&mut self, rows: &[(Vec<u32>, (usize, usize))]) -> Result<Vec<f64>> {
        self.forward();
        Ok(rows.iter().map(|(t, s)| Self::score_of(t, *s)).collect())
    }

    fn decode_step_sessions(&mut self, rows: &[(u64, &[u32])]) -> Result<Vec<StepOutcome>> {
        self.forward();
        Ok(rows.iter().map(|(_, p)| StepOutcome::Token(Self::next_token(p))).collect())
    }

    fn stop_tokens(&self) -> Vec<u32> {
        vec![Self::STOP]
    }
}

// ---------------------------------------------------------------- stats

/// Per-tenant serving counters (DESIGN.md §2.15). One entry per tenant
/// class in [`ReplicaStats::tenants`] / [`ServerStats::tenants`]; the
/// single-tenant default keeps exactly one, so legacy accounting is the
/// `tenants == [total]` degenerate case.
#[derive(Clone, Debug, Default)]
pub struct TenantStats {
    /// Requests admitted for this tenant.
    pub submitted: u64,
    /// Terminal responses delivered (ok or error).
    pub served: u64,
    /// Requests refused at admission — by the tenant quota or by the
    /// global queue cap while carrying this tenant id.
    pub shed: u64,
    /// Subset of `served` answered with `Response::Error`.
    pub errors: u64,
    /// Admission→dispatch staging wait (the fairness gate reads p95).
    pub queue_wait: Histogram,
    /// Submit→reply latency.
    pub latency: Histogram,
}

impl TenantStats {
    pub fn merge(&mut self, other: &TenantStats) {
        self.submitted += other.submitted;
        self.served += other.served;
        self.shed += other.shed;
        self.errors += other.errors;
        self.queue_wait.merge(&other.queue_wait);
        self.latency.merge(&other.latency);
    }
}

/// Per-replica serving counters + latency distribution. Snapshots are
/// cheap clones; the aggregate merge is exact (see [`Histogram::merge`]).
#[derive(Clone, Debug, Default)]
pub struct ReplicaStats {
    /// Scheduler batch capacity (0 until the replica reports in).
    pub capacity: usize,
    /// Requests admitted past the queue-depth gate.
    pub submitted: u64,
    /// Requests answered with a terminal response (ok or error). Generate
    /// sessions count exactly once, at completion, whether or not the
    /// client still listens — `--max-requests` stays deterministic under
    /// mixed workloads.
    pub served: u64,
    /// Subset of `served` answered with `Response::Error`.
    pub errors: u64,
    /// Requests refused at admission (queue full).
    pub rejected: u64,
    /// Staged requests this replica stole from a deeper queue while idle.
    pub stolen: u64,
    /// Successful backend rebuilds after a panic/error took the engine
    /// down (a crash-looping factory counts attempts nowhere — only a
    /// replica that came back).
    pub restarts: u64,
    /// In-flight scores this replica handed to a sibling after its
    /// backend failed (the sibling's counters record the eventual reply).
    pub retried: u64,
    /// Subset of `errors`: requests shed with [`ERR_TIMEOUT`] because
    /// their deadline expired while staged.
    pub timed_out: u64,
    /// Subset of `errors`: requests answered [`ERR_REPLICA_FAILED`]
    /// because the backend died while (or after) holding them.
    pub failed: u64,
    /// Engine dispatches (score batches + decode steps).
    pub batches: u64,
    /// Useful rows across those dispatches.
    pub batch_rows: u64,
    /// Available slots across those dispatches (`batches × capacity`).
    pub batch_slots: u64,
    /// Submit→reply latency of every served request.
    pub latency: Histogram,
    /// Admission→dispatch staging wait of every request that left the
    /// queue — dispatched to the engine, shed on deadline, or drained.
    pub queue_wait: Histogram,
    /// Per-tenant breakdown (len == configured tenant classes, ≥1).
    pub tenants: Vec<TenantStats>,
}

/// Aggregate view over all replicas.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub replicas: usize,
    pub submitted: u64,
    pub served: u64,
    pub errors: u64,
    pub rejected: u64,
    pub stolen: u64,
    pub restarts: u64,
    pub retried: u64,
    pub timed_out: u64,
    pub failed: u64,
    pub batches: u64,
    pub batch_rows: u64,
    pub batch_slots: u64,
    pub latency: Histogram,
    pub queue_wait: Histogram,
    /// Per-tenant breakdown merged across replicas.
    pub tenants: Vec<TenantStats>,
}

impl ServerStats {
    /// Fraction of dispatched batch slots that carried real rows — the
    /// `packing_efficiency` formula over the serving run.
    pub fn batch_occupancy(&self) -> f64 {
        occupancy(self.batch_rows as usize, self.batch_slots as usize, 1)
    }

    /// Rejected / (admitted + rejected).
    pub fn rejection_rate(&self) -> f64 {
        let offered = self.submitted + self.rejected;
        if offered == 0 {
            0.0
        } else {
            self.rejected as f64 / offered as f64
        }
    }

    /// All requests that reached a terminal outcome (served or shed).
    pub fn completed(&self) -> u64 {
        self.served + self.rejected
    }

    /// Deadline-expired requests / admitted requests.
    pub fn timeout_rate(&self) -> f64 {
        if self.submitted == 0 {
            0.0
        } else {
            self.timed_out as f64 / self.submitted as f64
        }
    }

    /// Replica-failure casualties / admitted requests.
    pub fn failure_rate(&self) -> f64 {
        if self.submitted == 0 {
            0.0
        } else {
            self.failed as f64 / self.submitted as f64
        }
    }
}

// ---------------------------------------------------------------- core

/// Tuning for [`ServerCore::start`].
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Engine replicas (worker threads), each with its own backend.
    pub replicas: usize,
    /// Max in-flight requests per replica before admission sheds load.
    pub queue_cap: usize,
    /// Max time a staged request waits for its batch to fill.
    pub max_wait: Duration,
    /// First rebuild delay after a backend failure; doubles per
    /// consecutive failure up to `restart_backoff_cap`, and resets on the
    /// next successful engine op (clamped to ≥100 µs so a crash-looping
    /// factory can never busy-spin a core).
    pub restart_backoff: Duration,
    /// Ceiling for the exponential rebuild backoff.
    pub restart_backoff_cap: Duration,
    /// Tenant classes for weighted-fair dispatch (DESIGN.md §2.15).
    /// 1 keeps the original single-queue behavior.
    pub tenants: usize,
    /// Deficit-round-robin weight per tenant class: a tenant earns
    /// `weight` dispatch slots per round while backlogged. Empty means
    /// equal weights; entries are clamped to ≥1.
    pub tenant_weights: Vec<u32>,
    /// Per-tenant in-flight quota per replica (0 = share `queue_cap`).
    /// Admission sheds a tenant past its quota even when the global cap
    /// still has room, so one tenant cannot monopolize the queue.
    pub tenant_quota: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            replicas: 1,
            queue_cap: 64,
            max_wait: Duration::from_millis(5),
            restart_backoff: Duration::from_millis(10),
            restart_backoff_cap: Duration::from_secs(1),
            tenants: 1,
            tenant_weights: Vec::new(),
            tenant_quota: 0,
        }
    }
}

/// Normalized tenant policy derived from a [`ServerConfig`].
fn tenant_policy(cfg: &ServerConfig) -> (usize, Vec<u32>, usize) {
    let tenants = cfg.tenants.max(1);
    let mut weights = cfg.tenant_weights.clone();
    weights.resize(tenants, 1);
    weights.truncate(tenants);
    for w in &mut weights {
        *w = (*w).max(1);
    }
    let quota = if cfg.tenant_quota == 0 {
        cfg.queue_cap.max(1)
    } else {
        cfg.tenant_quota.min(cfg.queue_cap.max(1))
    };
    (tenants, weights, quota)
}

/// One admitted request staged for (or stolen into) a replica.
struct Staged {
    req: Request,
    reply: mpsc::Sender<Response>,
    t0: Instant,
    /// Shed with [`ERR_TIMEOUT`] if still staged past this instant.
    deadline: Option<Instant>,
    /// Cross-replica retries consumed so far (scores only).
    retries: u32,
    /// Request-scoped span id minted at admission and carried through
    /// dispatch, retries and replica rebuilds, so one request's
    /// queue-wait and reply spans correlate in a trace export.
    trace_id: u64,
    /// Tenant class for fair dispatch + per-tenant accounting.
    tenant: u32,
    /// Streamed-generate lane: each decoded token is offered here
    /// (non-blocking) before the terminal reply settles the ticket.
    stream: Option<StreamSender>,
}

struct Shared {
    depth: Vec<AtomicUsize>,
    /// Per-replica × per-tenant in-flight depth, bounded by the tenant
    /// quota at admission and transferred on steal/retry like `depth`.
    tenant_depth: Vec<Vec<AtomicUsize>>,
    /// Per-tenant in-flight quota per replica (≥1).
    tenant_quota: usize,
    stats: Vec<Mutex<ReplicaStats>>,
    /// Per-replica staging queues. Work an idle replica may steal lives
    /// here; once a worker ingests an entry into its batcher/scheduler it
    /// is no longer stealable.
    inject: Vec<Mutex<VecDeque<Staged>>>,
    /// Replica `r`'s backend is down, awaiting rebuild. Stealing skips
    /// dead victims (their staged work is served after the rebuild, per
    /// affinity) and least-loaded routing penalizes them.
    dead: Vec<AtomicBool>,
    /// Replica `r`'s worker loop has exited (drain complete). Set under
    /// the replica's inject lock, checked under the same lock by
    /// submitters and by cross-replica retries — nothing can be pushed
    /// to a queue no worker will ever drain again.
    exited: Vec<AtomicBool>,
    shutdown: AtomicBool,
}

/// Everything optional about a submit: session affinity, deadline,
/// tenant class, and a streamed-token lane. `Default` reproduces the
/// plain `submit` behavior (least-loaded, no deadline, tenant 0,
/// buffered reply only).
#[derive(Default)]
pub struct SubmitOpts {
    /// Session-affinity key (`key % replicas` picks the replica).
    pub key: Option<u64>,
    /// Absolute deadline; expired-while-staged requests shed with
    /// [`ERR_TIMEOUT`].
    pub deadline: Option<Instant>,
    /// Tenant class for quota + weighted-fair dispatch; clamped to the
    /// configured tenant count.
    pub tenant: u32,
    /// Incremental token lane for a streamed generate (ignored for
    /// scores). The terminal response still arrives on the ticket.
    pub stream: Option<StreamSender>,
}

/// Cloneable submitter — IO threads and load generators each hold one.
#[derive(Clone)]
pub struct ServerHandle {
    /// Wake channels: one signal per staged request (plus shutdown/steal
    /// hints). Requests themselves travel through `Shared::inject`.
    txs: Vec<mpsc::Sender<()>>,
    shared: Arc<Shared>,
    rr: Arc<AtomicUsize>,
    queue_cap: usize,
}

impl ServerHandle {
    pub fn replicas(&self) -> usize {
        self.txs.len()
    }

    /// Submit with least-loaded routing.
    pub fn submit(&self, req: Request) -> Result<Ticket, SubmitError> {
        self.submit_with_key(None, req)
    }

    /// Submit with optional session affinity: a `Some(key)` always routes
    /// to `key % replicas`, so one session's traffic stays on one engine
    /// (an idle replica may still steal it before it starts).
    pub fn submit_with_key(&self, key: Option<u64>, req: Request) -> Result<Ticket, SubmitError> {
        self.submit_with(key, req, None)
    }

    /// [`ServerHandle::submit_with_key`] plus an optional absolute
    /// deadline: a request still staged past it is shed with a terminal
    /// [`ERR_TIMEOUT`] error instead of occupying a batch lane. A request
    /// already dispatched to the engine runs to completion — the deadline
    /// bounds queueing, not execution.
    pub fn submit_with(
        &self,
        key: Option<u64>,
        req: Request,
        deadline: Option<Instant>,
    ) -> Result<Ticket, SubmitError> {
        self.submit_opts(req, SubmitOpts { key, deadline, ..Default::default() })
    }

    /// Full-control submit: affinity, deadline, tenant class, and an
    /// optional streamed-token lane. Admission is two-gated — the
    /// tenant's quota first, then the global `queue_cap` — and both
    /// rejections count as a shed against the tenant.
    pub fn submit_opts(&self, req: Request, opts: SubmitOpts) -> Result<Ticket, SubmitError> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(SubmitError::Closed);
        }
        let n = self.txs.len();
        let replica = match opts.key {
            Some(k) => (k % n as u64) as usize,
            None => self.least_loaded(),
        };
        let tenants = self.shared.tenant_depth[replica].len();
        let tenant = (opts.tenant as usize).min(tenants - 1);
        let shed = |replica: usize| {
            let mut st = lock(&self.shared.stats[replica]);
            st.rejected += 1;
            st.tenants[tenant].shed += 1;
            drop(st);
            if tenants > 1 {
                trace::counter(&format!("serve.tenant{tenant}.shed")).inc();
            }
        };
        // Tenant quota gate first (cheap to undo), then the global gate.
        let quota = self.shared.tenant_quota;
        let tenant_ok = self.shared.tenant_depth[replica][tenant]
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |d| (d < quota).then_some(d + 1))
            .is_ok();
        if !tenant_ok {
            shed(replica);
            return Err(SubmitError::Overloaded { replica });
        }
        // Exact bounded admission: depth counts everything in flight on
        // the replica (staged + scheduled), decremented on terminal reply
        // (transferred to the thief when stolen).
        let admitted = self.shared.depth[replica]
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |d| {
                if d < self.queue_cap {
                    Some(d + 1)
                } else {
                    None
                }
            })
            .is_ok();
        if !admitted {
            self.shared.tenant_depth[replica][tenant].fetch_sub(1, Ordering::AcqRel);
            shed(replica);
            return Err(SubmitError::Overloaded { replica });
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let staged = Staged {
            req,
            reply: reply_tx,
            t0: Instant::now(),
            deadline: opts.deadline,
            retries: 0,
            trace_id: trace::next_id(),
            tenant: tenant as u32,
            stream: opts.stream,
        };
        {
            // Signal-then-push under the queue lock: the worker's ingest
            // also takes the lock, so a wake can never race past its own
            // request. The exited flag is set under this same lock just
            // before a worker's final queue check, so seeing it clear
            // here guarantees the push will be drained.
            let mut q = lock(&self.shared.inject[replica]);
            if self.shared.exited[replica].load(Ordering::Acquire)
                || self.txs[replica].send(()).is_err()
            {
                drop(q);
                self.shared.depth[replica].fetch_sub(1, Ordering::AcqRel);
                self.shared.tenant_depth[replica][tenant].fetch_sub(1, Ordering::AcqRel);
                return Err(SubmitError::Closed);
            }
            q.push_back(staged);
        }
        {
            let mut st = lock(&self.shared.stats[replica]);
            st.submitted += 1;
            st.tenants[tenant].submitted += 1;
        }
        // Steal hint: the target has a backlog — wake the least-loaded
        // other replica so an idle engine can pull from this queue.
        if n > 1 && self.shared.depth[replica].load(Ordering::Relaxed) >= 2 {
            let thief = self.least_loaded_excluding(replica);
            self.txs[thief].send(()).ok();
        }
        Ok(Ticket { replica, rx: reply_rx })
    }

    fn least_loaded(&self) -> usize {
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        let mut best = start;
        let mut best_depth = usize::MAX;
        for i in 0..self.txs.len() {
            let r = (start + i) % self.txs.len();
            let d = effective_depth(&self.shared, r);
            if d < best_depth {
                best = r;
                best_depth = d;
            }
        }
        best
    }

    fn least_loaded_excluding(&self, skip: usize) -> usize {
        let n = self.txs.len();
        let mut best = (skip + 1) % n;
        let mut best_depth = usize::MAX;
        for r in 0..n {
            if r == skip {
                continue;
            }
            let d = effective_depth(&self.shared, r);
            if d < best_depth {
                best = r;
                best_depth = d;
            }
        }
        best
    }

    /// In-flight depth of one replica.
    pub fn depth(&self, replica: usize) -> usize {
        self.shared.depth[replica].load(Ordering::Relaxed)
    }

    /// Snapshot every replica's counters.
    pub fn replica_stats(&self) -> Vec<ReplicaStats> {
        self.shared.stats.iter().map(|m| lock(m).clone()).collect()
    }

    /// Aggregate snapshot across replicas (exact histogram merge).
    pub fn stats(&self) -> ServerStats {
        let mut agg = ServerStats { replicas: self.txs.len(), ..Default::default() };
        for s in self.replica_stats() {
            agg.submitted += s.submitted;
            agg.served += s.served;
            agg.errors += s.errors;
            agg.rejected += s.rejected;
            agg.stolen += s.stolen;
            agg.restarts += s.restarts;
            agg.retried += s.retried;
            agg.timed_out += s.timed_out;
            agg.failed += s.failed;
            agg.batches += s.batches;
            agg.batch_rows += s.batch_rows;
            agg.batch_slots += s.batch_slots;
            agg.latency.merge(&s.latency);
            agg.queue_wait.merge(&s.queue_wait);
            if agg.tenants.len() < s.tenants.len() {
                agg.tenants.resize_with(s.tenants.len(), TenantStats::default);
            }
            for (t, ts) in s.tenants.iter().enumerate() {
                agg.tenants[t].merge(ts);
            }
        }
        agg
    }

    /// Requests with a terminal outcome so far (served + rejected).
    pub fn completed(&self) -> u64 {
        let s = self.stats();
        s.completed()
    }
}

/// The multi-replica serving core. See the module docs for the design.
pub struct ServerCore {
    handle: ServerHandle,
    workers: Vec<JoinHandle<()>>,
}

impl ServerCore {
    /// Spawn `cfg.replicas` worker threads. `factory(r)` runs *inside*
    /// thread `r` to build its backend (PJRT state never crosses
    /// threads); `start` waits until every replica is ready and fails
    /// fast if any factory errors.
    pub fn start<B, F>(cfg: ServerConfig, factory: F) -> Result<ServerCore>
    where
        B: ReplicaBackend + 'static,
        F: Fn(usize) -> Result<B> + Send + Sync + 'static,
    {
        let n = cfg.replicas.max(1);
        let queue_cap = cfg.queue_cap.max(1);
        let (tenants, tenant_weights, tenant_quota) = tenant_policy(&cfg);
        let fresh_stats = || {
            Mutex::new(ReplicaStats {
                tenants: vec![TenantStats::default(); tenants],
                ..Default::default()
            })
        };
        let shared = Arc::new(Shared {
            depth: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            tenant_depth: (0..n)
                .map(|_| (0..tenants).map(|_| AtomicUsize::new(0)).collect())
                .collect(),
            tenant_quota,
            stats: (0..n).map(|_| fresh_stats()).collect(),
            inject: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            dead: (0..n).map(|_| AtomicBool::new(false)).collect(),
            exited: (0..n).map(|_| AtomicBool::new(false)).collect(),
            shutdown: AtomicBool::new(false),
        });
        let factory = Arc::new(factory);
        // All wake channels exist before any worker spawns: each worker
        // holds the full peer list so a failed replica can requeue its
        // idempotent scores onto a sibling with the same signal-then-push
        // protocol submitters use.
        let mut txs = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = mpsc::channel::<()>();
            txs.push(tx);
            rxs.push(rx);
        }
        let wcfg = WorkerConfig {
            max_wait: cfg.max_wait,
            backoff: cfg.restart_backoff.max(Duration::from_micros(100)),
            backoff_cap: cfg.restart_backoff_cap.max(cfg.restart_backoff),
            tenant_weights,
        };
        let mut workers = Vec::with_capacity(n);
        let mut ready_rxs = Vec::with_capacity(n);
        for (r, rx) in rxs.into_iter().enumerate() {
            let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
            let shared_r = Arc::clone(&shared);
            let factory_r = Arc::clone(&factory);
            let peers = txs.clone();
            let wcfg = wcfg.clone();
            let worker = std::thread::Builder::new()
                .name(format!("nmsparse-replica-{r}"))
                .spawn(move || {
                    let backend = match factory_r(r) {
                        Ok(b) => {
                            ready_tx.send(Ok(())).ok();
                            b
                        }
                        Err(e) => {
                            ready_tx.send(Err(format!("{e:#}"))).ok();
                            return;
                        }
                    };
                    run_replica(r, backend, factory_r, rx, peers, shared_r, wcfg);
                })?;
            workers.push(worker);
            ready_rxs.push(ready_rx);
        }
        let core = ServerCore {
            handle: ServerHandle { txs, shared, rr: Arc::new(AtomicUsize::new(0)), queue_cap },
            workers,
        };
        for (r, ready) in ready_rxs.into_iter().enumerate() {
            match ready.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    core.stop_workers();
                    anyhow::bail!("replica {r} failed to start: {e}");
                }
                Err(_) => {
                    core.stop_workers();
                    anyhow::bail!("replica {r} died during startup");
                }
            }
        }
        Ok(core)
    }

    /// A cloneable submitter for IO threads / load generators.
    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    pub fn replicas(&self) -> usize {
        self.handle.replicas()
    }

    pub fn submit(&self, req: Request) -> Result<Ticket, SubmitError> {
        self.handle.submit(req)
    }

    pub fn submit_with_key(&self, key: Option<u64>, req: Request) -> Result<Ticket, SubmitError> {
        self.handle.submit_with_key(key, req)
    }

    pub fn submit_with(
        &self,
        key: Option<u64>,
        req: Request,
        deadline: Option<Instant>,
    ) -> Result<Ticket, SubmitError> {
        self.handle.submit_with(key, req, deadline)
    }

    pub fn submit_opts(&self, req: Request, opts: SubmitOpts) -> Result<Ticket, SubmitError> {
        self.handle.submit_opts(req, opts)
    }

    pub fn stats(&self) -> ServerStats {
        self.handle.stats()
    }

    pub fn replica_stats(&self) -> Vec<ReplicaStats> {
        self.handle.replica_stats()
    }

    pub fn completed(&self) -> u64 {
        self.handle.completed()
    }

    fn stop_workers(&self) {
        self.handle.shared.shutdown.store(true, Ordering::Release);
        for tx in &self.handle.txs {
            tx.send(()).ok();
        }
    }

    /// Graceful drain: stop admitting, wake every replica, and join them
    /// once all already-admitted work has been answered. Returns the
    /// final aggregate stats.
    pub fn shutdown(mut self) -> ServerStats {
        self.stop_workers();
        for w in self.workers.drain(..) {
            w.join().ok();
        }
        self.handle.stats()
    }
}

impl Drop for ServerCore {
    fn drop(&mut self) {
        if !self.workers.is_empty() {
            self.stop_workers();
            for w in self.workers.drain(..) {
                w.join().ok();
            }
        }
    }
}

// ---------------------------------------------------------------- worker

struct PendingReply {
    tx: mpsc::Sender<Response>,
    t0: Instant,
    deadline: Option<Instant>,
    retries: u32,
    trace_id: u64,
    tenant: u32,
    /// Streamed-token lane; dropped by [`finish`], which is how the
    /// receiving IO thread learns the stream ended (hangup, not an
    /// in-band sentinel — see `wire::stream`).
    stream: Option<StreamSender>,
}

/// How a terminal reply left the replica — drives the error counters.
enum Outcome {
    Ok,
    Error,
    TimedOut,
    Failed,
}

/// Queue depth for routing/steal decisions: a dead (restarting) replica
/// is heavily penalized so keyless submits and steal hints prefer live
/// engines, without ever becoming unroutable (keyed affinity still
/// lands, and its queue is served after the rebuild).
fn effective_depth(shared: &Shared, r: usize) -> usize {
    let d = shared.depth[r].load(Ordering::Relaxed);
    if shared.dead[r].load(Ordering::Relaxed) {
        d.saturating_add(1 << 20)
    } else {
        d
    }
}

/// Answer one request terminally and settle its accounting exactly once:
/// depth released, `served` bumped (so `completed()` balances), the error
/// taxonomy counter matching `outcome` bumped, latency recorded.
fn finish(shared: &Shared, r: usize, pending: PendingReply, resp: Response, outcome: Outcome) {
    // Close the stream lane *before* the terminal reply settles, so an IO
    // thread that sees the ticket answered never blocks on a still-open
    // lane (the reverse order could deliver the response while the lane
    // looks live).
    drop(pending.stream);
    let sg = trace::span_id(Phase::Reply, pending.trace_id);
    pending.tx.send(resp).ok(); // client may be gone; still count
    drop(sg);
    shared.depth[r].fetch_sub(1, Ordering::AcqRel);
    let tenant = (pending.tenant as usize).min(shared.tenant_depth[r].len() - 1);
    shared.tenant_depth[r][tenant].fetch_sub(1, Ordering::AcqRel);
    let latency = pending.t0.elapsed().as_secs_f64();
    let mut st = lock(&shared.stats[r]);
    st.served += 1;
    st.tenants[tenant].served += 1;
    let errored = !matches!(outcome, Outcome::Ok);
    match outcome {
        Outcome::Ok => {}
        Outcome::Error => st.errors += 1,
        Outcome::TimedOut => {
            st.errors += 1;
            st.timed_out += 1;
        }
        Outcome::Failed => {
            st.errors += 1;
            st.failed += 1;
        }
    }
    if errored {
        st.tenants[tenant].errors += 1;
    }
    st.latency.record(latency);
    st.tenants[tenant].latency.record(latency);
}

/// [`finish`] for a request that never reached the scheduler. The time it
/// sat staged still counts as queue wait — a shed request waited too, and
/// leaving sheds out would flatter the tail of the distribution.
fn fail_staged(shared: &Shared, r: usize, staged: Staged, message: &str, outcome: Outcome) {
    let Staged { reply, t0, deadline, retries, trace_id, tenant, stream, .. } = staged;
    let wait = t0.elapsed();
    record_queue_wait(shared, r, tenant, wait);
    trace::record_duration(Phase::QueueWait, trace_id, wait);
    if matches!(outcome, Outcome::TimedOut) {
        trace::counter("serve.shed_timeout").inc();
    }
    let pending = PendingReply { tx: reply, t0, deadline, retries, trace_id, tenant, stream };
    finish(shared, r, pending, Response::Error { message: message.into() }, outcome);
}

/// Record one request's staging wait into the replica histogram and its
/// tenant's breakdown.
fn record_queue_wait(shared: &Shared, r: usize, tenant: u32, wait: Duration) {
    let mut st = lock(&shared.stats[r]);
    st.queue_wait.record_duration(wait);
    let t = (tenant as usize).min(st.tenants.len().saturating_sub(1));
    st.tenants[t].queue_wait.record_duration(wait);
}

fn record_batch(shared: &Shared, r: usize, capacity: usize, rows: usize) {
    let mut st = lock(&shared.stats[r]);
    st.batches += 1;
    st.batch_rows += rows as u64;
    st.batch_slots += capacity as u64;
}

/// Per-tenant staging with deficit-round-robin dispatch (DESIGN.md
/// §2.15). Replaces the single admission `Batcher` of earlier
/// revisions: each tenant class stages in its own FIFO, and a flush
/// round drains up to one batch of requests by cycling tenants — a
/// backlogged tenant earns `weight` slots per visit, an empty queue
/// forfeits its accumulated deficit (standard DRR, so idle tenants
/// cannot bank credit). With one tenant this degenerates to the old
/// FIFO batcher exactly. Flush timing keeps the batcher's contract:
/// ready when a full batch is staged or the oldest entry has waited
/// `max_wait` (ages are measured from admission `t0`).
struct TenantStage {
    queues: Vec<VecDeque<Staged>>,
    weights: Vec<u32>,
    deficit: Vec<u64>,
    cursor: usize,
    len: usize,
    capacity: usize,
    max_wait: Duration,
}

impl TenantStage {
    fn new(weights: &[u32], capacity: usize, max_wait: Duration) -> TenantStage {
        let tenants = weights.len().max(1);
        TenantStage {
            queues: (0..tenants).map(|_| VecDeque::new()).collect(),
            weights: if weights.is_empty() { vec![1] } else { weights.to_vec() },
            deficit: vec![0; tenants],
            cursor: 0,
            len: 0,
            capacity: capacity.max(1),
            max_wait,
        }
    }

    fn push(&mut self, staged: Staged) {
        let t = (staged.tenant as usize).min(self.queues.len() - 1);
        self.queues[t].push_back(staged);
        self.len += 1;
    }

    fn len(&self) -> usize {
        self.len
    }

    fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Oldest staged admission instant across all tenant queues (each
    /// queue is FIFO in admission order, so fronts suffice).
    fn oldest(&self) -> Option<Instant> {
        self.queues.iter().filter_map(|q| q.front().map(|s| s.t0)).min()
    }

    fn ready(&self, now: Instant) -> bool {
        self.len >= self.capacity
            || self.oldest().is_some_and(|t0| now.saturating_duration_since(t0) >= self.max_wait)
    }

    fn next_deadline(&self) -> Option<Instant> {
        self.oldest().map(|t0| t0 + self.max_wait)
    }

    /// One DRR round: move up to `capacity` staged requests into `out`,
    /// weighted round-robin across backlogged tenants.
    fn drain_round_into(&mut self, out: &mut Vec<Staged>) {
        let want = self.capacity.min(self.len);
        let n = self.queues.len();
        let mut taken = 0;
        while taken < want {
            let t = self.cursor;
            self.cursor = (self.cursor + 1) % n;
            if self.queues[t].is_empty() {
                self.deficit[t] = 0; // no banking while idle
                continue;
            }
            self.deficit[t] += u64::from(self.weights[t].max(1));
            while self.deficit[t] > 0 && taken < want {
                match self.queues[t].pop_front() {
                    Some(s) => {
                        out.push(s);
                        self.len -= 1;
                        taken += 1;
                        self.deficit[t] -= 1;
                    }
                    None => {
                        self.deficit[t] = 0;
                        break;
                    }
                }
            }
        }
    }

    /// Drain everything (terminal paths: drain/fail).
    fn drain_all_into(&mut self, out: &mut Vec<Staged>) {
        for q in &mut self.queues {
            out.extend(q.drain(..));
        }
        self.len = 0;
    }
}

/// Steal the oldest staged request from the deepest other injection
/// queue, moving its in-flight accounting to replica `r`. Returns whether
/// anything was stolen. Three guards keep this behind the affinity rules:
/// only *staged* work moves (requests a replica has already scheduled —
/// including every step of a running decode session — stay put, so
/// session state never migrates), only from a victim that is actually
/// busy (`depth > staged backlog` means it has work in flight beyond its
/// queue; an idle replica is about to drain its own queue and should not
/// be robbed of it), and never from a dead or exited victim (a dead
/// replica's queue is its post-restart backlog; an exited one is
/// mid-teardown and its queue is settled by its own drain path).
fn try_steal(r: usize, shared: &Shared, admit: &mut TenantStage) -> bool {
    let n = shared.inject.len();
    if n <= 1 {
        return false;
    }
    let mut victim = None;
    let mut deepest = 0usize;
    for v in 0..n {
        if v == r
            || shared.dead[v].load(Ordering::Acquire)
            || shared.exited[v].load(Ordering::Acquire)
        {
            continue;
        }
        let backlog = lock(&shared.inject[v]).len();
        if backlog > deepest && shared.depth[v].load(Ordering::Acquire) > backlog {
            deepest = backlog;
            victim = Some(v);
        }
    }
    let Some(v) = victim else { return false };
    let Some(staged) = lock(&shared.inject[v]).pop_front() else {
        return false;
    };
    transfer_depth(shared, v, r, staged.tenant);
    lock(&shared.stats[r]).stolen += 1;
    trace::counter("serve.stolen").inc();
    admit.push(staged);
    true
}

/// Move one request's in-flight accounting (global + tenant depth) from
/// replica `from` to replica `to`.
fn transfer_depth(shared: &Shared, from: usize, to: usize, tenant: u32) {
    shared.depth[from].fetch_sub(1, Ordering::AcqRel);
    shared.depth[to].fetch_add(1, Ordering::AcqRel);
    let t = (tenant as usize).min(shared.tenant_depth[from].len() - 1);
    shared.tenant_depth[from][t].fetch_sub(1, Ordering::AcqRel);
    shared.tenant_depth[to][t].fetch_add(1, Ordering::AcqRel);
}

/// Hand a failed replica's in-flight score to the least-loaded live
/// sibling, transferring its depth accounting (retries bypass the
/// admission gate — the request was already admitted once). Mirrors the
/// submitter's signal-then-push protocol, and refuses targets that
/// already exited (checked under their inject lock) so a retry can never
/// strand in a queue no worker will drain. `false` means no live target:
/// the caller answers the request terminally instead.
fn requeue_score(shared: &Shared, peers: &[mpsc::Sender<()>], r: usize, staged: Staged) -> bool {
    let n = shared.inject.len();
    if n <= 1 {
        return false;
    }
    let mut best = usize::MAX;
    let mut victim = None;
    for v in 0..n {
        if v == r || shared.exited[v].load(Ordering::Acquire) {
            continue;
        }
        let d = effective_depth(shared, v);
        if d < best {
            best = d;
            victim = Some(v);
        }
    }
    let Some(v) = victim else { return false };
    let t = (staged.tenant as usize).min(shared.tenant_depth[v].len() - 1);
    shared.depth[v].fetch_add(1, Ordering::AcqRel);
    shared.tenant_depth[v][t].fetch_add(1, Ordering::AcqRel);
    {
        let mut q = lock(&shared.inject[v]);
        if shared.exited[v].load(Ordering::Acquire) || peers[v].send(()).is_err() {
            drop(q);
            shared.depth[v].fetch_sub(1, Ordering::AcqRel);
            shared.tenant_depth[v][t].fetch_sub(1, Ordering::AcqRel);
            return false;
        }
        q.push_back(staged);
    }
    shared.depth[r].fetch_sub(1, Ordering::AcqRel);
    shared.tenant_depth[r][t].fetch_sub(1, Ordering::AcqRel);
    lock(&shared.stats[r]).retried += 1;
    true
}

/// Tear down a failed backend and settle every request it held: scores
/// are retried on a live sibling (idempotent — a score has no session
/// state, re-running it is bitwise harmless) within the
/// [`MAX_SCORE_RETRIES`] budget, generates fail fast with
/// [`ERR_REPLICA_FAILED`] (their KV state died with the engine). Work
/// still staged (batcher + inject queue) is left in place — it never
/// touched the dead engine and is served after the rebuild; deadline
/// shedding bounds its wait. During drain nothing is retried
/// cross-replica (a sibling may already have exited), everything settles
/// locally.
#[allow(clippy::too_many_arguments)]
fn fail_replica<B: ReplicaBackend>(
    r: usize,
    shared: &Shared,
    peers: &[mpsc::Sender<()>],
    backend: &mut Option<B>,
    sched: &mut Scheduler,
    score_replies: &mut HashMap<u64, PendingReply>,
    gen_replies: &mut HashMap<u64, PendingReply>,
    capacity: usize,
    draining: bool,
) {
    if let Some(b) = backend.take() {
        // A backend whose Drop also panics must not kill the worker.
        let _ = catch_unwind(AssertUnwindSafe(move || drop(b)));
    }
    shared.dead[r].store(true, Ordering::Release);
    let score_ids: Vec<u64> = score_replies.keys().copied().collect();
    for id in score_ids {
        let Some(p) = score_replies.remove(&id) else { continue };
        let retried = match sched.score_job(id) {
            Some(job) if !draining && p.retries < MAX_SCORE_RETRIES => {
                let staged = Staged {
                    req: Request::Score { tokens: job.tokens.clone(), span: job.span },
                    reply: p.tx.clone(),
                    t0: p.t0,
                    deadline: p.deadline,
                    retries: p.retries + 1,
                    trace_id: p.trace_id,
                    tenant: p.tenant,
                    stream: None,
                };
                requeue_score(shared, peers, r, staged)
            }
            _ => false,
        };
        if !retried {
            let resp = Response::Error { message: ERR_REPLICA_FAILED.into() };
            finish(shared, r, p, resp, Outcome::Failed);
        }
    }
    for (_, p) in gen_replies.drain() {
        let resp = Response::Error { message: ERR_REPLICA_FAILED.into() };
        finish(shared, r, p, resp, Outcome::Failed);
    }
    *sched = Scheduler::new(capacity, SchedPolicy::default());
}

/// Shed every staged request whose deadline expired; re-stage the rest
/// in order. Used on the dead-replica wait path so a long restart
/// backoff never sits on already-expired requests (the live path sheds
/// at flush time instead).
fn shed_expired(shared: &Shared, r: usize, admit: &mut TenantStage) {
    if admit.is_empty() {
        return;
    }
    let now = Instant::now();
    let mut all: Vec<Staged> = Vec::with_capacity(admit.len());
    admit.drain_all_into(&mut all);
    for staged in all {
        if staged.deadline.is_some_and(|d| d <= now) {
            fail_staged(shared, r, staged, ERR_TIMEOUT, Outcome::TimedOut);
        } else {
            admit.push(staged);
        }
    }
}

/// Per-worker tuning handed down from [`ServerConfig`].
#[derive(Clone)]
struct WorkerConfig {
    max_wait: Duration,
    backoff: Duration,
    backoff_cap: Duration,
    tenant_weights: Vec<u32>,
}

/// One replica's supervised engine loop: ingest → stage →
/// flush-by-deadline → dispatch, stealing from deeper queues when idle.
/// Backend calls run under `catch_unwind`; a panic (or an `Err`) hands
/// everything the engine held to [`fail_replica`] and the backend is
/// rebuilt via the factory with capped exponential backoff — the backoff
/// escalates across consecutive failures and resets only once an engine
/// op actually succeeds, so a backend that crashes right after every
/// rebuild still backs off instead of crash-looping at full speed.
fn run_replica<B, F>(
    r: usize,
    backend: B,
    factory: Arc<F>,
    rx: mpsc::Receiver<()>,
    peers: Vec<mpsc::Sender<()>>,
    shared: Arc<Shared>,
    wcfg: WorkerConfig,
) where
    B: ReplicaBackend,
    F: Fn(usize) -> Result<B>,
{
    let mut backend = Some(backend);
    let mut capacity = backend.as_ref().map_or(1, |b| b.batch()).max(1);
    let mut stop = backend.as_ref().map_or_else(Vec::new, |b| b.stop_tokens());
    lock(&shared.stats[r]).capacity = capacity;
    let mut sched = Scheduler::new(capacity, SchedPolicy::default());
    // The admission stage keeps its staged entries across a backend
    // rebuild (they never touched the dead engine), so its capacity is
    // pinned at construction; the scheduler re-reads capacity from each
    // rebuilt backend.
    let mut admit = TenantStage::new(&wcfg.tenant_weights, capacity, wcfg.max_wait);
    let mut flush_buf: Vec<Staged> = Vec::new();
    let mut score_replies: HashMap<u64, PendingReply> = HashMap::new();
    let mut gen_replies: HashMap<u64, PendingReply> = HashMap::new();
    let mut disconnected = false;
    let mut backoff = wcfg.backoff;
    let mut rebuild_at = Instant::now();
    // Registered once per replica; set each pass so the metrics block of
    // the stats op shows live staging depth without touching submitters.
    let depth_gauge = trace::gauge(&format!("serve.replica{r}.queue_depth"));

    loop {
        // Drain pending wake signals FIRST, then ingest. A wake is sent
        // (under the inject lock) before its request is pushed, so any
        // signal consumed here has its request either already visible or
        // behind the lock the ingest below is about to take — consuming
        // signals *after* ingesting could eat the wake for a request
        // staged in between and then block forever on the channel with
        // work stranded in the queue.
        loop {
            match rx.try_recv() {
                Ok(()) => {}
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        // Ingest everything staged for this replica.
        {
            let mut q = lock(&shared.inject[r]);
            while let Some(staged) = q.pop_front() {
                admit.push(staged);
            }
        }
        let draining = disconnected || shared.shutdown.load(Ordering::Acquire);
        depth_gauge.set(shared.depth[r].load(Ordering::Relaxed) as u64);

        // Dead replica: rebuild (after the backoff) or wait. Staged work
        // stays queued for the rebuilt engine — except during drain,
        // where no rebuild is coming and everything settles terminally
        // here (no cross-replica retries either: a sibling may already
        // have drained and exited).
        if backend.is_none() {
            if draining {
                admit.drain_all_into(&mut flush_buf);
                for staged in flush_buf.drain(..) {
                    fail_staged(&shared, r, staged, ERR_REPLICA_FAILED, Outcome::Failed);
                }
                let q = lock(&shared.inject[r]);
                if q.is_empty() {
                    // Flag-then-break under the lock: submitters check
                    // `exited` under this same lock, so no request can
                    // slip into the queue after this final emptiness
                    // check.
                    shared.exited[r].store(true, Ordering::Release);
                    break;
                }
                drop(q);
                continue; // newly staged work — loop to ingest and fail it
            }
            let now = Instant::now();
            if now >= rebuild_at {
                match catch_unwind(AssertUnwindSafe(|| factory(r))) {
                    Ok(Ok(b)) => {
                        capacity = b.batch().max(1);
                        stop = b.stop_tokens();
                        sched = Scheduler::new(capacity, SchedPolicy::default());
                        let mut st = lock(&shared.stats[r]);
                        st.capacity = capacity;
                        st.restarts += 1;
                        drop(st);
                        trace::counter("serve.restarts").inc();
                        backend = Some(b);
                        shared.dead[r].store(false, Ordering::Release);
                        continue;
                    }
                    Ok(Err(_)) | Err(_) => {
                        // Factory failed (or panicked): escalate and
                        // schedule the next attempt.
                        rebuild_at = now + backoff;
                        backoff = (backoff * 2).min(wcfg.backoff_cap);
                    }
                }
            }
            // While waiting out the backoff, keep deadline promises for
            // work queued behind the dead engine.
            shed_expired(&shared, r, &mut admit);
            let wait = rebuild_at.saturating_duration_since(Instant::now());
            match rx.recv_timeout(wait) {
                Ok(()) | Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => disconnected = true,
            }
            continue;
        }

        // Move staged requests into the scheduler when the batch is full,
        // the oldest request's deadline expired, or we are draining —
        // shedding anything whose per-request deadline has already passed
        // instead of spending a batch lane on it.
        if admit.ready(Instant::now()) || (draining && !admit.is_empty()) {
            let sg = trace::span_id(Phase::TickBuild, r as u64);
            admit.drain_round_into(&mut flush_buf);
            let now = Instant::now();
            for staged in flush_buf.drain(..) {
                if staged.deadline.is_some_and(|d| d <= now) {
                    fail_staged(&shared, r, staged, ERR_TIMEOUT, Outcome::TimedOut);
                    continue;
                }
                let Staged { req, reply, t0, deadline, retries, trace_id, tenant, stream } =
                    staged;
                // Admission → dispatch: the request leaves staging here.
                let wait = t0.elapsed();
                record_queue_wait(&shared, r, tenant, wait);
                trace::record_duration(Phase::QueueWait, trace_id, wait);
                let p = PendingReply { tx: reply, t0, deadline, retries, trace_id, tenant, stream };
                match req {
                    Request::Score { tokens, span } => {
                        score_replies.insert(sched.submit_score(tokens, span), p);
                    }
                    Request::Generate { tokens, max_new } => {
                        gen_replies.insert(sched.submit_generate(tokens, max_new), p);
                    }
                }
            }
            drop(sg);
        }
        match sched.next_work() {
            Work::Idle => {
                if draining {
                    if admit.is_empty() {
                        let q = lock(&shared.inject[r]);
                        if q.is_empty() {
                            // Fully drained — every admitted request
                            // answered. Flag-then-break under the lock
                            // (see the dead-drain path above).
                            shared.exited[r].store(true, Ordering::Release);
                            break;
                        }
                    }
                    continue; // ingest/flush the rest without sleeping
                }
                // Idle with nothing staged: steal before sleeping.
                if admit.is_empty() && try_steal(r, &shared, &mut admit) {
                    continue;
                }
                // Deadline-driven wait (replaces the seed's 2 ms poll):
                // sleep until the oldest staged request must flush, or
                // block outright when nothing is staged. Belt-and-braces
                // against wake/ingest reorderings: never block without a
                // deadline while our own queue holds work.
                if admit.is_empty() && !lock(&shared.inject[r]).is_empty() {
                    continue;
                }
                let got = match admit.next_deadline() {
                    Some(d) => rx.recv_timeout(d.saturating_duration_since(Instant::now())),
                    None => rx.recv().map_err(|_| mpsc::RecvTimeoutError::Disconnected),
                };
                match got {
                    Ok(()) | Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => disconnected = true,
                }
            }
            Work::Score(ids) => {
                let rows: Vec<(Vec<u32>, (usize, usize))> = ids
                    .iter()
                    .map(|id| {
                        let j = sched.score_job(*id).expect("scheduled score has a job");
                        (j.tokens.clone(), j.span)
                    })
                    .collect();
                let result = {
                    let b = backend.as_mut().expect("backend alive in dispatch");
                    catch_unwind(AssertUnwindSafe(|| b.score_rows(&rows)))
                };
                record_batch(&shared, r, capacity, ids.len());
                match result {
                    Ok(Ok(scores)) => {
                        backoff = wcfg.backoff; // healthy op — reset escalation
                        for (id, score) in ids.iter().zip(scores) {
                            sched.complete_score(*id);
                            if let Some(p) = score_replies.remove(id) {
                                finish(&shared, r, p, Response::Score { score }, Outcome::Ok);
                            }
                        }
                    }
                    Ok(Err(_)) | Err(_) => {
                        fail_replica(
                            r,
                            &shared,
                            &peers,
                            &mut backend,
                            &mut sched,
                            &mut score_replies,
                            &mut gen_replies,
                            capacity,
                            draining,
                        );
                        rebuild_at = Instant::now() + backoff;
                        backoff = (backoff * 2).min(wcfg.backoff_cap);
                    }
                }
            }
            Work::Decode(ids) => {
                let step = {
                    let rows: Vec<(u64, &[u32])> = ids
                        .iter()
                        .map(|id| (*id, sched.session(*id).expect("live session").row()))
                        .collect();
                    let b = backend.as_mut().expect("backend alive in dispatch");
                    catch_unwind(AssertUnwindSafe(|| b.decode_step_sessions(&rows)))
                };
                record_batch(&shared, r, capacity, ids.len());
                match step {
                    Ok(Ok(outs)) => {
                        backoff = wcfg.backoff; // healthy op — reset escalation
                        for (id, out) in ids.iter().zip(outs) {
                            let sess = sched.session_mut(*id).expect("live session");
                            match out {
                                StepOutcome::Token(tok) => {
                                    let before = sess.generated.len();
                                    sess.push_token(tok, &stop);
                                    // Offer only tokens that actually
                                    // joined the transcript, so every
                                    // incremental frame is a prefix-
                                    // ordered subset of the terminal one.
                                    if sess.generated.len() > before {
                                        if let Some(p) = gen_replies.get(id) {
                                            if let Some(s) = &p.stream {
                                                s.offer(*sess.generated.last().unwrap());
                                            }
                                        }
                                    }
                                }
                                // Mid-prefill: the row is unchanged, the
                                // scheduler re-ticks the session next
                                // dispatch and the backend resumes from
                                // its persisted cursor.
                                StepOutcome::Pending => {}
                                StepOutcome::End => sess.done = true, // backend ended it
                            }
                        }
                        for sess in sched.reap_done() {
                            // Release per-session backend state (KV
                            // cache) — under catch_unwind so one
                            // session's cleanup can't take down the
                            // replica — then count the completion toward
                            // `served` exactly once, reply listener or
                            // not.
                            let b = backend.as_mut().expect("backend alive in dispatch");
                            let _ = catch_unwind(AssertUnwindSafe(|| b.end_session(sess.id)));
                            if let Some(p) = gen_replies.remove(&sess.id) {
                                let resp = Response::Generate { tokens: sess.generated };
                                finish(&shared, r, p, resp, Outcome::Ok);
                            }
                        }
                    }
                    Ok(Err(_)) | Err(_) => {
                        fail_replica(
                            r,
                            &shared,
                            &peers,
                            &mut backend,
                            &mut sched,
                            &mut score_replies,
                            &mut gen_replies,
                            capacity,
                            draining,
                        );
                        rebuild_at = Instant::now() + backoff;
                        backoff = (backoff * 2).min(wcfg.backoff_cap);
                    }
                }
            }
        }
    }
    // Normal exit: drop the (healthy) backend without letting a panicking
    // Drop impl abort the drain.
    if let Some(b) = backend.take() {
        let _ = catch_unwind(AssertUnwindSafe(move || drop(b)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_core(replicas: usize, queue_cap: usize) -> ServerCore {
        ServerCore::start(
            ServerConfig {
                replicas,
                queue_cap,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            |_r| Ok(SyntheticBackend::new(4, Duration::ZERO)),
        )
        .unwrap()
    }

    #[test]
    fn error_rate_helpers_guard_div0() {
        let mut s = ServerStats::default();
        assert_eq!(s.timeout_rate(), 0.0);
        assert_eq!(s.failure_rate(), 0.0);
        s.submitted = 10;
        s.timed_out = 2;
        s.failed = 1;
        assert!((s.timeout_rate() - 0.2).abs() < 1e-12);
        assert!((s.failure_rate() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn score_roundtrip_matches_formula() {
        let core = synth_core(2, 16);
        let tokens = vec![5u32, 9, 14, 3];
        let span = (1, 4);
        let t = core.submit(Request::Score { tokens: tokens.clone(), span }).unwrap();
        match t.recv().unwrap() {
            Response::Score { score } => {
                assert_eq!(score, SyntheticBackend::score_of(&tokens, span));
            }
            other => panic!("unexpected response {other:?}"),
        }
        let stats = core.shutdown();
        assert_eq!(stats.served, 1);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.latency.count(), 1);
    }

    #[test]
    fn session_affinity_pins_replica() {
        let core = synth_core(3, 32);
        let mut replicas = Vec::new();
        for _ in 0..6 {
            let t = core
                .submit_with_key(Some(41), Request::Score { tokens: vec![2, 3], span: (1, 2) })
                .unwrap();
            replicas.push(t.replica);
            assert!(t.recv().is_some());
        }
        assert!(replicas.windows(2).all(|w| w[0] == w[1]), "{replicas:?}");
        assert_eq!(replicas[0], (41 % 3) as usize);
        core.shutdown();
    }

    #[test]
    fn factory_failure_propagates() {
        let err = ServerCore::start(ServerConfig::default(), |r| {
            if r == 0 {
                anyhow::bail!("no artifacts here")
            }
            Ok(SyntheticBackend::new(2, Duration::ZERO))
        })
        .err()
        .expect("start must fail");
        assert!(format!("{err:#}").contains("no artifacts here"));
    }

    #[test]
    fn submit_after_shutdown_is_closed() {
        let core = synth_core(1, 4);
        let handle = core.handle();
        core.shutdown();
        let err = handle.submit(Request::Score { tokens: vec![2], span: (1, 1) }).err();
        assert_eq!(err, Some(SubmitError::Closed));
    }

    #[test]
    fn native_backend_generates_engine_identical_tokens() {
        // End-to-end through the serving loop: the KV-cached NativeBackend
        // must produce exactly what the bare engine produces.
        let cfg = EngineConfig {
            vocab: 48,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            ffn: 64,
            max_seq: 32,
        };
        let pattern = Pattern::NM { n: 8, m: 16 };
        let stop: Vec<u32> = vec![2];
        let core = {
            let (cfg, stop) = (cfg.clone(), stop.clone());
            ServerCore::start(ServerConfig::default(), move |_r| {
                NativeBackend::synthetic(&cfg, 5, NativeSparsity::act(pattern), stop.clone(), 4)
            })
            .unwrap()
        };
        let mut engine = NativeEngine::synthetic(&cfg, 5, NativeSparsity::act(pattern)).unwrap();
        let mut pool = engine.new_kv_pool();
        let mut kv = pool.new_cache();
        let prompts: Vec<Vec<u32>> = vec![vec![3, 7, 11], vec![40, 1, 2, 3, 4], vec![9]];
        let mut tickets = Vec::new();
        for p in &prompts {
            tickets.push(
                core.submit(Request::Generate { tokens: p.clone(), max_new: 12 }).unwrap(),
            );
        }
        for (t, p) in tickets.iter().zip(&prompts) {
            let want = engine.generate_greedy_sliding(&mut kv, &mut pool, p, 12, &stop).unwrap();
            match t.recv().unwrap() {
                Response::Generate { tokens } => assert_eq!(tokens, want, "prompt {p:?}"),
                other => panic!("unexpected {other:?}"),
            }
        }
        let stats = core.shutdown();
        assert_eq!(stats.served, 3);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn native_backend_scores_match_engine() {
        let cfg = EngineConfig {
            vocab: 48,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            ffn: 64,
            max_seq: 32,
        };
        let pattern = Pattern::NM { n: 2, m: 4 };
        let core = {
            let cfg = cfg.clone();
            ServerCore::start(ServerConfig::default(), move |_r| {
                NativeBackend::synthetic(&cfg, 6, NativeSparsity::act(pattern), vec![2], 4)
            })
            .unwrap()
        };
        let mut engine = NativeEngine::synthetic(&cfg, 6, NativeSparsity::act(pattern)).unwrap();
        let mut pool = engine.new_kv_pool();
        let mut kv = pool.new_cache();
        let tokens = vec![4u32, 9, 13, 2, 30, 8];
        let span = (2, 6);
        let want = engine.score_span(&mut kv, &mut pool, &tokens, span).unwrap();
        let t = core.submit(Request::Score { tokens, span }).unwrap();
        assert_eq!(t.recv().unwrap(), Response::Score { score: want });
        core.shutdown();
    }
}
