//! IFEval-analog scoring: greedy decode + verifiable-constraint checking.
//!
//! Prompt-level **strict** accuracy: the generated answer satisfies the
//! constraint exactly as stated (exact repeat sequence / exact word count
//! AND factually valid answer). Prompt-level **loose** accuracy: the
//! constraint's countable property holds, ignoring content validity and
//! extra scaffolding — mirroring IFEval's strict/loose split (Table 3's
//! PS/PL columns).

use crate::coordinator::methods::MethodConfig;
use crate::coordinator::Coordinator;
use crate::synthlang::tasks::{Constraint, IfevalSet};
use crate::synthlang::vocab::{Vocab, EOS};
use anyhow::Result;

/// Result of an IFEval run under one configuration.
#[derive(Clone, Debug)]
pub struct IfevalResult {
    pub method: String,
    pub strict: f64,
    pub loose: f64,
    pub n: usize,
}

/// The answer = generated tokens up to (excluding) the first period/EOS.
pub fn answer_tokens(generated: &[u32], period: u32) -> &[u32] {
    let end = generated
        .iter()
        .position(|t| *t == period || *t == EOS)
        .unwrap_or(generated.len());
    &generated[..end]
}

/// Check one constraint; returns (strict, loose).
pub fn check(constraint: &Constraint, answer: &[u32]) -> (bool, bool) {
    match constraint {
        Constraint::RepeatWord { word, count } => {
            let occurrences = answer.iter().filter(|t| **t == *word).count();
            let loose = occurrences == *count;
            let strict = loose && answer.len() == *count;
            (strict, loose)
        }
        Constraint::ExactWords { count, valid_answers } => {
            let loose = answer.len() == *count;
            let strict = loose && valid_answers.iter().any(|v| v.as_slice() == answer);
            (strict, loose)
        }
    }
}

/// Run the IFEval analog: greedy-generate for each prompt, stop at the
/// first period/EOS or `max_new` tokens, then check constraints.
pub fn eval_ifeval(
    coord: &Coordinator,
    cfg: &MethodConfig,
    set: &IfevalSet,
    vocab: &Vocab,
    limit: usize,
    max_new: usize,
) -> Result<IfevalResult> {
    let period = vocab.id(".")?;
    let examples = &set.examples[..set.examples.len().min(limit.max(1))];
    let prompts: Vec<Vec<u32>> = examples.iter().map(|e| e.prompt.clone()).collect();
    let outputs = coord.generate(cfg, &prompts, max_new, &[period, EOS])?;
    let mut strict = 0usize;
    let mut loose = 0usize;
    for (ex, out) in examples.iter().zip(&outputs) {
        let ans = answer_tokens(out, period);
        let (s, l) = check(&ex.constraint, ans);
        strict += s as usize;
        loose += l as usize;
    }
    Ok(IfevalResult {
        method: cfg.id.clone(),
        strict: strict as f64 / examples.len() as f64,
        loose: loose as f64 / examples.len() as f64,
        n: examples.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn answer_stops_at_period() {
        // period id fake = 9.
        assert_eq!(answer_tokens(&[5, 5, 9, 7], 9), &[5, 5]);
        assert_eq!(answer_tokens(&[5, 5], 9), &[5, 5]);
        assert_eq!(answer_tokens(&[EOS, 5], 9), &[] as &[u32]);
    }

    #[test]
    fn repeat_word_checks() {
        let c = Constraint::RepeatWord { word: 7, count: 3 };
        assert_eq!(check(&c, &[7, 7, 7]), (true, true));
        assert_eq!(check(&c, &[7, 7, 7, 1]), (false, true)); // extra junk
        assert_eq!(check(&c, &[7, 7]), (false, false));
        assert_eq!(check(&c, &[7, 7, 7, 7]), (false, false)); // too many
    }

    #[test]
    fn exact_words_checks() {
        let c = Constraint::ExactWords {
            count: 2,
            valid_answers: vec![vec![4, 5], vec![6, 7]],
        };
        assert_eq!(check(&c, &[4, 5]), (true, true));
        assert_eq!(check(&c, &[6, 7]), (true, true));
        assert_eq!(check(&c, &[5, 4]), (false, true)); // right length, wrong fact
        assert_eq!(check(&c, &[4]), (false, false));
        assert_eq!(check(&c, &[4, 5, 6]), (false, false));
    }

    #[test]
    fn strict_implies_loose() {
        // Property: for any constraint/answer, strict => loose.
        use crate::util::miniprop::{forall_simple, Config};
        use crate::util::prng::Rng;
        let cfg = Config::default();
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let c = if rng.chance(0.5) {
                    Constraint::RepeatWord {
                        word: rng.below(10) as u32,
                        count: rng.range(1, 5),
                    }
                } else {
                    Constraint::ExactWords {
                        count: rng.range(1, 4),
                        valid_answers: vec![vec![1, 2, 3][..rng.range(1, 4)].to_vec()],
                    }
                };
                let ans: Vec<u32> = (0..rng.range(0, 6)).map(|_| rng.below(10) as u32).collect();
                (c, ans)
            },
            |(c, ans)| {
                let (s, l) = check(c, ans);
                !s || l
            },
        );
    }
}
