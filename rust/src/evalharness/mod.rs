//! LM-Eval-Harness-style evaluation engine.
//!
//! Multiple-choice tasks are scored by continuation loglikelihood (argmax
//! over summed choice-token logprobs, exactly LM-eval's `loglikelihood`
//! protocol); the IFEval analog greedy-decodes and checks verifiable
//! constraints at prompt level, reporting strict/loose accuracy like the
//! original benchmark.

pub mod ifeval;

use crate::coordinator::methods::MethodConfig;
use crate::coordinator::Coordinator;
use crate::sparsity::{PackedNM, Scratch, Sparsifier};
use crate::synthlang::tasks::TaskSet;
use crate::util::tensor::Tensor;
use anyhow::Result;

/// Result of evaluating one multiple-choice task under one configuration.
#[derive(Clone, Debug)]
pub struct TaskResult {
    pub task: String,
    pub method: String,
    pub accuracy: f64,
    pub n: usize,
}

/// Evaluate a task set (optionally limited to the first `limit` examples).
pub fn eval_taskset(
    coord: &Coordinator,
    cfg: &MethodConfig,
    task: &TaskSet,
    limit: usize,
) -> Result<TaskResult> {
    let examples = &task.examples[..task.examples.len().min(limit.max(1))];
    // One scoring row per (example, choice).
    let mut rows: Vec<(Vec<u32>, (usize, usize))> = Vec::new();
    for ex in examples {
        for choice in &ex.choices {
            let mut row = ex.context.clone();
            let start = row.len();
            row.extend(choice);
            rows.push((row, (start, start + choice.len())));
        }
    }
    let scores = coord.score_rows(cfg, &rows)?;
    // Argmax per example.
    let mut correct = 0usize;
    let mut idx = 0;
    for ex in examples {
        let k = ex.choices.len();
        let slice = &scores[idx..idx + k];
        let mut best = 0;
        for (i, s) in slice.iter().enumerate() {
            if *s > slice[best] {
                best = i;
            }
        }
        if best == ex.label {
            correct += 1;
        }
        idx += k;
    }
    Ok(TaskResult {
        task: task.name.clone(),
        method: cfg.id.clone(),
        accuracy: correct as f64 / examples.len() as f64,
        n: examples.len(),
    })
}

/// Evaluate several tasks and return (per-task accuracies, mean accuracy).
pub fn eval_suite(
    coord: &Coordinator,
    cfg: &MethodConfig,
    tasks: &[TaskSet],
    limit: usize,
) -> Result<(Vec<TaskResult>, f64)> {
    let mut results = Vec::with_capacity(tasks.len());
    for t in tasks {
        results.push(eval_taskset(coord, cfg, t, limit)?);
    }
    let mean = results.iter().map(|r| r.accuracy).sum::<f64>() / results.len().max(1) as f64;
    Ok((results, mean))
}

/// Software-side sparsification-fidelity proxy: relative L2 reconstruction
/// error `‖x − sparsify(x)‖₂ / ‖x‖₂` of a fused pipeline over an activation
/// matrix. Needs no compiled engines — build the cell's pipeline with
/// [`MethodConfig::sparsifier`] and rank method cells cheaply before paying
/// for a full engine evaluation.
///
/// Selection-only pipelines (every plain criterion cell) go through the
/// compressed domain: the `Sparsifier` emits a [`PackedNM`] stream and the
/// error is reduced from the stream's dropped-element set — no dense
/// pruned copy is ever materialized, and the result is bit-identical to
/// the dense formula (pinned by a test below). Pipelines that rewrite
/// values (shift / VAR) fall back to the dense difference.
pub fn sparsify_proxy_error(sp: &Sparsifier, x: &Tensor) -> f64 {
    if sp.is_packable() {
        let mut packed = PackedNM::new(sp.pattern(), x.cols());
        let mut scratch = Scratch::new();
        sp.pack(x, &mut packed, &mut scratch);
        return packed.fidelity_error_vs(x);
    }
    let mut y = x.clone();
    let mut scratch = Scratch::new();
    sp.sparsify(&mut y, &mut scratch);
    dense_proxy_error(x, &y)
}

/// The dense-difference fidelity formula — the fallback path and the
/// oracle the packed reduction is pinned against.
fn dense_proxy_error(x: &Tensor, y: &Tensor) -> f64 {
    let denom = x.l2().max(1e-12);
    let diff = x
        .data
        .iter()
        .zip(&y.data)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    diff / denom
}

/// The paper's headline number: average relative drop (%) of a method's
/// per-task accuracies vs the dense baseline's (positive = worse).
pub fn avg_relative_drop(baseline: &[TaskResult], method: &[TaskResult]) -> f64 {
    assert_eq!(baseline.len(), method.len());
    let drops: Vec<f64> = baseline
        .iter()
        .zip(method)
        .map(|(b, m)| {
            debug_assert_eq!(b.task, m.task);
            crate::util::stats::relative_drop_pct(b.accuracy, m.accuracy)
        })
        .collect();
    crate::util::stats::mean(&drops)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(task: &str, acc: f64) -> TaskResult {
        TaskResult {
            task: task.into(),
            method: "m".into(),
            accuracy: acc,
            n: 10,
        }
    }

    #[test]
    fn drop_is_mean_of_per_task_drops() {
        let base = vec![tr("a", 0.8), tr("b", 0.5)];
        let meth = vec![tr("a", 0.72), tr("b", 0.55)];
        // drops: 10% and -10% -> mean 0.
        let d = avg_relative_drop(&base, &meth);
        assert!(d.abs() < 1e-9, "{d}");
    }

    #[test]
    fn drop_positive_for_degradation() {
        let base = vec![tr("a", 0.8)];
        let meth = vec![tr("a", 0.4)];
        assert!((avg_relative_drop(&base, &meth) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn packed_proxy_matches_dense_formula_bitwise() {
        use crate::sparsity::{paper_patterns, Pattern};
        use crate::util::prng::Rng;
        let mut rng = Rng::new(77);
        let x = Tensor::from_vec(
            &[12, 64],
            (0..12 * 64).map(|_| rng.normal() as f32).collect(),
        );
        for pattern in paper_patterns().into_iter().chain([Pattern::Dense]) {
            let sp = Sparsifier::new(pattern);
            assert!(sp.is_selection_only());
            // The packed-stream reduction vs the dense-difference oracle.
            let packed = sparsify_proxy_error(&sp, &x);
            let mut y = x.clone();
            let mut scratch = Scratch::new();
            sp.sparsify(&mut y, &mut scratch);
            let dense = dense_proxy_error(&x, &y);
            assert_eq!(packed.to_bits(), dense.to_bits(), "{pattern}");
        }
    }

    #[test]
    fn shifted_pipeline_uses_dense_fallback() {
        use crate::sparsity::transforms::Shift;
        use crate::sparsity::Pattern;
        use crate::util::prng::Rng;
        let mut rng = Rng::new(79);
        let x = Tensor::from_vec(
            &[4, 32],
            (0..4 * 32).map(|_| rng.normal() as f32 + 2.0).collect(),
        );
        let sp = Sparsifier::new(Pattern::NM { n: 2, m: 4 }).with_shift(Shift::DynamicPerToken);
        assert!(!sp.is_selection_only());
        // Shift compensation reconstructs better than plain selection.
        let e_shift = sparsify_proxy_error(&sp, &x);
        let e_plain = sparsify_proxy_error(&Sparsifier::new(Pattern::NM { n: 2, m: 4 }), &x);
        assert!(e_shift > 0.0 && e_shift < e_plain, "{e_shift} vs {e_plain}");
    }

    #[test]
    fn proxy_error_orders_patterns_by_aggressiveness() {
        use crate::sparsity::Pattern;
        use crate::util::prng::Rng;
        let mut rng = Rng::new(42);
        let x = Tensor::from_vec(
            &[16, 64],
            (0..16 * 64).map(|_| rng.normal() as f32).collect(),
        );
        let e_dense = sparsify_proxy_error(&Sparsifier::new(Pattern::Dense), &x);
        let e_24 = sparsify_proxy_error(&Sparsifier::new(Pattern::NM { n: 2, m: 4 }), &x);
        let e_816 = sparsify_proxy_error(&Sparsifier::new(Pattern::NM { n: 8, m: 16 }), &x);
        let e_u70 =
            sparsify_proxy_error(&Sparsifier::new(Pattern::Unstructured { keep_pct: 30 }), &x);
        assert_eq!(e_dense, 0.0);
        // Flexible 8:16 reconstructs better than rigid 2:4 at equal density;
        // keeping only 30% is worse than either.
        assert!(e_816 < e_24, "{e_816} vs {e_24}");
        assert!(e_u70 > e_24, "{e_u70} vs {e_24}");
        assert!(e_24 > 0.0);
    }
}
