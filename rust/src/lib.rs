//! # nmsparse — Flexible N:M Activation Sparsity
//!
//! A three-layer reproduction of *"Motivating Next-Gen Accelerators with
//! Flexible N:M Activation Sparsity via Benchmarking Lightweight
//! Post-Training Sparsification Approaches"* (Alanova et al., 2025):
//!
//! - **L1** (`python/compile/kernels/`): Pallas N:M sparsification kernel.
//! - **L2** (`python/compile/model.py`): Llama-style JAX transformer whose
//!   linear layers route through the kernel; AOT-lowered to HLO text.
//! - **L3** (this crate): coordinator — PJRT runtime, request batching and
//!   scheduling, the lm-eval-style harness, the SynthLang data substrate,
//!   the fused rust-native sparsification pipeline
//!   ([`sparsity::pipeline::Sparsifier`]), the native KV-cached decode
//!   engine ([`engine::NativeEngine`]) and quantization baselines, the
//!   hardware cost model, and the paper-table reproduction harness.
//!
//! See `DESIGN.md` (repo root) for the three-layer architecture, the
//! `Sparsifier` dataflow, the experiment index, and the tier-1 CI gate
//! (`tools/ci.sh`). Measured results are dumped by `nmsparse table` under
//! `results/` and rendered with `tools/results_to_md.py`.

pub mod coordinator;
pub mod engine;
pub mod evalharness;
pub mod hwmodel;
pub mod launcher;
pub mod metadata;
pub mod quant;
pub mod runtime;
pub mod sparsity;
pub mod synthlang;
pub mod tables;
pub mod util;
pub mod wire;

pub use util::prng::Rng;
pub use util::tensor::{Tensor, TensorStore};
