//! Hardware cost model (paper Appendix A).
//!
//! Implements the Energy-Delay-Product break-even analysis (A.1), the
//! hardware-requirement thresholds (A.2) and the microarchitectural
//! complexity comparison (A.3 / Table 6) as executable, unit-tested code.
//! `nmsparse table table6` and the `hw_breakeven` example render these.

use crate::metadata::{bits_per_element, Encoding};
use crate::sparsity::Pattern;

/// Parameters of the EDP model:
/// `EDP_improvement = r * eta / (1 + alpha)` (Appendix A.1).
#[derive(Clone, Copy, Debug)]
pub struct EdpModel {
    /// Theoretical bandwidth-reduction ratio `r` (2.0 for 50% density).
    pub bandwidth_reduction: f64,
    /// Hardware utilization efficiency `eta` (paper: 0.85).
    pub utilization: f64,
    /// Sparsification overhead factor `alpha` (paper: 0.3, calibrated from
    /// MaskLLM's 30–35% dynamic-sparsification latency overhead).
    pub overhead: f64,
}

impl EdpModel {
    /// The paper's reference parameterization for 8:16.
    pub fn paper_default() -> EdpModel {
        EdpModel {
            bandwidth_reduction: 2.0,
            utilization: 0.85,
            overhead: 0.3,
        }
    }

    /// Model for an arbitrary pattern: bandwidth reduction = 1/density,
    /// overhead grows mildly with block size (wider unpack logic), matching
    /// the qualitative scaling in Table 6's controller-logic column.
    pub fn for_pattern(p: Pattern) -> EdpModel {
        let r = 1.0 / p.density().max(1e-9);
        let overhead = match p {
            Pattern::Dense => 0.0,
            Pattern::NM { m, .. } => 0.3 + 0.01 * ((m as f64) / 4.0).log2().max(0.0),
            Pattern::Unstructured { .. } => 0.45, // irregular gather is pricier
        };
        EdpModel {
            bandwidth_reduction: r,
            utilization: 0.85,
            overhead,
        }
    }

    /// Replace the theoretical bandwidth-reduction ratio `r` (1/density)
    /// with one *measured* from packed activation streams: dense bytes per
    /// row over packed bytes per row (kept values + encoded metadata), as
    /// reported by `BENCH_packed.json`. The measured ratio is lower than
    /// the theoretical one because it pays for real metadata and word
    /// padding — exactly the honesty Appendix A's break-even needs.
    pub fn with_measured_bandwidth(
        mut self,
        dense_bytes_per_row: f64,
        packed_bytes_per_row: f64,
    ) -> EdpModel {
        if packed_bytes_per_row > 0.0 && dense_bytes_per_row > 0.0 {
            self.bandwidth_reduction = dense_bytes_per_row / packed_bytes_per_row;
        }
        self
    }

    /// `EDP_dense / EDP_sparse ≈ r·η / (1+α)`.
    pub fn edp_improvement(&self) -> f64 {
        self.bandwidth_reduction * self.utilization / (1.0 + self.overhead)
    }

    /// Minimum hardware acceleration factor `k` for net EDP benefit:
    /// solving `r·η > k·(1+α)` (Appendix A.1: k > 1.7/1.3 ≈ 1.31).
    pub fn breakeven_k(&self) -> f64 {
        self.bandwidth_reduction * self.utilization / (1.0 + self.overhead)
    }

    /// The paper's conservative amortized requirement (A.1: "we will
    /// consider a higher amortized k > 1.6x").
    pub const CONSERVATIVE_K: f64 = 1.6;

    /// Does a hardware design achieving `k` speedup on sparse ops deliver
    /// net benefit under this model (conservative margin applied)?
    pub fn net_benefit(&self, k: f64) -> bool {
        k >= Self::CONSERVATIVE_K && self.edp_improvement() > 1.0
    }
}

/// Qualitative complexity rating (Table 6's Low/Low-Med/Medium scale).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Complexity {
    Low,
    LowMedium,
    Medium,
    High,
}

impl std::fmt::Display for Complexity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Complexity::Low => write!(f, "Low"),
            Complexity::LowMedium => write!(f, "Low-Med"),
            Complexity::Medium => write!(f, "Medium"),
            Complexity::High => write!(f, "High"),
        }
    }
}

/// One row of the Table 6 comparison for a given N:M pattern.
#[derive(Clone, Debug)]
pub struct ComplexityAssessment {
    pub pattern: Pattern,
    pub metadata_bits_per_elt: f64,
    pub metadata_rating: Complexity,
    pub controller_bits: u64,
    pub controller_rating: Complexity,
    pub bandwidth_rating: Complexity,
    pub nre_rating: Complexity,
}

/// Assess a semi-structured pattern the way Appendix A.3 does.
pub fn assess(p: Pattern) -> ComplexityAssessment {
    let (n, m) = match p {
        Pattern::NM { n, m } => (n as u64, m as u64),
        _ => (0, 0),
    };
    let meta = if m > 0 {
        bits_per_element(n, m, Encoding::Combinadic)
    } else {
        0.0
    };
    // Controller logic width: the combinadic rank the decoder must unpack.
    let ctrl_bits = if m > 0 {
        crate::metadata::bits_per_block(n, m, Encoding::Combinadic)
    } else {
        0
    };
    let meta_rating = if meta <= 0.75 {
        Complexity::Low
    } else if meta <= 1.0 {
        Complexity::LowMedium
    } else {
        Complexity::Medium
    };
    let ctrl_rating = if ctrl_bits <= 4 {
        Complexity::Low
    } else if ctrl_bits <= 16 {
        Complexity::Medium
    } else {
        Complexity::High
    };
    let bw_rating = if meta <= 0.75 {
        Complexity::Low
    } else {
        Complexity::LowMedium
    };
    let nre_rating = if m <= 4 {
        Complexity::Low
    } else if m <= 16 {
        Complexity::Medium
    } else {
        Complexity::High
    };
    ComplexityAssessment {
        pattern: p,
        metadata_bits_per_elt: meta,
        metadata_rating: meta_rating,
        controller_bits: ctrl_bits,
        controller_rating: ctrl_rating,
        bandwidth_rating: bw_rating,
        nre_rating,
    }
}

/// Die-area overhead estimate for extending a 2:4 pipeline to N:M
/// (Appendix A.3: "conservatively ... < 2%" for 8:16). Modeled as decoder
/// LUT growth relative to a baseline tensor-core area budget.
pub fn incremental_die_area_pct(p: Pattern) -> f64 {
    match p {
        Pattern::NM { n, m } => {
            let ctrl = crate::metadata::bits_per_block(n as u64, m as u64, Encoding::Combinadic);
            // 2:4 (3 bits) is the mature baseline at ~0 incremental cost;
            // each extra rank bit adds ~0.17% (LUT + gather scheduling).
            ((ctrl as f64 - 3.0).max(0.0)) * 0.17
        }
        _ => 0.0,
    }
}

/// VMEM/MXU estimate for an L1 kernel tile configuration — used by the
/// DESIGN.md §Perf structural analysis (interpret-mode wallclock is not a
/// TPU proxy, so we reason about footprints and utilization analytically).
#[derive(Clone, Copy, Debug)]
pub struct KernelTileEstimate {
    pub tile_rows: usize,
    pub hidden: usize,
    pub tile_cols: usize,
    pub dtype_bytes: usize,
}

impl KernelTileEstimate {
    /// Total VMEM bytes for x-tile + w-tile + out-tile + mask/stats scratch.
    pub fn vmem_bytes(&self) -> usize {
        let x = self.tile_rows * self.hidden * self.dtype_bytes;
        let w = self.hidden * self.tile_cols * self.dtype_bytes;
        let o = self.tile_rows * self.tile_cols * self.dtype_bytes;
        let scratch = self.tile_rows * self.hidden * self.dtype_bytes // shifted copy
            + self.tile_rows * 4 * 4; // per-token mean/var/nu/eta f32
        x + w + o + scratch
    }

    /// Fits the 16 MiB VMEM budget of a TPU core?
    pub fn fits_vmem(&self) -> bool {
        self.vmem_bytes() <= 16 * 1024 * 1024
    }

    /// MXU utilization estimate: fraction of the matmul's MACs that land on
    /// 128x128-aligned tiles (ragged edges idle lanes).
    pub fn mxu_utilization(&self) -> f64 {
        let align = |x: usize| ((x + 127) / 128 * 128) as f64;
        let useful = (self.tile_rows * self.hidden * self.tile_cols) as f64;
        let padded = align(self.tile_rows) * align(self.hidden) * align(self.tile_cols);
        useful / padded
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_edp_numbers() {
        let m = EdpModel::paper_default();
        // A.1: EDP_improvement ≈ 2.0*0.85/1.3 = 1.307..., and the solved
        // break-even k > 1.7/1.3 ≈ 1.31.
        assert!((m.edp_improvement() - 1.3077).abs() < 1e-3);
        assert!((m.breakeven_k() - 1.31).abs() < 0.01);
    }

    #[test]
    fn net_benefit_thresholds() {
        let m = EdpModel::paper_default();
        assert!(m.net_benefit(1.7));
        assert!(m.net_benefit(EdpModel::CONSERVATIVE_K));
        assert!(!m.net_benefit(1.3)); // below the conservative 1.6x bar
    }

    #[test]
    fn measured_bandwidth_overrides_theoretical_r() {
        // 4096 dense bytes vs 2296 packed (2048 values + 248 metadata for
        // 8:16 at h=1024): r drops from 2.0 to ~1.78.
        let m = EdpModel::paper_default().with_measured_bandwidth(4096.0, 2296.0);
        assert!((m.bandwidth_reduction - 4096.0 / 2296.0).abs() < 1e-12);
        assert!(m.bandwidth_reduction < 2.0);
        assert!(m.edp_improvement() < EdpModel::paper_default().edp_improvement());
        // Degenerate measurements leave the model untouched.
        let untouched = EdpModel::paper_default().with_measured_bandwidth(4096.0, 0.0);
        assert_eq!(untouched.bandwidth_reduction, 2.0);
    }

    #[test]
    fn pattern_models_ordering() {
        // Bigger blocks at equal density: slightly more overhead, same r.
        let m24 = EdpModel::for_pattern(Pattern::NM { n: 2, m: 4 });
        let m816 = EdpModel::for_pattern(Pattern::NM { n: 8, m: 16 });
        assert_eq!(m24.bandwidth_reduction, m816.bandwidth_reduction);
        assert!(m816.overhead > m24.overhead);
        // Unstructured pays the most overhead.
        let mu = EdpModel::for_pattern(Pattern::Unstructured { keep_pct: 50 });
        assert!(mu.overhead > m816.overhead);
    }

    #[test]
    fn table6_ratings() {
        let a24 = assess(Pattern::NM { n: 2, m: 4 });
        let a816 = assess(Pattern::NM { n: 8, m: 16 });
        // Table 6 rows: 2:4 metadata Low (0.75 b/elt), 8:16 Low-Med (0.875).
        assert_eq!(a24.metadata_bits_per_elt, 0.75);
        assert_eq!(a24.metadata_rating, Complexity::Low);
        assert_eq!(a816.metadata_bits_per_elt, 0.875);
        assert_eq!(a816.metadata_rating, Complexity::LowMedium);
        // Controller: 2-bit-ish decoders (3-bit rank) vs 14-bit unpacking.
        assert_eq!(a24.controller_bits, 3);
        assert_eq!(a816.controller_bits, 14);
        assert_eq!(a24.controller_rating, Complexity::Low);
        assert_eq!(a816.controller_rating, Complexity::Medium);
        // NRE: mature IP vs medium.
        assert_eq!(a24.nre_rating, Complexity::Low);
        assert_eq!(a816.nre_rating, Complexity::Medium);
    }

    #[test]
    fn die_area_under_two_pct_for_8_16() {
        // A.3: "incremental die area overhead of < 2%" for 8:16.
        let pct = incremental_die_area_pct(Pattern::NM { n: 8, m: 16 });
        assert!(pct > 0.0 && pct < 2.0, "{pct}");
        assert_eq!(incremental_die_area_pct(Pattern::NM { n: 2, m: 4 }), 0.0);
    }

    #[test]
    fn kernel_tiles_fit_vmem() {
        // Our L1 default tiling (64-row tiles over H<=1024, f32).
        let est = KernelTileEstimate {
            tile_rows: 64,
            hidden: 1024,
            tile_cols: 256,
            dtype_bytes: 4,
        };
        assert!(est.fits_vmem(), "{} bytes", est.vmem_bytes());
        assert!(est.mxu_utilization() > 0.4);
        // A hopeless tile does not fit.
        let big = KernelTileEstimate {
            tile_rows: 4096,
            hidden: 8192,
            tile_cols: 4096,
            dtype_bytes: 4,
        };
        assert!(!big.fits_vmem());
    }

    #[test]
    fn mxu_utilization_bounds() {
        let aligned = KernelTileEstimate {
            tile_rows: 128,
            hidden: 1024,
            tile_cols: 128,
            dtype_bytes: 4,
        };
        assert!((aligned.mxu_utilization() - 1.0).abs() < 1e-12);
        let tiny = KernelTileEstimate {
            tile_rows: 1,
            hidden: 128,
            tile_cols: 1,
            dtype_bytes: 4,
        };
        assert!(tiny.mxu_utilization() < 0.01);
    }
}
