//! N:M mask metadata encodings and their cost accounting.
//!
//! The paper's hardware argument (§1, Appendix A.3) hinges on metadata cost:
//! a 2:4 block has C(4,2)=6 layouts ⇒ ⌈log2 6⌉ = 3 bits per 4 elements =
//! 0.75 bits/elt; an 8:16 block has C(16,8)=12870 layouts ⇒ ⌈log2 12870⌉ =
//! 14 bits per 16 elements = 0.875 bits/elt (a 16.7% increase). This module
//! implements three concrete codecs and reproduces those numbers:
//!
//! - **Bitmap**: 1 bit per element (M bits/block) — the trivial encoding.
//! - **Index list**: N × ⌈log2 M⌉ bits/block — what gather units consume.
//! - **Combinadic**: ⌈log2 C(M,N)⌉ bits/block — the information-theoretic
//!   floor (up to block granularity), via the combinatorial number system.

pub mod codec;

pub use codec::{
    decode_combinadic, encode_combinadic, mask_to_word, word_to_mask, CombinadicLut, MaskCodec,
    WordReader, WordWriter,
};

/// Binomial coefficient C(n, k) in u128 (exact for every pattern we use).
pub fn binomial(n: u64, k: u64) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut num: u128 = 1;
    for i in 0..k {
        num = num * (n - i) as u128 / (i + 1) as u128;
    }
    num
}

/// Bits per block for each codec family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Encoding {
    Bitmap,
    IndexList,
    Combinadic,
}

/// Bits of metadata per block of an N:M pattern under `enc`.
pub fn bits_per_block(n: u64, m: u64, enc: Encoding) -> u64 {
    match enc {
        Encoding::Bitmap => m,
        Encoding::IndexList => n * ceil_log2(m as u128),
        Encoding::Combinadic => ceil_log2(binomial(m, n)),
    }
}

/// Bits of metadata per *element* — the paper's headline unit.
pub fn bits_per_element(n: u64, m: u64, enc: Encoding) -> f64 {
    bits_per_block(n, m, enc) as f64 / m as f64
}

/// ⌈log2 x⌉ for x ≥ 1.
pub fn ceil_log2(x: u128) -> u64 {
    if x <= 1 {
        return 0;
    }
    128 - (x - 1).leading_zeros() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binomial_values() {
        assert_eq!(binomial(4, 2), 6);
        assert_eq!(binomial(8, 4), 70);
        assert_eq!(binomial(16, 8), 12_870);
        assert_eq!(binomial(32, 16), 601_080_390);
        assert_eq!(binomial(3, 5), 0);
        assert_eq!(binomial(5, 0), 1);
    }

    #[test]
    fn paper_metadata_numbers() {
        // §1: "a modest increase in metadata cost (from ≈0.75 to ≈0.875 bits
        // per element)".
        assert_eq!(bits_per_element(2, 4, Encoding::Combinadic), 0.75);
        assert_eq!(bits_per_element(8, 16, Encoding::Combinadic), 0.875);
        // Appendix A.3: 16.7% higher metadata bandwidth (0.875/0.75 ≈ 1.167).
        let ratio = bits_per_element(8, 16, Encoding::Combinadic)
            / bits_per_element(2, 4, Encoding::Combinadic);
        assert!((ratio - 7.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn combinadic_is_floor_of_codecs() {
        for (n, m) in [(2u64, 4u64), (4, 8), (8, 16), (16, 32)] {
            let c = bits_per_block(n, m, Encoding::Combinadic);
            let b = bits_per_block(n, m, Encoding::Bitmap);
            let i = bits_per_block(n, m, Encoding::IndexList);
            assert!(c <= b, "{n}:{m} combinadic {c} <= bitmap {b}");
            assert!(c <= i, "{n}:{m} combinadic {c} <= indexlist {i}");
        }
    }

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(6), 3);
        assert_eq!(ceil_log2(12_870), 14);
        assert_eq!(ceil_log2(16), 4);
        assert_eq!(ceil_log2(17), 5);
    }

    #[test]
    fn flexibility_vs_concatenated_blocks() {
        // 8:16 vs four 2:4 blocks: 12870 / 6^4 ≈ 9.93x more layouts (§1).
        let flexible = binomial(16, 8) as f64;
        let rigid = 6f64.powi(4);
        assert!(flexible / rigid > 9.9 && flexible / rigid < 10.0);
    }
}
