//! Concrete mask codecs: bitmap, index-list and combinadic rank coding,
//! rebuilt for compressed-domain execution (word-at-a-time bit packing and
//! LUT-accelerated combinadics).
//!
//! The combinadic (combinatorial number system) codec maps an N-of-M keep
//! mask to its rank in the lexicographic enumeration of all C(M,N)
//! combinations — the densest possible fixed-width block encoding, and the
//! scheme Appendix A.3's "combinatorial encoder/decoder ... lightweight
//! lookup tables" refers to. Two implementations coexist:
//!
//! - the **word path** ([`MaskCodec::encode_words`]/[`decode_words`]): block
//!   masks are `u32` words (bit `i` = element `i` kept), bit streams move
//!   through a `u64` accumulator ([`WordWriter`]/[`WordReader`]) instead of
//!   one bit at a time, and combinadic ranks go through a [`CombinadicLut`]
//!   of precomputed binomial rows (plus a full rank→word table for small
//!   patterns) — Appendix A.3's lookup tables, literally;
//! - the **reference path** ([`MaskCodec::reference_encode_blocks`]/
//!   [`reference_decode_blocks`]): the seed per-bit `BitWriter`/`BitReader`
//!   loops over `Vec<bool>` masks, preserved verbatim as the equivalence
//!   oracle and the baseline `rust/benches/substrate.rs` measures the word
//!   path against (`BENCH_packed.json`).
//!
//! The byte streams of the two paths are bit-identical (LSB-first within
//! the stream); property tests pin this for every codec and paper pattern.

use super::binomial;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Encode a keep-mask (length M, exactly N ones) to its combinadic rank.
/// Loop reference: recomputes each binomial from scratch. Hot paths use
/// [`CombinadicLut`]; property tests pin the two equal.
pub fn encode_combinadic(mask: &[bool]) -> u128 {
    let m = mask.len() as u64;
    let n_total = mask.iter().filter(|b| **b).count() as u64;
    let mut rank: u128 = 0;
    let mut remaining = n_total;
    for (pos, &keep) in mask.iter().enumerate() {
        if remaining == 0 {
            break;
        }
        let slots_after = m - pos as u64 - 1;
        if keep {
            remaining -= 1;
        } else {
            // All combinations that placed a one at this position (and the
            // remaining-1 others among the later slots) come first.
            rank += binomial(slots_after, remaining - 1);
        }
    }
    rank
}

/// Decode a combinadic rank back to a keep-mask of `n` ones in `m` slots.
/// Loop reference counterpart of [`encode_combinadic`].
pub fn decode_combinadic(mut rank: u128, n: usize, m: usize) -> Result<Vec<bool>> {
    let total = binomial(m as u64, n as u64);
    if rank >= total {
        bail!("rank {rank} out of range for {n}:{m} (max {total})");
    }
    let mut mask = vec![false; m];
    let mut remaining = n as u64;
    for pos in 0..m {
        if remaining == 0 {
            break;
        }
        let slots_after = (m - pos - 1) as u64;
        let with_here = binomial(slots_after, remaining - 1);
        if rank < with_here {
            mask[pos] = true;
            remaining -= 1;
        } else {
            rank -= with_here;
        }
    }
    if remaining != 0 {
        bail!("decode ended with {remaining} bits unplaced");
    }
    Ok(mask)
}

/// Bit `i` of the word = `mask[i]`. Masks wider than 32 are rejected by the
/// callers (the word APIs assert `m <= 32`).
pub fn mask_to_word(mask: &[bool]) -> u32 {
    debug_assert!(mask.len() <= 32);
    let mut w = 0u32;
    for (i, &b) in mask.iter().enumerate() {
        if b {
            w |= 1 << i;
        }
    }
    w
}

/// Inverse of [`mask_to_word`].
pub fn word_to_mask(word: u32, m: usize) -> Vec<bool> {
    debug_assert!(m <= 32);
    (0..m).map(|i| word >> i & 1 == 1).collect()
}

/// Precomputed combinadic tables for one N:M pattern (Appendix A.3's
/// "lightweight lookup tables"): one row of binomials per remaining-count,
/// so encode/decode never recompute C(s, k), plus a full rank→word table
/// when the pattern is small enough (covers 2:4, 4:8 and 8:16; 16:32 falls
/// back to the table-driven loop).
///
/// Build once per (n, m) and reuse — construction costs O(n·m) binomials
/// plus the optional O(C(m,n)) decode table.
#[derive(Clone, Debug)]
pub struct CombinadicLut {
    n: usize,
    m: usize,
    /// ⌈log2 C(m,n)⌉ — the fixed stream width of one encoded block.
    width: usize,
    /// Total number of valid words, C(m, n). Fits u64 for every m ≤ 32.
    total: u64,
    /// `binom[k * (m+1) + s] = C(s, k)` for k ≤ n, s ≤ m.
    binom: Vec<u64>,
    /// rank → word, when `total` is small enough to tabulate fully.
    decode_table: Option<Vec<u32>>,
}

impl CombinadicLut {
    /// Largest C(m,n) for which the full rank→word decode table is built.
    /// 8:16 (12 870 entries, ~50 KiB) is in; 16:32 (6·10⁸) is out.
    pub const DECODE_TABLE_MAX: u64 = 1 << 16;

    pub fn new(n: usize, m: usize) -> CombinadicLut {
        assert!(n > 0 && n <= m && m <= 32, "invalid N:M {n}:{m} for LUT");
        let total = binomial(m as u64, n as u64) as u64;
        let mut lut_binom = Vec::with_capacity((n + 1) * (m + 1));
        for k in 0..=n {
            for s in 0..=m {
                lut_binom.push(binomial(s as u64, k as u64) as u64);
            }
        }
        let mut lut = CombinadicLut {
            n,
            m,
            width: super::ceil_log2(total as u128) as usize,
            total,
            binom: lut_binom,
            decode_table: None,
        };
        if total <= Self::DECODE_TABLE_MAX {
            let table: Vec<u32> = (0..total).map(|r| lut.decode_loop(r)).collect();
            lut.decode_table = Some(table);
        }
        lut
    }

    /// Process-wide cached LUT for a pattern. Construction (binomial rows
    /// plus the rank→word table) happens once per (n, m) for the process
    /// lifetime; every stream encode/decode afterwards is pure table work.
    /// [`MaskCodec`] goes through here so per-call codec cost measures the
    /// codec, not LUT construction. The cache is bounded by the n ≤ m ≤ 32
    /// pattern space.
    pub fn cached(n: usize, m: usize) -> Arc<CombinadicLut> {
        static CACHE: OnceLock<Mutex<HashMap<(usize, usize), Arc<CombinadicLut>>>> =
            OnceLock::new();
        let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let mut guard = cache.lock().unwrap();
        Arc::clone(
            guard
                .entry((n, m))
                .or_insert_with(|| Arc::new(CombinadicLut::new(n, m))),
        )
    }

    #[inline]
    fn b(&self, s: usize, k: usize) -> u64 {
        self.binom[k * (self.m + 1) + s]
    }

    /// Stream width of one encoded block, ⌈log2 C(m,n)⌉ bits.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Number of valid words, C(m, n).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Rank of a block word with exactly `n` of the low `m` bits set.
    /// Equal to [`encode_combinadic`] of the corresponding bool mask.
    #[inline]
    pub fn encode_word(&self, word: u32) -> u64 {
        debug_assert_eq!(word.count_ones() as usize, self.n, "word popcount != N");
        let mut rank = 0u64;
        let mut remaining = self.n;
        for pos in 0..self.m {
            if remaining == 0 {
                break;
            }
            let slots_after = self.m - pos - 1;
            if word >> pos & 1 == 1 {
                remaining -= 1;
            } else {
                rank += self.b(slots_after, remaining - 1);
            }
        }
        rank
    }

    fn decode_loop(&self, mut rank: u64) -> u32 {
        let mut word = 0u32;
        let mut remaining = self.n;
        for pos in 0..self.m {
            if remaining == 0 {
                break;
            }
            let slots_after = self.m - pos - 1;
            let with_here = self.b(slots_after, remaining - 1);
            if rank < with_here {
                word |= 1 << pos;
                remaining -= 1;
            } else {
                rank -= with_here;
            }
        }
        debug_assert_eq!(remaining, 0);
        word
    }

    /// Word for a rank. Table lookup for small patterns, LUT-driven loop
    /// otherwise. Errors on out-of-range ranks like [`decode_combinadic`].
    #[inline]
    pub fn decode_word(&self, rank: u64) -> Result<u32> {
        if rank >= self.total {
            bail!(
                "rank {rank} out of range for {}:{} (max {})",
                self.n,
                self.m,
                self.total
            );
        }
        match &self.decode_table {
            Some(t) => Ok(t[rank as usize]),
            None => Ok(self.decode_loop(rank)),
        }
    }
}

/// A codec for streams of N:M block masks, tracking encoded size in bits.
#[derive(Clone, Copy, Debug)]
pub enum MaskCodec {
    Bitmap,
    IndexList,
    Combinadic,
}

impl MaskCodec {
    /// Encode a stream of `u32` block words (bit `i` = element `i` kept,
    /// exactly `n` bits set per word for N:M streams) into a bit-packed
    /// byte buffer. Returns (bytes, bits_used). The primary compressed-
    /// domain entry point — `PackedNM` metadata flows through here.
    pub fn encode_words(&self, words: &[u32], n: usize, m: usize) -> (Vec<u8>, usize) {
        assert!(m <= 32, "word codec supports block widths up to 32");
        let mut bits = WordWriter::new();
        match self {
            MaskCodec::Bitmap => {
                for &word in words {
                    bits.push_word(word as u64, m);
                }
            }
            MaskCodec::IndexList => {
                let w = super::ceil_log2(m as u128) as usize;
                for &word in words {
                    let mut x = word;
                    while x != 0 {
                        bits.push_word(x.trailing_zeros() as u64, w);
                        x &= x - 1;
                    }
                }
            }
            MaskCodec::Combinadic => {
                let lut = CombinadicLut::cached(n, m);
                let w = lut.width();
                for &word in words {
                    bits.push_word(lut.encode_word(word), w);
                }
            }
        }
        let used = bits.len_bits();
        (bits.into_bytes(), used)
    }

    /// Decode `count` block words back out of a bit-packed buffer.
    pub fn decode_words(&self, bytes: &[u8], count: usize, n: usize, m: usize) -> Result<Vec<u32>> {
        assert!(m <= 32, "word codec supports block widths up to 32");
        let mut r = WordReader::new(bytes);
        let mut out = Vec::with_capacity(count);
        match self {
            MaskCodec::Bitmap => {
                for _ in 0..count {
                    out.push(r.read_word(m)? as u32);
                }
            }
            MaskCodec::IndexList => {
                let w = super::ceil_log2(m as u128) as usize;
                for _ in 0..count {
                    let mut word = 0u32;
                    for _ in 0..n {
                        let idx = r.read_word(w)? as usize;
                        if idx >= m {
                            bail!("index {idx} out of range");
                        }
                        if word >> idx & 1 == 1 {
                            bail!("duplicate index {idx} in block (mask would under-fill)");
                        }
                        word |= 1 << idx;
                    }
                    out.push(word);
                }
            }
            MaskCodec::Combinadic => {
                let lut = CombinadicLut::cached(n, m);
                let w = lut.width();
                for _ in 0..count {
                    out.push(lut.decode_word(r.read_word(w)?)?);
                }
            }
        }
        Ok(out)
    }

    /// Encode a sequence of block masks (each length m) into a bit-packed
    /// byte buffer. Returns (bytes, bits_used). Thin shim over
    /// [`MaskCodec::encode_words`] for the common `m <= 32` case (property
    /// tests pin it bit-identical to the reference path); wider blocks fall
    /// back to the reference per-bit encoder.
    pub fn encode_blocks(&self, masks: &[Vec<bool>], n: usize, m: usize) -> (Vec<u8>, usize) {
        if m > 32 {
            return self.reference_encode_blocks(masks, n, m);
        }
        let words: Vec<u32> = masks
            .iter()
            .map(|mask| {
                debug_assert_eq!(mask.len(), m);
                mask_to_word(mask)
            })
            .collect();
        self.encode_words(&words, n, m)
    }

    /// Decode `count` block masks back out of a bit-packed buffer. Shim
    /// over [`MaskCodec::decode_words`] (see [`MaskCodec::encode_blocks`]).
    pub fn decode_blocks(
        &self,
        bytes: &[u8],
        count: usize,
        n: usize,
        m: usize,
    ) -> Result<Vec<Vec<bool>>> {
        if m > 32 {
            return self.reference_decode_blocks(bytes, count, n, m);
        }
        let words = self.decode_words(bytes, count, n, m)?;
        Ok(words.into_iter().map(|w| word_to_mask(w, m)).collect())
    }

    /// The seed per-bit encoder, preserved verbatim as the oracle for the
    /// word path and the baseline `benches/substrate.rs` measures against.
    pub fn reference_encode_blocks(
        &self,
        masks: &[Vec<bool>],
        n: usize,
        m: usize,
    ) -> (Vec<u8>, usize) {
        let mut bits = BitWriter::new();
        for mask in masks {
            debug_assert_eq!(mask.len(), m);
            match self {
                MaskCodec::Bitmap => {
                    for &b in mask {
                        bits.push_bits(b as u128, 1);
                    }
                }
                MaskCodec::IndexList => {
                    let w = super::ceil_log2(m as u128) as usize;
                    for (i, &b) in mask.iter().enumerate() {
                        if b {
                            bits.push_bits(i as u128, w);
                        }
                    }
                }
                MaskCodec::Combinadic => {
                    let w = super::ceil_log2(binomial(m as u64, n as u64)) as usize;
                    bits.push_bits(encode_combinadic(mask), w);
                }
            }
        }
        let used = bits.len_bits();
        (bits.into_bytes(), used)
    }

    /// The seed per-bit decoder (plus the duplicate-index guard the seed
    /// was missing: an IndexList block naming the same slot twice would
    /// silently yield a mask with fewer than N ones).
    pub fn reference_decode_blocks(
        &self,
        bytes: &[u8],
        count: usize,
        n: usize,
        m: usize,
    ) -> Result<Vec<Vec<bool>>> {
        let mut r = BitReader::new(bytes);
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            match self {
                MaskCodec::Bitmap => {
                    let mut mask = vec![false; m];
                    for slot in mask.iter_mut() {
                        *slot = r.read_bits(1)? == 1;
                    }
                    out.push(mask);
                }
                MaskCodec::IndexList => {
                    let w = super::ceil_log2(m as u128) as usize;
                    let mut mask = vec![false; m];
                    for _ in 0..n {
                        let idx = r.read_bits(w)? as usize;
                        if idx >= m {
                            bail!("index {idx} out of range");
                        }
                        if mask[idx] {
                            bail!("duplicate index {idx} in block (mask would under-fill)");
                        }
                        mask[idx] = true;
                    }
                    out.push(mask);
                }
                MaskCodec::Combinadic => {
                    let w = super::ceil_log2(binomial(m as u64, n as u64)) as usize;
                    let rank = r.read_bits(w)?;
                    out.push(decode_combinadic(rank, n, m)?);
                }
            }
        }
        Ok(out)
    }
}

/// LSB-first bit writer with a u64 accumulator: bits collect in `acc` and
/// spill to the word buffer 64 at a time, so a 14-bit combinadic rank costs
/// one shift/or (plus an occasional word flush) instead of 14 single-bit
/// read-modify-writes. Byte output is identical to the seed [`BitWriter`].
#[derive(Debug, Default)]
pub struct WordWriter {
    words: Vec<u64>,
    acc: u64,
    /// Bits currently buffered in `acc`; invariant: < 64.
    acc_bits: usize,
    bits: usize,
}

impl WordWriter {
    pub fn new() -> WordWriter {
        WordWriter::default()
    }

    /// Append the low `width` (≤ 64) bits of `value`, LSB first.
    #[inline]
    pub fn push_word(&mut self, value: u64, width: usize) {
        debug_assert!(width <= 64);
        if width == 0 {
            return;
        }
        let v = if width == 64 {
            value
        } else {
            value & ((1u64 << width) - 1)
        };
        self.acc |= v << self.acc_bits;
        if self.acc_bits + width >= 64 {
            self.words.push(self.acc);
            let used = 64 - self.acc_bits;
            self.acc = if used >= width { 0 } else { v >> used };
            self.acc_bits = width - used;
        } else {
            self.acc_bits += width;
        }
        self.bits += width;
    }

    /// Append the low `width` (≤ 128) bits of `value`, LSB first.
    pub fn push_bits(&mut self, value: u128, width: usize) {
        if width <= 64 {
            self.push_word(value as u64, width);
        } else {
            self.push_word(value as u64, 64);
            self.push_word((value >> 64) as u64, width - 64);
        }
    }

    pub fn len_bits(&self) -> usize {
        self.bits
    }

    /// Serialize to bytes (little-endian words, truncated to ⌈bits/8⌉) —
    /// byte-for-byte what the seed per-bit writer produces.
    pub fn into_bytes(mut self) -> Vec<u8> {
        if self.acc_bits > 0 {
            self.words.push(self.acc);
        }
        let mut out = Vec::with_capacity(self.words.len() * 8);
        for w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out.truncate((self.bits + 7) / 8);
        out
    }
}

/// LSB-first reader consuming byte-sized chunks (≤ 8 per 64-bit read)
/// instead of single bits; accepts any buffer the writers produce.
pub struct WordReader<'a> {
    bytes: &'a [u8],
    bit: usize,
}

impl<'a> WordReader<'a> {
    pub fn new(bytes: &'a [u8]) -> WordReader<'a> {
        WordReader { bytes, bit: 0 }
    }

    /// Read `width` (≤ 64) bits, LSB first.
    #[inline]
    pub fn read_word(&mut self, width: usize) -> Result<u64> {
        debug_assert!(width <= 64);
        let mut v = 0u64;
        let mut got = 0usize;
        while got < width {
            let byte = self.bit / 8;
            if byte >= self.bytes.len() {
                bail!("bit buffer exhausted");
            }
            let off = self.bit % 8;
            let take = (width - got).min(8 - off);
            let chunk = (self.bytes[byte] >> off) as u64 & ((1u64 << take) - 1);
            v |= chunk << got;
            got += take;
            self.bit += take;
        }
        Ok(v)
    }

    /// Read `width` (≤ 128) bits, LSB first.
    pub fn read_bits(&mut self, width: usize) -> Result<u128> {
        if width <= 64 {
            return Ok(self.read_word(width)? as u128);
        }
        let lo = self.read_word(64)? as u128;
        let hi = self.read_word(width - 64)? as u128;
        Ok(lo | hi << 64)
    }
}

/// LSB-first bit writer (seed implementation, kept as the reference the
/// word-level [`WordWriter`] is pinned against and benchmarked over).
struct BitWriter {
    bytes: Vec<u8>,
    bit: usize,
}

impl BitWriter {
    fn new() -> Self {
        BitWriter { bytes: Vec::new(), bit: 0 }
    }

    fn push_bits(&mut self, value: u128, width: usize) {
        for i in 0..width {
            let b = ((value >> i) & 1) as u8;
            if self.bit % 8 == 0 {
                self.bytes.push(0);
            }
            let last = self.bytes.len() - 1;
            self.bytes[last] |= b << (self.bit % 8);
            self.bit += 1;
        }
    }

    fn len_bits(&self) -> usize {
        self.bit
    }

    fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }
}

/// LSB-first bit reader (seed implementation, reference for [`WordReader`]).
struct BitReader<'a> {
    bytes: &'a [u8],
    bit: usize,
}

impl<'a> BitReader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, bit: 0 }
    }

    fn read_bits(&mut self, width: usize) -> Result<u128> {
        let mut v: u128 = 0;
        for i in 0..width {
            let byte = self.bit / 8;
            if byte >= self.bytes.len() {
                bail!("bit buffer exhausted");
            }
            let b = (self.bytes[byte] >> (self.bit % 8)) & 1;
            v |= (b as u128) << i;
            self.bit += 1;
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop::{forall_simple, Config};
    use crate::util::prng::Rng;

    fn random_mask(rng: &mut Rng, n: usize, m: usize) -> Vec<bool> {
        let idx = rng.sample_indices(m, n);
        let mut mask = vec![false; m];
        for i in idx {
            mask[i] = true;
        }
        mask
    }

    #[test]
    fn combinadic_enumerates_all_2_4() {
        // All 6 masks of 2:4 map to distinct ranks in [0, 6).
        let mut seen = std::collections::BTreeSet::new();
        for a in 0..4 {
            for b in (a + 1)..4 {
                let mut mask = vec![false; 4];
                mask[a] = true;
                mask[b] = true;
                let r = encode_combinadic(&mask);
                assert!(r < 6);
                seen.insert(r);
                assert_eq!(decode_combinadic(r, 2, 4).unwrap(), mask);
            }
        }
        assert_eq!(seen.len(), 6);
    }

    #[test]
    fn combinadic_roundtrip_all_patterns() {
        let cfg = Config { cases: 256, ..Config::default() };
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let (n, m) = *rng.choose(&[(2usize, 4usize), (4, 8), (8, 16), (16, 32)]);
                random_mask(rng, n, m)
            },
            |mask| {
                let n = mask.iter().filter(|b| **b).count();
                let r = encode_combinadic(mask);
                decode_combinadic(r, n, mask.len()).unwrap() == *mask
            },
        );
    }

    #[test]
    fn lut_matches_loop_exhaustively_small_patterns() {
        // Satellite: LUT-combinadic ≡ loop-combinadic for EVERY rank at the
        // tabulated patterns (2:4 required; 4:8 and 8:16 ride along).
        for (n, m) in [(2usize, 4usize), (4, 8), (8, 16)] {
            let lut = CombinadicLut::new(n, m);
            assert_eq!(lut.total() as u128, binomial(m as u64, n as u64));
            for rank in 0..lut.total() {
                let mask = decode_combinadic(rank as u128, n, m).unwrap();
                let word = mask_to_word(&mask);
                assert_eq!(lut.encode_word(word) as u128, encode_combinadic(&mask));
                assert_eq!(lut.decode_word(rank).unwrap(), word, "{n}:{m} rank {rank}");
            }
        }
    }

    #[test]
    fn lut_matches_loop_sampled_16_32() {
        // 16:32 exceeds DECODE_TABLE_MAX, so the loop-with-LUT path runs.
        let lut = CombinadicLut::new(16, 32);
        assert!(lut.total() > CombinadicLut::DECODE_TABLE_MAX);
        let cfg = Config { cases: 512, ..Config::default() };
        forall_simple(
            &cfg,
            |rng: &mut Rng| (rng.next_u64() % lut.total()),
            |&rank| {
                let mask = decode_combinadic(rank as u128, 16, 32).unwrap();
                let word = mask_to_word(&mask);
                lut.encode_word(word) == rank
                    && lut.decode_word(rank).unwrap() == word
                    && lut.encode_word(word) as u128 == encode_combinadic(&mask)
            },
        );
    }

    #[test]
    fn cached_lut_is_shared_and_equivalent() {
        let a = CombinadicLut::cached(8, 16);
        let b = CombinadicLut::cached(8, 16);
        assert!(Arc::ptr_eq(&a, &b), "same pattern returns the same Arc");
        let fresh = CombinadicLut::new(8, 16);
        for rank in [0u64, 1, 6434, 12_869] {
            assert_eq!(a.decode_word(rank).unwrap(), fresh.decode_word(rank).unwrap());
        }
        let other = CombinadicLut::cached(2, 4);
        assert!(!Arc::ptr_eq(&a, &other));
    }

    #[test]
    fn lut_rejects_out_of_range_rank() {
        assert!(CombinadicLut::new(2, 4).decode_word(6).is_err());
        assert!(CombinadicLut::new(8, 16).decode_word(12_870).is_err());
        assert!(CombinadicLut::new(16, 32).decode_word(601_080_390).is_err());
    }

    #[test]
    fn rank_out_of_range_rejected() {
        assert!(decode_combinadic(6, 2, 4).is_err());
        assert!(decode_combinadic(12_870, 8, 16).is_err());
    }

    #[test]
    fn stream_roundtrip_every_codec() {
        let mut rng = Rng::new(17);
        for codec in [MaskCodec::Bitmap, MaskCodec::IndexList, MaskCodec::Combinadic] {
            let (n, m) = (8, 16);
            let masks: Vec<Vec<bool>> = (0..64).map(|_| random_mask(&mut rng, n, m)).collect();
            let (bytes, bits) = codec.encode_blocks(&masks, n, m);
            assert!(bits <= bytes.len() * 8);
            let decoded = codec.decode_blocks(&bytes, masks.len(), n, m).unwrap();
            assert_eq!(decoded, masks, "{codec:?}");
        }
    }

    #[test]
    fn word_stream_bit_identical_to_reference_stream() {
        // The tentpole pin: the word path's byte output equals the seed
        // per-bit path's for every codec and paper pattern, and both decode
        // each other's streams.
        let cfg = Config { cases: 96, ..Config::default() };
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let (n, m) = *rng.choose(&[(2usize, 4usize), (4, 8), (8, 16), (16, 32)]);
                let count = rng.range(1, 20);
                let masks: Vec<Vec<bool>> =
                    (0..count).map(|_| random_mask(rng, n, m)).collect();
                let codec_i = rng.below(3);
                (masks, n, m, codec_i)
            },
            |(masks, n, m, codec_i)| {
                let codec = [MaskCodec::Bitmap, MaskCodec::IndexList, MaskCodec::Combinadic]
                    [*codec_i];
                let (ref_bytes, ref_bits) = codec.reference_encode_blocks(masks, *n, *m);
                let (word_bytes, word_bits) = codec.encode_blocks(masks, *n, *m);
                if ref_bytes != word_bytes || ref_bits != word_bits {
                    return false;
                }
                // Cross-decode: each path reads the other's bytes.
                let via_ref = codec
                    .reference_decode_blocks(&word_bytes, masks.len(), *n, *m)
                    .unwrap();
                let via_word = codec.decode_blocks(&ref_bytes, masks.len(), *n, *m).unwrap();
                via_ref == *masks && via_word == *masks
            },
        );
    }

    #[test]
    fn words_api_roundtrip() {
        let mut rng = Rng::new(29);
        for codec in [MaskCodec::Bitmap, MaskCodec::IndexList, MaskCodec::Combinadic] {
            let (n, m) = (4usize, 8usize);
            let words: Vec<u32> = (0..100)
                .map(|_| mask_to_word(&random_mask(&mut rng, n, m)))
                .collect();
            let (bytes, bits) = codec.encode_words(&words, n, m);
            assert!(bits <= bytes.len() * 8);
            let decoded = codec.decode_words(&bytes, words.len(), n, m).unwrap();
            assert_eq!(decoded, words, "{codec:?}");
        }
    }

    #[test]
    fn index_list_duplicate_indices_rejected() {
        // Satellite bugfix: a corrupted IndexList stream naming the same
        // slot twice used to decode silently into a mask with < N ones.
        let (n, m) = (2usize, 4usize);
        // Two blocks, 2 bits per index: [0, 0] (duplicate) then [1, 3].
        let mut w = WordWriter::new();
        for idx in [0u64, 0, 1, 3] {
            w.push_word(idx, 2);
        }
        let bytes = w.into_bytes();
        let err = MaskCodec::IndexList
            .decode_words(&bytes, 2, n, m)
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate index 0"), "{err}");
        let err = MaskCodec::IndexList
            .decode_blocks(&bytes, 2, n, m)
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate index 0"), "{err}");
        let err = MaskCodec::IndexList
            .reference_decode_blocks(&bytes, 2, n, m)
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate index 0"), "{err}");
        // A valid stream still decodes.
        let mut w = WordWriter::new();
        for idx in [0u64, 2, 1, 3] {
            w.push_word(idx, 2);
        }
        let ok = MaskCodec::IndexList
            .decode_words(&w.into_bytes(), 2, n, m)
            .unwrap();
        assert_eq!(ok, vec![0b0101, 0b1010]);
    }

    #[test]
    fn encoded_sizes_match_theory() {
        let mut rng = Rng::new(23);
        let blocks = 100;
        for (n, m, enc, per_block) in [
            (2usize, 4usize, MaskCodec::Bitmap, 4usize),
            (2, 4, MaskCodec::IndexList, 4),
            (2, 4, MaskCodec::Combinadic, 3),
            (8, 16, MaskCodec::Combinadic, 14),
            (16, 32, MaskCodec::Combinadic, 30),
        ] {
            let masks: Vec<Vec<bool>> =
                (0..blocks).map(|_| random_mask(&mut rng, n, m)).collect();
            let (_, bits) = enc.encode_blocks(&masks, n, m);
            assert_eq!(bits, blocks * per_block, "{enc:?} {n}:{m}");
        }
    }

    #[test]
    fn word_writer_matches_bit_writer_on_random_pushes() {
        // Byte-for-byte equivalence of the u64-accumulator writer and the
        // seed per-bit writer over adversarial (value, width) sequences,
        // and both readers read both outputs back.
        let widths = [1usize, 2, 3, 7, 8, 9, 13, 14, 30, 31, 32, 33, 63, 64, 65, 100, 127, 128];
        let cfg = Config { cases: 128, ..Config::default() };
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let count = rng.range(1, 24);
                (0..count)
                    .map(|_| {
                        let w = *rng.choose(&widths);
                        let v = if w >= 128 {
                            (rng.next_u64() as u128) << 64 | rng.next_u64() as u128
                        } else {
                            ((rng.next_u64() as u128) << 64 | rng.next_u64() as u128)
                                & ((1u128 << w) - 1)
                        };
                        (v, w)
                    })
                    .collect::<Vec<(u128, usize)>>()
            },
            |seq| {
                let mut bw = BitWriter::new();
                let mut ww = WordWriter::new();
                for &(v, w) in seq {
                    bw.push_bits(v, w);
                    ww.push_bits(v, w);
                }
                if bw.len_bits() != ww.len_bits() {
                    return false;
                }
                let b1 = bw.into_bytes();
                let b2 = ww.into_bytes();
                if b1 != b2 {
                    return false;
                }
                let mut br = BitReader::new(&b1);
                let mut wr = WordReader::new(&b1);
                seq.iter().all(|&(v, w)| {
                    br.read_bits(w).unwrap() == v && wr.read_bits(w).unwrap() == v
                })
            },
        );
    }

    #[test]
    fn word_writer_cross_boundaries() {
        let mut w = WordWriter::new();
        w.push_bits(0b1_0110_1011, 9);
        w.push_bits(0b111, 3);
        let bits = w.len_bits();
        let bytes = w.into_bytes();
        assert_eq!(bits, 12);
        let mut r = WordReader::new(&bytes);
        assert_eq!(r.read_bits(9).unwrap(), 0b1_0110_1011);
        assert_eq!(r.read_bits(3).unwrap(), 0b111);
        assert!(r.read_bits(1).is_err() || bytes.len() * 8 >= 13);
    }

    #[test]
    fn reader_errors_when_exhausted() {
        let mut w = WordWriter::new();
        w.push_word(0x7, 3);
        let bytes = w.into_bytes(); // one byte
        let mut r = WordReader::new(&bytes);
        assert_eq!(r.read_word(8).unwrap(), 0x7); // within the padded byte
        assert!(r.read_word(1).is_err());
    }

    #[test]
    fn bitwriter_cross_byte_boundaries() {
        let mut w = BitWriter::new();
        w.push_bits(0b1_0110_1011, 9);
        w.push_bits(0b111, 3);
        let bits = w.len_bits();
        let bytes = w.into_bytes();
        assert_eq!(bits, 12);
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(9).unwrap(), 0b1_0110_1011);
        assert_eq!(r.read_bits(3).unwrap(), 0b111);
        assert!(r.read_bits(1).is_err() || bytes.len() * 8 >= 13);
    }

    #[test]
    fn mask_word_roundtrip() {
        let mask = vec![true, false, false, true, true, false];
        let w = mask_to_word(&mask);
        assert_eq!(w, 0b011001);
        assert_eq!(word_to_mask(w, 6), mask);
    }
}
