//! Concrete mask codecs: bitmap, index-list and combinadic rank coding.
//!
//! The combinadic (combinatorial number system) codec maps an N-of-M keep
//! mask to its rank in the lexicographic enumeration of all C(M,N)
//! combinations — the densest possible fixed-width block encoding, and the
//! scheme Appendix A.3's "combinatorial encoder/decoder ... lightweight
//! lookup tables" refers to. Round-trip correctness is property-tested.

use super::binomial;
use anyhow::{bail, Result};

/// Encode a keep-mask (length M, exactly N ones) to its combinadic rank.
pub fn encode_combinadic(mask: &[bool]) -> u128 {
    let m = mask.len() as u64;
    let n_total = mask.iter().filter(|b| **b).count() as u64;
    let mut rank: u128 = 0;
    let mut remaining = n_total;
    for (pos, &keep) in mask.iter().enumerate() {
        if remaining == 0 {
            break;
        }
        let slots_after = m - pos as u64 - 1;
        if keep {
            remaining -= 1;
        } else {
            // All combinations that placed a one at this position (and the
            // remaining-1 others among the later slots) come first.
            rank += binomial(slots_after, remaining - 1);
        }
    }
    rank
}

/// Decode a combinadic rank back to a keep-mask of `n` ones in `m` slots.
pub fn decode_combinadic(mut rank: u128, n: usize, m: usize) -> Result<Vec<bool>> {
    let total = binomial(m as u64, n as u64);
    if rank >= total {
        bail!("rank {rank} out of range for {n}:{m} (max {total})");
    }
    let mut mask = vec![false; m];
    let mut remaining = n as u64;
    for pos in 0..m {
        if remaining == 0 {
            break;
        }
        let slots_after = (m - pos - 1) as u64;
        let with_here = binomial(slots_after, remaining - 1);
        if rank < with_here {
            mask[pos] = true;
            remaining -= 1;
        } else {
            rank -= with_here;
        }
    }
    if remaining != 0 {
        bail!("decode ended with {remaining} bits unplaced");
    }
    Ok(mask)
}

/// A codec for streams of N:M block masks, tracking encoded size in bits.
#[derive(Clone, Copy, Debug)]
pub enum MaskCodec {
    Bitmap,
    IndexList,
    Combinadic,
}

impl MaskCodec {
    /// Encode a sequence of block masks (each length m) into a bit-packed
    /// byte buffer. Returns (bytes, bits_used).
    pub fn encode_blocks(&self, masks: &[Vec<bool>], n: usize, m: usize) -> (Vec<u8>, usize) {
        let mut bits = BitWriter::new();
        for mask in masks {
            debug_assert_eq!(mask.len(), m);
            match self {
                MaskCodec::Bitmap => {
                    for &b in mask {
                        bits.push_bits(b as u128, 1);
                    }
                }
                MaskCodec::IndexList => {
                    let w = super::ceil_log2(m as u128) as usize;
                    for (i, &b) in mask.iter().enumerate() {
                        if b {
                            bits.push_bits(i as u128, w);
                        }
                    }
                }
                MaskCodec::Combinadic => {
                    let w = super::ceil_log2(binomial(m as u64, n as u64)) as usize;
                    bits.push_bits(encode_combinadic(mask), w);
                }
            }
        }
        let used = bits.len_bits();
        (bits.into_bytes(), used)
    }

    /// Decode `count` block masks back out of a bit-packed buffer.
    pub fn decode_blocks(
        &self,
        bytes: &[u8],
        count: usize,
        n: usize,
        m: usize,
    ) -> Result<Vec<Vec<bool>>> {
        let mut r = BitReader::new(bytes);
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            match self {
                MaskCodec::Bitmap => {
                    let mut mask = vec![false; m];
                    for slot in mask.iter_mut() {
                        *slot = r.read_bits(1)? == 1;
                    }
                    out.push(mask);
                }
                MaskCodec::IndexList => {
                    let w = super::ceil_log2(m as u128) as usize;
                    let mut mask = vec![false; m];
                    for _ in 0..n {
                        let idx = r.read_bits(w)? as usize;
                        if idx >= m {
                            bail!("index {idx} out of range");
                        }
                        mask[idx] = true;
                    }
                    out.push(mask);
                }
                MaskCodec::Combinadic => {
                    let w = super::ceil_log2(binomial(m as u64, n as u64)) as usize;
                    let rank = r.read_bits(w)?;
                    out.push(decode_combinadic(rank, n, m)?);
                }
            }
        }
        Ok(out)
    }
}

/// LSB-first bit writer.
struct BitWriter {
    bytes: Vec<u8>,
    bit: usize,
}

impl BitWriter {
    fn new() -> Self {
        BitWriter { bytes: Vec::new(), bit: 0 }
    }

    fn push_bits(&mut self, value: u128, width: usize) {
        for i in 0..width {
            let b = ((value >> i) & 1) as u8;
            if self.bit % 8 == 0 {
                self.bytes.push(0);
            }
            let last = self.bytes.len() - 1;
            self.bytes[last] |= b << (self.bit % 8);
            self.bit += 1;
        }
    }

    fn len_bits(&self) -> usize {
        self.bit
    }

    fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }
}

/// LSB-first bit reader.
struct BitReader<'a> {
    bytes: &'a [u8],
    bit: usize,
}

impl<'a> BitReader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, bit: 0 }
    }

    fn read_bits(&mut self, width: usize) -> Result<u128> {
        let mut v: u128 = 0;
        for i in 0..width {
            let byte = self.bit / 8;
            if byte >= self.bytes.len() {
                bail!("bit buffer exhausted");
            }
            let b = (self.bytes[byte] >> (self.bit % 8)) & 1;
            v |= (b as u128) << i;
            self.bit += 1;
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop::{forall_simple, Config};
    use crate::util::prng::Rng;

    fn random_mask(rng: &mut Rng, n: usize, m: usize) -> Vec<bool> {
        let idx = rng.sample_indices(m, n);
        let mut mask = vec![false; m];
        for i in idx {
            mask[i] = true;
        }
        mask
    }

    #[test]
    fn combinadic_enumerates_all_2_4() {
        // All 6 masks of 2:4 map to distinct ranks in [0, 6).
        let mut seen = std::collections::BTreeSet::new();
        for a in 0..4 {
            for b in (a + 1)..4 {
                let mut mask = vec![false; 4];
                mask[a] = true;
                mask[b] = true;
                let r = encode_combinadic(&mask);
                assert!(r < 6);
                seen.insert(r);
                assert_eq!(decode_combinadic(r, 2, 4).unwrap(), mask);
            }
        }
        assert_eq!(seen.len(), 6);
    }

    #[test]
    fn combinadic_roundtrip_all_patterns() {
        let cfg = Config { cases: 256, ..Config::default() };
        forall_simple(
            &cfg,
            |rng: &mut Rng| {
                let (n, m) = *rng.choose(&[(2usize, 4usize), (4, 8), (8, 16), (16, 32)]);
                random_mask(rng, n, m)
            },
            |mask| {
                let n = mask.iter().filter(|b| **b).count();
                let r = encode_combinadic(mask);
                decode_combinadic(r, n, mask.len()).unwrap() == *mask
            },
        );
    }

    #[test]
    fn rank_out_of_range_rejected() {
        assert!(decode_combinadic(6, 2, 4).is_err());
        assert!(decode_combinadic(12_870, 8, 16).is_err());
    }

    #[test]
    fn stream_roundtrip_every_codec() {
        let mut rng = Rng::new(17);
        for codec in [MaskCodec::Bitmap, MaskCodec::IndexList, MaskCodec::Combinadic] {
            let (n, m) = (8, 16);
            let masks: Vec<Vec<bool>> = (0..64).map(|_| random_mask(&mut rng, n, m)).collect();
            let (bytes, bits) = codec.encode_blocks(&masks, n, m);
            assert!(bits <= bytes.len() * 8);
            let decoded = codec.decode_blocks(&bytes, masks.len(), n, m).unwrap();
            assert_eq!(decoded, masks, "{codec:?}");
        }
    }

    #[test]
    fn encoded_sizes_match_theory() {
        let mut rng = Rng::new(23);
        let blocks = 100;
        for (n, m, enc, per_block) in [
            (2usize, 4usize, MaskCodec::Bitmap, 4usize),
            (2, 4, MaskCodec::IndexList, 4),
            (2, 4, MaskCodec::Combinadic, 3),
            (8, 16, MaskCodec::Combinadic, 14),
            (16, 32, MaskCodec::Combinadic, 30),
        ] {
            let masks: Vec<Vec<bool>> =
                (0..blocks).map(|_| random_mask(&mut rng, n, m)).collect();
            let (_, bits) = enc.encode_blocks(&masks, n, m);
            assert_eq!(bits, blocks * per_block, "{enc:?} {n}:{m}");
        }
    }

    #[test]
    fn bitwriter_cross_byte_boundaries() {
        let mut w = BitWriter::new();
        w.push_bits(0b1_0110_1011, 9);
        w.push_bits(0b111, 3);
        let bits = w.len_bits();
        let bytes = w.into_bytes();
        assert_eq!(bits, 12);
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(9).unwrap(), 0b1_0110_1011);
        assert_eq!(r.read_bits(3).unwrap(), 0b111);
        assert!(r.read_bits(1).is_err() || bytes.len() * 8 >= 13);
    }
}
