//! `nmsparse serve` — the TCP front-end over the multi-replica
//! [`ServerCore`].
//!
//! Line-delimited JSON over TCP (no HTTP stack in the offline image — the
//! protocol is deliberately minimal):
//!
//! ```text
//! -> {"op":"ping"}
//! <- {"ok":true,"variant":"8_16","method":"S-PTS","replicas":2}
//! -> {"op":"score","text":"does the red fox live in the den ?","choice":" yes"}
//! <- {"ok":true,"score":-1.23}
//! -> {"op":"generate","text":"repeat the word fox two times :","max_new":8}
//! <- {"ok":true,"text":"fox fox ."}
//! -> {"op":"stats"}
//! <- {"ok":true,"served":412,"rejected":3,"latency_ms":{"p50":...},...}
//! ```
//!
//! When a replica's admission queue is full the request is shed
//! immediately with `{"ok":false,"error":"overloaded"}` — clients retry
//! with backoff instead of stacking unbounded work.
//!
//! `--request-timeout-ms` attaches a deadline to every engine request:
//! the core sheds expired work with `{"ok":false,"error":"timeout"}`,
//! and the IO thread waits with `recv_timeout` (plus a socket
//! write-timeout) so a failed replica can never hang a client
//! connection indefinitely — the supervisor answers in-flight requests
//! terminally with `replica_failed` and rebuilds the replica (see
//! DESIGN.md §2.12).
//!
//! Architecture: this file owns only sockets and JSON. Each accepted
//! connection gets an IO thread holding a [`ServerHandle`]; requests
//! route session-affine (connection id as the key) into the engine
//! replicas, which batch by deadline and record per-request latency (see
//! `coordinator/server.rs`). `--max-requests N` serves exactly N
//! requests (scores, generates, rejections, pings and stats all count),
//! then drains gracefully — the loadgen smoke in `tools/ci.sh` relies on
//! that determinism.

use crate::coordinator::methods::MethodConfig;
use crate::coordinator::server::{
    CoordinatorBackend, NativeBackend, Request, Response, ServerConfig, ServerCore, ServerHandle,
    SubmitError, ERR_TIMEOUT,
};
use crate::sparsity::Pattern;
use crate::synthlang::vocab::{Vocab, EOS};
use crate::util::cli::{usage, Args, OptSpec};
use crate::util::json::{self, Json};
use crate::util::trace::{self, TraceLevel};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

pub fn cmd_serve(rest: Vec<String>) -> Result<()> {
    #[rustfmt::skip]
    let specs = vec![
        OptSpec { name: "artifacts", takes_value: true, default: Some("artifacts"), help: "artifacts dir" },
        OptSpec { name: "addr", takes_value: true, default: Some("127.0.0.1:7433"), help: "listen address" },
        OptSpec { name: "pattern", takes_value: true, default: Some("8:16"), help: "sparsity pattern" },
        OptSpec { name: "method", takes_value: true, default: Some("S-PTS"), help: "method name" },
        OptSpec { name: "backend", takes_value: true, default: Some("coordinator"), help: "coordinator (PJRT, full-context) | native (KV-cached)" },
        OptSpec { name: "seed", takes_value: true, default: Some("7"), help: "native synthetic-model seed (no artifacts)" },
        OptSpec { name: "threads", takes_value: true, default: Some("1"), help: "native worker-pool width per replica (0 = auto; never changes bits)" },
        OptSpec { name: "prefill-block", takes_value: true, default: Some("0"), help: "native resumable-prefill block size in positions (0 = feed-to-completion; never changes bits)" },
        OptSpec { name: "replicas", takes_value: true, default: Some("1"), help: "engine replicas (each opens its own pool)" },
        OptSpec { name: "queue-cap", takes_value: true, default: Some("64"), help: "per-replica admission cap" },
        OptSpec { name: "max-wait-ms", takes_value: true, default: Some("5"), help: "batch deadline (ms)" },
        OptSpec { name: "max-requests", takes_value: true, default: Some("0"), help: "exit after N requests (0 = run forever)" },
        OptSpec { name: "request-timeout-ms", takes_value: true, default: Some("0"), help: "per-request deadline (ms, 0 = none)" },
        OptSpec { name: "trace", takes_value: true, default: Some(""), help: "write Chrome trace-event JSON (Perfetto-loadable) on exit" },
        OptSpec { name: "help", takes_value: false, default: None, help: "show help" },
    ];
    let a = Args::parse(rest, &specs)?;
    if a.flag("help") {
        print!("{}", usage("serve", "Run the TCP scoring/generation server.", &specs));
        return Ok(());
    }
    let pattern = Pattern::parse(&a.get("pattern"))?;
    let backend_kind = a.get("backend");
    // The serve-wide default method (S-PTS) needs per-site calibration
    // vectors, which the native backend only has when an artifacts
    // methodparams store exists; when the native backend is selected and
    // --method was not given, fall back to ACT so the artifact-free path
    // still starts (an *explicit* S-PTS without artifacts errors loudly
    // at startup, and runs natively when artifacts are present). The
    // banner and ping replies show the method actually served.
    let method_name = if backend_kind == "native" && !a.given("method") {
        "ACT".to_string()
    } else {
        a.get("method")
    };
    let cfg = MethodConfig::by_name(&method_name, pattern)?;
    let vocab = Arc::new(Vocab::synthlang());
    let stop = vec![vocab.id(".")?, EOS];
    let artifacts = PathBuf::from(a.get("artifacts"));
    let max_requests = a.get_usize("max-requests")? as u64;
    let request_timeout = {
        let ms = a.get_u64("request-timeout-ms")?;
        (ms > 0).then(|| Duration::from_millis(ms))
    };
    let trace_path = a.get("trace");
    // Metrics-level aggregation is always on for serve — the stats op's
    // `phases` block costs per-thread counters, not span events. The
    // full span ring only arms when a trace export was requested.
    trace::ensure(TraceLevel::Metrics);
    if !trace_path.is_empty() {
        trace::set_level(TraceLevel::Full);
    }

    let server_cfg = ServerConfig {
        replicas: a.get_usize("replicas")?,
        queue_cap: a.get_usize("queue-cap")?,
        max_wait: Duration::from_millis(a.get_u64("max-wait-ms")?),
        ..Default::default()
    };
    // Each replica thread builds its own backend (PJRT handles are not
    // Send; native engines simply stay per-thread); start() blocks until
    // every engine is ready.
    let core = match backend_kind.as_str() {
        "coordinator" => {
            let factory_cfg = cfg.clone();
            let (artifacts, stop) = (artifacts.clone(), stop.clone());
            ServerCore::start(server_cfg, move |_r| {
                CoordinatorBackend::open(&artifacts, factory_cfg.clone(), stop.clone())
            })?
        }
        "native" => {
            // KV-cached native decode: artifacts checkpoint when present,
            // seeded synthetic model otherwise (no PJRT either way).
            let (artifacts, stop) = (artifacts.clone(), stop.clone());
            let method = method_name.clone();
            let seed = a.get_u64("seed")?;
            let threads = super::decode::resolve_threads(a.get_usize("threads")?);
            let prefill_block = a.get_usize("prefill-block")?;
            ServerCore::start(server_cfg, move |_r| {
                NativeBackend::open(&artifacts, pattern, &method, stop.clone(), 8, seed)
                    .map(|b| b.with_threads(threads).with_prefill_block(prefill_block))
            })?
        }
        other => anyhow::bail!("unknown --backend '{other}' (coordinator, native)"),
    };

    let listener = TcpListener::bind(a.get("addr")).context("binding server address")?;
    listener.set_nonblocking(true)?;
    println!(
        "serving {} / {} on {} ({} replica(s), queue cap {}, {} backend)",
        cfg.variant_key,
        cfg.id,
        a.get("addr"),
        core.replicas(),
        server_cfg.queue_cap.max(1),
        backend_kind,
    );

    // Requests answered at this protocol layer (ping/stats/parse errors);
    // score/generate outcomes are counted inside the core.
    let extra = Arc::new(AtomicU64::new(0));
    let banner = Arc::new((cfg.variant_key.clone(), cfg.id.clone()));
    let started = Instant::now();
    let mut conn_seq = 0u64;
    loop {
        // The accept path may poll; the engine replicas never do — they
        // block on their channels / batch deadlines.
        match listener.accept() {
            Ok((stream, _)) => {
                conn_seq += 1;
                spawn_io_thread(
                    stream,
                    core.handle(),
                    Arc::clone(&vocab),
                    Arc::clone(&extra),
                    Arc::clone(&banner),
                    conn_seq,
                    request_timeout,
                    started,
                );
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
        if max_requests > 0 && core.completed() + extra.load(Ordering::Relaxed) >= max_requests {
            break;
        }
    }
    let stats = core.shutdown();
    println!(
        "served {} requests ({} rejected, {} errors); exiting (--max-requests)",
        stats.served + extra.load(Ordering::Relaxed),
        stats.rejected,
        stats.errors,
    );
    println!("latency: {} | occupancy {:.2}", stats.latency.summary(), stats.batch_occupancy());
    println!("queue wait: {}", stats.queue_wait.summary());
    println!("{}", trace::snapshot().summary());
    if !trace_path.is_empty() {
        let n = trace::write_chrome_trace(std::path::Path::new(&trace_path))?;
        println!("trace: wrote {n} spans to {trace_path}");
    }
    Ok(())
}

/// One parsed protocol line.
enum ClientOp {
    Ping,
    Stats,
    Engine(Request),
}

fn parse_request(line: &str, vocab: &Vocab) -> Result<ClientOp> {
    let j = json::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
    let op = j.req("op")?.as_str().context("op")?;
    match op {
        "ping" => Ok(ClientOp::Ping),
        "stats" => Ok(ClientOp::Stats),
        "score" => {
            let ctx = vocab.encode(j.req("text")?.as_str().context("text")?)?;
            let choice = vocab.encode(j.req("choice")?.as_str().context("choice")?)?;
            anyhow::ensure!(!ctx.is_empty() && !choice.is_empty(), "empty text/choice");
            let mut tokens = ctx.clone();
            let start = tokens.len();
            tokens.extend(&choice);
            Ok(ClientOp::Engine(Request::Score { span: (start, tokens.len()), tokens }))
        }
        "generate" => {
            let tokens = vocab.encode(j.req("text")?.as_str().context("text")?)?;
            anyhow::ensure!(!tokens.is_empty(), "empty prompt");
            let max_new = j
                .get("max_new")
                .and_then(|x| x.as_usize())
                .unwrap_or(12)
                .clamp(1, 48);
            Ok(ClientOp::Engine(Request::Generate { tokens, max_new }))
        }
        other => anyhow::bail!("unknown op '{other}'"),
    }
}

fn error_reply(message: &str) -> String {
    let mut r = Json::obj();
    r.insert("ok", false.into());
    r.insert("error", message.into());
    r.dump()
}

fn response_reply(resp: &Response, vocab: &Vocab) -> String {
    let mut r = Json::obj();
    match resp {
        Response::Score { score } => {
            r.insert("ok", true.into());
            r.insert("score", (*score).into());
        }
        Response::Generate { tokens } => {
            r.insert("ok", true.into());
            r.insert(
                "tokens",
                Json::Arr(tokens.iter().map(|t| Json::Num(*t as f64)).collect()),
            );
            r.insert("text", vocab.decode(tokens).into());
        }
        Response::Error { message } => {
            r.insert("ok", false.into());
            r.insert("error", message.as_str().into());
        }
    }
    r.dump()
}

fn stats_reply(handle: &ServerHandle, started: Instant) -> String {
    let s = handle.stats();
    let mut r = Json::obj();
    r.insert("ok", true.into());
    r.insert("replicas", (s.replicas as f64).into());
    r.insert("submitted", (s.submitted as f64).into());
    r.insert("served", (s.served as f64).into());
    r.insert("rejected", (s.rejected as f64).into());
    r.insert("errors", (s.errors as f64).into());
    r.insert("stolen", (s.stolen as f64).into());
    r.insert("restarts", (s.restarts as f64).into());
    r.insert("retried", (s.retried as f64).into());
    r.insert("timed_out", (s.timed_out as f64).into());
    r.insert("failed", (s.failed as f64).into());
    r.insert("latency_ms", super::loadgen::latency_ms_json(&s.latency));
    r.insert("queue_wait_ms", super::loadgen::latency_ms_json(&s.queue_wait));
    r.insert("phases", trace::snapshot().to_json(started.elapsed().as_secs_f64()));
    r.insert("metrics", trace::metrics_json());
    r.insert("batch_occupancy", s.batch_occupancy().into());
    r.insert("rejection_rate", s.rejection_rate().into());
    r.insert("timeout_rate", s.timeout_rate().into());
    r.insert("failure_rate", s.failure_rate().into());
    r.insert(
        "depth",
        Json::Arr((0..s.replicas).map(|i| Json::Num(handle.depth(i) as f64)).collect()),
    );
    r.dump()
}

/// Grace past the core's shed deadline before the IO thread gives up on
/// a ticket: a quarter of the request timeout, clamped to [50 ms, 1 s]
/// (the old hard-coded 250 ms only fit mid-range timeouts — a 100 ms
/// deadline wants a tighter bound, a 10 s one more slack).
fn reply_grace(request_timeout: Option<Duration>) -> Duration {
    match request_timeout {
        Some(d) => (d / 4).clamp(Duration::from_millis(50), Duration::from_secs(1)),
        None => Duration::from_millis(250),
    }
}

/// Socket write timeout: twice the request timeout (min 1 s) so a slow
/// client gets strictly more patience than the engine path, or the old
/// 30 s ceiling when no request timeout bounds the connection.
fn write_timeout(request_timeout: Option<Duration>) -> Duration {
    match request_timeout {
        Some(d) => (d * 2).max(Duration::from_secs(1)),
        None => Duration::from_secs(30),
    }
}

/// Per-connection IO thread: read a line, route it, write the reply. The
/// connection id is the session-affinity key, so one client's decode
/// sessions stay on one replica. With a request timeout the ticket wait
/// is bounded (`recv_timeout` with [`reply_grace`] headroom past the
/// core's own shed deadline) and the socket write is bounded by
/// [`write_timeout`], so neither a wedged replica nor a stalled client
/// can pin this thread forever — and both give-up paths count in the
/// metrics registry instead of dropping silently.
#[allow(clippy::too_many_arguments)]
fn spawn_io_thread(
    stream: TcpStream,
    handle: ServerHandle,
    vocab: Arc<Vocab>,
    extra: Arc<AtomicU64>,
    banner: Arc<(String, String)>,
    conn_id: u64,
    request_timeout: Option<Duration>,
    started: Instant,
) {
    std::thread::spawn(move || {
        stream.set_nonblocking(false).ok();
        stream.set_write_timeout(Some(write_timeout(request_timeout))).ok();
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let Ok(line) = line else { break };
            if line.trim().is_empty() {
                continue;
            }
            let reply = match parse_request(&line, &vocab) {
                Ok(ClientOp::Ping) => {
                    extra.fetch_add(1, Ordering::Relaxed);
                    let mut r = Json::obj();
                    r.insert("ok", true.into());
                    r.insert("variant", banner.0.as_str().into());
                    r.insert("method", banner.1.as_str().into());
                    r.insert("replicas", (handle.replicas() as f64).into());
                    r.dump()
                }
                Ok(ClientOp::Stats) => {
                    extra.fetch_add(1, Ordering::Relaxed);
                    stats_reply(&handle, started)
                }
                Ok(ClientOp::Engine(req)) => {
                    let deadline = request_timeout.map(|d| Instant::now() + d);
                    match handle.submit_with(Some(conn_id), req, deadline) {
                        // One request in flight per connection, like the
                        // line protocol implies. With a deadline, the
                        // wait is bounded: the core sheds the request
                        // shortly after expiry, and the extra headroom
                        // lets the terminal `timeout` reply arrive first.
                        Ok(ticket) => {
                            let got = match deadline {
                                Some(d) => ticket.recv_timeout(
                                    d.saturating_duration_since(Instant::now())
                                        + reply_grace(request_timeout),
                                ),
                                None => ticket.recv(),
                            };
                            match got {
                                Some(resp) => response_reply(&resp, &vocab),
                                None if deadline.is_some() => {
                                    trace::counter("serve.io_reply_timeout").inc();
                                    error_reply(ERR_TIMEOUT)
                                }
                                None => error_reply(&SubmitError::Closed.to_string()),
                            }
                        }
                        Err(e) => error_reply(&e.to_string()), // "overloaded" / shutdown
                    }
                }
                Err(e) => {
                    extra.fetch_add(1, Ordering::Relaxed);
                    error_reply(&format!("{e:#}"))
                }
            };
            if writer.write_all(reply.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
                trace::counter("serve.io_write_errors").inc();
                break;
            }
        }
    });
}
