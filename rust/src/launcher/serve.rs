//! `nmsparse serve` — the TCP front-end over the multi-replica
//! [`ServerCore`].
//!
//! The wire format is pluggable (`--codec`, DESIGN.md §2.15). The default
//! is the original line-delimited JSON protocol (no HTTP stack in the
//! offline image — the protocol is deliberately minimal):
//!
//! ```text
//! -> {"op":"ping"}
//! <- {"ok":true,"variant":"8_16","method":"S-PTS","replicas":2}
//! -> {"op":"score","text":"does the red fox live in the den ?","choice":" yes"}
//! <- {"ok":true,"score":-1.23}
//! -> {"op":"generate","text":"repeat the word fox two times :","max_new":8}
//! <- {"ok":true,"text":"fox fox ."}
//! -> {"op":"stats"}
//! <- {"ok":true,"served":412,"rejected":3,"latency_ms":{"p50":...},...}
//! ```
//!
//! `--codec binary` speaks the length-prefixed compact framing instead
//! (`wire::binary`): the client opens with a 6-byte versioned hello, and
//! a `generate` with the stream flag receives incremental per-token
//! `chunk` frames before the terminal `end` frame. Both codecs implement
//! `wire::Codec`; this file never branches on the encoding beyond the
//! connect handshake. A malformed frame is answered with an error frame
//! and skipped — the connection survives.
//!
//! When a replica's admission queue is full the request is shed
//! immediately with `{"ok":false,"error":"overloaded"}` — clients retry
//! with backoff instead of stacking unbounded work. `--tenants K` splits
//! admission and dispatch into weighted-fair tenant classes (requests
//! carry a `tenant` field; see `coordinator/server.rs`).
//!
//! `--request-timeout-ms` attaches a deadline to every engine request:
//! the core sheds expired work with `{"ok":false,"error":"timeout"}`,
//! and the IO thread waits with `recv_timeout` (plus a socket
//! write-timeout) so a failed replica can never hang a client
//! connection indefinitely — the supervisor answers in-flight requests
//! terminally with `replica_failed` and rebuilds the replica (see
//! DESIGN.md §2.12).
//!
//! Architecture: this file owns only sockets and codecs. Each accepted
//! connection gets an IO thread holding a [`ServerHandle`]; requests
//! route session-affine (connection id as the key) into the engine
//! replicas, which batch by deadline and record per-request latency (see
//! `coordinator/server.rs`). `--max-requests N` serves exactly N
//! requests (scores, generates, rejections, pings and stats all count),
//! then drains gracefully — the loadgen smoke in `tools/ci.sh` relies on
//! that determinism.

use crate::coordinator::methods::MethodConfig;
use crate::coordinator::server::{
    CoordinatorBackend, NativeBackend, Request, Response, ServerConfig, ServerCore, ServerHandle,
    SubmitError, SubmitOpts, ERR_TIMEOUT,
};
use crate::sparsity::Pattern;
use crate::synthlang::vocab::{Vocab, EOS};
use crate::util::cli::{usage, Args, OptSpec};
use crate::util::json::Json;
use crate::util::trace::{self, TraceLevel};
use crate::wire::{binary, stream_channel, Codec, CodecKind, StreamOutcome, StreamPoll};
use crate::wire::{WireReply, WireRequest, LANE_CAP};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

pub fn cmd_serve(rest: Vec<String>) -> Result<()> {
    #[rustfmt::skip]
    let specs = vec![
        OptSpec { name: "artifacts", takes_value: true, default: Some("artifacts"), help: "artifacts dir" },
        OptSpec { name: "addr", takes_value: true, default: Some("127.0.0.1:7433"), help: "listen address" },
        OptSpec { name: "pattern", takes_value: true, default: Some("8:16"), help: "sparsity pattern" },
        OptSpec { name: "method", takes_value: true, default: Some("S-PTS"), help: "method name" },
        OptSpec { name: "backend", takes_value: true, default: Some("coordinator"), help: "coordinator (PJRT, full-context) | native (KV-cached)" },
        OptSpec { name: "seed", takes_value: true, default: Some("7"), help: "native synthetic-model seed (no artifacts)" },
        OptSpec { name: "threads", takes_value: true, default: Some("1"), help: "native worker-pool width per replica (0 = auto; never changes bits)" },
        OptSpec { name: "prefill-block", takes_value: true, default: Some("0"), help: "native resumable-prefill block size in positions (0 = feed-to-completion; never changes bits)" },
        OptSpec { name: "replicas", takes_value: true, default: Some("1"), help: "engine replicas (each opens its own pool)" },
        OptSpec { name: "queue-cap", takes_value: true, default: Some("64"), help: "per-replica admission cap" },
        OptSpec { name: "max-wait-ms", takes_value: true, default: Some("5"), help: "batch deadline (ms)" },
        OptSpec { name: "max-requests", takes_value: true, default: Some("0"), help: "exit after N requests (0 = run forever)" },
        OptSpec { name: "request-timeout-ms", takes_value: true, default: Some("0"), help: "per-request deadline (ms, 0 = none)" },
        OptSpec { name: "codec", takes_value: true, default: Some("json"), help: "wire codec: json (line-delimited, historical) | binary (length-prefixed frames)" },
        OptSpec { name: "tenants", takes_value: true, default: Some("1"), help: "tenant classes for weighted-fair dispatch" },
        OptSpec { name: "tenant-weights", takes_value: true, default: Some(""), help: "comma-separated DRR weights (empty = equal)" },
        OptSpec { name: "tenant-quota", takes_value: true, default: Some("0"), help: "per-tenant in-flight quota per replica (0 = share queue-cap)" },
        OptSpec { name: "trace", takes_value: true, default: Some(""), help: "write Chrome trace-event JSON (Perfetto-loadable) on exit" },
        OptSpec { name: "help", takes_value: false, default: None, help: "show help" },
    ];
    let a = Args::parse(rest, &specs)?;
    if a.flag("help") {
        print!("{}", usage("serve", "Run the TCP scoring/generation server.", &specs));
        return Ok(());
    }
    let pattern = Pattern::parse(&a.get("pattern"))?;
    let backend_kind = a.get("backend");
    // The serve-wide default method (S-PTS) needs per-site calibration
    // vectors, which the native backend only has when an artifacts
    // methodparams store exists; when the native backend is selected and
    // --method was not given, fall back to ACT so the artifact-free path
    // still starts (an *explicit* S-PTS without artifacts errors loudly
    // at startup, and runs natively when artifacts are present). The
    // banner and ping replies show the method actually served.
    let method_name = if backend_kind == "native" && !a.given("method") {
        "ACT".to_string()
    } else {
        a.get("method")
    };
    let cfg = MethodConfig::by_name(&method_name, pattern)?;
    let vocab = Arc::new(Vocab::synthlang());
    let stop = vec![vocab.id(".")?, EOS];
    let artifacts = PathBuf::from(a.get("artifacts"));
    let max_requests = a.get_usize("max-requests")? as u64;
    let request_timeout = {
        let ms = a.get_u64("request-timeout-ms")?;
        (ms > 0).then(|| Duration::from_millis(ms))
    };
    let codec_kind = CodecKind::parse(&a.get("codec"))
        .with_context(|| format!("unknown --codec '{}' (json, binary)", a.get("codec")))?;
    let trace_path = a.get("trace");
    // Metrics-level aggregation is always on for serve — the stats op's
    // `phases` block costs per-thread counters, not span events. The
    // full span ring only arms when a trace export was requested.
    trace::ensure(TraceLevel::Metrics);
    if !trace_path.is_empty() {
        trace::set_level(TraceLevel::Full);
    }

    let server_cfg = ServerConfig {
        replicas: a.get_usize("replicas")?,
        queue_cap: a.get_usize("queue-cap")?,
        max_wait: Duration::from_millis(a.get_u64("max-wait-ms")?),
        tenants: a.get_usize("tenants")?,
        tenant_weights: parse_weights(&a.get("tenant-weights"))?,
        tenant_quota: a.get_usize("tenant-quota")?,
        ..Default::default()
    };
    let queue_cap = server_cfg.queue_cap.max(1);
    let tenants = server_cfg.tenants.max(1);
    // Each replica thread builds its own backend (PJRT handles are not
    // Send; native engines simply stay per-thread); start() blocks until
    // every engine is ready.
    let core = match backend_kind.as_str() {
        "coordinator" => {
            let factory_cfg = cfg.clone();
            let (artifacts, stop) = (artifacts.clone(), stop.clone());
            ServerCore::start(server_cfg, move |_r| {
                CoordinatorBackend::open(&artifacts, factory_cfg.clone(), stop.clone())
            })?
        }
        "native" => {
            // KV-cached native decode: artifacts checkpoint when present,
            // seeded synthetic model otherwise (no PJRT either way).
            let (artifacts, stop) = (artifacts.clone(), stop.clone());
            let method = method_name.clone();
            let seed = a.get_u64("seed")?;
            let threads = super::decode::resolve_threads(a.get_usize("threads")?);
            let prefill_block = a.get_usize("prefill-block")?;
            ServerCore::start(server_cfg, move |_r| {
                NativeBackend::open(&artifacts, pattern, &method, stop.clone(), 8, seed)
                    .map(|b| b.with_threads(threads).with_prefill_block(prefill_block))
            })?
        }
        other => anyhow::bail!("unknown --backend '{other}' (coordinator, native)"),
    };

    let listener = TcpListener::bind(a.get("addr")).context("binding server address")?;
    listener.set_nonblocking(true)?;
    println!(
        "serving {} / {} on {} ({} replica(s), queue cap {}, {} backend, {} codec)",
        cfg.variant_key,
        cfg.id,
        a.get("addr"),
        core.replicas(),
        queue_cap,
        backend_kind,
        codec_kind.as_str(),
    );

    // Requests answered at this protocol layer (ping/stats/parse errors);
    // score/generate outcomes are counted inside the core.
    let extra = Arc::new(AtomicU64::new(0));
    let ctx = Arc::new(ConnCtx {
        handle: core.handle(),
        vocab: Arc::clone(&vocab),
        extra: Arc::clone(&extra),
        banner: (cfg.variant_key.clone(), cfg.id.clone()),
        request_timeout,
        started: Instant::now(),
        codec: codec_kind,
        tenants,
    });
    let mut conn_seq = 0u64;
    loop {
        // The accept path may poll; the engine replicas never do — they
        // block on their channels / batch deadlines.
        match listener.accept() {
            Ok((stream, _)) => {
                conn_seq += 1;
                spawn_io_thread(stream, Arc::clone(&ctx), conn_seq);
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
        if max_requests > 0 && core.completed() + extra.load(Ordering::Relaxed) >= max_requests {
            break;
        }
    }
    let stats = core.shutdown();
    println!(
        "served {} requests ({} rejected, {} errors); exiting (--max-requests)",
        stats.served + extra.load(Ordering::Relaxed),
        stats.rejected,
        stats.errors,
    );
    println!("latency: {} | occupancy {:.2}", stats.latency.summary(), stats.batch_occupancy());
    println!("queue wait: {}", stats.queue_wait.summary());
    println!("{}", trace::snapshot().summary());
    if !trace_path.is_empty() {
        let n = trace::write_chrome_trace(std::path::Path::new(&trace_path))?;
        println!("trace: wrote {n} spans to {trace_path}");
    }
    Ok(())
}

/// Parse a comma-separated DRR weight list ("10,1"); empty means equal
/// weights. Shared with `nmsparse loadgen`.
pub fn parse_weights(s: &str) -> Result<Vec<u32>> {
    let s = s.trim();
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|w| {
            let w = w.trim();
            w.parse::<u32>().map_err(|_| anyhow::anyhow!("bad tenant weight '{w}'"))
        })
        .collect()
}

/// Map a request's optional tenant field onto a configured class: numeric
/// ids map directly, names hash (FNV-1a), both reduced mod the class
/// count. Absent or single-tenant → class 0.
fn tenant_index(name: Option<&str>, tenants: usize) -> u32 {
    let Some(name) = name else { return 0 };
    if tenants <= 1 {
        return 0;
    }
    let id = match name.parse::<u64>() {
        Ok(n) => n,
        Err(_) => {
            let mut h: u64 = 0xcbf2_9ce4_8422_2325;
            for b in name.bytes() {
                h = (h ^ b as u64).wrapping_mul(0x0100_0000_01b3);
            }
            h
        }
    };
    (id % tenants as u64) as u32
}

/// Everything a connection's IO thread needs, shared across connections.
struct ConnCtx {
    handle: ServerHandle,
    vocab: Arc<Vocab>,
    extra: Arc<AtomicU64>,
    /// (variant_key, method id) for the ping banner.
    banner: (String, String),
    request_timeout: Option<Duration>,
    started: Instant,
    codec: CodecKind,
    tenants: usize,
}

fn ping_reply(ctx: &ConnCtx) -> Json {
    let mut r = Json::obj();
    r.insert("ok", true.into());
    r.insert("variant", ctx.banner.0.as_str().into());
    r.insert("method", ctx.banner.1.as_str().into());
    r.insert("replicas", (ctx.handle.replicas() as f64).into());
    r
}

fn stats_reply(handle: &ServerHandle, started: Instant, tenants: usize) -> Json {
    let s = handle.stats();
    let mut r = Json::obj();
    r.insert("ok", true.into());
    r.insert("replicas", (s.replicas as f64).into());
    r.insert("submitted", (s.submitted as f64).into());
    r.insert("served", (s.served as f64).into());
    r.insert("rejected", (s.rejected as f64).into());
    r.insert("errors", (s.errors as f64).into());
    r.insert("stolen", (s.stolen as f64).into());
    r.insert("restarts", (s.restarts as f64).into());
    r.insert("retried", (s.retried as f64).into());
    r.insert("timed_out", (s.timed_out as f64).into());
    r.insert("failed", (s.failed as f64).into());
    r.insert("latency_ms", super::loadgen::latency_ms_json(&s.latency));
    r.insert("queue_wait_ms", super::loadgen::latency_ms_json(&s.queue_wait));
    r.insert("phases", trace::snapshot().to_json(started.elapsed().as_secs_f64()));
    r.insert("metrics", trace::metrics_json());
    r.insert("batch_occupancy", s.batch_occupancy().into());
    r.insert("rejection_rate", s.rejection_rate().into());
    r.insert("timeout_rate", s.timeout_rate().into());
    r.insert("failure_rate", s.failure_rate().into());
    r.insert(
        "depth",
        Json::Arr((0..s.replicas).map(|i| Json::Num(handle.depth(i) as f64)).collect()),
    );
    // Single-tenant servers keep the historical stats shape byte-for-byte;
    // the tenants block only appears when fairness is actually configured.
    if tenants > 1 {
        r.insert("tenants", super::loadgen::tenants_json(&s.tenants, &[]));
    }
    r
}

/// Grace past the core's shed deadline before the IO thread gives up on
/// a ticket: a quarter of the request timeout, clamped to [50 ms, 1 s]
/// (the old hard-coded 250 ms only fit mid-range timeouts — a 100 ms
/// deadline wants a tighter bound, a 10 s one more slack).
fn reply_grace(request_timeout: Option<Duration>) -> Duration {
    match request_timeout {
        Some(d) => (d / 4).clamp(Duration::from_millis(50), Duration::from_secs(1)),
        None => Duration::from_millis(250),
    }
}

/// Socket write timeout: twice the request timeout (min 1 s) so a slow
/// client gets strictly more patience than the engine path, or the old
/// 30 s ceiling when no request timeout bounds the connection.
fn write_timeout(request_timeout: Option<Duration>) -> Duration {
    match request_timeout {
        Some(d) => (d * 2).max(Duration::from_secs(1)),
        None => Duration::from_secs(30),
    }
}

/// Per-connection IO thread: decode a request, route it, write the reply
/// frame(s). The connection id is the session-affinity key, so one
/// client's decode sessions stay on one replica. With a request timeout
/// the ticket wait is bounded (`recv_timeout` with [`reply_grace`]
/// headroom past the core's own shed deadline) and the socket write is
/// bounded by [`write_timeout`], so neither a wedged replica nor a
/// stalled client can pin this thread forever — and both give-up paths
/// count in the metrics registry instead of dropping silently.
fn spawn_io_thread(stream: TcpStream, ctx: Arc<ConnCtx>, conn_id: u64) {
    std::thread::spawn(move || {
        let _ = serve_conn(stream, &ctx, conn_id);
    });
}

fn serve_conn(stream: TcpStream, ctx: &ConnCtx, conn_id: u64) -> std::io::Result<()> {
    stream.set_nonblocking(false).ok();
    stream.set_write_timeout(Some(write_timeout(ctx.request_timeout))).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = stream;
    let codec = ctx.codec.codec();
    // Binary connections open with a fixed versioned hello; a mismatch is
    // answered with an error frame and the connection dropped — there is
    // nothing to resynchronize on before the versions agree.
    if ctx.codec == CodecKind::Binary {
        let mut hello = [0u8; binary::HELLO_LEN];
        reader.read_exact(&mut hello)?;
        if let Err(message) = binary::check_hello(&hello) {
            ctx.extra.fetch_add(1, Ordering::Relaxed);
            trace::counter("wire.bad_hello").inc();
            write_reply(codec, &WireReply::Error { message }, &mut writer)?;
            return Ok(());
        }
    }
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    let mut chunk = [0u8; 4096];
    loop {
        // Serve every whole frame the buffer holds before reading more.
        let mut pos = 0usize;
        loop {
            match codec.decode_request(&buf[pos..]) {
                Ok(None) => break,
                Ok(Some((req, used))) => {
                    pos += used;
                    handle_request(req, ctx, conn_id, codec, &mut writer)?;
                }
                Err(e) => {
                    // Malformed frame: answer, skip it, keep serving.
                    pos += e.consumed.min(buf.len() - pos).max(1);
                    ctx.extra.fetch_add(1, Ordering::Relaxed);
                    trace::counter("wire.bad_frames").inc();
                    write_reply(codec, &WireReply::Error { message: e.message }, &mut writer)?;
                }
            }
        }
        buf.drain(..pos);
        let n = reader.read(&mut chunk)?;
        if n == 0 {
            return Ok(()); // clean disconnect
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

fn write_reply(codec: &dyn Codec, rep: &WireReply, writer: &mut TcpStream) -> std::io::Result<()> {
    let mut out = Vec::new();
    codec.encode_reply(rep, &mut out);
    let res = writer.write_all(&out);
    if res.is_err() {
        trace::counter("serve.io_write_errors").inc();
    }
    res
}

fn handle_request(
    req: WireRequest,
    ctx: &ConnCtx,
    conn_id: u64,
    codec: &dyn Codec,
    writer: &mut TcpStream,
) -> std::io::Result<()> {
    match req {
        WireRequest::Ping => {
            ctx.extra.fetch_add(1, Ordering::Relaxed);
            write_reply(codec, &WireReply::Blob(ping_reply(ctx)), writer)
        }
        WireRequest::Stats => {
            ctx.extra.fetch_add(1, Ordering::Relaxed);
            let blob = stats_reply(&ctx.handle, ctx.started, ctx.tenants);
            write_reply(codec, &WireReply::Blob(blob), writer)
        }
        WireRequest::Score { text, choice, tenant } => {
            let tenant = tenant_index(tenant.as_deref(), ctx.tenants);
            match encode_score(&ctx.vocab, &text, &choice) {
                Ok(req) => run_buffered(ctx, conn_id, tenant, req, codec, writer),
                Err(e) => {
                    ctx.extra.fetch_add(1, Ordering::Relaxed);
                    write_reply(codec, &WireReply::Error { message: format!("{e:#}") }, writer)
                }
            }
        }
        WireRequest::Generate { text, max_new, tenant, stream } => {
            let tenant = tenant_index(tenant.as_deref(), ctx.tenants);
            match encode_generate(&ctx.vocab, &text, max_new) {
                Ok(req) => {
                    if stream {
                        run_stream(ctx, conn_id, tenant, req, codec, writer)
                    } else {
                        run_buffered(ctx, conn_id, tenant, req, codec, writer)
                    }
                }
                Err(e) => {
                    ctx.extra.fetch_add(1, Ordering::Relaxed);
                    write_reply(codec, &WireReply::Error { message: format!("{e:#}") }, writer)
                }
            }
        }
        WireRequest::ScoreTokens { tokens, span, tenant } => {
            let tenant = tenant % ctx.tenants.max(1) as u32;
            let span = (span.0 as usize, span.1 as usize);
            run_buffered(ctx, conn_id, tenant, Request::Score { tokens, span }, codec, writer)
        }
        WireRequest::GenerateTokens { tokens, max_new, tenant, stream } => {
            let tenant = tenant % ctx.tenants.max(1) as u32;
            let req = Request::Generate { tokens, max_new: (max_new as usize).clamp(1, 48) };
            if stream {
                run_stream(ctx, conn_id, tenant, req, codec, writer)
            } else {
                run_buffered(ctx, conn_id, tenant, req, codec, writer)
            }
        }
    }
}

/// Text-level score → token-level engine request (vocab errors reply as
/// protocol errors, identical to the historical parse path).
fn encode_score(vocab: &Vocab, text: &str, choice: &str) -> Result<Request> {
    let ctx = vocab.encode(text)?;
    let choice = vocab.encode(choice)?;
    anyhow::ensure!(!ctx.is_empty() && !choice.is_empty(), "empty text/choice");
    let mut tokens = ctx.clone();
    let start = tokens.len();
    tokens.extend(&choice);
    Ok(Request::Score { span: (start, tokens.len()), tokens })
}

fn encode_generate(vocab: &Vocab, text: &str, max_new: Option<usize>) -> Result<Request> {
    let tokens = vocab.encode(text)?;
    anyhow::ensure!(!tokens.is_empty(), "empty prompt");
    let max_new = max_new.unwrap_or(12).clamp(1, 48);
    Ok(Request::Generate { tokens, max_new })
}

/// Response -> terminal reply frame for the buffered (non-streamed) path.
fn buffered_reply(resp: &Response, vocab: &Vocab) -> WireReply {
    match resp {
        Response::Score { score } => WireReply::Score { score: *score },
        Response::Generate { tokens } => {
            WireReply::Generate { tokens: tokens.clone(), text: vocab.decode(tokens) }
        }
        Response::Error { message } => WireReply::Error { message: message.clone() },
    }
}

/// Submit one engine request and wait for its single terminal reply.
/// One request in flight per connection, like the line protocol implies.
/// With a deadline, the wait is bounded: the core sheds the request
/// shortly after expiry, and the extra headroom lets the terminal
/// `timeout` reply arrive first.
fn run_buffered(
    ctx: &ConnCtx,
    conn_id: u64,
    tenant: u32,
    req: Request,
    codec: &dyn Codec,
    writer: &mut TcpStream,
) -> std::io::Result<()> {
    let deadline = ctx.request_timeout.map(|d| Instant::now() + d);
    let opts = SubmitOpts { key: Some(conn_id), deadline, tenant, stream: None };
    let rep = match ctx.handle.submit_opts(req, opts) {
        Ok(ticket) => {
            let got = match deadline {
                Some(d) => ticket.recv_timeout(
                    d.saturating_duration_since(Instant::now()) + reply_grace(ctx.request_timeout),
                ),
                None => ticket.recv(),
            };
            match got {
                Some(resp) => buffered_reply(&resp, &ctx.vocab),
                None if deadline.is_some() => {
                    trace::counter("serve.io_reply_timeout").inc();
                    WireReply::Error { message: ERR_TIMEOUT.into() }
                }
                None => WireReply::Error { message: SubmitError::Closed.to_string() },
            }
        }
        Err(e) => WireReply::Error { message: e.to_string() }, // "overloaded" / shutdown
    };
    write_reply(codec, &rep, writer)
}

/// Streamed generate: incremental `chunk` frames as the replica decodes,
/// then the terminal `end` frame carrying the authoritative transcript
/// and the PR 7 outcome taxonomy. The lane is bounded — a client that
/// stops reading stalls only this thread; the replica's offers drop once
/// the lane fills and decode never blocks.
fn run_stream(
    ctx: &ConnCtx,
    conn_id: u64,
    tenant: u32,
    req: Request,
    codec: &dyn Codec,
    writer: &mut TcpStream,
) -> std::io::Result<()> {
    let deadline = ctx.request_timeout.map(|d| Instant::now() + d);
    let (tx, rx) = stream_channel(LANE_CAP);
    let opts = SubmitOpts { key: Some(conn_id), deadline, tenant, stream: Some(tx) };
    let ticket = match ctx.handle.submit_opts(req, opts) {
        Ok(t) => t,
        Err(e) => return write_reply(codec, &WireReply::Error { message: e.to_string() }, writer),
    };
    let give_up = deadline.map(|d| d + reply_grace(ctx.request_timeout));
    let mut index = 0u32;
    loop {
        match rx.poll(Duration::from_millis(20)) {
            StreamPoll::Token(token) => {
                write_reply(codec, &WireReply::Chunk { index, token }, writer)?;
                index += 1;
            }
            StreamPoll::Idle => {
                if give_up.is_some_and(|d| Instant::now() >= d) {
                    // The core should have shed this by now; answer
                    // terminally rather than wait on a wedged replica.
                    trace::counter("serve.io_reply_timeout").inc();
                    let end = WireReply::End {
                        outcome: StreamOutcome::Timeout,
                        tokens: Vec::new(),
                        text: String::new(),
                    };
                    return write_reply(codec, &end, writer);
                }
            }
            StreamPoll::Closed => break,
        }
    }
    // Lane closed — the core settled the ticket (the stream drops before
    // the terminal send, so grant the reply a short grace window).
    let end = match ticket.recv_timeout(reply_grace(ctx.request_timeout)) {
        Some(Response::Generate { tokens }) => {
            let text = ctx.vocab.decode(&tokens);
            WireReply::End { outcome: StreamOutcome::End, tokens, text }
        }
        Some(Response::Error { message }) => WireReply::End {
            outcome: match message.as_str() {
                ERR_TIMEOUT => StreamOutcome::Timeout,
                _ => StreamOutcome::ReplicaFailed,
            },
            tokens: Vec::new(),
            text: String::new(),
        },
        Some(Response::Score { .. }) | None => WireReply::End {
            outcome: StreamOutcome::ReplicaFailed,
            tokens: Vec::new(),
            text: String::new(),
        },
    };
    write_reply(codec, &end, writer)
}
