//! `nmsparse serve` — a single-process scoring/generation server.
//!
//! Line-delimited JSON over TCP (no HTTP stack in the offline image — the
//! protocol is deliberately minimal; see `examples/serving_client.rs`):
//!
//! ```text
//! -> {"op":"ping"}
//! <- {"ok":true,"variant":"8_16","method":"S-PTS"}
//! -> {"op":"score","text":"does the red fox live in the den ?","choice":" yes"}
//! <- {"ok":true,"score":-1.23}
//! -> {"op":"generate","text":"repeat the word fox two times :","max_new":8}
//! <- {"ok":true,"text":"fox fox ."}
//! ```
//!
//! Architecture: IO threads own sockets and exchange requests/responses
//! with the single engine thread (PJRT handles are not `Send`) over
//! channels; the engine thread runs a continuous-batching loop using
//! [`crate::coordinator::scheduler::Scheduler`] + the dynamic
//! [`crate::coordinator::batcher::Batcher`] policy.

use crate::coordinator::methods::MethodConfig;
use crate::coordinator::scheduler::{SchedPolicy, Scheduler, Work};
use crate::coordinator::Coordinator;
use crate::sparsity::Pattern;
use crate::synthlang::vocab::{Vocab, EOS};
use crate::util::cli::{usage, Args, OptSpec};
use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Duration;

/// A request forwarded from an IO thread to the engine loop.
struct IoRequest {
    line: String,
    reply: mpsc::Sender<String>,
}

pub fn cmd_serve(rest: Vec<String>) -> Result<()> {
    let specs = vec![
        OptSpec { name: "artifacts", takes_value: true, default: Some("artifacts"), help: "artifacts dir" },
        OptSpec { name: "addr", takes_value: true, default: Some("127.0.0.1:7433"), help: "listen address" },
        OptSpec { name: "pattern", takes_value: true, default: Some("8:16"), help: "sparsity pattern" },
        OptSpec { name: "method", takes_value: true, default: Some("S-PTS"), help: "method name" },
        OptSpec { name: "max-requests", takes_value: true, default: Some("0"), help: "exit after N requests (0 = run forever)" },
        OptSpec { name: "help", takes_value: false, default: None, help: "show help" },
    ];
    let a = Args::parse(rest, &specs)?;
    if a.flag("help") {
        print!("{}", usage("serve", "Run the TCP scoring/generation server.", &specs));
        return Ok(());
    }
    let coord = Coordinator::open(&PathBuf::from(a.get("artifacts")))?;
    let pattern = Pattern::parse(&a.get("pattern"))?;
    let cfg = MethodConfig::by_name(&a.get("method"), pattern)?;
    let engine = coord.pool.engine(&cfg)?; // bind before accepting traffic
    let dims = engine.dims().clone();
    drop(engine);
    let vocab = Vocab::synthlang();
    let max_requests = a.get_usize("max-requests")?;

    let listener = TcpListener::bind(a.get("addr")).context("binding server address")?;
    listener.set_nonblocking(true)?;
    println!(
        "serving {} / {} on {} (batch {} x seq {})",
        cfg.variant_key,
        cfg.id,
        a.get("addr"),
        dims.batch,
        dims.seq
    );

    let (req_tx, req_rx) = mpsc::channel::<IoRequest>();
    let mut served = 0usize;
    let mut scheduler = Scheduler::new(dims.batch, SchedPolicy::default());
    // Pending replies: scheduler id -> (reply channel, kind-specific state).
    let mut score_replies: HashMap<u64, mpsc::Sender<String>> = HashMap::new();
    let mut gen_replies: HashMap<u64, mpsc::Sender<String>> = HashMap::new();
    let period = vocab.id(".")?;

    loop {
        // Accept new connections; spawn an IO thread per client.
        match listener.accept() {
            Ok((stream, _)) => spawn_io_thread(stream, req_tx.clone()),
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
            Err(e) => return Err(e.into()),
        }
        // Ingest queued requests (non-blocking).
        while let Ok(req) = req_rx.try_recv() {
            match parse_request(&req.line, &vocab) {
                Ok(ParsedRequest::Ping) => {
                    let mut r = Json::obj();
                    r.insert("ok", true.into());
                    r.insert("variant", cfg.variant_key.as_str().into());
                    r.insert("method", cfg.id.as_str().into());
                    req.reply.send(r.dump()).ok();
                    served += 1;
                }
                Ok(ParsedRequest::Score { tokens, span }) => {
                    let id = scheduler.submit_score(tokens, span);
                    score_replies.insert(id, req.reply);
                }
                Ok(ParsedRequest::Generate { tokens, max_new }) => {
                    let id = scheduler.submit_generate(tokens, max_new);
                    gen_replies.insert(id, req.reply);
                }
                Err(e) => {
                    let mut r = Json::obj();
                    r.insert("ok", false.into());
                    r.insert("error", format!("{e:#}").into());
                    req.reply.send(r.dump()).ok();
                    served += 1;
                }
            }
        }
        // Dispatch one unit of work.
        match scheduler.next_work() {
            Work::Idle => {
                if max_requests > 0 && served >= max_requests {
                    println!("served {served} requests; exiting (--max-requests)");
                    return Ok(());
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Work::Score(ids) => {
                let rows: Vec<(Vec<u32>, (usize, usize))> = ids
                    .iter()
                    .map(|id| {
                        let j = scheduler.score_job(*id).unwrap();
                        (j.tokens.clone(), j.span)
                    })
                    .collect();
                match coord.score_rows(&cfg, &rows) {
                    Ok(scores) => {
                        for (id, score) in ids.iter().zip(scores) {
                            if let Some(tx) = score_replies.remove(id) {
                                let mut r = Json::obj();
                                r.insert("ok", true.into());
                                r.insert("score", score.into());
                                tx.send(r.dump()).ok();
                                served += 1;
                            }
                            scheduler.complete_score(*id);
                        }
                    }
                    Err(e) => {
                        for id in ids {
                            if let Some(tx) = score_replies.remove(&id) {
                                let mut r = Json::obj();
                                r.insert("ok", false.into());
                                r.insert("error", format!("{e:#}").into());
                                tx.send(r.dump()).ok();
                                served += 1;
                            }
                            scheduler.complete_score(id);
                        }
                    }
                }
            }
            Work::Decode(ids) => {
                // One decode step for each active session. Rows are
                // borrowed straight from the sessions' incremental
                // buffers — no per-token clone at this call site.
                let prompts: Vec<&[u32]> = ids
                    .iter()
                    .map(|id| scheduler.session(*id).unwrap().row())
                    .collect();
                match coord.generate_refs(&cfg, &prompts, 1, &[period, EOS]) {
                    Ok(outs) => {
                        for (id, out) in ids.iter().zip(outs) {
                            let sess = scheduler.session_mut(*id).unwrap();
                            match out.first() {
                                Some(tok) => sess.push_token(*tok, &[period, EOS]),
                                None => sess.done = true, // context full
                            }
                        }
                    }
                    Err(e) => {
                        for id in &ids {
                            scheduler.session_mut(*id).unwrap().done = true;
                            if let Some(tx) = gen_replies.remove(id) {
                                let mut r = Json::obj();
                                r.insert("ok", false.into());
                                r.insert("error", format!("{e:#}").into());
                                tx.send(r.dump()).ok();
                                served += 1;
                            }
                        }
                    }
                }
                for sess in scheduler.reap_done() {
                    if let Some(tx) = gen_replies.remove(&sess.id) {
                        let mut r = Json::obj();
                        r.insert("ok", true.into());
                        r.insert(
                            "tokens",
                            Json::Arr(
                                sess.generated
                                    .iter()
                                    .map(|t| Json::Num(*t as f64))
                                    .collect(),
                            ),
                        );
                        r.insert("text", vocab.decode(&sess.generated).into());
                        tx.send(r.dump()).ok();
                        served += 1;
                    }
                }
            }
        }
    }
}

enum ParsedRequest {
    Ping,
    Score { tokens: Vec<u32>, span: (usize, usize) },
    Generate { tokens: Vec<u32>, max_new: usize },
}

fn parse_request(line: &str, vocab: &Vocab) -> Result<ParsedRequest> {
    let j = json::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
    let op = j.req("op")?.as_str().context("op")?;
    match op {
        "ping" => Ok(ParsedRequest::Ping),
        "score" => {
            let ctx = vocab.encode(j.req("text")?.as_str().context("text")?)?;
            let choice = vocab.encode(j.req("choice")?.as_str().context("choice")?)?;
            anyhow::ensure!(!ctx.is_empty() && !choice.is_empty(), "empty text/choice");
            let mut tokens = ctx.clone();
            let start = tokens.len();
            tokens.extend(&choice);
            Ok(ParsedRequest::Score { span: (start, tokens.len()), tokens })
        }
        "generate" => {
            let tokens = vocab.encode(j.req("text")?.as_str().context("text")?)?;
            anyhow::ensure!(!tokens.is_empty(), "empty prompt");
            let max_new = j
                .get("max_new")
                .and_then(|x| x.as_usize())
                .unwrap_or(12)
                .clamp(1, 48);
            Ok(ParsedRequest::Generate { tokens, max_new })
        }
        other => anyhow::bail!("unknown op '{other}'"),
    }
}

fn spawn_io_thread(stream: TcpStream, req_tx: mpsc::Sender<IoRequest>) {
    std::thread::spawn(move || {
        stream.set_nonblocking(false).ok();
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let Ok(line) = line else { break };
            if line.trim().is_empty() {
                continue;
            }
            let (tx, rx) = mpsc::channel();
            if req_tx
                .send(IoRequest { line, reply: tx })
                .is_err()
            {
                break;
            }
            match rx.recv() {
                Ok(resp) => {
                    if writer.write_all(resp.as_bytes()).is_err()
                        || writer.write_all(b"\n").is_err()
                    {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
    });
}
