//! CLI launcher: subcommand dispatch for the `nmsparse` binary.

use crate::coordinator::methods::MethodConfig;
use crate::coordinator::Coordinator;
use crate::evalharness::{self, ifeval::eval_ifeval};
use crate::sparsity::Pattern;
use crate::synthlang::{self, corpus::Corpus, tasks, vocab::Vocab, DatagenConfig};
use crate::util::cli::{usage, Args, OptSpec};
use anyhow::{bail, Result};
use std::path::PathBuf;

mod decode;
pub mod loadgen;
mod serve;

/// Common options shared by evaluation subcommands.
#[rustfmt::skip]
fn common_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "artifacts", takes_value: true, default: Some("artifacts"), help: "artifacts directory" },
        OptSpec { name: "data", takes_value: true, default: Some("artifacts/data"), help: "data directory" },
        OptSpec { name: "help", takes_value: false, default: None, help: "show help" },
    ]
}

/// Entry point called by `main`.
pub fn dispatch(raw: &[String]) -> Result<()> {
    let Some(cmd) = raw.first().map(|s| s.as_str()) else {
        print!("{}", top_usage());
        return Ok(());
    };
    let rest: Vec<String> = raw[1..].to_vec();
    match cmd {
        "datagen" => cmd_datagen(rest),
        "smoke" => cmd_smoke(rest),
        "info" => cmd_info(rest),
        "eval" => cmd_eval(rest),
        "ppl" => cmd_ppl(rest),
        "ifeval" => cmd_ifeval(rest),
        "table" => crate::tables::cmd_table(rest),
        "serve" => serve::cmd_serve(rest),
        "loadgen" => loadgen::cmd_loadgen(rest),
        "decode" => decode::cmd_decode(rest),
        "--help" | "-h" | "help" => {
            print!("{}", top_usage());
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{}", top_usage()),
    }
}

fn top_usage() -> String {
    "nmsparse — flexible N:M activation sparsity (paper reproduction)\n\n\
     Usage: nmsparse <command> [options]\n\n\
     Commands:\n\
       datagen   generate SynthLang corpus + eval tasks under artifacts/data\n\
       smoke     end-to-end PJRT + artifact load check\n\
       info      print manifest / config / training summary\n\
       eval      evaluate one (pattern, method) on multiple-choice tasks\n\
       ppl       perplexity on the validation corpus\n\
       ifeval    instruction-following strict/loose accuracy\n\
       table     regenerate a paper table/figure (fig1 fig2 table2 table3\n\
                 table4 table5 table6 table7 table8 table10 table11 table12\n\
                 table14 serving)\n\
       serve     TCP scoring/generation server (multi-replica; see\n\
                 examples/serving_demo.rs; --backend coordinator|native;\n\
                 --codec json|binary wire protocol with streamed-token\n\
                 replies and per-tenant weighted-fair dispatch; per-phase\n\
                 timing behind the stats op, --trace exports Chrome\n\
                 trace-event JSON)\n\
       loadgen   closed/open-loop load generator against a ServerCore;\n\
                 emits BENCH_serving.json with a phases block (--sweep\n\
                 emits BENCH_serving_sweep.json; --codec/--stream wire\n\
                 roundtrips, --tenants/--burst/--pareto traffic shaping;\n\
                 --trace exports Chrome trace-event JSON)\n\
       decode    native KV-cached decode engine (synthetic or artifacts;\n\
                 --check pins KV == full-context; --trace exports Chrome\n\
                 trace-event JSON)\n"
        .to_string()
}

fn cmd_datagen(rest: Vec<String>) -> Result<()> {
    let mut specs = common_specs();
    #[rustfmt::skip]
    specs.extend([
        OptSpec { name: "seed", takes_value: true, default: Some("20250710"), help: "world seed" },
        OptSpec { name: "entities", takes_value: true, default: Some("48"), help: "world entities" },
        OptSpec { name: "train-tokens", takes_value: true, default: Some("300000"), help: "training tokens" },
        OptSpec { name: "task-examples", takes_value: true, default: Some("200"), help: "examples per task" },
        OptSpec { name: "out", takes_value: true, default: Some("artifacts/data"), help: "output dir" },
    ]);
    let a = Args::parse(rest, &specs)?;
    if a.flag("help") {
        print!("{}", usage("datagen", "Generate the SynthLang data directory.", &specs));
        return Ok(());
    }
    let cfg = DatagenConfig {
        seed: a.get_u64("seed")?,
        entities: a.get_usize("entities")?,
        train_tokens: a.get_usize("train-tokens")?,
        task_examples: a.get_usize("task-examples")?,
        ..Default::default()
    };
    let out = PathBuf::from(a.get("out"));
    synthlang::generate_all(&cfg, &out)?;
    println!(
        "datagen: wrote corpus ({} train tokens), {} task suites + ifeval to {}",
        cfg.train_tokens,
        tasks::CORE_TASKS.len() + tasks::EXTENDED_TASKS.len(),
        out.display()
    );
    Ok(())
}

fn open_coordinator(a: &Args) -> Result<Coordinator> {
    Coordinator::open(&PathBuf::from(a.get("artifacts")))
}

fn cmd_smoke(rest: Vec<String>) -> Result<()> {
    let specs = common_specs();
    let a = Args::parse(rest, &specs)?;
    let coord = open_coordinator(&a)?;
    println!(
        "platform={} variants={} params={}",
        coord.pool.rt.platform(),
        coord.pool.manifest.variants.len(),
        coord.pool.weights.num_params()
    );
    // Run one dense batch of zeros.
    let cfg = MethodConfig::dense();
    let engine = coord.pool.engine(&cfg)?;
    let d = engine.dims().clone();
    let tokens = vec![0i32; d.batch * d.seq];
    let lens = vec![4i32; d.batch];
    let out = engine.run(&coord.pool.rt, &tokens, &lens)?;
    println!(
        "smoke OK: forward ran, tgt_lp[0]={:.4}, |last_logits|={}",
        out.tgt_logprobs[0],
        out.last_logits.len()
    );
    Ok(())
}

fn cmd_info(rest: Vec<String>) -> Result<()> {
    let specs = common_specs();
    let a = Args::parse(rest, &specs)?;
    let coord = open_coordinator(&a)?;
    let m = &coord.pool.manifest;
    println!(
        "model: {} params, vocab {}, d_model {}, layers {}, heads {}, ffn {}",
        m.dims.num_params,
        m.dims.vocab,
        m.dims.d_model,
        m.dims.n_layers,
        m.dims.n_heads,
        m.dims.ffn
    );
    println!("eval shape: batch {} x seq {}", m.dims.batch, m.dims.seq);
    println!("training: final loss {:.4}, valid ppl {:.3}", m.train_final_loss, m.train_valid_ppl);
    println!("variants ({}):", m.variants.len());
    for (k, v) in &m.variants {
        println!("  {k:16} pattern={} inputs={} file={}", v.pattern, v.inputs.len(), v.file);
    }
    Ok(())
}

/// Load task sets by name from the data dir.
pub fn load_tasks(data: &std::path::Path, names: &[&str]) -> Result<Vec<tasks::TaskSet>> {
    names
        .iter()
        .map(|n| tasks::TaskSet::load(&data.join("tasks").join(format!("{n}.json"))))
        .collect()
}

fn cmd_eval(rest: Vec<String>) -> Result<()> {
    let mut specs = common_specs();
    #[rustfmt::skip]
    specs.extend([
        OptSpec { name: "pattern", takes_value: true, default: Some("8:16"), help: "sparsity pattern (dense, 2:4, 8:16, u50, ...)" },
        OptSpec { name: "method", takes_value: true, default: Some("ACT"), help: "method name (ACT, S-PTS, VAR, CLACT, ...)" },
        OptSpec { name: "tasks", takes_value: true, default: Some("core"), help: "'core', 'extended', 'all' or comma list" },
        OptSpec { name: "examples", takes_value: true, default: Some("100"), help: "examples per task" },
        OptSpec { name: "skip-qkv", takes_value: false, default: None, help: "exempt q/k/v sites (Qwen-style, §3.8)" },
    ]);
    let a = Args::parse(rest, &specs)?;
    if a.flag("help") {
        print!("{}", usage("eval", "Evaluate one (pattern, method) cell.", &specs));
        return Ok(());
    }
    let coord = open_coordinator(&a)?;
    let data = PathBuf::from(a.get("data"));
    let names = resolve_task_names(&a.get("tasks"));
    let task_sets = load_tasks(&data, &names)?;
    let pattern = Pattern::parse(&a.get("pattern"))?;
    let mut cfg = MethodConfig::by_name(&a.get("method"), pattern)?;
    if a.flag("skip-qkv") {
        cfg = cfg.with_disabled_sites(&["q", "k", "v"]);
    }
    let limit = a.get_usize("examples")?;

    let base = MethodConfig::dense();
    let (base_res, base_mean) = evalharness::eval_suite(&coord, &base, &task_sets, limit)?;
    let (res, mean) = evalharness::eval_suite(&coord, &cfg, &task_sets, limit)?;
    println!("{:<18} {:>10} {:>10}", "task", "dense", &cfg.id);
    for (b, r) in base_res.iter().zip(&res) {
        println!("{:<18} {:>10.4} {:>10.4}", b.task, b.accuracy, r.accuracy);
    }
    println!(
        "mean acc: dense {base_mean:.4} vs {} {mean:.4}  | avg drop {:.2}%",
        cfg.id,
        evalharness::avg_relative_drop(&base_res, &res)
    );
    Ok(())
}

/// Expand a --tasks argument into task names.
pub fn resolve_task_names(arg: &str) -> Vec<&'static str> {
    match arg {
        "core" => tasks::CORE_TASKS.to_vec(),
        "extended" => tasks::EXTENDED_TASKS.to_vec(),
        "all" => tasks::CORE_TASKS
            .iter()
            .chain(tasks::EXTENDED_TASKS)
            .copied()
            .collect(),
        list => {
            // Leak is fine: CLI once per process.
            list.split(',')
                .map(|s| &*Box::leak(s.trim().to_string().into_boxed_str()))
                .collect()
        }
    }
}

fn cmd_ppl(rest: Vec<String>) -> Result<()> {
    let mut specs = common_specs();
    #[rustfmt::skip]
    specs.extend([
        OptSpec { name: "pattern", takes_value: true, default: Some("8:16"), help: "sparsity pattern" },
        OptSpec { name: "method", takes_value: true, default: Some("ACT"), help: "method name" },
        OptSpec { name: "windows", takes_value: true, default: Some("32"), help: "max eval windows" },
    ]);
    let a = Args::parse(rest, &specs)?;
    if a.flag("help") {
        print!("{}", usage("ppl", "Validation-corpus perplexity.", &specs));
        return Ok(());
    }
    let coord = open_coordinator(&a)?;
    let data = PathBuf::from(a.get("data"));
    let stream = Corpus::read_tokens(&data.join("corpus_valid.tokens"))?;
    let pattern = Pattern::parse(&a.get("pattern"))?;
    let cfg = MethodConfig::by_name(&a.get("method"), pattern)?;
    let windows = a.get_usize("windows")?;
    let dense = coord.perplexity(&MethodConfig::dense(), &stream, windows)?;
    let p = coord.perplexity(&cfg, &stream, windows)?;
    println!("ppl: dense {dense:.3} | {} {p:.3}", cfg.id);
    Ok(())
}

fn cmd_ifeval(rest: Vec<String>) -> Result<()> {
    let mut specs = common_specs();
    #[rustfmt::skip]
    specs.extend([
        OptSpec { name: "pattern", takes_value: true, default: Some("8:16"), help: "sparsity pattern" },
        OptSpec { name: "method", takes_value: true, default: Some("S-PTS"), help: "method name" },
        OptSpec { name: "examples", takes_value: true, default: Some("64"), help: "prompt count" },
        OptSpec { name: "max-new", takes_value: true, default: Some("12"), help: "max generated tokens" },
    ]);
    let a = Args::parse(rest, &specs)?;
    if a.flag("help") {
        print!("{}", usage("ifeval", "Instruction-following eval (strict/loose).", &specs));
        return Ok(());
    }
    let coord = open_coordinator(&a)?;
    let data = PathBuf::from(a.get("data"));
    let set = tasks::IfevalSet::load(&data.join("tasks").join("synth_ifeval.json"))?;
    let vocab = Vocab::synthlang();
    let pattern = Pattern::parse(&a.get("pattern"))?;
    let cfg = MethodConfig::by_name(&a.get("method"), pattern)?;
    let limit = a.get_usize("examples")?;
    let max_new = a.get_usize("max-new")?;
    let base = eval_ifeval(&coord, &MethodConfig::dense(), &set, &vocab, limit, max_new)?;
    let r = eval_ifeval(&coord, &cfg, &set, &vocab, limit, max_new)?;
    println!("ifeval (PS/PL): dense {:.4}/{:.4} | {} {:.4}/{:.4}",
        base.strict, base.loose, cfg.id, r.strict, r.loose);
    Ok(())
}
