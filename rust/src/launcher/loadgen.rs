//! `nmsparse loadgen` — closed- and open-loop load generator for the
//! multi-replica [`ServerCore`], emitting `BENCH_serving.json`.
//!
//! Closed loop (`--rate 0`, default): `--concurrency` client threads each
//! keep exactly one request in flight — measures latency under a fixed
//! offered concurrency. Each client uses its index as the session key, so
//! the run also exercises session-affine routing.
//!
//! Open loop (`--rate R`): requests are submitted at a fixed R req/s
//! regardless of completion — measures behavior at a target arrival rate,
//! including admission-control shedding (`rejection_rate`).
//!
//! Sweep (`--sweep r1,r2,...`): one bounded open-loop run per offered
//! rate against a fresh core, emitting the latency-vs-offered-rate curve
//! as `BENCH_serving_sweep.json` (rendered by `nmsparse table serving`).
//!
//! Default backend is [`SyntheticBackend`] (deterministic, artifact-free,
//! optional simulated per-forward cost) so the CI smoke runs on a machine
//! with only rustc/cargo; `--backend artifacts` drives the real PJRT
//! engine replicas and `--backend native` the KV-cached
//! [`NativeBackend`] (artifacts checkpoint when present, seeded synthetic
//! model otherwise). The report (throughput, p50/p95/p99 latency from
//! the server-side [`Histogram`], batch occupancy, rejection and
//! timeout/failure rates) is what `tables` and
//! `tools/check_bench_json.py` consume.
//!
//! Robustness knobs: `--request-timeout-ms` attaches a deadline to every
//! request (expired ones shed with a terminal `timeout` error), and
//! `--chaos <seed-or-spec>` wraps every replica backend in a
//! [`ChaosBackend`] executing a deterministic [`FaultPlan`] — the CI
//! chaos smoke drives supervised restarts this way and asserts the
//! availability counters (`restarts`/`retried`/`timed_out`/`failed`)
//! stay balanced.

use crate::coordinator::chaos::{ChaosArg, ChaosBackend, ChaosHandle};
use crate::coordinator::methods::MethodConfig;
use crate::coordinator::server::{
    CoordinatorBackend, NativeBackend, Request, ServerConfig, ServerCore, ServerStats,
    SubmitError, SyntheticBackend, Ticket,
};
use crate::sparsity::Pattern;
use crate::synthlang::vocab::{Vocab, EOS};
use crate::util::cli::{usage, Args, OptSpec};
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::util::trace::{self, TraceLevel};
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Traffic mix. `LongMix` is the continuous-batching scenario: every 4th
/// request is a long-prompt generate (prompt far beyond the tiny engine's
/// `max_seq`, so sliding-window crop and resumable blocked prefill both
/// engage) and the rest are short decodes — the per-class client-side
/// latency split (`classes` in the JSON) shows whether long prefills
/// stall short decodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Score,
    Generate,
    Mixed,
    LongMix,
}

impl Mode {
    pub fn parse(s: &str) -> Result<Mode> {
        match s {
            "score" => Ok(Mode::Score),
            "generate" => Ok(Mode::Generate),
            "mixed" => Ok(Mode::Mixed),
            "longmix" => Ok(Mode::LongMix),
            other => bail!("unknown --mode '{other}' (score, generate, mixed, longmix)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Score => "score",
            Mode::Generate => "generate",
            Mode::Mixed => "mixed",
            Mode::LongMix => "longmix",
        }
    }
}

/// Is request `idx` of a longmix run the long-prompt class?
pub fn longmix_is_long(idx: usize) -> bool {
    idx % 4 == 0
}

/// Which engine the replicas run.
#[derive(Clone, Debug)]
pub enum BackendChoice {
    /// Deterministic artifact-free backend; `forward_cost` is charged once
    /// per dispatched batch (so batching amortizes it, like PJRT).
    Synthetic { batch: usize, forward_cost: Duration },
    /// Real engines: each replica opens its own pool from this directory.
    Artifacts { dir: PathBuf, pattern: String, method: String },
    /// KV-cached native decode engines — artifacts checkpoint when `dir`
    /// holds one, seeded synthetic model otherwise. No PJRT either way.
    /// `threads` is each replica engine's worker-pool width (wall time
    /// only; decode bits are thread-count-invariant).
    Native {
        dir: PathBuf,
        pattern: String,
        method: String,
        seed: u64,
        batch: usize,
        threads: usize,
        /// Resumable-prefill block size per scheduler tick (0 = legacy
        /// feed-to-completion; never changes decoded bits).
        prefill_block: usize,
    },
}

/// One loadgen run, fully specified.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    pub replicas: usize,
    pub queue_cap: usize,
    pub max_requests: usize,
    /// Closed-loop client threads (ignored in open-loop mode).
    pub concurrency: usize,
    /// Open-loop arrival rate in req/s; 0 selects the closed loop.
    pub rate_rps: f64,
    pub mode: Mode,
    pub max_new: usize,
    pub max_wait: Duration,
    pub seed: u64,
    /// Per-request deadline; expired requests shed with a `timeout` reply.
    pub request_timeout: Option<Duration>,
    /// Deterministic fault injection (seed or explicit `FaultPlan` spec).
    pub chaos: Option<ChaosArg>,
    pub backend: BackendChoice,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            replicas: 2,
            queue_cap: 128,
            max_requests: 256,
            concurrency: 16,
            rate_rps: 0.0,
            mode: Mode::Mixed,
            max_new: 8,
            max_wait: Duration::from_millis(5),
            seed: 7,
            request_timeout: None,
            chaos: None,
            backend: BackendChoice::Synthetic {
                batch: 16,
                forward_cost: Duration::from_micros(150),
            },
        }
    }
}

/// Client-side per-class latency, recorded only in longmix runs:
/// `long_prompt` holds the `longmix_is_long` long-prefill generates,
/// `short_decode` everything else. Measured submit → terminal reply on
/// the client, so it includes queueing — the tail of `short_decode` is
/// what resumable prefill (`--prefill-block`) is meant to protect.
#[derive(Clone, Debug, Default)]
pub struct ClassLatency {
    pub long_prompt: crate::util::stats::Histogram,
    pub short_decode: crate::util::stats::Histogram,
}

impl ClassLatency {
    fn record(&mut self, long: bool, d: Duration) {
        if long {
            self.long_prompt.record_duration(d);
        } else {
            self.short_decode.record_duration(d);
        }
    }

    /// The `classes` JSON block: one `{count, latency_ms}` entry per class.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        for (name, hist) in
            [("long_prompt", &self.long_prompt), ("short_decode", &self.short_decode)]
        {
            let mut c = Json::obj();
            c.insert("count", (hist.count() as f64).into());
            c.insert("latency_ms", latency_ms_json(hist));
            j.insert(name, c);
        }
        j
    }
}

/// Outcome of a run: final server stats plus wall-clock derived rates.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    pub stats: ServerStats,
    pub requests: usize,
    pub wall_s: f64,
    pub mode: Mode,
    pub replicas: usize,
    pub queue_cap: usize,
    pub backend_name: &'static str,
    /// Per-class client-side latency; `Some` only for longmix runs.
    pub classes: Option<ClassLatency>,
    /// Per-phase span breakdown recorded over the run (the `phases`
    /// block of `BENCH_serving.json`). Always populated — `run` turns
    /// metrics-level tracing on for the run's duration.
    pub phases: trace::PhaseSnapshot,
}

impl LoadgenReport {
    pub fn throughput_rps(&self) -> f64 {
        self.stats.served as f64 / self.wall_s.max(1e-9)
    }

    /// The `BENCH_serving.json` document (see `tools/check_bench_json.py`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.insert("suite", "serving".into());
        j.insert("mode", self.mode.as_str().into());
        j.insert("backend", self.backend_name.into());
        j.insert("replicas", (self.replicas as f64).into());
        j.insert("queue_cap", (self.queue_cap as f64).into());
        j.insert("requests", (self.requests as f64).into());
        j.insert("served", (self.stats.served as f64).into());
        j.insert("rejected", (self.stats.rejected as f64).into());
        j.insert("errors", (self.stats.errors as f64).into());
        j.insert("wall_s", self.wall_s.into());
        j.insert("throughput_rps", self.throughput_rps().into());
        j.insert("latency_ms", latency_ms_json(&self.stats.latency));
        j.insert("queue_wait_ms", latency_ms_json(&self.stats.queue_wait));
        j.insert("phases", self.phases.to_json(self.wall_s));
        j.insert("batch_occupancy", self.stats.batch_occupancy().into());
        j.insert("rejection_rate", self.stats.rejection_rate().into());
        j.insert("stolen", (self.stats.stolen as f64).into());
        j.insert("restarts", (self.stats.restarts as f64).into());
        j.insert("retried", (self.stats.retried as f64).into());
        j.insert("timed_out", (self.stats.timed_out as f64).into());
        j.insert("failed", (self.stats.failed as f64).into());
        j.insert("timeout_rate", self.stats.timeout_rate().into());
        j.insert("failure_rate", self.stats.failure_rate().into());
        if let Some(c) = &self.classes {
            j.insert("classes", c.to_json());
        }
        j
    }

    /// Human summary printed by the CLI and the bench. The error column
    /// breaks out deadline sheds from died-in-flight so sweep rows can
    /// distinguish the two without opening the JSON.
    pub fn summary(&self) -> String {
        format!(
            "{} reqs in {:.2}s -> {:.1} req/s | served {} rejected {} errors {} \
             (timeout {} failed {}) | restarts {} retried {} | latency {} | \
             qwait p95 {:.2}ms | occupancy {:.2}",
            self.requests,
            self.wall_s,
            self.throughput_rps(),
            self.stats.served,
            self.stats.rejected,
            self.stats.errors,
            self.stats.timed_out,
            self.stats.failed,
            self.stats.restarts,
            self.stats.retried,
            self.stats.latency.summary(),
            self.stats.queue_wait.percentile(95.0) * 1e3,
            self.stats.batch_occupancy(),
        )
    }
}

/// The `latency_ms` JSON block (mean/p50/p95/p99/max, milliseconds) —
/// shared by `BENCH_serving.json` and the serve `{"op":"stats"}` reply so
/// the two consumers can never desync.
pub fn latency_ms_json(lat: &crate::util::stats::Histogram) -> Json {
    let ms = 1e3;
    let mut l = Json::obj();
    l.insert("mean", (lat.mean_s() * ms).into());
    l.insert("p50", (lat.percentile(50.0) * ms).into());
    l.insert("p95", (lat.percentile(95.0) * ms).into());
    l.insert("p99", (lat.percentile(99.0) * ms).into());
    l.insert("max", (lat.max_s() * ms).into());
    l
}

/// Deterministic request synthesis: request `idx` of a run is the same
/// tokens/span/budget for a given seed, independent of thread timing.
pub fn make_request(seed: u64, idx: usize, mode: Mode, max_new: usize) -> Request {
    let mut rng = Rng::new(seed ^ (idx as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
    let score = match mode {
        Mode::Score => true,
        Mode::Generate | Mode::LongMix => false,
        Mode::Mixed => idx % 3 != 2, // 2:1 score:generate
    };
    if score {
        let len = rng.range(4, 24);
        let tokens: Vec<u32> = (0..len).map(|_| rng.range(3, 120) as u32).collect();
        let start = rng.range(1, len);
        let end = rng.range(start + 1, len + 1);
        Request::Score { tokens, span: (start, end) }
    } else if mode == Mode::LongMix {
        // Long class: a prompt far beyond the tiny engine's max_seq (64),
        // so the backend crops to the sliding window and still prefills a
        // near-full context; short class: a quick decode that should not
        // queue behind it when resumable prefill is on.
        let long = longmix_is_long(idx);
        let len = if long { rng.range(96, 161) } else { rng.range(3, 10) };
        let tokens: Vec<u32> = (0..len).map(|_| rng.range(3, 120) as u32).collect();
        let budget = if long { rng.range(1, 4) } else { rng.range(1, max_new.max(1) + 1) };
        Request::Generate { tokens, max_new: budget }
    } else {
        let len = rng.range(3, 16);
        let tokens: Vec<u32> = (0..len).map(|_| rng.range(3, 120) as u32).collect();
        Request::Generate { tokens, max_new: rng.range(1, max_new.max(1) + 1) }
    }
}

fn start_core(cfg: &LoadgenConfig) -> Result<(ServerCore, &'static str)> {
    let server_cfg = ServerConfig {
        replicas: cfg.replicas,
        queue_cap: cfg.queue_cap,
        max_wait: cfg.max_wait,
        ..Default::default()
    };
    // Chaos handles are created OUTSIDE the factories so that a rebuilt
    // replica continues its fault plan (tick counter and consumed faults
    // survive the restart) instead of replaying it from the start. With
    // `--chaos` unset every handle is `None` and `ChaosBackend` is a pure
    // passthrough, keeping no-fault runs bitwise identical to before.
    let horizon = (cfg.max_requests as u64).max(8);
    let chaos: Vec<Option<ChaosHandle>> = (0..cfg.replicas.max(1))
        .map(|r| cfg.chaos.as_ref().map(|c| c.handle_for(r, horizon)))
        .collect();
    match &cfg.backend {
        BackendChoice::Synthetic { batch, forward_cost } => {
            let (batch, forward_cost) = (*batch, *forward_cost);
            let core = ServerCore::start(server_cfg, move |r| {
                Ok(ChaosBackend::new(SyntheticBackend::new(batch, forward_cost), chaos[r].clone()))
            })?;
            Ok((core, "synthetic"))
        }
        BackendChoice::Artifacts { dir, pattern, method } => {
            let pattern = Pattern::parse(pattern)?;
            let mcfg = MethodConfig::by_name(method, pattern)?;
            let vocab = Vocab::synthlang();
            let stop = vec![vocab.id(".")?, EOS];
            let dir = dir.clone();
            let core = ServerCore::start(server_cfg, move |r| {
                CoordinatorBackend::open(&dir, mcfg.clone(), stop.clone())
                    .map(|b| ChaosBackend::new(b, chaos[r].clone()))
            })?;
            Ok((core, "artifacts"))
        }
        BackendChoice::Native { dir, pattern, method, seed, batch, threads, prefill_block } => {
            let pattern = Pattern::parse(pattern)?;
            let vocab = Vocab::synthlang();
            let stop = vec![vocab.id(".")?, EOS];
            let (dir, method) = (dir.clone(), method.clone());
            let (seed, batch, threads) = (*seed, *batch, *threads);
            let prefill_block = *prefill_block;
            let core = ServerCore::start(server_cfg, move |r| {
                NativeBackend::open(&dir, pattern, &method, stop.clone(), batch, seed)
                    .map(|b| b.with_threads(threads).with_prefill_block(prefill_block))
                    .map(|b| ChaosBackend::new(b, chaos[r].clone()))
            })?;
            Ok((core, "native"))
        }
    }
}

/// Run the generator to completion and return the report. The server-side
/// histogram provides the latency distribution (submit → terminal reply).
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    anyhow::ensure!(cfg.max_requests > 0, "--max-requests must be > 0 for a bounded run");
    // Metrics-level tracing is on for every loadgen run so the report's
    // `phases` block is always populated; reset isolates this run's
    // aggregates (a sweep snapshots per point). `ensure` never lowers
    // the level, so a `--trace` Full export survives.
    trace::ensure(TraceLevel::Metrics);
    trace::reset();
    let (core, backend_name) = start_core(cfg)?;
    // Client-side per-class split, longmix only (keeps every other mode's
    // JSON — and the sweep schema old consumers parse — unchanged).
    let classes = (cfg.mode == Mode::LongMix).then(|| Mutex::new(ClassLatency::default()));
    let t0 = Instant::now();
    if cfg.rate_rps > 0.0 {
        run_open_loop(&core, cfg, classes.as_ref());
    } else {
        run_closed_loop(&core, cfg, classes.as_ref());
    }
    let wall_s = t0.elapsed().as_secs_f64();
    // Shutdown joins the replica threads, whose TLS sinks flush on exit,
    // so the snapshot below sees every worker's spans.
    let stats = core.shutdown();
    Ok(LoadgenReport {
        stats,
        requests: cfg.max_requests,
        wall_s,
        mode: cfg.mode,
        replicas: cfg.replicas,
        queue_cap: cfg.queue_cap,
        backend_name,
        classes: classes.map(|m| m.into_inner().unwrap()),
        phases: trace::snapshot(),
    })
}

fn run_closed_loop(core: &ServerCore, cfg: &LoadgenConfig, classes: Option<&Mutex<ClassLatency>>) {
    let next = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|scope| {
        for client in 0..cfg.concurrency.max(1) {
            let handle = core.handle();
            let next = Arc::clone(&next);
            scope.spawn(move || loop {
                let idx = next.fetch_add(1, Ordering::Relaxed);
                if idx >= cfg.max_requests {
                    break;
                }
                let req = make_request(cfg.seed, idx, cfg.mode, cfg.max_new);
                let deadline = cfg.request_timeout.map(|d| Instant::now() + d);
                let t_req = Instant::now();
                // Session affinity: one client = one session key.
                match handle.submit_with(Some(client as u64), req, deadline) {
                    Ok(ticket) => {
                        let _ = ticket.recv(); // one in flight per client
                        if let Some(c) = classes {
                            c.lock().unwrap().record(longmix_is_long(idx), t_req.elapsed());
                        }
                    }
                    Err(SubmitError::Overloaded { .. }) => {} // shed; counted server-side
                    Err(SubmitError::Closed) => break,
                }
            });
        }
    });
}

fn run_open_loop(core: &ServerCore, cfg: &LoadgenConfig, classes: Option<&Mutex<ClassLatency>>) {
    let interval = Duration::from_secs_f64(1.0 / cfg.rate_rps);
    let start = Instant::now();
    std::thread::scope(|scope| {
        let mut tickets: Vec<Ticket> = Vec::with_capacity(cfg.max_requests);
        for idx in 0..cfg.max_requests {
            let due = start + interval.mul_f64(idx as f64);
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
            let req = make_request(cfg.seed, idx, cfg.mode, cfg.max_new);
            let deadline = cfg.request_timeout.map(|d| Instant::now() + d);
            let t_req = Instant::now();
            match core.submit_with(None, req, deadline) {
                Ok(t) => {
                    if let Some(c) = classes {
                        // Per-ticket collector thread: recv the moment the
                        // reply lands, so the class histogram records true
                        // submit -> terminal latency (draining at the end
                        // would overcount for early finishers). Bounded by
                        // max_requests; longmix runs only.
                        let long = longmix_is_long(idx);
                        scope.spawn(move || {
                            let _ = t.recv();
                            c.lock().unwrap().record(long, t_req.elapsed());
                        });
                    } else {
                        tickets.push(t);
                    }
                }
                Err(SubmitError::Overloaded { .. }) => {} // shed; counted server-side
                Err(SubmitError::Closed) => break,
            }
        }
        for t in &tickets {
            let _ = t.recv();
        }
    });
}

/// Write `report.to_json()` to `path` (pretty, trailing newline).
pub fn write_bench_json(report: &LoadgenReport, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, report.to_json().pretty())
        .with_context(|| format!("writing {}", path.display()))
}

// ------------------------------------------------------------------ sweep

/// One point of a latency-vs-offered-rate sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub rate_rps: f64,
    pub report: LoadgenReport,
}

/// Open-loop sweep: one bounded run per offered rate, each against a
/// fresh core (clean histograms, no cross-rate pollution). Rates must be
/// positive; `cfg.max_requests` requests are offered at every point.
pub fn run_sweep(cfg: &LoadgenConfig, rates: &[f64]) -> Result<Vec<SweepPoint>> {
    anyhow::ensure!(!rates.is_empty(), "--sweep needs at least one rate");
    anyhow::ensure!(
        rates.windows(2).all(|w| w[0] < w[1]),
        "--sweep rates must be strictly increasing (the sweep curve is rate-ordered)"
    );
    let mut points = Vec::with_capacity(rates.len());
    for &rate_rps in rates {
        anyhow::ensure!(rate_rps > 0.0, "sweep rates must be positive (got {rate_rps})");
        let mut point_cfg = cfg.clone();
        point_cfg.rate_rps = rate_rps;
        let report = run(&point_cfg)?;
        println!("sweep @ {rate_rps:>8.1} req/s: {}", report.summary());
        points.push(SweepPoint { rate_rps, report });
    }
    Ok(points)
}

/// The `BENCH_serving_sweep.json` document (see
/// `tools/check_bench_json.py`): shared run shape at the top level, one
/// entry per offered rate under `points`.
pub fn sweep_json(cfg: &LoadgenConfig, points: &[SweepPoint]) -> Json {
    let mut j = Json::obj();
    j.insert("suite", "serving_sweep".into());
    j.insert("mode", cfg.mode.as_str().into());
    j.insert(
        "backend",
        points.first().map(|p| p.report.backend_name).unwrap_or("synthetic").into(),
    );
    j.insert("replicas", (cfg.replicas as f64).into());
    j.insert("queue_cap", (cfg.queue_cap as f64).into());
    j.insert("requests_per_point", (cfg.max_requests as f64).into());
    let mut arr = Vec::with_capacity(points.len());
    for p in points {
        let mut e = Json::obj();
        e.insert("rate_rps", p.rate_rps.into());
        e.insert("served", (p.report.stats.served as f64).into());
        e.insert("rejected", (p.report.stats.rejected as f64).into());
        e.insert("throughput_rps", p.report.throughput_rps().into());
        e.insert("latency_ms", latency_ms_json(&p.report.stats.latency));
        e.insert("queue_wait_ms", latency_ms_json(&p.report.stats.queue_wait));
        e.insert("rejection_rate", p.report.stats.rejection_rate().into());
        e.insert("batch_occupancy", p.report.stats.batch_occupancy().into());
        e.insert("timed_out", (p.report.stats.timed_out as f64).into());
        e.insert("failed", (p.report.stats.failed as f64).into());
        e.insert("timeout_rate", p.report.stats.timeout_rate().into());
        e.insert("failure_rate", p.report.stats.failure_rate().into());
        e.insert("restarts", (p.report.stats.restarts as f64).into());
        e.insert("retried", (p.report.stats.retried as f64).into());
        if let Some(c) = &p.report.classes {
            e.insert("classes", c.to_json());
        }
        arr.push(e);
    }
    j.insert("points", Json::Arr(arr));
    j
}

/// Write the sweep document to `path`.
pub fn write_sweep_json(
    cfg: &LoadgenConfig,
    points: &[SweepPoint],
    path: &std::path::Path,
) -> Result<()> {
    std::fs::write(path, sweep_json(cfg, points).pretty())
        .with_context(|| format!("writing {}", path.display()))
}

pub fn cmd_loadgen(rest: Vec<String>) -> Result<()> {
    #[rustfmt::skip]
    let specs = vec![
        OptSpec { name: "replicas", takes_value: true, default: Some("2"), help: "engine replicas" },
        OptSpec { name: "queue-cap", takes_value: true, default: Some("128"), help: "per-replica admission cap" },
        OptSpec { name: "max-requests", takes_value: true, default: Some("256"), help: "total requests (bounded run)" },
        OptSpec { name: "concurrency", takes_value: true, default: Some("16"), help: "closed-loop clients" },
        OptSpec { name: "rate", takes_value: true, default: Some("0"), help: "open-loop req/s (0 = closed loop)" },
        OptSpec { name: "mode", takes_value: true, default: Some("mixed"), help: "score | generate | mixed | longmix (long-prompt/short-decode mix, per-class latency)" },
        OptSpec { name: "max-new", takes_value: true, default: Some("8"), help: "max generated tokens" },
        OptSpec { name: "max-wait-ms", takes_value: true, default: Some("5"), help: "batch deadline (ms)" },
        OptSpec { name: "seed", takes_value: true, default: Some("7"), help: "request-synthesis seed" },
        OptSpec { name: "backend", takes_value: true, default: Some("synthetic"), help: "synthetic | artifacts | native" },
        OptSpec { name: "batch", takes_value: true, default: Some("16"), help: "synthetic/native batch capacity" },
        OptSpec { name: "threads", takes_value: true, default: Some("1"), help: "native worker-pool width per replica (0 = auto; never changes bits)" },
        OptSpec { name: "prefill-block", takes_value: true, default: Some("0"), help: "native resumable-prefill block size per tick (0 = feed-to-completion; never changes bits)" },
        OptSpec { name: "forward-us", takes_value: true, default: Some("150"), help: "synthetic per-forward cost (us)" },
        OptSpec { name: "artifacts", takes_value: true, default: Some("artifacts"), help: "artifacts dir (artifacts/native backends)" },
        OptSpec { name: "pattern", takes_value: true, default: Some("8:16"), help: "sparsity pattern (artifacts/native backends)" },
        OptSpec { name: "method", takes_value: true, default: Some("S-PTS"), help: "method (artifacts/native backends)" },
        OptSpec { name: "request-timeout-ms", takes_value: true, default: Some("0"), help: "per-request deadline (ms, 0 = none)" },
        OptSpec { name: "chaos", takes_value: true, default: Some(""), help: "fault injection: integer seed or 'panic@N;err@N;stall@N:D' spec ('' = off)" },
        OptSpec { name: "sweep", takes_value: true, default: Some(""), help: "open-loop rate grid 'r1,r2,...' (req/s)" },
        OptSpec { name: "sweep-out", takes_value: true, default: Some("BENCH_serving_sweep.json"), help: "sweep report path" },
        OptSpec { name: "out", takes_value: true, default: Some("BENCH_serving.json"), help: "report path ('' = skip)" },
        OptSpec { name: "trace", takes_value: true, default: Some(""), help: "write Chrome trace-event JSON here ('' = off)" },
        OptSpec { name: "help", takes_value: false, default: None, help: "show help" },
    ];
    let a = Args::parse(rest, &specs)?;
    if a.flag("help") {
        print!("{}", usage("loadgen", "Drive a multi-replica ServerCore and measure it.", &specs));
        return Ok(());
    }
    let backend = match a.get("backend").as_str() {
        "synthetic" => BackendChoice::Synthetic {
            batch: a.get_usize("batch")?,
            forward_cost: Duration::from_micros(a.get_u64("forward-us")?),
        },
        "artifacts" => BackendChoice::Artifacts {
            dir: PathBuf::from(a.get("artifacts")),
            pattern: a.get("pattern"),
            method: a.get("method"),
        },
        "native" => BackendChoice::Native {
            dir: PathBuf::from(a.get("artifacts")),
            pattern: a.get("pattern"),
            // Without artifacts the native engine has no methodparams,
            // so the loadgen default S-PTS cannot load its per-site eta
            // vectors; default to ACT here (an explicit --method S-PTS
            // works against a real artifacts dir).
            method: if a.given("method") { a.get("method") } else { "ACT".to_string() },
            seed: a.get_u64("seed")?,
            batch: a.get_usize("batch")?,
            threads: super::decode::resolve_threads(a.get_usize("threads")?),
            prefill_block: a.get_usize("prefill-block")?,
        },
        other => bail!("unknown --backend '{other}' (synthetic, artifacts, native)"),
    };
    let cfg = LoadgenConfig {
        replicas: a.get_usize("replicas")?,
        queue_cap: a.get_usize("queue-cap")?,
        max_requests: a.get_usize("max-requests")?,
        concurrency: a.get_usize("concurrency")?,
        rate_rps: a.get_f64("rate")?,
        mode: Mode::parse(&a.get("mode"))?,
        max_new: a.get_usize("max-new")?,
        max_wait: Duration::from_millis(a.get_u64("max-wait-ms")?),
        seed: a.get_u64("seed")?,
        request_timeout: {
            let ms = a.get_u64("request-timeout-ms")?;
            (ms > 0).then(|| Duration::from_millis(ms))
        },
        chaos: {
            let s = a.get("chaos");
            if s.is_empty() { None } else { Some(ChaosArg::parse(&s)?) }
        },
        backend,
    };
    if let Some(c) = &cfg.chaos {
        println!("loadgen: chaos enabled ({})", c.describe());
    }
    let trace_path = a.get("trace");
    if !trace_path.is_empty() {
        trace::set_level(TraceLevel::Full);
    }
    // Sweep mode: one open-loop run per rate -> BENCH_serving_sweep.json.
    let sweep_rates = a.get("sweep");
    if !sweep_rates.is_empty() {
        let rates: Vec<f64> = sweep_rates
            .split(',')
            .map(|r| {
                r.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("bad sweep rate '{r}' (want req/s numbers)"))
            })
            .collect::<Result<_>>()?;
        println!(
            "loadgen sweep: {} rates x {} requests, {} replicas (cap {}), {} backend",
            rates.len(),
            cfg.max_requests,
            cfg.replicas,
            cfg.queue_cap,
            a.get("backend"),
        );
        let points = run_sweep(&cfg, &rates)?;
        let path = PathBuf::from(a.get("sweep-out"));
        write_sweep_json(&cfg, &points, &path)?;
        println!("wrote {}", path.display());
        // Each point resets the recorder, so a sweep's trace export
        // covers only the final rate — still useful for eyeballing one
        // steady-state point in Perfetto.
        return finish_trace(&trace_path);
    }
    println!(
        "loadgen: {} requests, {} replicas (cap {}), {} loop, {} backend",
        cfg.max_requests,
        cfg.replicas,
        cfg.queue_cap,
        if cfg.rate_rps > 0.0 { "open" } else { "closed" },
        a.get("backend"),
    );
    let report = run(&cfg)?;
    println!("loadgen: {}", report.summary());
    println!("loadgen: {}", report.phases.summary());
    let out = a.get("out");
    if !out.is_empty() {
        let path = PathBuf::from(out);
        write_bench_json(&report, &path)?;
        println!("wrote {}", path.display());
    }
    finish_trace(&trace_path)
}

/// Export the accumulated spans as Chrome trace-event JSON when
/// `--trace` was given; a no-op otherwise.
fn finish_trace(path: &str) -> Result<()> {
    if path.is_empty() {
        return Ok(());
    }
    let n = trace::write_chrome_trace(std::path::Path::new(path))?;
    println!("trace: wrote {n} spans to {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_synthesis_is_deterministic_and_valid() {
        for idx in 0..200 {
            let a = make_request(42, idx, Mode::Mixed, 8);
            let b = make_request(42, idx, Mode::Mixed, 8);
            assert_eq!(a, b);
            match a {
                Request::Score { tokens, span: (s, e) } => {
                    assert!(!tokens.is_empty());
                    assert!(s >= 1 && s < e && e <= tokens.len());
                }
                Request::Generate { tokens, max_new } => {
                    assert!(!tokens.is_empty());
                    assert!((1..=8).contains(&max_new));
                }
            }
        }
        // Mode filters hold.
        assert!((0..60).all(|i| matches!(
            make_request(1, i, Mode::Score, 4),
            Request::Score { .. }
        )));
        assert!((0..60).all(|i| matches!(
            make_request(1, i, Mode::Generate, 4),
            Request::Generate { .. }
        )));
    }

    #[test]
    fn closed_loop_synthetic_run_reports() {
        let cfg = LoadgenConfig {
            replicas: 2,
            queue_cap: 32,
            max_requests: 48,
            concurrency: 6,
            max_new: 4,
            backend: BackendChoice::Synthetic { batch: 4, forward_cost: Duration::ZERO },
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.stats.served + report.stats.rejected, 48);
        assert_eq!(report.stats.errors, 0);
        assert!(report.throughput_rps() > 0.0);
        assert_eq!(report.stats.latency.count(), report.stats.served);
        let j = report.to_json();
        assert_eq!(j.get("suite").and_then(|s| s.as_str()), Some("serving"));
        let lat = j.get("latency_ms").unwrap();
        let p50 = lat.get("p50").and_then(|x| x.as_f64()).unwrap();
        let p95 = lat.get("p95").and_then(|x| x.as_f64()).unwrap();
        let p99 = lat.get("p99").and_then(|x| x.as_f64()).unwrap();
        assert!(p50 <= p95 && p95 <= p99);
        let occ = j.get("batch_occupancy").and_then(|x| x.as_f64()).unwrap();
        assert!((0.0..=1.0).contains(&occ));
    }

    #[test]
    fn native_backend_run_completes_without_errors() {
        let cfg = LoadgenConfig {
            replicas: 1,
            queue_cap: 64,
            max_requests: 24,
            concurrency: 4,
            max_new: 4,
            mode: Mode::Mixed,
            backend: BackendChoice::Native {
                dir: PathBuf::from("/definitely/not/here"),
                pattern: "8:16".into(),
                method: "ACT".into(),
                seed: 3,
                batch: 4,
                threads: 2,
                prefill_block: 0,
            },
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.backend_name, "native");
        assert_eq!(report.stats.served + report.stats.rejected, 24);
        assert_eq!(report.stats.errors, 0);
        assert!(report.classes.is_none(), "classes is a longmix-only field");
    }

    #[test]
    fn longmix_synthesis_mixes_long_and_short_generates() {
        for idx in 0..32 {
            match make_request(9, idx, Mode::LongMix, 8) {
                Request::Generate { tokens, max_new } => {
                    if longmix_is_long(idx) {
                        assert!((96..=160).contains(&tokens.len()), "len {}", tokens.len());
                        assert!((1..=3).contains(&max_new));
                    } else {
                        assert!((3..=9).contains(&tokens.len()), "len {}", tokens.len());
                    }
                }
                other => panic!("longmix emitted a non-generate request: {other:?}"),
            }
        }
    }

    #[test]
    fn longmix_native_run_reports_per_class_latency() {
        let cfg = LoadgenConfig {
            replicas: 1,
            queue_cap: 64,
            max_requests: 16,
            concurrency: 4,
            max_new: 4,
            mode: Mode::LongMix,
            backend: BackendChoice::Native {
                dir: PathBuf::from("/definitely/not/here"),
                pattern: "8:16".into(),
                method: "ACT".into(),
                seed: 3,
                batch: 4,
                threads: 1,
                prefill_block: 8,
            },
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.stats.served + report.stats.rejected, 16);
        assert_eq!(report.stats.errors, 0);
        let classes = report.classes.as_ref().expect("longmix records classes");
        // 16 requests, idx % 4 == 0 -> 4 long, 12 short (none shed: cap 64).
        assert_eq!(classes.long_prompt.count(), 4);
        assert_eq!(classes.short_decode.count(), 12);
        let j = report.to_json();
        let c = j.get("classes").expect("classes block in longmix JSON");
        for class in ["long_prompt", "short_decode"] {
            let e = c.get(class).unwrap();
            assert!(e.get("count").and_then(|x| x.as_f64()).unwrap() > 0.0);
            let lat = e.get("latency_ms").unwrap();
            let p50 = lat.get("p50").and_then(|x| x.as_f64()).unwrap();
            let p99 = lat.get("p99").and_then(|x| x.as_f64()).unwrap();
            assert!(p50 <= p99, "{class}: p50 {p50} > p99 {p99}");
        }
    }

    #[test]
    fn longmix_open_loop_sweep_point_carries_classes() {
        let cfg = LoadgenConfig {
            replicas: 1,
            queue_cap: 64,
            max_requests: 12,
            mode: Mode::LongMix,
            backend: BackendChoice::Native {
                dir: PathBuf::from("/definitely/not/here"),
                pattern: "8:16".into(),
                method: "ACT".into(),
                seed: 5,
                batch: 4,
                threads: 1,
                prefill_block: 8,
            },
            ..Default::default()
        };
        let points = run_sweep(&cfg, &[2000.0]).unwrap();
        let j = sweep_json(&cfg, &points);
        assert_eq!(j.get("mode").and_then(|m| m.as_str()), Some("longmix"));
        let arr = j.get("points").and_then(|p| p.as_arr()).unwrap();
        let c = arr[0].get("classes").expect("longmix sweep points carry classes");
        let total: f64 = ["long_prompt", "short_decode"]
            .iter()
            .map(|k| c.get(k).and_then(|e| e.get("count")).and_then(|x| x.as_f64()).unwrap())
            .sum();
        assert_eq!(total as u64, 12, "every submitted request lands in one class");
    }

    #[test]
    fn sweep_produces_one_point_per_rate() {
        let cfg = LoadgenConfig {
            replicas: 1,
            queue_cap: 16,
            max_requests: 16,
            mode: Mode::Score,
            backend: BackendChoice::Synthetic { batch: 4, forward_cost: Duration::ZERO },
            ..Default::default()
        };
        let points = run_sweep(&cfg, &[2000.0, 4000.0]).unwrap();
        assert_eq!(points.len(), 2);
        for p in &points {
            assert_eq!(p.report.stats.served + p.report.stats.rejected, 16);
            assert!((p.rate_rps - 2000.0).abs() < 1e-9 || (p.rate_rps - 4000.0).abs() < 1e-9);
        }
        let j = sweep_json(&cfg, &points);
        assert_eq!(j.get("suite").and_then(|s| s.as_str()), Some("serving_sweep"));
        let arr = j.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(arr.len(), 2);
        for e in arr {
            assert!(e.get("rate_rps").and_then(|x| x.as_f64()).unwrap() > 0.0);
            assert!(e.get("latency_ms").and_then(|l| l.get("p95")).is_some());
        }
        // Degenerate sweeps are rejected.
        assert!(run_sweep(&cfg, &[]).is_err());
        assert!(run_sweep(&cfg, &[0.0]).is_err());
    }

    #[test]
    fn chaos_run_restarts_replicas_and_keeps_accounting_balanced() {
        let cfg = LoadgenConfig {
            replicas: 2,
            queue_cap: 64,
            max_requests: 80,
            concurrency: 8,
            max_new: 4,
            request_timeout: Some(Duration::from_secs(5)),
            chaos: Some(ChaosArg::parse("panic@2;err@9;stall@5:1").unwrap()),
            backend: BackendChoice::Synthetic { batch: 4, forward_cost: Duration::ZERO },
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        // Exactly-once accounting holds under injected faults: every
        // request is served (possibly with a terminal error) or shed.
        assert_eq!(report.stats.served + report.stats.rejected, 80);
        assert_eq!(report.stats.latency.count(), report.stats.served);
        // Spec plans run on every replica, so each panics once and both
        // replicas are rebuilt by the supervisor.
        assert!(report.stats.restarts >= 2, "restarts = {}", report.stats.restarts);
        let j = report.to_json();
        for key in ["restarts", "retried", "timed_out", "failed"] {
            assert!(j.get(key).and_then(|x| x.as_f64()).is_some(), "missing {key}");
        }
        for key in ["timeout_rate", "failure_rate"] {
            let v = j.get(key).and_then(|x| x.as_f64()).unwrap();
            assert!((0.0..=1.0).contains(&v), "{key} = {v}");
        }
    }

    #[test]
    fn open_loop_reports_rate_and_resolves_all_tickets() {
        let cfg = LoadgenConfig {
            replicas: 1,
            queue_cap: 8,
            max_requests: 32,
            rate_rps: 4000.0,
            mode: Mode::Score,
            backend: BackendChoice::Synthetic { batch: 4, forward_cost: Duration::ZERO },
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        // Every request reached a terminal outcome: served or shed.
        assert_eq!(report.stats.served + report.stats.rejected, 32);
        assert!(report.stats.served > 0);
    }
}
