//! `nmsparse loadgen` — closed- and open-loop load generator for the
//! multi-replica [`ServerCore`], emitting `BENCH_serving.json`.
//!
//! Closed loop (`--rate 0`, default): `--concurrency` client threads each
//! keep exactly one request in flight — measures latency under a fixed
//! offered concurrency. Each client uses its index as the session key, so
//! the run also exercises session-affine routing.
//!
//! Open loop (`--rate R`): requests are submitted at a fixed R req/s
//! regardless of completion — measures behavior at a target arrival rate,
//! including admission-control shedding (`rejection_rate`).
//!
//! Sweep (`--sweep r1,r2,...`): one bounded open-loop run per offered
//! rate against a fresh core, emitting the latency-vs-offered-rate curve
//! as `BENCH_serving_sweep.json` (rendered by `nmsparse table serving`).
//!
//! Default backend is [`SyntheticBackend`] (deterministic, artifact-free,
//! optional simulated per-forward cost) so the CI smoke runs on a machine
//! with only rustc/cargo; `--backend artifacts` drives the real PJRT
//! engine replicas and `--backend native` the KV-cached
//! [`NativeBackend`] (artifacts checkpoint when present, seeded synthetic
//! model otherwise). The report (throughput, p50/p95/p99 latency from
//! the server-side [`Histogram`], batch occupancy, rejection and
//! timeout/failure rates) is what `tables` and
//! `tools/check_bench_json.py` consume.
//!
//! Robustness knobs: `--request-timeout-ms` attaches a deadline to every
//! request (expired ones shed with a terminal `timeout` error), and
//! `--chaos <seed-or-spec>` wraps every replica backend in a
//! [`ChaosBackend`] executing a deterministic [`FaultPlan`] — the CI
//! chaos smoke drives supervised restarts this way and asserts the
//! availability counters (`restarts`/`retried`/`timed_out`/`failed`)
//! stay balanced.

use crate::coordinator::chaos::{ChaosArg, ChaosBackend, ChaosHandle};
use crate::coordinator::methods::MethodConfig;
use crate::coordinator::server::{
    CoordinatorBackend, NativeBackend, Request, Response, ServerConfig, ServerCore, ServerHandle,
    ServerStats, SubmitError, SubmitOpts, SyntheticBackend, TenantStats, Ticket, ERR_TIMEOUT,
};
use crate::sparsity::Pattern;
use crate::synthlang::vocab::{Vocab, EOS};
use crate::util::cli::{usage, Args, OptSpec};
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::util::trace::{self, TraceLevel};
use crate::wire::{
    stream_channel, Codec, CodecKind, StreamOutcome, StreamPoll, StreamReceiver, WireReply,
    WireRequest, LANE_CAP,
};
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Traffic mix. `LongMix` is the continuous-batching scenario: every 4th
/// request is a long-prompt generate (prompt far beyond the tiny engine's
/// `max_seq`, so sliding-window crop and resumable blocked prefill both
/// engage) and the rest are short decodes — the per-class client-side
/// latency split (`classes` in the JSON) shows whether long prefills
/// stall short decodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Score,
    Generate,
    Mixed,
    LongMix,
}

impl Mode {
    pub fn parse(s: &str) -> Result<Mode> {
        match s {
            "score" => Ok(Mode::Score),
            "generate" => Ok(Mode::Generate),
            "mixed" => Ok(Mode::Mixed),
            "longmix" => Ok(Mode::LongMix),
            other => bail!("unknown --mode '{other}' (score, generate, mixed, longmix)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Score => "score",
            Mode::Generate => "generate",
            Mode::Mixed => "mixed",
            Mode::LongMix => "longmix",
        }
    }
}

/// Is request `idx` of a longmix run the long-prompt class?
pub fn longmix_is_long(idx: usize) -> bool {
    idx % 4 == 0
}

/// Tenant traffic plan: how offered load splits across tenant classes.
/// `mix` holds *traffic* weights — request `idx` is assigned a tenant by
/// a seeded weighted draw — not the server's dispatch weights. The
/// fairness smoke deliberately runs a skewed mix (e.g. `2:10,1`) against
/// equal dispatch weights and gates on per-tenant queue-wait p95.
#[derive(Clone, Debug)]
pub struct TenantPlan {
    pub count: usize,
    pub mix: Vec<u32>,
}

impl Default for TenantPlan {
    fn default() -> Self {
        TenantPlan { count: 1, mix: vec![1] }
    }
}

/// Parse `--tenants k[:w1,...,wk]`; omitted weights mean an even mix.
pub fn parse_tenant_plan(s: &str) -> Result<TenantPlan> {
    let (count_s, mix_s) = match s.split_once(':') {
        Some((c, m)) => (c, Some(m)),
        None => (s, None),
    };
    let count: usize = count_s
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad --tenants count '{count_s}'"))?;
    anyhow::ensure!(count >= 1, "--tenants needs at least one tenant class");
    let mix = match mix_s {
        None => vec![1; count],
        Some(m) => super::serve::parse_weights(m)?,
    };
    anyhow::ensure!(
        mix.len() == count && mix.iter().all(|&w| w > 0),
        "--tenants wants exactly {count} positive mix weights"
    );
    Ok(TenantPlan { count, mix })
}

/// Two-state MMPP (Markov-modulated Poisson process) plan for bursty
/// open-loop arrivals: exponential inter-arrivals at `rate * rate_mult`
/// during ON phases and at the base rate during OFF phases, with
/// exponentially distributed phase durations (means `on` / `off`).
#[derive(Clone, Copy, Debug)]
pub struct BurstPlan {
    pub on: Duration,
    pub off: Duration,
    pub rate_mult: f64,
}

/// Parse `--burst on_ms,off_ms,rate_mult`.
pub fn parse_burst(s: &str) -> Result<BurstPlan> {
    let parts: Vec<&str> = s.split(',').map(str::trim).collect();
    let bad = || anyhow::anyhow!("bad --burst '{s}' (want 'on_ms,off_ms,rate_mult')");
    anyhow::ensure!(parts.len() == 3, bad());
    let on_ms: u64 = parts[0].parse().map_err(|_| bad())?;
    let off_ms: u64 = parts[1].parse().map_err(|_| bad())?;
    let rate_mult: f64 = parts[2].parse().map_err(|_| bad())?;
    anyhow::ensure!(on_ms > 0 && off_ms > 0, "--burst phase durations must be > 0 ms");
    anyhow::ensure!(rate_mult > 0.0, "--burst rate_mult must be > 0");
    Ok(BurstPlan {
        on: Duration::from_millis(on_ms),
        off: Duration::from_millis(off_ms),
        rate_mult,
    })
}

/// Which engine the replicas run.
#[derive(Clone, Debug)]
pub enum BackendChoice {
    /// Deterministic artifact-free backend; `forward_cost` is charged once
    /// per dispatched batch (so batching amortizes it, like PJRT).
    Synthetic { batch: usize, forward_cost: Duration },
    /// Real engines: each replica opens its own pool from this directory.
    Artifacts { dir: PathBuf, pattern: String, method: String },
    /// KV-cached native decode engines — artifacts checkpoint when `dir`
    /// holds one, seeded synthetic model otherwise. No PJRT either way.
    /// `threads` is each replica engine's worker-pool width (wall time
    /// only; decode bits are thread-count-invariant).
    Native {
        dir: PathBuf,
        pattern: String,
        method: String,
        seed: u64,
        batch: usize,
        threads: usize,
        /// Resumable-prefill block size per scheduler tick (0 = legacy
        /// feed-to-completion; never changes decoded bits).
        prefill_block: usize,
    },
}

/// One loadgen run, fully specified.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    pub replicas: usize,
    pub queue_cap: usize,
    pub max_requests: usize,
    /// Closed-loop client threads (ignored in open-loop mode).
    pub concurrency: usize,
    /// Open-loop arrival rate in req/s; 0 selects the closed loop.
    pub rate_rps: f64,
    pub mode: Mode,
    pub max_new: usize,
    pub max_wait: Duration,
    pub seed: u64,
    /// Per-request deadline; expired requests shed with a `timeout` reply.
    pub request_timeout: Option<Duration>,
    /// Deterministic fault injection (seed or explicit `FaultPlan` spec).
    pub chaos: Option<ChaosArg>,
    pub backend: BackendChoice,
    /// Tenant classes + traffic mix (`--tenants k[:weights]`).
    pub tenants: TenantPlan,
    /// Server-side DRR dispatch weights (empty = equal).
    pub tenant_weights: Vec<u32>,
    /// Per-tenant in-flight quota per replica (0 = share the queue cap).
    pub tenant_quota: usize,
    /// MMPP bursty arrivals for the open loop (`None` = fixed interval,
    /// bitwise-identical schedule to earlier revisions).
    pub burst: Option<BurstPlan>,
    /// Bounded-Pareto shape for prompt lengths (0 = uniform, legacy).
    pub pareto_alpha: f64,
    /// Roundtrip every request and reply through this wire codec
    /// in-process (`None` = plain structs, no codec on the path).
    pub codec: Option<CodecKind>,
    /// Attach a streamed-token lane to every generate and count the
    /// per-token chunk frames client-side.
    pub stream: bool,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            replicas: 2,
            queue_cap: 128,
            max_requests: 256,
            concurrency: 16,
            rate_rps: 0.0,
            mode: Mode::Mixed,
            max_new: 8,
            max_wait: Duration::from_millis(5),
            seed: 7,
            request_timeout: None,
            chaos: None,
            backend: BackendChoice::Synthetic {
                batch: 16,
                forward_cost: Duration::from_micros(150),
            },
            tenants: TenantPlan::default(),
            tenant_weights: Vec::new(),
            tenant_quota: 0,
            burst: None,
            pareto_alpha: 0.0,
            codec: None,
            stream: false,
        }
    }
}

/// Client-side per-class latency, recorded only in longmix runs:
/// `long_prompt` holds the `longmix_is_long` long-prefill generates,
/// `short_decode` everything else. Measured submit → terminal reply on
/// the client, so it includes queueing — the tail of `short_decode` is
/// what resumable prefill (`--prefill-block`) is meant to protect.
#[derive(Clone, Debug, Default)]
pub struct ClassLatency {
    pub long_prompt: crate::util::stats::Histogram,
    pub short_decode: crate::util::stats::Histogram,
}

impl ClassLatency {
    fn record(&mut self, long: bool, d: Duration) {
        if long {
            self.long_prompt.record_duration(d);
        } else {
            self.short_decode.record_duration(d);
        }
    }

    /// The `classes` JSON block: one `{count, latency_ms}` entry per class.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        for (name, hist) in
            [("long_prompt", &self.long_prompt), ("short_decode", &self.short_decode)]
        {
            let mut c = Json::obj();
            c.insert("count", (hist.count() as f64).into());
            c.insert("latency_ms", latency_ms_json(hist));
            j.insert(name, c);
        }
        j
    }
}

/// Outcome of a run: final server stats plus wall-clock derived rates.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    pub stats: ServerStats,
    pub requests: usize,
    pub wall_s: f64,
    pub mode: Mode,
    pub replicas: usize,
    pub queue_cap: usize,
    pub backend_name: &'static str,
    /// Per-class client-side latency; `Some` only for longmix runs.
    pub classes: Option<ClassLatency>,
    /// Per-phase span breakdown recorded over the run (the `phases`
    /// block of `BENCH_serving.json`). Always populated — `run` turns
    /// metrics-level tracing on for the run's duration.
    pub phases: trace::PhaseSnapshot,
    /// Traffic plan the run offered (tenant count + mix weights).
    pub tenant_plan: TenantPlan,
    /// Server-side DRR dispatch weights, one `>= 1` entry per tenant.
    pub dispatch_weights: Vec<u32>,
    /// Wire codec the run roundtripped through ("direct" = none).
    pub codec_name: &'static str,
    /// Streamed chunk frames observed client-side over the whole run.
    pub stream_chunks: u64,
    /// XOR of per-request reply digests ([`digest_reply`]) — order
    /// independent, so equal hashes mean equal reply payloads regardless
    /// of completion order. The codec-equivalence smoke pins the json,
    /// binary, and direct paths to the same value.
    pub transcript_hash: u64,
}

impl LoadgenReport {
    pub fn throughput_rps(&self) -> f64 {
        self.stats.served as f64 / self.wall_s.max(1e-9)
    }

    /// The `BENCH_serving.json` document (see `tools/check_bench_json.py`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.insert("suite", "serving".into());
        j.insert("mode", self.mode.as_str().into());
        j.insert("backend", self.backend_name.into());
        j.insert("replicas", (self.replicas as f64).into());
        j.insert("queue_cap", (self.queue_cap as f64).into());
        j.insert("requests", (self.requests as f64).into());
        j.insert("served", (self.stats.served as f64).into());
        j.insert("rejected", (self.stats.rejected as f64).into());
        j.insert("errors", (self.stats.errors as f64).into());
        j.insert("wall_s", self.wall_s.into());
        j.insert("throughput_rps", self.throughput_rps().into());
        j.insert("latency_ms", latency_ms_json(&self.stats.latency));
        j.insert("queue_wait_ms", latency_ms_json(&self.stats.queue_wait));
        j.insert("phases", self.phases.to_json(self.wall_s));
        j.insert("batch_occupancy", self.stats.batch_occupancy().into());
        j.insert("rejection_rate", self.stats.rejection_rate().into());
        j.insert("stolen", (self.stats.stolen as f64).into());
        j.insert("restarts", (self.stats.restarts as f64).into());
        j.insert("retried", (self.stats.retried as f64).into());
        j.insert("timed_out", (self.stats.timed_out as f64).into());
        j.insert("failed", (self.stats.failed as f64).into());
        j.insert("timeout_rate", self.stats.timeout_rate().into());
        j.insert("failure_rate", self.stats.failure_rate().into());
        j.insert("codec", self.codec_name.into());
        j.insert("stream_chunks", (self.stream_chunks as f64).into());
        j.insert("transcript_hash", format!("{:016x}", self.transcript_hash).into());
        j.insert("tenants", tenants_json(&self.stats.tenants, &self.dispatch_weights));
        if let Some(c) = &self.classes {
            j.insert("classes", c.to_json());
        }
        j
    }

    /// Human summary printed by the CLI and the bench. The error column
    /// breaks out deadline sheds from died-in-flight so sweep rows can
    /// distinguish the two without opening the JSON.
    pub fn summary(&self) -> String {
        format!(
            "{} reqs in {:.2}s -> {:.1} req/s | served {} rejected {} errors {} \
             (timeout {} failed {}) | restarts {} retried {} | latency {} | \
             qwait p95 {:.2}ms | occupancy {:.2}",
            self.requests,
            self.wall_s,
            self.throughput_rps(),
            self.stats.served,
            self.stats.rejected,
            self.stats.errors,
            self.stats.timed_out,
            self.stats.failed,
            self.stats.restarts,
            self.stats.retried,
            self.stats.latency.summary(),
            self.stats.queue_wait.percentile(95.0) * 1e3,
            self.stats.batch_occupancy(),
        )
    }
}

/// The `latency_ms` JSON block (mean/p50/p95/p99/max, milliseconds) —
/// shared by `BENCH_serving.json` and the serve `{"op":"stats"}` reply so
/// the two consumers can never desync.
pub fn latency_ms_json(lat: &crate::util::stats::Histogram) -> Json {
    let ms = 1e3;
    let mut l = Json::obj();
    l.insert("mean", (lat.mean_s() * ms).into());
    l.insert("p50", (lat.percentile(50.0) * ms).into());
    l.insert("p95", (lat.percentile(95.0) * ms).into());
    l.insert("p99", (lat.percentile(99.0) * ms).into());
    l.insert("max", (lat.max_s() * ms).into());
    l
}

/// The `tenants` JSON block: dispatch weights plus per-tenant counters
/// and queue-wait/latency percentiles. Shared by `BENCH_serving.json`
/// and the serve `{"op":"stats"}` reply (which passes no weights — they
/// default to 1). The fairness gate in `tools/check_bench_json.py`
/// reads `weights` and each tenant's `queue_wait_ms.p95`.
pub fn tenants_json(ts: &[TenantStats], weights: &[u32]) -> Json {
    let mut j = Json::obj();
    j.insert("count", (ts.len() as f64).into());
    let w: Vec<Json> = (0..ts.len())
        .map(|t| Json::Num(*weights.get(t).unwrap_or(&1) as f64))
        .collect();
    j.insert("weights", Json::Arr(w));
    let mut arr = Vec::with_capacity(ts.len());
    for (t, s) in ts.iter().enumerate() {
        let mut e = Json::obj();
        e.insert("tenant", (t as f64).into());
        e.insert("submitted", (s.submitted as f64).into());
        e.insert("served", (s.served as f64).into());
        e.insert("shed", (s.shed as f64).into());
        e.insert("errors", (s.errors as f64).into());
        e.insert("queue_wait_ms", latency_ms_json(&s.queue_wait));
        e.insert("latency_ms", latency_ms_json(&s.latency));
        arr.push(e);
    }
    j.insert("per_tenant", Json::Arr(arr));
    j
}

/// Dispatch weights padded/clamped to one `>= 1` entry per tenant.
fn normalized_weights(weights: &[u32], count: usize) -> Vec<u32> {
    (0..count).map(|t| weights.get(t).copied().unwrap_or(1).max(1)).collect()
}

/// Deterministic weighted tenant assignment for request `idx`: the mix
/// weights partition a seeded draw, so a 10:1 mix sends ~10/11 of the
/// traffic to tenant 0 with the exact split fixed by the seed.
pub fn tenant_of(seed: u64, idx: usize, plan: &TenantPlan) -> u32 {
    if plan.count <= 1 {
        return 0;
    }
    let total: u64 = plan.mix.iter().map(|&w| w as u64).sum();
    let mut rng = Rng::new(seed ^ 0x7e6a_a171 ^ (idx as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
    let mut r = rng.below(total.max(1) as usize) as u64;
    for (t, &w) in plan.mix.iter().enumerate() {
        if r < w as u64 {
            return t as u32;
        }
        r -= w as u64;
    }
    (plan.count - 1) as u32
}

/// Prompt length draw over `[lo, hi)`: uniform with `alpha == 0` (the
/// historical distribution, bit-for-bit), bounded-Pareto inverse CDF
/// otherwise — heavy-tailed toward `lo`, with occasional near-`hi`
/// prompts, the shape real serving traces show.
fn prompt_len(rng: &mut Rng, lo: usize, hi: usize, alpha: f64) -> usize {
    if alpha <= 0.0 {
        return rng.range(lo, hi);
    }
    let (l, h) = (lo as f64, (hi - 1).max(lo) as f64);
    let u = rng.f64();
    let ratio = (l / h).powf(alpha);
    let x = l * (1.0 - u * (1.0 - ratio)).powf(-1.0 / alpha);
    (x as usize).clamp(lo, hi - 1)
}

/// Deterministic request synthesis: request `idx` of a run is the same
/// tokens/span/budget for a given seed, independent of thread timing.
pub fn make_request(seed: u64, idx: usize, mode: Mode, max_new: usize) -> Request {
    make_request_opts(seed, idx, mode, max_new, 0.0)
}

/// [`make_request`] with a bounded-Pareto prompt-length shape; `alpha ==
/// 0` reproduces the uniform lengths earlier revisions drew.
pub fn make_request_opts(
    seed: u64,
    idx: usize,
    mode: Mode,
    max_new: usize,
    pareto_alpha: f64,
) -> Request {
    let mut rng = Rng::new(seed ^ (idx as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
    let score = match mode {
        Mode::Score => true,
        Mode::Generate | Mode::LongMix => false,
        Mode::Mixed => idx % 3 != 2, // 2:1 score:generate
    };
    if score {
        let len = prompt_len(&mut rng, 4, 24, pareto_alpha);
        let tokens: Vec<u32> = (0..len).map(|_| rng.range(3, 120) as u32).collect();
        let start = rng.range(1, len);
        let end = rng.range(start + 1, len + 1);
        Request::Score { tokens, span: (start, end) }
    } else if mode == Mode::LongMix {
        // Long class: a prompt far beyond the tiny engine's max_seq (64),
        // so the backend crops to the sliding window and still prefills a
        // near-full context; short class: a quick decode that should not
        // queue behind it when resumable prefill is on.
        let long = longmix_is_long(idx);
        let len = if long {
            prompt_len(&mut rng, 96, 161, pareto_alpha)
        } else {
            prompt_len(&mut rng, 3, 10, pareto_alpha)
        };
        let tokens: Vec<u32> = (0..len).map(|_| rng.range(3, 120) as u32).collect();
        let budget = if long { rng.range(1, 4) } else { rng.range(1, max_new.max(1) + 1) };
        Request::Generate { tokens, max_new: budget }
    } else {
        let len = prompt_len(&mut rng, 3, 16, pareto_alpha);
        let tokens: Vec<u32> = (0..len).map(|_| rng.range(3, 120) as u32).collect();
        Request::Generate { tokens, max_new: rng.range(1, max_new.max(1) + 1) }
    }
}

fn start_core(cfg: &LoadgenConfig) -> Result<(ServerCore, &'static str)> {
    let server_cfg = ServerConfig {
        replicas: cfg.replicas,
        queue_cap: cfg.queue_cap,
        max_wait: cfg.max_wait,
        tenants: cfg.tenants.count,
        tenant_weights: cfg.tenant_weights.clone(),
        tenant_quota: cfg.tenant_quota,
        ..Default::default()
    };
    // Chaos handles are created OUTSIDE the factories so that a rebuilt
    // replica continues its fault plan (tick counter and consumed faults
    // survive the restart) instead of replaying it from the start. With
    // `--chaos` unset every handle is `None` and `ChaosBackend` is a pure
    // passthrough, keeping no-fault runs bitwise identical to before.
    let horizon = (cfg.max_requests as u64).max(8);
    let chaos: Vec<Option<ChaosHandle>> = (0..cfg.replicas.max(1))
        .map(|r| cfg.chaos.as_ref().map(|c| c.handle_for(r, horizon)))
        .collect();
    match &cfg.backend {
        BackendChoice::Synthetic { batch, forward_cost } => {
            let (batch, forward_cost) = (*batch, *forward_cost);
            let core = ServerCore::start(server_cfg, move |r| {
                Ok(ChaosBackend::new(SyntheticBackend::new(batch, forward_cost), chaos[r].clone()))
            })?;
            Ok((core, "synthetic"))
        }
        BackendChoice::Artifacts { dir, pattern, method } => {
            let pattern = Pattern::parse(pattern)?;
            let mcfg = MethodConfig::by_name(method, pattern)?;
            let vocab = Vocab::synthlang();
            let stop = vec![vocab.id(".")?, EOS];
            let dir = dir.clone();
            let core = ServerCore::start(server_cfg, move |r| {
                CoordinatorBackend::open(&dir, mcfg.clone(), stop.clone())
                    .map(|b| ChaosBackend::new(b, chaos[r].clone()))
            })?;
            Ok((core, "artifacts"))
        }
        BackendChoice::Native { dir, pattern, method, seed, batch, threads, prefill_block } => {
            let pattern = Pattern::parse(pattern)?;
            let vocab = Vocab::synthlang();
            let stop = vec![vocab.id(".")?, EOS];
            let (dir, method) = (dir.clone(), method.clone());
            let (seed, batch, threads) = (*seed, *batch, *threads);
            let prefill_block = *prefill_block;
            let core = ServerCore::start(server_cfg, move |r| {
                NativeBackend::open(&dir, pattern, &method, stop.clone(), batch, seed)
                    .map(|b| b.with_threads(threads).with_prefill_block(prefill_block))
                    .map(|b| ChaosBackend::new(b, chaos[r].clone()))
            })?;
            Ok((core, "native"))
        }
    }
}

// -------------------------------------------------------------- wire path

/// Shared wire-path accumulators for one run.
struct WireAcc {
    transcript: AtomicU64,
    chunks: AtomicU64,
}

fn fnv(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Digest of one terminal reply, XOR-folded into the run's transcript
/// hash. Buffered `Generate` and streamed `End` replies digest their
/// token list identically, so a streamed run pins to its buffered twin.
pub fn digest_reply(idx: usize, rep: &WireReply) -> u64 {
    let mut h = fnv(0xcbf2_9ce4_8422_2325, &(idx as u64).to_le_bytes());
    match rep {
        WireReply::Score { score } => {
            h = fnv(h, &[1]);
            h = fnv(h, &score.to_bits().to_le_bytes());
        }
        WireReply::Generate { tokens, .. } | WireReply::End { tokens, .. } => {
            h = fnv(h, &[2]);
            for t in tokens {
                h = fnv(h, &t.to_le_bytes());
            }
        }
        WireReply::Error { message } => {
            h = fnv(h, &[3]);
            h = fnv(h, message.as_bytes());
        }
        WireReply::Blob(_) | WireReply::Chunk { .. } => {}
    }
    h
}

/// The token-level wire twin of an engine request (what a remote client
/// speaking the codec would send for this synthesized request).
fn to_wire_request(req: &Request, tenant: u32, stream: bool) -> WireRequest {
    match req {
        Request::Score { tokens, span } => WireRequest::ScoreTokens {
            tokens: tokens.clone(),
            span: (span.0 as u32, span.1 as u32),
            tenant,
        },
        Request::Generate { tokens, max_new } => WireRequest::GenerateTokens {
            tokens: tokens.clone(),
            max_new: *max_new as u32,
            tenant,
            stream,
        },
    }
}

fn wire_request_to_parts(w: WireRequest) -> (Request, u32, bool) {
    match w {
        WireRequest::ScoreTokens { tokens, span, tenant } => {
            let span = (span.0 as usize, span.1 as usize);
            (Request::Score { tokens, span }, tenant, false)
        }
        WireRequest::GenerateTokens { tokens, max_new, tenant, stream } => {
            (Request::Generate { tokens, max_new: max_new as usize }, tenant, stream)
        }
        other => panic!("loadgen synthesizes token-level requests only, got {other:?}"),
    }
}

/// The wire reply the server would frame for this terminal response —
/// streamed generates terminate with an `End` frame carrying the PR 7
/// outcome taxonomy, buffered ones with a plain reply.
fn response_to_wire(resp: &Response, streamed: bool) -> WireReply {
    match resp {
        Response::Score { score } => WireReply::Score { score: *score },
        Response::Generate { tokens } if streamed => WireReply::End {
            outcome: StreamOutcome::End,
            tokens: tokens.clone(),
            text: String::new(),
        },
        Response::Generate { tokens } => {
            WireReply::Generate { tokens: tokens.clone(), text: String::new() }
        }
        Response::Error { message } if streamed => WireReply::End {
            outcome: if message == ERR_TIMEOUT {
                StreamOutcome::Timeout
            } else {
                StreamOutcome::ReplicaFailed
            },
            tokens: Vec::new(),
            text: String::new(),
        },
        Response::Error { message } => WireReply::Error { message: message.clone() },
    }
}

/// Encode → decode through the codec, panicking on any mismatch: the
/// loadgen wire path is a correctness harness, so a lossy roundtrip is a
/// codec bug worth a loud failure, not a skipped sample.
fn roundtrip_request(c: &dyn Codec, req: &WireRequest) -> WireRequest {
    let mut buf = Vec::new();
    c.encode_request(req, &mut buf);
    match c.decode_request(&buf) {
        Ok(Some((decoded, used))) if used == buf.len() => decoded,
        other => panic!("codec {} failed to roundtrip a request: {other:?}", c.name()),
    }
}

fn roundtrip_reply(c: &dyn Codec, rep: &WireReply) -> WireReply {
    let mut buf = Vec::new();
    c.encode_reply(rep, &mut buf);
    match c.decode_reply(&buf) {
        Ok(Some((decoded, used))) if used == buf.len() => decoded,
        other => panic!("codec {} failed to roundtrip a reply: {other:?}", c.name()),
    }
}

/// One submitted request awaiting its terminal reply (and, for streamed
/// generates, draining its per-token lane).
struct InFlight {
    idx: usize,
    t0: Instant,
    ticket: Ticket,
    rx: Option<StreamReceiver>,
}

/// Synthesize request `idx`, optionally roundtrip it through the wire
/// codec, and submit it with its tenant class + optional stream lane.
fn launch(
    handle: &ServerHandle,
    cfg: &LoadgenConfig,
    idx: usize,
    key: Option<u64>,
) -> Result<InFlight, SubmitError> {
    let req = make_request_opts(cfg.seed, idx, cfg.mode, cfg.max_new, cfg.pareto_alpha);
    let tenant = tenant_of(cfg.seed, idx, &cfg.tenants);
    let stream = cfg.stream && matches!(req, Request::Generate { .. });
    let (req, tenant, stream) = match cfg.codec {
        None => (req, tenant, stream),
        Some(kind) => {
            let c = kind.codec();
            wire_request_to_parts(roundtrip_request(c, &to_wire_request(&req, tenant, stream)))
        }
    };
    let (tx, rx) = if stream {
        let (tx, rx) = stream_channel(LANE_CAP);
        (Some(tx), Some(rx))
    } else {
        (None, None)
    };
    let deadline = cfg.request_timeout.map(|d| Instant::now() + d);
    let t0 = Instant::now();
    let ticket = handle.submit_opts(req, SubmitOpts { key, deadline, tenant, stream: tx })?;
    Ok(InFlight { idx, t0, ticket, rx })
}

/// Wait out one in-flight request: drain its stream lane (chunk frames
/// roundtrip through the codec too), fold the terminal reply into the
/// transcript hash, and record its class latency. The lane closes by
/// sender drop just before the terminal reply, so this always returns.
fn collect(f: InFlight, cfg: &LoadgenConfig, classes: Option<&Mutex<ClassLatency>>, w: &WireAcc) {
    let codec = cfg.codec.map(|k| k.codec());
    if let Some(rx) = &f.rx {
        let mut chunks = 0u64;
        loop {
            match rx.poll(Duration::from_millis(10)) {
                StreamPoll::Token(tok) => {
                    if let Some(c) = codec {
                        roundtrip_reply(c, &WireReply::Chunk { index: chunks as u32, token: tok });
                    }
                    chunks += 1;
                }
                StreamPoll::Idle => {}
                StreamPoll::Closed => break,
            }
        }
        w.chunks.fetch_add(chunks, Ordering::Relaxed);
    }
    let Some(resp) = f.ticket.recv() else {
        return; // core torn down ungracefully; no terminal reply to pin
    };
    let rep = response_to_wire(&resp, f.rx.is_some());
    let rep = match codec {
        Some(c) => roundtrip_reply(c, &rep),
        None => rep,
    };
    w.transcript.fetch_xor(digest_reply(f.idx, &rep), Ordering::Relaxed);
    if let Some(c) = classes {
        c.lock().unwrap().record(longmix_is_long(f.idx), f.t0.elapsed());
    }
}

/// Arrival-time offsets for an open-loop run. Without `--burst` this is
/// the exact fixed-interval schedule earlier revisions used; with it,
/// arrivals follow the seeded two-state MMPP of [`BurstPlan`].
pub fn arrival_offsets(cfg: &LoadgenConfig, n: usize) -> Vec<Duration> {
    let interval = Duration::from_secs_f64(1.0 / cfg.rate_rps);
    let Some(b) = cfg.burst else {
        return (0..n).map(|i| interval.mul_f64(i as f64)).collect();
    };
    fn exp_s(rng: &mut Rng, mean_s: f64) -> f64 {
        -mean_s.max(1e-6) * (1.0 - rng.f64()).ln()
    }
    let mut rng = Rng::new(cfg.seed ^ 0xb417_57a1);
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    let mut on = true;
    let mut phase_end = exp_s(&mut rng, b.on.as_secs_f64());
    while out.len() < n {
        let rate = if on { cfg.rate_rps * b.rate_mult } else { cfg.rate_rps };
        t += exp_s(&mut rng, 1.0 / rate.max(1e-9));
        while t > phase_end {
            on = !on;
            let mean = if on { b.on } else { b.off };
            phase_end += exp_s(&mut rng, mean.as_secs_f64());
        }
        out.push(Duration::from_secs_f64(t));
    }
    out
}

/// Run the generator to completion and return the report. The server-side
/// histogram provides the latency distribution (submit → terminal reply).
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    anyhow::ensure!(cfg.max_requests > 0, "--max-requests must be > 0 for a bounded run");
    // Metrics-level tracing is on for every loadgen run so the report's
    // `phases` block is always populated; reset isolates this run's
    // aggregates (a sweep snapshots per point). `ensure` never lowers
    // the level, so a `--trace` Full export survives.
    trace::ensure(TraceLevel::Metrics);
    trace::reset();
    let (core, backend_name) = start_core(cfg)?;
    // Client-side per-class split, longmix only (keeps every other mode's
    // JSON — and the sweep schema old consumers parse — unchanged).
    let classes = (cfg.mode == Mode::LongMix).then(|| Mutex::new(ClassLatency::default()));
    let wire = WireAcc { transcript: AtomicU64::new(0), chunks: AtomicU64::new(0) };
    let t0 = Instant::now();
    if cfg.rate_rps > 0.0 {
        run_open_loop(&core, cfg, classes.as_ref(), &wire);
    } else {
        run_closed_loop(&core, cfg, classes.as_ref(), &wire);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    // Shutdown joins the replica threads, whose TLS sinks flush on exit,
    // so the snapshot below sees every worker's spans.
    let stats = core.shutdown();
    Ok(LoadgenReport {
        stats,
        requests: cfg.max_requests,
        wall_s,
        mode: cfg.mode,
        replicas: cfg.replicas,
        queue_cap: cfg.queue_cap,
        backend_name,
        classes: classes.map(|m| m.into_inner().unwrap()),
        phases: trace::snapshot(),
        tenant_plan: cfg.tenants.clone(),
        dispatch_weights: normalized_weights(&cfg.tenant_weights, cfg.tenants.count),
        codec_name: cfg.codec.map(|k| k.as_str()).unwrap_or("direct"),
        stream_chunks: wire.chunks.load(Ordering::Relaxed),
        transcript_hash: wire.transcript.load(Ordering::Relaxed),
    })
}

fn run_closed_loop(
    core: &ServerCore,
    cfg: &LoadgenConfig,
    classes: Option<&Mutex<ClassLatency>>,
    wire: &WireAcc,
) {
    let next = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|scope| {
        for client in 0..cfg.concurrency.max(1) {
            let handle = core.handle();
            let next = Arc::clone(&next);
            scope.spawn(move || loop {
                let idx = next.fetch_add(1, Ordering::Relaxed);
                if idx >= cfg.max_requests {
                    break;
                }
                // Session affinity: one client = one session key.
                match launch(&handle, cfg, idx, Some(client as u64)) {
                    Ok(f) => collect(f, cfg, classes, wire), // one in flight per client
                    Err(SubmitError::Overloaded { .. }) => {} // shed; counted server-side
                    Err(SubmitError::Closed) => break,
                }
            });
        }
    });
}

fn run_open_loop(
    core: &ServerCore,
    cfg: &LoadgenConfig,
    classes: Option<&Mutex<ClassLatency>>,
    wire: &WireAcc,
) {
    let offsets = arrival_offsets(cfg, cfg.max_requests);
    let handle = core.handle();
    let start = Instant::now();
    std::thread::scope(|scope| {
        let mut pending: Vec<InFlight> = Vec::with_capacity(cfg.max_requests);
        for idx in 0..cfg.max_requests {
            let due = start + offsets[idx];
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
            match launch(&handle, cfg, idx, None) {
                Ok(f) => {
                    if classes.is_some() {
                        // Per-ticket collector thread: recv the moment the
                        // reply lands, so the class histogram records true
                        // submit -> terminal latency (draining at the end
                        // would overcount for early finishers). Bounded by
                        // max_requests; longmix runs only.
                        scope.spawn(move || collect(f, cfg, classes, wire));
                    } else {
                        pending.push(f);
                    }
                }
                Err(SubmitError::Overloaded { .. }) => {} // shed; counted server-side
                Err(SubmitError::Closed) => break,
            }
        }
        // Streamed lanes hold up to LANE_CAP tokens, so draining after
        // the arrival loop loses no chunks for max_new <= LANE_CAP.
        for f in pending {
            collect(f, cfg, classes, wire);
        }
    });
}

/// Write `report.to_json()` to `path` (pretty, trailing newline).
pub fn write_bench_json(report: &LoadgenReport, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, report.to_json().pretty())
        .with_context(|| format!("writing {}", path.display()))
}

// ------------------------------------------------------------------ sweep

/// One point of a latency-vs-offered-rate sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub rate_rps: f64,
    pub report: LoadgenReport,
}

/// Open-loop sweep: one bounded run per offered rate, each against a
/// fresh core (clean histograms, no cross-rate pollution). Rates must be
/// positive; `cfg.max_requests` requests are offered at every point.
pub fn run_sweep(cfg: &LoadgenConfig, rates: &[f64]) -> Result<Vec<SweepPoint>> {
    anyhow::ensure!(!rates.is_empty(), "--sweep needs at least one rate");
    anyhow::ensure!(
        rates.windows(2).all(|w| w[0] < w[1]),
        "--sweep rates must be strictly increasing (the sweep curve is rate-ordered)"
    );
    let mut points = Vec::with_capacity(rates.len());
    for &rate_rps in rates {
        anyhow::ensure!(rate_rps > 0.0, "sweep rates must be positive (got {rate_rps})");
        let mut point_cfg = cfg.clone();
        point_cfg.rate_rps = rate_rps;
        let report = run(&point_cfg)?;
        println!("sweep @ {rate_rps:>8.1} req/s: {}", report.summary());
        points.push(SweepPoint { rate_rps, report });
    }
    Ok(points)
}

/// The `BENCH_serving_sweep.json` document (see
/// `tools/check_bench_json.py`): shared run shape at the top level, one
/// entry per offered rate under `points`.
pub fn sweep_json(cfg: &LoadgenConfig, points: &[SweepPoint]) -> Json {
    let mut j = Json::obj();
    j.insert("suite", "serving_sweep".into());
    j.insert("mode", cfg.mode.as_str().into());
    j.insert(
        "backend",
        points.first().map(|p| p.report.backend_name).unwrap_or("synthetic").into(),
    );
    j.insert("replicas", (cfg.replicas as f64).into());
    j.insert("queue_cap", (cfg.queue_cap as f64).into());
    j.insert("requests_per_point", (cfg.max_requests as f64).into());
    let mut arr = Vec::with_capacity(points.len());
    for p in points {
        let mut e = Json::obj();
        e.insert("rate_rps", p.rate_rps.into());
        e.insert("served", (p.report.stats.served as f64).into());
        e.insert("rejected", (p.report.stats.rejected as f64).into());
        e.insert("throughput_rps", p.report.throughput_rps().into());
        e.insert("latency_ms", latency_ms_json(&p.report.stats.latency));
        e.insert("queue_wait_ms", latency_ms_json(&p.report.stats.queue_wait));
        e.insert("rejection_rate", p.report.stats.rejection_rate().into());
        e.insert("batch_occupancy", p.report.stats.batch_occupancy().into());
        e.insert("timed_out", (p.report.stats.timed_out as f64).into());
        e.insert("failed", (p.report.stats.failed as f64).into());
        e.insert("timeout_rate", p.report.stats.timeout_rate().into());
        e.insert("failure_rate", p.report.stats.failure_rate().into());
        e.insert("restarts", (p.report.stats.restarts as f64).into());
        e.insert("retried", (p.report.stats.retried as f64).into());
        e.insert("stream_chunks", (p.report.stream_chunks as f64).into());
        e.insert("transcript_hash", format!("{:016x}", p.report.transcript_hash).into());
        e.insert("tenants", tenants_json(&p.report.stats.tenants, &p.report.dispatch_weights));
        if let Some(c) = &p.report.classes {
            e.insert("classes", c.to_json());
        }
        arr.push(e);
    }
    j.insert("points", Json::Arr(arr));
    j
}

/// Write the sweep document to `path`.
pub fn write_sweep_json(
    cfg: &LoadgenConfig,
    points: &[SweepPoint],
    path: &std::path::Path,
) -> Result<()> {
    std::fs::write(path, sweep_json(cfg, points).pretty())
        .with_context(|| format!("writing {}", path.display()))
}

pub fn cmd_loadgen(rest: Vec<String>) -> Result<()> {
    #[rustfmt::skip]
    let specs = vec![
        OptSpec { name: "replicas", takes_value: true, default: Some("2"), help: "engine replicas" },
        OptSpec { name: "queue-cap", takes_value: true, default: Some("128"), help: "per-replica admission cap" },
        OptSpec { name: "max-requests", takes_value: true, default: Some("256"), help: "total requests (bounded run)" },
        OptSpec { name: "concurrency", takes_value: true, default: Some("16"), help: "closed-loop clients" },
        OptSpec { name: "rate", takes_value: true, default: Some("0"), help: "open-loop req/s (0 = closed loop)" },
        OptSpec { name: "mode", takes_value: true, default: Some("mixed"), help: "score | generate | mixed | longmix (long-prompt/short-decode mix, per-class latency)" },
        OptSpec { name: "max-new", takes_value: true, default: Some("8"), help: "max generated tokens" },
        OptSpec { name: "max-wait-ms", takes_value: true, default: Some("5"), help: "batch deadline (ms)" },
        OptSpec { name: "seed", takes_value: true, default: Some("7"), help: "request-synthesis seed" },
        OptSpec { name: "backend", takes_value: true, default: Some("synthetic"), help: "synthetic | artifacts | native" },
        OptSpec { name: "batch", takes_value: true, default: Some("16"), help: "synthetic/native batch capacity" },
        OptSpec { name: "threads", takes_value: true, default: Some("1"), help: "native worker-pool width per replica (0 = auto; never changes bits)" },
        OptSpec { name: "prefill-block", takes_value: true, default: Some("0"), help: "native resumable-prefill block size per tick (0 = feed-to-completion; never changes bits)" },
        OptSpec { name: "forward-us", takes_value: true, default: Some("150"), help: "synthetic per-forward cost (us)" },
        OptSpec { name: "artifacts", takes_value: true, default: Some("artifacts"), help: "artifacts dir (artifacts/native backends)" },
        OptSpec { name: "pattern", takes_value: true, default: Some("8:16"), help: "sparsity pattern (artifacts/native backends)" },
        OptSpec { name: "method", takes_value: true, default: Some("S-PTS"), help: "method (artifacts/native backends)" },
        OptSpec { name: "request-timeout-ms", takes_value: true, default: Some("0"), help: "per-request deadline (ms, 0 = none)" },
        OptSpec { name: "chaos", takes_value: true, default: Some(""), help: "fault injection: integer seed or 'panic@N;err@N;stall@N:D' spec ('' = off)" },
        OptSpec { name: "tenants", takes_value: true, default: Some("1"), help: "tenant classes 'k[:w1,..,wk]' (weights = traffic mix, default equal)" },
        OptSpec { name: "tenant-weights", takes_value: true, default: Some(""), help: "server DRR dispatch weights 'w1,..,wk' ('' = equal)" },
        OptSpec { name: "tenant-quota", takes_value: true, default: Some("0"), help: "per-tenant in-flight quota per replica (0 = share queue cap)" },
        OptSpec { name: "burst", takes_value: true, default: Some(""), help: "MMPP open-loop arrivals 'on_ms,off_ms,rate_mult' ('' = fixed interval)" },
        OptSpec { name: "pareto", takes_value: true, default: Some("0"), help: "bounded-Pareto prompt-length shape alpha (0 = uniform)" },
        OptSpec { name: "codec", takes_value: true, default: Some(""), help: "roundtrip the wire codec in-process: json | binary ('' = off)" },
        OptSpec { name: "stream", takes_value: false, default: None, help: "streamed generates: per-token lanes, chunk frames counted client-side" },
        OptSpec { name: "sweep", takes_value: true, default: Some(""), help: "open-loop rate grid 'r1,r2,...' (req/s)" },
        OptSpec { name: "sweep-out", takes_value: true, default: Some("BENCH_serving_sweep.json"), help: "sweep report path" },
        OptSpec { name: "out", takes_value: true, default: Some("BENCH_serving.json"), help: "report path ('' = skip)" },
        OptSpec { name: "trace", takes_value: true, default: Some(""), help: "write Chrome trace-event JSON here ('' = off)" },
        OptSpec { name: "help", takes_value: false, default: None, help: "show help" },
    ];
    let a = Args::parse(rest, &specs)?;
    if a.flag("help") {
        print!("{}", usage("loadgen", "Drive a multi-replica ServerCore and measure it.", &specs));
        return Ok(());
    }
    let backend = match a.get("backend").as_str() {
        "synthetic" => BackendChoice::Synthetic {
            batch: a.get_usize("batch")?,
            forward_cost: Duration::from_micros(a.get_u64("forward-us")?),
        },
        "artifacts" => BackendChoice::Artifacts {
            dir: PathBuf::from(a.get("artifacts")),
            pattern: a.get("pattern"),
            method: a.get("method"),
        },
        "native" => BackendChoice::Native {
            dir: PathBuf::from(a.get("artifacts")),
            pattern: a.get("pattern"),
            // Without artifacts the native engine has no methodparams,
            // so the loadgen default S-PTS cannot load its per-site eta
            // vectors; default to ACT here (an explicit --method S-PTS
            // works against a real artifacts dir).
            method: if a.given("method") { a.get("method") } else { "ACT".to_string() },
            seed: a.get_u64("seed")?,
            batch: a.get_usize("batch")?,
            threads: super::decode::resolve_threads(a.get_usize("threads")?),
            prefill_block: a.get_usize("prefill-block")?,
        },
        other => bail!("unknown --backend '{other}' (synthetic, artifacts, native)"),
    };
    let cfg = LoadgenConfig {
        replicas: a.get_usize("replicas")?,
        queue_cap: a.get_usize("queue-cap")?,
        max_requests: a.get_usize("max-requests")?,
        concurrency: a.get_usize("concurrency")?,
        rate_rps: a.get_f64("rate")?,
        mode: Mode::parse(&a.get("mode"))?,
        max_new: a.get_usize("max-new")?,
        max_wait: Duration::from_millis(a.get_u64("max-wait-ms")?),
        seed: a.get_u64("seed")?,
        request_timeout: {
            let ms = a.get_u64("request-timeout-ms")?;
            (ms > 0).then(|| Duration::from_millis(ms))
        },
        chaos: {
            let s = a.get("chaos");
            if s.is_empty() { None } else { Some(ChaosArg::parse(&s)?) }
        },
        backend,
        tenants: parse_tenant_plan(&a.get("tenants"))?,
        tenant_weights: super::serve::parse_weights(&a.get("tenant-weights"))?,
        tenant_quota: a.get_usize("tenant-quota")?,
        burst: {
            let s = a.get("burst");
            if s.is_empty() { None } else { Some(parse_burst(&s)?) }
        },
        pareto_alpha: a.get_f64("pareto")?,
        codec: {
            let s = a.get("codec");
            if s.is_empty() {
                None
            } else {
                Some(CodecKind::parse(&s).ok_or_else(|| {
                    anyhow::anyhow!("unknown --codec '{s}' (json, binary)")
                })?)
            }
        },
        stream: a.flag("stream"),
    };
    if let Some(c) = &cfg.chaos {
        println!("loadgen: chaos enabled ({})", c.describe());
    }
    if cfg.tenants.count > 1 {
        println!("loadgen: {} tenants, traffic mix {:?}", cfg.tenants.count, cfg.tenants.mix);
    }
    if let Some(k) = cfg.codec {
        let streamed = if cfg.stream { " (streamed generates)" } else { "" };
        println!("loadgen: wire codec {}{streamed}", k.as_str());
    }
    let trace_path = a.get("trace");
    if !trace_path.is_empty() {
        trace::set_level(TraceLevel::Full);
    }
    // Sweep mode: one open-loop run per rate -> BENCH_serving_sweep.json.
    let sweep_rates = a.get("sweep");
    if !sweep_rates.is_empty() {
        let rates: Vec<f64> = sweep_rates
            .split(',')
            .map(|r| {
                r.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("bad sweep rate '{r}' (want req/s numbers)"))
            })
            .collect::<Result<_>>()?;
        println!(
            "loadgen sweep: {} rates x {} requests, {} replicas (cap {}), {} backend",
            rates.len(),
            cfg.max_requests,
            cfg.replicas,
            cfg.queue_cap,
            a.get("backend"),
        );
        let points = run_sweep(&cfg, &rates)?;
        let path = PathBuf::from(a.get("sweep-out"));
        write_sweep_json(&cfg, &points, &path)?;
        println!("wrote {}", path.display());
        // Each point resets the recorder, so a sweep's trace export
        // covers only the final rate — still useful for eyeballing one
        // steady-state point in Perfetto.
        return finish_trace(&trace_path);
    }
    println!(
        "loadgen: {} requests, {} replicas (cap {}), {} loop, {} backend",
        cfg.max_requests,
        cfg.replicas,
        cfg.queue_cap,
        if cfg.rate_rps > 0.0 { "open" } else { "closed" },
        a.get("backend"),
    );
    let report = run(&cfg)?;
    println!("loadgen: {}", report.summary());
    println!("loadgen: {}", report.phases.summary());
    let out = a.get("out");
    if !out.is_empty() {
        let path = PathBuf::from(out);
        write_bench_json(&report, &path)?;
        println!("wrote {}", path.display());
    }
    finish_trace(&trace_path)
}

/// Export the accumulated spans as Chrome trace-event JSON when
/// `--trace` was given; a no-op otherwise.
fn finish_trace(path: &str) -> Result<()> {
    if path.is_empty() {
        return Ok(());
    }
    let n = trace::write_chrome_trace(std::path::Path::new(path))?;
    println!("trace: wrote {n} spans to {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_synthesis_is_deterministic_and_valid() {
        for idx in 0..200 {
            let a = make_request(42, idx, Mode::Mixed, 8);
            let b = make_request(42, idx, Mode::Mixed, 8);
            assert_eq!(a, b);
            match a {
                Request::Score { tokens, span: (s, e) } => {
                    assert!(!tokens.is_empty());
                    assert!(s >= 1 && s < e && e <= tokens.len());
                }
                Request::Generate { tokens, max_new } => {
                    assert!(!tokens.is_empty());
                    assert!((1..=8).contains(&max_new));
                }
            }
        }
        // Mode filters hold.
        assert!((0..60).all(|i| matches!(
            make_request(1, i, Mode::Score, 4),
            Request::Score { .. }
        )));
        assert!((0..60).all(|i| matches!(
            make_request(1, i, Mode::Generate, 4),
            Request::Generate { .. }
        )));
    }

    #[test]
    fn closed_loop_synthetic_run_reports() {
        let cfg = LoadgenConfig {
            replicas: 2,
            queue_cap: 32,
            max_requests: 48,
            concurrency: 6,
            max_new: 4,
            backend: BackendChoice::Synthetic { batch: 4, forward_cost: Duration::ZERO },
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.stats.served + report.stats.rejected, 48);
        assert_eq!(report.stats.errors, 0);
        assert!(report.throughput_rps() > 0.0);
        assert_eq!(report.stats.latency.count(), report.stats.served);
        let j = report.to_json();
        assert_eq!(j.get("suite").and_then(|s| s.as_str()), Some("serving"));
        let lat = j.get("latency_ms").unwrap();
        let p50 = lat.get("p50").and_then(|x| x.as_f64()).unwrap();
        let p95 = lat.get("p95").and_then(|x| x.as_f64()).unwrap();
        let p99 = lat.get("p99").and_then(|x| x.as_f64()).unwrap();
        assert!(p50 <= p95 && p95 <= p99);
        let occ = j.get("batch_occupancy").and_then(|x| x.as_f64()).unwrap();
        assert!((0.0..=1.0).contains(&occ));
    }

    #[test]
    fn native_backend_run_completes_without_errors() {
        let cfg = LoadgenConfig {
            replicas: 1,
            queue_cap: 64,
            max_requests: 24,
            concurrency: 4,
            max_new: 4,
            mode: Mode::Mixed,
            backend: BackendChoice::Native {
                dir: PathBuf::from("/definitely/not/here"),
                pattern: "8:16".into(),
                method: "ACT".into(),
                seed: 3,
                batch: 4,
                threads: 2,
                prefill_block: 0,
            },
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.backend_name, "native");
        assert_eq!(report.stats.served + report.stats.rejected, 24);
        assert_eq!(report.stats.errors, 0);
        assert!(report.classes.is_none(), "classes is a longmix-only field");
    }

    #[test]
    fn longmix_synthesis_mixes_long_and_short_generates() {
        for idx in 0..32 {
            match make_request(9, idx, Mode::LongMix, 8) {
                Request::Generate { tokens, max_new } => {
                    if longmix_is_long(idx) {
                        assert!((96..=160).contains(&tokens.len()), "len {}", tokens.len());
                        assert!((1..=3).contains(&max_new));
                    } else {
                        assert!((3..=9).contains(&tokens.len()), "len {}", tokens.len());
                    }
                }
                other => panic!("longmix emitted a non-generate request: {other:?}"),
            }
        }
    }

    #[test]
    fn longmix_native_run_reports_per_class_latency() {
        let cfg = LoadgenConfig {
            replicas: 1,
            queue_cap: 64,
            max_requests: 16,
            concurrency: 4,
            max_new: 4,
            mode: Mode::LongMix,
            backend: BackendChoice::Native {
                dir: PathBuf::from("/definitely/not/here"),
                pattern: "8:16".into(),
                method: "ACT".into(),
                seed: 3,
                batch: 4,
                threads: 1,
                prefill_block: 8,
            },
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.stats.served + report.stats.rejected, 16);
        assert_eq!(report.stats.errors, 0);
        let classes = report.classes.as_ref().expect("longmix records classes");
        // 16 requests, idx % 4 == 0 -> 4 long, 12 short (none shed: cap 64).
        assert_eq!(classes.long_prompt.count(), 4);
        assert_eq!(classes.short_decode.count(), 12);
        let j = report.to_json();
        let c = j.get("classes").expect("classes block in longmix JSON");
        for class in ["long_prompt", "short_decode"] {
            let e = c.get(class).unwrap();
            assert!(e.get("count").and_then(|x| x.as_f64()).unwrap() > 0.0);
            let lat = e.get("latency_ms").unwrap();
            let p50 = lat.get("p50").and_then(|x| x.as_f64()).unwrap();
            let p99 = lat.get("p99").and_then(|x| x.as_f64()).unwrap();
            assert!(p50 <= p99, "{class}: p50 {p50} > p99 {p99}");
        }
    }

    #[test]
    fn longmix_open_loop_sweep_point_carries_classes() {
        let cfg = LoadgenConfig {
            replicas: 1,
            queue_cap: 64,
            max_requests: 12,
            mode: Mode::LongMix,
            backend: BackendChoice::Native {
                dir: PathBuf::from("/definitely/not/here"),
                pattern: "8:16".into(),
                method: "ACT".into(),
                seed: 5,
                batch: 4,
                threads: 1,
                prefill_block: 8,
            },
            ..Default::default()
        };
        let points = run_sweep(&cfg, &[2000.0]).unwrap();
        let j = sweep_json(&cfg, &points);
        assert_eq!(j.get("mode").and_then(|m| m.as_str()), Some("longmix"));
        let arr = j.get("points").and_then(|p| p.as_arr()).unwrap();
        let c = arr[0].get("classes").expect("longmix sweep points carry classes");
        let total: f64 = ["long_prompt", "short_decode"]
            .iter()
            .map(|k| c.get(k).and_then(|e| e.get("count")).and_then(|x| x.as_f64()).unwrap())
            .sum();
        assert_eq!(total as u64, 12, "every submitted request lands in one class");
    }

    #[test]
    fn sweep_produces_one_point_per_rate() {
        let cfg = LoadgenConfig {
            replicas: 1,
            queue_cap: 16,
            max_requests: 16,
            mode: Mode::Score,
            backend: BackendChoice::Synthetic { batch: 4, forward_cost: Duration::ZERO },
            ..Default::default()
        };
        let points = run_sweep(&cfg, &[2000.0, 4000.0]).unwrap();
        assert_eq!(points.len(), 2);
        for p in &points {
            assert_eq!(p.report.stats.served + p.report.stats.rejected, 16);
            assert!((p.rate_rps - 2000.0).abs() < 1e-9 || (p.rate_rps - 4000.0).abs() < 1e-9);
        }
        let j = sweep_json(&cfg, &points);
        assert_eq!(j.get("suite").and_then(|s| s.as_str()), Some("serving_sweep"));
        let arr = j.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(arr.len(), 2);
        for e in arr {
            assert!(e.get("rate_rps").and_then(|x| x.as_f64()).unwrap() > 0.0);
            assert!(e.get("latency_ms").and_then(|l| l.get("p95")).is_some());
        }
        // Degenerate sweeps are rejected.
        assert!(run_sweep(&cfg, &[]).is_err());
        assert!(run_sweep(&cfg, &[0.0]).is_err());
    }

    #[test]
    fn chaos_run_restarts_replicas_and_keeps_accounting_balanced() {
        let cfg = LoadgenConfig {
            replicas: 2,
            queue_cap: 64,
            max_requests: 80,
            concurrency: 8,
            max_new: 4,
            request_timeout: Some(Duration::from_secs(5)),
            chaos: Some(ChaosArg::parse("panic@2;err@9;stall@5:1").unwrap()),
            backend: BackendChoice::Synthetic { batch: 4, forward_cost: Duration::ZERO },
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        // Exactly-once accounting holds under injected faults: every
        // request is served (possibly with a terminal error) or shed.
        assert_eq!(report.stats.served + report.stats.rejected, 80);
        assert_eq!(report.stats.latency.count(), report.stats.served);
        // Spec plans run on every replica, so each panics once and both
        // replicas are rebuilt by the supervisor.
        assert!(report.stats.restarts >= 2, "restarts = {}", report.stats.restarts);
        let j = report.to_json();
        for key in ["restarts", "retried", "timed_out", "failed"] {
            assert!(j.get(key).and_then(|x| x.as_f64()).is_some(), "missing {key}");
        }
        for key in ["timeout_rate", "failure_rate"] {
            let v = j.get(key).and_then(|x| x.as_f64()).unwrap();
            assert!((0.0..=1.0).contains(&v), "{key} = {v}");
        }
    }

    #[test]
    fn tenant_plan_and_burst_parse() {
        let p = parse_tenant_plan("2:10,1").unwrap();
        assert_eq!((p.count, p.mix), (2, vec![10, 1]));
        let p = parse_tenant_plan("3").unwrap();
        assert_eq!((p.count, p.mix), (3, vec![1, 1, 1]));
        assert!(parse_tenant_plan("0").is_err());
        assert!(parse_tenant_plan("2:1").is_err(), "mix length must match count");
        assert!(parse_tenant_plan("2:1,0").is_err(), "mix weights must be positive");
        let b = parse_burst("5,20,8.0").unwrap();
        assert_eq!(b.on, Duration::from_millis(5));
        assert_eq!(b.off, Duration::from_millis(20));
        assert!((b.rate_mult - 8.0).abs() < 1e-12);
        assert!(parse_burst("5,20").is_err());
        assert!(parse_burst("0,20,2").is_err());
        assert!(parse_burst("5,20,0").is_err());
    }

    #[test]
    fn tenant_assignment_is_deterministic_and_follows_mix() {
        let plan = parse_tenant_plan("2:10,1").unwrap();
        let mut counts = [0usize; 2];
        for idx in 0..2200 {
            let t = tenant_of(11, idx, &plan);
            assert_eq!(t, tenant_of(11, idx, &plan));
            counts[t as usize] += 1;
        }
        // 10:1 mix: tenant 0 gets ~10/11 of the traffic.
        let ratio = counts[0] as f64 / counts[1].max(1) as f64;
        assert!((5.0..=20.0).contains(&ratio), "mix ratio {ratio} (counts {counts:?})");
        // Single tenant always maps to 0.
        assert_eq!(tenant_of(11, 123, &TenantPlan::default()), 0);
    }

    #[test]
    fn pareto_lengths_stay_bounded_and_skew_short() {
        let mut uni_sum = 0usize;
        let mut par_sum = 0usize;
        for idx in 0..400 {
            let (a, b) = (
                make_request_opts(5, idx, Mode::Score, 8, 1.2),
                make_request_opts(5, idx, Mode::Score, 8, 1.2),
            );
            assert_eq!(a, b, "pareto synthesis is deterministic");
            let Request::Score { tokens, span: (s, e) } = a else { unreachable!() };
            assert!((4..24).contains(&tokens.len()), "len {}", tokens.len());
            assert!(s >= 1 && s < e && e <= tokens.len());
            par_sum += tokens.len();
            let Request::Score { tokens, .. } = make_request_opts(5, idx, Mode::Score, 8, 0.0)
            else {
                unreachable!()
            };
            uni_sum += tokens.len();
        }
        // Heavy tail toward the minimum: the Pareto mean sits well below
        // the uniform mean over the same support.
        assert!(par_sum < uni_sum, "pareto {par_sum} >= uniform {uni_sum}");
    }

    #[test]
    fn burst_offsets_are_monotone_and_seeded() {
        let cfg = LoadgenConfig {
            rate_rps: 1000.0,
            burst: Some(parse_burst("5,10,6").unwrap()),
            ..Default::default()
        };
        let a = arrival_offsets(&cfg, 64);
        let b = arrival_offsets(&cfg, 64);
        assert_eq!(a, b, "burst schedule is seeded-deterministic");
        assert_eq!(a.len(), 64);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "offsets are non-decreasing");
        // Without burst the schedule is the exact fixed-interval grid.
        let fixed = arrival_offsets(&LoadgenConfig { rate_rps: 1000.0, ..Default::default() }, 4);
        assert_eq!(fixed[2], Duration::from_millis(2));
    }

    #[test]
    fn codec_roundtrip_runs_match_direct_transcript() {
        let base = LoadgenConfig {
            replicas: 1,
            queue_cap: 64,
            max_requests: 32,
            concurrency: 4,
            max_new: 4,
            backend: BackendChoice::Synthetic { batch: 4, forward_cost: Duration::ZERO },
            ..Default::default()
        };
        let direct = run(&base).unwrap();
        assert_eq!(direct.stats.errors, 0);
        assert_eq!(direct.codec_name, "direct");
        assert_ne!(direct.transcript_hash, 0, "a served run hashes its replies");
        for kind in [CodecKind::Json, CodecKind::Binary] {
            let cfg = LoadgenConfig { codec: Some(kind), ..base.clone() };
            let report = run(&cfg).unwrap();
            assert_eq!(report.stats.served, direct.stats.served);
            assert_eq!(report.stats.errors, 0);
            assert_eq!(
                report.transcript_hash, direct.transcript_hash,
                "codec {} changed the reply transcript",
                kind.as_str()
            );
        }
    }

    #[test]
    fn streamed_run_counts_chunks_and_matches_buffered_transcript() {
        let base = LoadgenConfig {
            replicas: 1,
            queue_cap: 64,
            max_requests: 24,
            concurrency: 4,
            max_new: 4,
            mode: Mode::Generate,
            codec: Some(CodecKind::Binary),
            backend: BackendChoice::Synthetic { batch: 4, forward_cost: Duration::ZERO },
            ..Default::default()
        };
        let buffered = run(&base).unwrap();
        assert_eq!(buffered.stream_chunks, 0);
        let streamed = run(&LoadgenConfig { stream: true, ..base.clone() }).unwrap();
        assert_eq!(streamed.stats.errors, 0);
        assert!(streamed.stream_chunks > 0, "streamed run observed no chunk frames");
        // Buffered Generate and streamed End digest the same token list,
        // so the two runs pin to one transcript hash.
        assert_eq!(streamed.transcript_hash, buffered.transcript_hash);
    }

    #[test]
    fn multi_tenant_run_reports_per_tenant_block() {
        let cfg = LoadgenConfig {
            replicas: 1,
            queue_cap: 64,
            max_requests: 60,
            concurrency: 6,
            max_new: 4,
            tenants: parse_tenant_plan("2:3,1").unwrap(),
            backend: BackendChoice::Synthetic { batch: 4, forward_cost: Duration::ZERO },
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.stats.served + report.stats.rejected, 60);
        assert_eq!(report.stats.tenants.len(), 2);
        let submitted: u64 = report.stats.tenants.iter().map(|t| t.submitted).sum();
        let shed: u64 = report.stats.tenants.iter().map(|t| t.shed).sum();
        assert_eq!(submitted, report.stats.submitted);
        assert_eq!(shed, report.stats.rejected);
        assert!(report.stats.tenants.iter().all(|t| t.submitted > 0), "both tenants saw traffic");
        let j = report.to_json();
        let ten = j.get("tenants").expect("tenants block");
        assert_eq!(ten.get("count").and_then(|x| x.as_f64()), Some(2.0));
        let per = ten.get("per_tenant").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(per.len(), 2);
        for e in per {
            assert!(e.get("queue_wait_ms").and_then(|l| l.get("p95")).is_some());
            assert!(e.get("submitted").and_then(|x| x.as_f64()).unwrap() > 0.0);
        }
        assert_eq!(ten.get("weights").and_then(|w| w.as_arr()).map(|w| w.len()), Some(2));
    }

    #[test]
    fn open_loop_reports_rate_and_resolves_all_tickets() {
        let cfg = LoadgenConfig {
            replicas: 1,
            queue_cap: 8,
            max_requests: 32,
            rate_rps: 4000.0,
            mode: Mode::Score,
            backend: BackendChoice::Synthetic { batch: 4, forward_cost: Duration::ZERO },
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        // Every request reached a terminal outcome: served or shed.
        assert_eq!(report.stats.served + report.stats.rejected, 32);
        assert!(report.stats.served > 0);
    }
}
