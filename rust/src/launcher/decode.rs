//! `nmsparse decode` — drive the native KV-cached decode engine from the
//! command line.
//!
//! Loads the artifacts checkpoint when `--artifacts` points at a real
//! directory, otherwise builds the seeded synthetic model, prefills a
//! deterministic prompt, greedy-decodes, and prints the tokens plus an
//! FNV-64 hash of the output. `--check` additionally replays the same
//! generation through the full-context reference loop and errors on any
//! divergence — the CI smoke in `tools/ci.sh` runs this twice and pins
//! both the in-process KV≡full equivalence and the cross-run hash.
//!
//! `--lanes N` (N > 1) switches to the batched session-stepping path:
//! N concurrent sliding-window sessions driven through a real
//! [`NativeBackend`] (one `StepBatch` per tick, exactly the serving
//! loop), hashing all lanes' outputs. `--no-batch` runs the same N
//! sessions through the sequential sliding reference loop instead — the
//! CI batched-decode smoke pins the two hashes equal.

use crate::coordinator::methods::MethodConfig;
use crate::coordinator::server::{NativeBackend, ReplicaBackend, StepOutcome};
use crate::engine::decode::load_native_parts;
use crate::engine::NativeEngine;
use crate::sparsity::Pattern;
use crate::util::cli::{usage, Args, OptSpec};
use crate::util::prng::Rng;
use crate::util::trace::{self, TraceLevel};
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

pub fn cmd_decode(rest: Vec<String>) -> Result<()> {
    #[rustfmt::skip]
    let specs = vec![
        OptSpec { name: "artifacts", takes_value: true, default: Some("artifacts"), help: "artifacts dir (missing -> synthetic model)" },
        OptSpec { name: "pattern", takes_value: true, default: Some("8:16"), help: "sparsity pattern" },
        OptSpec { name: "method", takes_value: true, default: Some("ACT"), help: "method (ACT, D-PTS, VAR, dense; S-PTS/L-PTS/Amber with artifacts)" },
        OptSpec { name: "seed", takes_value: true, default: Some("7"), help: "synthetic weights + prompt seed" },
        OptSpec { name: "prompt-len", takes_value: true, default: Some("8"), help: "random prompt length" },
        OptSpec { name: "prompt-tokens", takes_value: true, default: Some(""), help: "explicit comma-separated prompt token ids" },
        OptSpec { name: "max-new", takes_value: true, default: Some("16"), help: "tokens to generate" },
        OptSpec { name: "lanes", takes_value: true, default: Some("1"), help: "concurrent sessions (>1 = batched step_batch path)" },
        OptSpec { name: "threads", takes_value: true, default: Some("1"), help: "worker-pool width for site matmuls (0 = auto; never changes bits)" },
        OptSpec { name: "no-batch", takes_value: false, default: None, help: "step --lanes sessions sequentially (sliding reference)" },
        OptSpec { name: "page-tokens", takes_value: true, default: Some("0"), help: "KV page size in positions (0 = engine default)" },
        OptSpec { name: "prefill-block", takes_value: true, default: Some("0"), help: "blocked-prefill block size in positions (0 = per-token oracle; never changes bits)" },
        OptSpec { name: "check", takes_value: false, default: None, help: "verify KV-cached == full-context reference" },
        OptSpec { name: "trace", takes_value: true, default: Some(""), help: "write Chrome trace-event JSON (Perfetto-loadable) to this path" },
        OptSpec { name: "dense-path", takes_value: false, default: None, help: "disable the compressed-domain matvec" },
        OptSpec { name: "help", takes_value: false, default: None, help: "show help" },
    ];
    let a = Args::parse(rest, &specs)?;
    if a.flag("help") {
        print!("{}", usage("decode", "Run the native KV-cached decode engine.", &specs));
        return Ok(());
    }
    let pattern = Pattern::parse(&a.get("pattern"))?;
    let mcfg = MethodConfig::by_name(&a.get("method"), pattern)?;
    let seed = a.get_u64("seed")?;
    let max_new = a.get_usize("max-new")?.max(1);
    let lanes = a.get_usize("lanes")?.max(1);
    let threads = resolve_threads(a.get_usize("threads")?);
    let page_tokens = a.get_usize("page-tokens")?;
    let prefill_block = a.get_usize("prefill-block")?;
    let artifacts = PathBuf::from(a.get("artifacts"));
    let trace_path = a.get("trace");
    if !trace_path.is_empty() {
        // Spans only read the clock and write thread-local state, so the
        // decoded tokens (and the printed hash) are bitwise identical
        // with tracing on or off — `tools/ci.sh` pins exactly that.
        trace::set_level(TraceLevel::Full);
    }

    if lanes > 1 {
        anyhow::ensure!(
            a.get("prompt-tokens").is_empty(),
            "--prompt-tokens drives a single session; use --lanes 1 with it"
        );
        decode_lanes(
            &artifacts,
            pattern,
            &mcfg,
            seed,
            a.get_usize("prompt-len")?.max(1),
            max_new,
            lanes,
            threads,
            page_tokens,
            prefill_block,
            a.flag("no-batch"),
            a.flag("dense-path"),
            a.flag("check"),
        )?;
        return finish_trace(&trace_path);
    }

    let (model, sparsity, origin) = load_native_parts(&artifacts, &mcfg, seed)?;
    let sparsity = sparsity.with_force_dense(a.flag("dense-path"));
    let cfg = model.cfg.clone();
    let mut engine = NativeEngine::new(model, sparsity)?.with_threads(threads);
    let mut pool = if page_tokens > 0 {
        engine.new_kv_pool_with(page_tokens)
    } else {
        engine.new_kv_pool()
    };

    let prompt: Vec<u32> = {
        let explicit = a.get("prompt-tokens");
        if explicit.is_empty() {
            lane_prompt(seed, 0, a.get_usize("prompt-len")?.max(1), cfg.vocab)
        } else {
            explicit
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<u32>()
                        .map_err(|_| anyhow::anyhow!("bad token id '{t}' in --prompt-tokens"))
                })
                .collect::<Result<Vec<u32>>>()?
        }
    };

    println!(
        "decode: {origin} model (vocab {}, d_model {}, {} layers, ffn {}, max_seq {}), \
         pattern {}, method {}, packed={}, threads={}",
        cfg.vocab,
        cfg.d_model,
        cfg.n_layers,
        cfg.ffn,
        cfg.max_seq,
        pattern,
        mcfg.id,
        engine.uses_packed(),
        engine.threads(),
    );

    let mut kv = pool.new_cache();
    let t0 = std::time::Instant::now();
    let out = engine
        .generate_greedy_with_block(&mut kv, &mut pool, &prompt, max_new, &[], prefill_block)?;
    let dt = t0.elapsed().as_secs_f64();
    if a.flag("check") {
        let full = engine.generate_greedy_full(&mut kv, &mut pool, &prompt, max_new, &[])?;
        if out != full {
            bail!(
                "KV-cached decode diverged from the full-context reference:\n  \
                 kv:   {out:?}\n  full: {full:?}"
            );
        }
        println!("check: KV-cached decode == full-context reference ({} tokens)", out.len());
    }
    let stats = engine.stats();
    println!("prompt {prompt:?}\ntokens {out:?}");
    println!(
        "decoded {} tokens in {:.3}s ({:.1} tok/s) | activation bytes: dense-equivalent {} -> \
         moved {} ({:.2}x reduction)",
        out.len(),
        dt,
        out.len() as f64 / dt.max(1e-9),
        stats.dense_activation_bytes,
        stats.moved_activation_bytes,
        stats.bytes_reduction(),
    );
    println!("hash {:016x}", fnv64_lanes(std::slice::from_ref(&out)));
    finish_trace(&trace_path)
}

/// Write the Chrome trace-event export when `--trace` was given, with a
/// one-line per-phase breakdown so the terminal shows where the run's
/// time went without opening Perfetto.
fn finish_trace(path: &str) -> Result<()> {
    if path.is_empty() {
        return Ok(());
    }
    println!("{}", trace::snapshot().summary());
    let n = trace::write_chrome_trace(Path::new(path))?;
    println!("trace: wrote {n} spans to {path}");
    Ok(())
}

/// `--threads 0` means "ask the machine":
/// [`default_threads`](crate::util::threadpool::default_threads) honours
/// the `NMSPARSE_THREADS` override, else `available_parallelism`. Shared
/// by the `decode`, `serve` and `loadgen` launchers so the flag means the
/// same thing everywhere.
pub(crate) fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        crate::util::threadpool::default_threads()
    } else {
        requested
    }
}

/// Deterministic per-lane prompt: a pure function of `(seed, lane)`.
fn lane_prompt(seed: u64, lane: u64, len: usize, vocab: usize) -> Vec<u32> {
    let mut rng = Rng::new(seed ^ 0x9e37_79b9 ^ lane.wrapping_mul(0x1000_0000_01b3));
    (0..len).map(|_| rng.range(3, vocab.min(128)) as u32).collect()
}

/// Sequential sliding reference: one session at a time through
/// [`NativeEngine::generate_greedy_sliding`].
fn lanes_sequential(
    mut engine: NativeEngine,
    prompts: &[Vec<u32>],
    max_new: usize,
    page_tokens: usize,
) -> Result<Vec<Vec<u32>>> {
    let mut pool = if page_tokens > 0 {
        engine.new_kv_pool_with(page_tokens)
    } else {
        engine.new_kv_pool()
    };
    let mut kv = pool.new_cache();
    prompts
        .iter()
        .map(|p| engine.generate_greedy_sliding(&mut kv, &mut pool, p, max_new, &[]))
        .collect()
}

/// The serving loop: every tick is one batched step across all live
/// sessions through a real [`NativeBackend`].
fn lanes_batched(
    engine: NativeEngine,
    prompts: &[Vec<u32>],
    max_new: usize,
    page_tokens: usize,
    prefill_block: usize,
) -> Result<Vec<Vec<u32>>> {
    let lanes = prompts.len();
    let mut backend = NativeBackend::from_engine(engine, vec![], lanes);
    if page_tokens > 0 {
        backend = backend.with_page_tokens(page_tokens);
    }
    if prefill_block > 0 {
        backend = backend.with_prefill_block(prefill_block);
    }
    let mut rows = prompts.to_vec();
    let mut outs: Vec<Vec<u32>> = vec![Vec::new(); lanes];
    let mut done = vec![false; lanes];
    loop {
        let live: Vec<(u64, &[u32])> = (0..lanes)
            .filter(|i| !done[*i])
            .map(|i| (i as u64 + 1, rows[i].as_slice()))
            .collect();
        if live.is_empty() {
            break;
        }
        let ids: Vec<usize> = (0..lanes).filter(|i| !done[*i]).collect();
        let step = backend.decode_step_sessions(&live)?;
        for (i, out) in ids.into_iter().zip(step) {
            match out {
                StepOutcome::Token(tok) => {
                    outs[i].push(tok);
                    rows[i].push(tok);
                    if outs[i].len() >= max_new {
                        done[i] = true;
                        backend.end_session(i as u64 + 1);
                    }
                }
                // Mid-prefill: the scheduler (here, this loop) re-ticks
                // the unchanged row next iteration.
                StepOutcome::Pending => {}
                StepOutcome::End => {
                    done[i] = true;
                    backend.end_session(i as u64 + 1);
                }
            }
        }
    }
    Ok(outs)
}

/// The batched-decode smoke: `lanes` concurrent sliding-window sessions,
/// either through a real [`NativeBackend`] (one `StepBatch` per tick —
/// the serving loop) or, with `no_batch`, through the sequential sliding
/// reference. Both print the same per-lane tokens and one hash over all
/// lanes; `tools/ci.sh` pins the two hashes equal across invocations,
/// and `--check` pins them equal in-process (batched ≡ sequential).
#[allow(clippy::too_many_arguments)]
fn decode_lanes(
    artifacts: &Path,
    pattern: Pattern,
    mcfg: &MethodConfig,
    seed: u64,
    prompt_len: usize,
    max_new: usize,
    lanes: usize,
    threads: usize,
    page_tokens: usize,
    prefill_block: usize,
    no_batch: bool,
    dense_path: bool,
    check: bool,
) -> Result<()> {
    let (model, sparsity, origin) = load_native_parts(artifacts, mcfg, seed)?;
    let sparsity = sparsity.with_force_dense(dense_path);
    let cfg = model.cfg.clone();
    let prompts: Vec<Vec<u32>> =
        (0..lanes as u64).map(|l| lane_prompt(seed, l, prompt_len, cfg.vocab)).collect();
    let mode = if no_batch { "sequential" } else { "batched" };
    println!(
        "decode: {origin} model, pattern {pattern}, method {}, {lanes} lanes ({mode}), \
         max_new {max_new}, threads {threads}",
        mcfg.id,
    );

    // With --check, run the OTHER path too (on a same-weights engine,
    // same worker-pool width) and pin token identity in-process.
    let other: Option<Vec<Vec<u32>>> = if check {
        let twin = NativeEngine::new(model.clone(), sparsity.clone())?.with_threads(threads);
        Some(if no_batch {
            lanes_batched(twin, &prompts, max_new, page_tokens, prefill_block)?
        } else {
            lanes_sequential(twin, &prompts, max_new, page_tokens)?
        })
    } else {
        None
    };
    let t0 = std::time::Instant::now();
    let engine = NativeEngine::new(model, sparsity)?.with_threads(threads);
    let outs: Vec<Vec<u32>> = if no_batch {
        lanes_sequential(engine, &prompts, max_new, page_tokens)?
    } else {
        lanes_batched(engine, &prompts, max_new, page_tokens, prefill_block)?
    };
    if let Some(other) = other {
        if other != outs {
            bail!(
                "batched and sequential sliding decode diverged:\n  {mode}: {outs:?}\n  \
                 other: {other:?}"
            );
        }
        println!("check: batched == sequential sliding decode ({lanes} lanes)");
    }
    let dt = t0.elapsed().as_secs_f64();
    let total: usize = outs.iter().map(|o| o.len()).sum();
    for (i, (p, o)) in prompts.iter().zip(&outs).enumerate() {
        println!("lane {i}: prompt {p:?} -> tokens {o:?}");
    }
    println!(
        "decoded {total} tokens across {lanes} lanes in {:.3}s ({:.1} tok/s, {mode})",
        dt,
        total as f64 / dt.max(1e-9),
    );
    println!("hash {:016x}", fnv64_lanes(&outs));
    Ok(())
}

/// FNV-1a over all lanes' token streams (LE bytes, `0xffff_ffff` lane
/// separators) — the determinism pin the CI smokes compare across runs
/// and across the batched/sequential paths.
fn fnv64_lanes(lanes: &[Vec<u32>]) -> u64 {
    let mut bytes: Vec<u8> = Vec::new();
    for tokens in lanes {
        for t in tokens {
            bytes.extend_from_slice(&t.to_le_bytes());
        }
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
    }
    crate::util::prng::fnv1a64(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_order_and_lane_sensitive() {
        assert_ne!(fnv64_lanes(&[vec![1, 2, 3]]), fnv64_lanes(&[vec![3, 2, 1]]));
        assert_eq!(fnv64_lanes(&[vec![1, 2, 3]]), fnv64_lanes(&[vec![1, 2, 3]]));
        assert_ne!(fnv64_lanes(&[]), fnv64_lanes(&[vec![]]));
        // Lane boundaries matter: [1,2]+[3] != [1]+[2,3].
        assert_ne!(
            fnv64_lanes(&[vec![1, 2], vec![3]]),
            fnv64_lanes(&[vec![1], vec![2, 3]])
        );
    }

    #[test]
    fn decode_smoke_runs_synthetic() {
        // No artifacts dir -> synthetic model; --check pins kv == full.
        let args: Vec<String> = [
            "--artifacts", "/definitely/not/here",
            "--seed", "3",
            "--prompt-len", "4",
            "--max-new", "6",
            "--check",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        cmd_decode(args).unwrap();
    }

    #[test]
    fn batched_and_sequential_lanes_agree() {
        // The CI smoke's property, in-process: --check makes decode_lanes
        // run BOTH the batched backend loop and the sequential sliding
        // loops and bail on any divergence.
        let base: Vec<String> = [
            "--artifacts", "/definitely/not/here",
            "--seed", "11",
            "--prompt-len", "5",
            "--max-new", "8",
            "--lanes", "3",
            "--page-tokens", "8",
            "--check",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        cmd_decode(base.clone()).unwrap();
        let mut seq = base;
        seq.push("--no-batch".into());
        cmd_decode(seq).unwrap();
    }

    #[test]
    fn threaded_decode_smoke_passes_check() {
        // --threads only changes wall time, never bits: --check still pins
        // batched == sequential with a 3-wide worker pool on both sides.
        let args: Vec<String> = [
            "--artifacts", "/definitely/not/here",
            "--seed", "5",
            "--prompt-len", "5",
            "--max-new", "6",
            "--lanes", "3",
            "--threads", "3",
            "--page-tokens", "8",
            "--check",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        cmd_decode(args).unwrap();
    }
}
