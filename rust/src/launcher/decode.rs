//! `nmsparse decode` — drive the native KV-cached decode engine from the
//! command line.
//!
//! Loads the artifacts checkpoint when `--artifacts` points at a real
//! directory, otherwise builds the seeded synthetic model, prefills a
//! deterministic prompt, greedy-decodes, and prints the tokens plus an
//! FNV-64 hash of the output. `--check` additionally replays the same
//! generation through the full-context reference loop and errors on any
//! divergence — the CI smoke in `tools/ci.sh` runs this twice and pins
//! both the in-process KV≡full equivalence and the cross-run hash.

use crate::coordinator::methods::MethodConfig;
use crate::engine::{EngineConfig, NativeEngine, NativeModel, NativeSparsity};
use crate::runtime::Manifest;
use crate::sparsity::Pattern;
use crate::util::cli::{usage, Args, OptSpec};
use crate::util::prng::Rng;
use crate::util::tensor::TensorStore;
use anyhow::{bail, Result};
use std::path::PathBuf;

pub fn cmd_decode(rest: Vec<String>) -> Result<()> {
    #[rustfmt::skip]
    let specs = vec![
        OptSpec { name: "artifacts", takes_value: true, default: Some("artifacts"), help: "artifacts dir (missing -> synthetic model)" },
        OptSpec { name: "pattern", takes_value: true, default: Some("8:16"), help: "sparsity pattern" },
        OptSpec { name: "method", takes_value: true, default: Some("ACT"), help: "method (ACT, D-PTS, VAR, dense)" },
        OptSpec { name: "seed", takes_value: true, default: Some("7"), help: "synthetic weights + prompt seed" },
        OptSpec { name: "prompt-len", takes_value: true, default: Some("8"), help: "random prompt length" },
        OptSpec { name: "prompt-tokens", takes_value: true, default: Some(""), help: "explicit comma-separated prompt token ids" },
        OptSpec { name: "max-new", takes_value: true, default: Some("16"), help: "tokens to generate" },
        OptSpec { name: "check", takes_value: false, default: None, help: "verify KV-cached == full-context reference" },
        OptSpec { name: "dense-path", takes_value: false, default: None, help: "disable the compressed-domain matvec" },
        OptSpec { name: "help", takes_value: false, default: None, help: "show help" },
    ];
    let a = Args::parse(rest, &specs)?;
    if a.flag("help") {
        print!("{}", usage("decode", "Run the native KV-cached decode engine.", &specs));
        return Ok(());
    }
    let pattern = Pattern::parse(&a.get("pattern"))?;
    let mcfg = MethodConfig::by_name(&a.get("method"), pattern)?;
    let sparsity =
        NativeSparsity::from_method(&mcfg)?.with_force_dense(a.flag("dense-path"));
    let seed = a.get_u64("seed")?;
    let max_new = a.get_usize("max-new")?.max(1);

    let artifacts = PathBuf::from(a.get("artifacts"));
    let (model, origin) = if artifacts.join("io_manifest.json").exists() {
        let manifest = Manifest::load(&artifacts)?;
        let weights = mcfg.transformed_weights(&TensorStore::load(&artifacts.join("ckpt"))?)?;
        let cfg = EngineConfig::from_dims(&manifest.dims);
        (NativeModel::from_store(&weights, &cfg)?, "artifacts")
    } else {
        (NativeModel::synthetic(&EngineConfig::tiny(), seed), "synthetic")
    };
    let cfg = model.cfg.clone();
    let mut engine = NativeEngine::new(model, sparsity)?;

    let prompt: Vec<u32> = {
        let explicit = a.get("prompt-tokens");
        if explicit.is_empty() {
            let mut rng = Rng::new(seed ^ 0x9e37_79b9);
            let len = a.get_usize("prompt-len")?.max(1);
            (0..len).map(|_| rng.range(3, cfg.vocab.min(128)) as u32).collect()
        } else {
            explicit
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<u32>()
                        .map_err(|_| anyhow::anyhow!("bad token id '{t}' in --prompt-tokens"))
                })
                .collect::<Result<Vec<u32>>>()?
        }
    };

    println!(
        "decode: {origin} model (vocab {}, d_model {}, {} layers, ffn {}, max_seq {}), \
         pattern {}, method {}, packed={}",
        cfg.vocab,
        cfg.d_model,
        cfg.n_layers,
        cfg.ffn,
        cfg.max_seq,
        pattern,
        mcfg.id,
        engine.uses_packed(),
    );

    let mut kv = engine.new_cache();
    let t0 = std::time::Instant::now();
    let out = engine.generate_greedy(&mut kv, &prompt, max_new, &[])?;
    let dt = t0.elapsed().as_secs_f64();
    if a.flag("check") {
        let full = engine.generate_greedy_full(&mut kv, &prompt, max_new, &[])?;
        if out != full {
            bail!(
                "KV-cached decode diverged from the full-context reference:\n  \
                 kv:   {out:?}\n  full: {full:?}"
            );
        }
        println!("check: KV-cached decode == full-context reference ({} tokens)", out.len());
    }
    let stats = engine.stats();
    println!("prompt {prompt:?}\ntokens {out:?}");
    println!(
        "decoded {} tokens in {:.3}s ({:.1} tok/s) | activation bytes: dense-equivalent {} -> \
         moved {} ({:.2}x reduction)",
        out.len(),
        dt,
        out.len() as f64 / dt.max(1e-9),
        stats.dense_activation_bytes,
        stats.moved_activation_bytes,
        stats.bytes_reduction(),
    );
    println!("hash {:016x}", fnv64(&out));
    Ok(())
}

/// FNV-1a over the generated token stream (LE bytes) — the determinism
/// pin the CI smoke compares across runs.
fn fnv64(tokens: &[u32]) -> u64 {
    let bytes: Vec<u8> = tokens.iter().flat_map(|t| t.to_le_bytes()).collect();
    crate::util::prng::fnv1a64(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_order_sensitive() {
        assert_ne!(fnv64(&[1, 2, 3]), fnv64(&[3, 2, 1]));
        assert_eq!(fnv64(&[1, 2, 3]), fnv64(&[1, 2, 3]));
        assert_ne!(fnv64(&[]), fnv64(&[0]));
    }

    #[test]
    fn decode_smoke_runs_synthetic() {
        // No artifacts dir -> synthetic model; --check pins kv == full.
        let args: Vec<String> = [
            "--artifacts", "/definitely/not/here",
            "--seed", "3",
            "--prompt-len", "4",
            "--max-new", "6",
            "--check",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        cmd_decode(args).unwrap();
    }
}
