//! Native decode-engine weights + configuration.
//!
//! [`NativeModel`] is the rust-side twin of `python/compile/model.py`: the
//! same Llama-style architecture (RMSNorm → q/k/v/o attention with RoPE →
//! RMSNorm → SwiGLU gate/up/down), the same seven sparsifiable linear
//! sites, and the same checkpoint tensor names (`embed.w`, `lm_head.w`,
//! `final_norm.g`, `layers.{l}.{site}.w`, `layers.{l}.norm{1,2}.g`), so a
//! checkpoint written by `aot.py` loads directly via
//! [`NativeModel::from_store`]. When no artifacts exist (CI, benches,
//! tests), [`NativeModel::synthetic`] builds a seeded deterministic model
//! with the python `init_params` shape rules — every weight is a pure
//! function of `(seed, tensor name)`, so two processes agree bit-for-bit.

use crate::runtime::ModelDims;
use crate::util::prng::Rng;
use crate::util::tensor::{Tensor, TensorStore};
use anyhow::Result;

/// The seven sparsifiable linear sites, in the canonical order shared with
/// `python/compile/model.py` (`SITES`) and the AOT manifest.
pub const SITES: [&str; 7] = ["q", "k", "v", "o", "gate", "up", "down"];

/// Static dimensions of a native engine model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EngineConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn: usize,
    /// KV-cache capacity: the longest context a session may reach.
    pub max_seq: usize,
}

impl EngineConfig {
    /// CI-sized synthetic default: big enough that packed 8:16/16:32
    /// matvecs are real work, small enough that tests and the loadgen
    /// smoke stay fast. All widths are multiples of 32 so every paper
    /// N:M pattern divides every site.
    pub fn tiny() -> EngineConfig {
        EngineConfig {
            vocab: 160,
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            ffn: 128,
            max_seq: 64,
        }
    }

    /// Adopt the dimensions recorded in an artifacts manifest (the KV
    /// capacity is the artifact's eval sequence length).
    pub fn from_dims(d: &ModelDims) -> EngineConfig {
        EngineConfig {
            vocab: d.vocab,
            d_model: d.d_model,
            n_layers: d.n_layers,
            n_heads: d.n_heads,
            ffn: d.ffn,
            max_seq: d.seq,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Input width of a linear site — what gets sparsified.
    pub fn site_in_dim(&self, site: &str) -> usize {
        if site == "down" {
            self.ffn
        } else {
            self.d_model
        }
    }

    pub fn site_out_dim(&self, site: &str) -> usize {
        if site == "gate" || site == "up" {
            self.ffn
        } else {
            self.d_model
        }
    }

    /// Total parameter count (embedding + head + norms + site weights).
    pub fn num_params(&self) -> usize {
        let sites: usize = SITES
            .iter()
            .map(|s| self.site_in_dim(s) * self.site_out_dim(s))
            .sum();
        2 * self.vocab * self.d_model            // embed.w + lm_head.w
            + self.d_model * (2 * self.n_layers + 1) // norms
            + sites * self.n_layers
    }
}

/// One transformer layer's weights. Linear weights are `[out, in]`
/// row-major — `y[o] = w.row(o) · x`, the layout `matmul_nt_into` and the
/// python `h2d @ w.T` both assume.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub norm1: Vec<f32>,
    pub norm2: Vec<f32>,
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub wgate: Tensor,
    pub wup: Tensor,
    pub wdown: Tensor,
}

impl LayerWeights {
    /// Weight matrix of a named site.
    pub fn site(&self, site: &str) -> &Tensor {
        match site {
            "q" => &self.wq,
            "k" => &self.wk,
            "v" => &self.wv,
            "o" => &self.wo,
            "gate" => &self.wgate,
            "up" => &self.wup,
            "down" => &self.wdown,
            other => panic!("unknown site '{other}'"),
        }
    }
}

/// Full weights of the native engine.
#[derive(Clone, Debug)]
pub struct NativeModel {
    pub cfg: EngineConfig,
    /// `[vocab, d_model]` token embedding (dense — never sparsified).
    pub embed: Tensor,
    /// `[vocab, d_model]` untied output head (dense).
    pub lm_head: Tensor,
    pub final_norm: Vec<f32>,
    pub layers: Vec<LayerWeights>,
}

impl NativeModel {
    /// Seeded deterministic synthetic model: scaled-normal site weights
    /// (`N(0,1)/sqrt(fan_in)`, python's `init_params` rule), all norms 1.
    /// Each tensor's stream is `Rng::new(seed ^ fnv1a64(name))` — a pure
    /// function of `(seed, name)`, never of construction order.
    pub fn synthetic(cfg: &EngineConfig, seed: u64) -> NativeModel {
        let stream = |name: &str| Rng::new(seed ^ crate::util::prng::fnv1a64(name.as_bytes()));
        let normal = |name: &str, rows: usize, cols: usize| -> Tensor {
            let mut rng = stream(name);
            let scale = 1.0 / (cols as f64).sqrt();
            Tensor::from_vec(
                &[rows, cols],
                (0..rows * cols)
                    .map(|_| (rng.normal() * scale) as f32)
                    .collect(),
            )
        };
        let embed = normal("embed.w", cfg.vocab, cfg.d_model);
        let lm_head = normal("lm_head.w", cfg.vocab, cfg.d_model);
        let layers = (0..cfg.n_layers)
            .map(|l| {
                let w = |s: &str| {
                    normal(
                        &format!("layers.{l}.{s}.w"),
                        cfg.site_out_dim(s),
                        cfg.site_in_dim(s),
                    )
                };
                LayerWeights {
                    norm1: vec![1.0; cfg.d_model],
                    norm2: vec![1.0; cfg.d_model],
                    wq: w("q"),
                    wk: w("k"),
                    wv: w("v"),
                    wo: w("o"),
                    wgate: w("gate"),
                    wup: w("up"),
                    wdown: w("down"),
                }
            })
            .collect();
        NativeModel {
            cfg: cfg.clone(),
            embed,
            lm_head,
            final_norm: vec![1.0; cfg.d_model],
            layers,
        }
    }

    /// Load from a checkpoint store (`aot.py` / [`TensorStore::save`]
    /// naming). Shapes are validated against `cfg`.
    pub fn from_store(store: &TensorStore, cfg: &EngineConfig) -> Result<NativeModel> {
        let matrix = |name: &str, rows: usize, cols: usize| -> Result<Tensor> {
            let t = store.get(name)?;
            anyhow::ensure!(
                t.shape == [rows, cols],
                "tensor '{name}': checkpoint shape {:?}, engine config wants [{rows}, {cols}]",
                t.shape
            );
            Ok(t.clone())
        };
        let gain = |name: &str| -> Result<Vec<f32>> {
            let t = store.get(name)?;
            anyhow::ensure!(
                t.shape == [cfg.d_model],
                "tensor '{name}': checkpoint shape {:?}, engine config wants [{}]",
                t.shape,
                cfg.d_model
            );
            Ok(t.data.clone())
        };
        let embed = matrix("embed.w", cfg.vocab, cfg.d_model)?;
        let lm_head = matrix("lm_head.w", cfg.vocab, cfg.d_model)?;
        let final_norm = gain("final_norm.g")?;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let w = |s: &str| -> Result<Tensor> {
                matrix(
                    &format!("layers.{l}.{s}.w"),
                    cfg.site_out_dim(s),
                    cfg.site_in_dim(s),
                )
            };
            layers.push(LayerWeights {
                norm1: gain(&format!("layers.{l}.norm1.g"))?,
                norm2: gain(&format!("layers.{l}.norm2.g"))?,
                wq: w("q")?,
                wk: w("k")?,
                wv: w("v")?,
                wo: w("o")?,
                wgate: w("gate")?,
                wup: w("up")?,
                wdown: w("down")?,
            });
        }
        Ok(NativeModel {
            cfg: cfg.clone(),
            embed,
            lm_head,
            final_norm,
            layers,
        })
    }

    /// Serialize back to the `aot.py` naming — the round-trip oracle for
    /// [`NativeModel::from_store`], also used by tests to fabricate a
    /// loadable artifacts directory without python.
    pub fn to_store(&self) -> TensorStore {
        let cfg = &self.cfg;
        let mut s = TensorStore::new();
        s.insert("embed.w", self.embed.clone());
        s.insert("lm_head.w", self.lm_head.clone());
        s.insert(
            "final_norm.g",
            Tensor::from_vec(&[cfg.d_model], self.final_norm.clone()),
        );
        for (l, layer) in self.layers.iter().enumerate() {
            for site in SITES {
                s.insert(&format!("layers.{l}.{site}.w"), layer.site(site).clone());
            }
            s.insert(
                &format!("layers.{l}.norm1.g"),
                Tensor::from_vec(&[cfg.d_model], layer.norm1.clone()),
            );
            s.insert(
                &format!("layers.{l}.norm2.g"),
                Tensor::from_vec(&[cfg.d_model], layer.norm2.clone()),
            );
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_dims_follow_python_rules() {
        let cfg = EngineConfig::tiny();
        assert_eq!(cfg.head_dim(), 32);
        assert_eq!(cfg.site_in_dim("down"), cfg.ffn);
        assert_eq!(cfg.site_in_dim("q"), cfg.d_model);
        assert_eq!(cfg.site_out_dim("gate"), cfg.ffn);
        assert_eq!(cfg.site_out_dim("o"), cfg.d_model);
        // num_params matches a hand count for the tiny config:
        // 2*160*64 + 64*(2*2+1) + 2*(4*64*64 + 2*128*64 + 128*64).
        let sites_per_layer = 4 * 64 * 64 + 2 * 128 * 64 + 128 * 64;
        assert_eq!(cfg.num_params(), 2 * 160 * 64 + 64 * 5 + 2 * sites_per_layer);
    }

    #[test]
    fn synthetic_is_deterministic_and_order_free() {
        let cfg = EngineConfig::tiny();
        let a = NativeModel::synthetic(&cfg, 7);
        let b = NativeModel::synthetic(&cfg, 7);
        assert_eq!(a.embed.data, b.embed.data);
        assert_eq!(a.layers[1].wdown.data, b.layers[1].wdown.data);
        let c = NativeModel::synthetic(&cfg, 8);
        assert_ne!(a.embed.data, c.embed.data);
        // Scaled init keeps values small.
        assert!(a.embed.data.iter().all(|v| v.abs() < 2.0));
    }

    #[test]
    fn store_roundtrip_preserves_weights() {
        let cfg = EngineConfig {
            vocab: 32,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            ffn: 32,
            max_seq: 16,
        };
        let m = NativeModel::synthetic(&cfg, 3);
        let store = m.to_store();
        assert_eq!(store.num_params(), cfg.num_params());
        let back = NativeModel::from_store(&store, &cfg).unwrap();
        assert_eq!(back.embed.data, m.embed.data);
        assert_eq!(back.lm_head.data, m.lm_head.data);
        assert_eq!(back.final_norm, m.final_norm);
        for l in 0..cfg.n_layers {
            for site in SITES {
                assert_eq!(
                    back.layers[l].site(site).data,
                    m.layers[l].site(site).data,
                    "layer {l} site {site}"
                );
            }
        }
        // Wrong dims are a shape error, not silent misload.
        let mut bad = cfg.clone();
        bad.d_model = 16;
        bad.n_heads = 1;
        assert!(NativeModel::from_store(&store, &bad).is_err());
    }
}
