//! Batched session stepping: [`StepBatch`] + [`NativeEngine::step_batch`].
//!
//! PR 4's engine stepped one session per call, so serving K concurrent
//! decodes ran each of the seven sparsified sites K times as independent
//! matvecs — the compressed-domain kernels never amortized across
//! sessions. `step_batch` is the multiplexed form (DESIGN.md §2.10): a
//! [`StepBatch`] is a reusable plan of `{session, token}` lanes (the KV
//! handle rides in the [`SessionKvPool`] keyed by the session id), and
//! one call advances every lane by one token, running each site as **one
//! packed multi-row matmul** across all lanes
//! ([`PackedNM::matmul_nt_into`](crate::sparsity::PackedNM) over a
//! lanes-row stream) and the lm head as one multi-row dense matmul. A
//! weight row is streamed once per step instead of once per lane — the
//! batched-vs-sequential tok/s rows in `BENCH_decode.json` measure
//! exactly that amortization. Every one of those matmuls (and the
//! per-lane pack/sparsify fan-out feeding them) runs on the engine's
//! persistent [`WorkerPool`](crate::util::threadpool::WorkerPool),
//! partitioned by weight-row ranges — each output row is one whole dot
//! computed by one worker, so `--threads` changes wall time, never bits
//! (DESIGN.md §2.11; the threads×lanes grid in `BENCH_decode.json`
//! measures the scaling).
//!
//! **Token identity is structural**: per lane, the batched step performs
//! the same operations in the same order as [`NativeEngine::step`] —
//! packing a lane's row is the same single-row selection pass, every
//! matmul output is the same ascending-column dot, and attention reads
//! the lane's own cache — so `step_batch` over K sessions is bitwise
//! logits-identical to K sequential `step` loops at any lane count,
//! ragged lane lengths included (`rust/tests/step_batch.rs` pins it).
//!
//! Contract: every lane's session must already be resident in the
//! [`SessionKvPool`] (callers chunk batches to the pool's `cap`, so a
//! mid-batch LRU eviction can never rob a live lane), session ids must
//! be unique within a batch, and no lane's cache may be full — sliding
//! full sessions is the serving layer's job
//! (`NativeBackend::decode_step_sessions`).

use crate::engine::decode::{
    add_assign, apply_site_batch, argmax, attention_paged, dense_matmul_nt, pick, rmsnorm_into,
    rope_in_place, silu, NativeEngine,
};
use crate::engine::kv::{KvPagePool, SessionKvPool};
use crate::util::trace::{self, Phase};
use anyhow::{Context, Result};

/// One lane of a batched step: which session advances, and by which
/// token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Lane {
    pub session: u64,
    pub token: u32,
}

/// A reusable batched-step plan: push one lane per live session each
/// tick, step, read per-lane logits, clear, repeat. All per-lane scratch
/// (lane-major `[lanes × width]` working buffers, per-lane logits) lives
/// here and is retained across ticks, so steady-state batched decode
/// allocates nothing once the peak lane count has been seen.
#[derive(Debug, Default)]
pub struct StepBatch {
    lanes: Vec<Lane>,
    /// Logit width (set by the last step; 0 before any step).
    vocab: usize,
    // Lane-major working buffers, `[lanes × d_model]`…
    x: Vec<f32>,
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctx: Vec<f32>,
    out_d: Vec<f32>,
    // …`[lanes × ffn]`…
    gate: Vec<f32>,
    up: Vec<f32>,
    fbuf: Vec<f32>,
    // …and `[lanes × vocab]` next-token logits.
    logits: Vec<f32>,
    probs: Vec<f32>,
}

impl StepBatch {
    pub fn new() -> StepBatch {
        StepBatch::default()
    }

    /// Drop all lanes, keeping buffers for reuse.
    pub fn clear(&mut self) {
        self.lanes.clear();
    }

    /// Add a lane: advance `session` by `token` on the next step.
    pub fn push(&mut self, session: u64, token: u32) {
        self.lanes.push(Lane { session, token });
    }

    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    pub fn lanes(&self) -> &[Lane] {
        &self.lanes
    }

    /// Next-token logits of `lane` after the last
    /// [`NativeEngine::step_batch`].
    pub fn logits(&self, lane: usize) -> &[f32] {
        &self.logits[lane * self.vocab..(lane + 1) * self.vocab]
    }

    /// Greedy token of `lane` (first index on ties — the same rule as
    /// [`NativeEngine::argmax_token`]).
    pub fn argmax(&self, lane: usize) -> u32 {
        argmax(self.logits(lane))
    }

    fn resize(&mut self, d_model: usize, ffn: usize, vocab: usize) {
        let n = self.lanes.len();
        self.vocab = vocab;
        for buf in [
            &mut self.x,
            &mut self.h,
            &mut self.q,
            &mut self.k,
            &mut self.v,
            &mut self.ctx,
            &mut self.out_d,
        ] {
            buf.resize(n * d_model, 0.0);
        }
        for buf in [&mut self.gate, &mut self.up, &mut self.fbuf] {
            buf.resize(n * ffn, 0.0);
        }
        self.logits.resize(n * vocab, 0.0);
    }
}

impl NativeEngine {
    /// Advance every lane of `batch` by one token — the batched,
    /// session-multiplexed form of [`NativeEngine::step`]. Each of the
    /// seven sparsified sites runs as one packed multi-row matmul across
    /// all lanes; per-lane next-token logits land in the batch
    /// ([`StepBatch::logits`] / [`StepBatch::argmax`]). A no-op on an
    /// empty batch. Errors (before touching any cache) on a duplicate
    /// session id, an out-of-vocabulary token, a lane whose session is
    /// not resident in `sessions`, or a full lane cache.
    pub fn step_batch(
        &mut self,
        batch: &mut StepBatch,
        sessions: &mut SessionKvPool,
        pool: &mut KvPagePool,
    ) -> Result<()> {
        let n = batch.lanes.len();
        if n == 0 {
            return Ok(());
        }
        let cfg = self.model.cfg.clone();
        let (d, ffn) = (cfg.d_model, cfg.ffn);
        for (i, lane) in batch.lanes.iter().enumerate() {
            anyhow::ensure!(
                (lane.token as usize) < cfg.vocab,
                "lane {i}: token {} out of vocabulary ({})",
                lane.token,
                cfg.vocab
            );
            anyhow::ensure!(
                batch.lanes[..i].iter().all(|prev| prev.session != lane.session),
                "lane {i}: session {} appears twice in one StepBatch",
                lane.session
            );
            let slot = sessions.get_mut(lane.session).with_context(|| {
                format!(
                    "lane {i}: session {} not resident in the SessionKvPool — \
                     reserve caches (chunked to the pool cap) before stepping",
                    lane.session
                )
            })?;
            anyhow::ensure!(
                !slot.kv.is_full(),
                "lane {i}: KV cache full: context length {} reached",
                slot.kv.capacity()
            );
        }
        batch.resize(d, ffn, cfg.vocab);
        let StepBatch { lanes, x, h, q, k, v, ctx, out_d, gate, up, fbuf, logits, probs, .. } =
            batch;

        for (i, lane) in lanes.iter().enumerate() {
            x[i * d..(i + 1) * d].copy_from_slice(self.model.embed.row(lane.token as usize));
        }
        for l in 0..cfg.n_layers {
            let layer = &self.model.layers[l];
            // Attention block: batched q/k/v sites, per-lane rope +
            // cache write + attention over the lane's own pages.
            for i in 0..n {
                rmsnorm_into(&x[i * d..(i + 1) * d], &layer.norm1, &mut h[i * d..(i + 1) * d]);
            }
            let s0 = site_sp(&self.sparsity, &self.enabled, l, 0);
            let p0 = pick(s0, self.packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteQ, self.stats.steps);
            apply_site_batch(
                &layer.wq,
                h,
                n,
                s0,
                p0,
                &mut self.act,
                q,
                &mut self.stats,
                &self.workers,
            );
            drop(sg);
            let s1 = site_sp(&self.sparsity, &self.enabled, l, 1);
            let p1 = pick(s1, self.packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteK, self.stats.steps);
            apply_site_batch(
                &layer.wk,
                h,
                n,
                s1,
                p1,
                &mut self.act,
                k,
                &mut self.stats,
                &self.workers,
            );
            drop(sg);
            let s2 = site_sp(&self.sparsity, &self.enabled, l, 2);
            let p2 = pick(s2, self.packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteV, self.stats.steps);
            apply_site_batch(
                &layer.wv,
                h,
                n,
                s2,
                p2,
                &mut self.act,
                v,
                &mut self.stats,
                &self.workers,
            );
            drop(sg);
            let sg = trace::span_id(Phase::Attention, self.stats.steps);
            for (i, lane) in lanes.iter().enumerate() {
                let slot = sessions.get_mut(lane.session).expect("validated resident");
                let pos = slot.kv.len();
                let (hd, nh) = (cfg.head_dim(), cfg.n_heads);
                rope_in_place(&mut q[i * d..(i + 1) * d], nh, hd, pos, &self.rope_freqs);
                rope_in_place(&mut k[i * d..(i + 1) * d], nh, hd, pos, &self.rope_freqs);
                slot.kv.write_row(pool, l, &k[i * d..(i + 1) * d], &v[i * d..(i + 1) * d]);
                attention_paged(
                    &q[i * d..(i + 1) * d],
                    &slot.kv,
                    l,
                    pos + 1,
                    nh,
                    hd,
                    probs,
                    &mut ctx[i * d..(i + 1) * d],
                );
            }
            drop(sg);
            let s3 = site_sp(&self.sparsity, &self.enabled, l, 3);
            let p3 = pick(s3, self.packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteO, self.stats.steps);
            apply_site_batch(
                &layer.wo,
                ctx,
                n,
                s3,
                p3,
                &mut self.act,
                out_d,
                &mut self.stats,
                &self.workers,
            );
            drop(sg);
            add_assign(x, out_d);

            // FFN block (SwiGLU): batched gate/up/down sites.
            for i in 0..n {
                rmsnorm_into(&x[i * d..(i + 1) * d], &layer.norm2, &mut h[i * d..(i + 1) * d]);
            }
            let s4 = site_sp(&self.sparsity, &self.enabled, l, 4);
            let p4 = pick(s4, self.packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteGate, self.stats.steps);
            apply_site_batch(
                &layer.wgate,
                h,
                n,
                s4,
                p4,
                &mut self.act,
                gate,
                &mut self.stats,
                &self.workers,
            );
            drop(sg);
            let s5 = site_sp(&self.sparsity, &self.enabled, l, 5);
            let p5 = pick(s5, self.packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteUp, self.stats.steps);
            apply_site_batch(
                &layer.wup,
                h,
                n,
                s5,
                p5,
                &mut self.act,
                up,
                &mut self.stats,
                &self.workers,
            );
            drop(sg);
            for ((f, g), u) in fbuf.iter_mut().zip(gate.iter()).zip(up.iter()) {
                *f = silu(*g) * u;
            }
            let s6 = site_sp(&self.sparsity, &self.enabled, l, 6);
            let p6 = pick(s6, self.packed_f.as_mut());
            let sg = trace::span_id(Phase::SiteDown, self.stats.steps);
            apply_site_batch(
                &layer.wdown,
                fbuf,
                n,
                s6,
                p6,
                &mut self.act,
                out_d,
                &mut self.stats,
                &self.workers,
            );
            drop(sg);
            add_assign(x, out_d);
        }
        for lane in lanes.iter() {
            sessions.get_mut(lane.session).expect("validated resident").kv.advance();
        }
        for i in 0..n {
            let hx = &mut h[i * d..(i + 1) * d];
            rmsnorm_into(&x[i * d..(i + 1) * d], &self.model.final_norm, hx);
        }
        let sg = trace::span_id(Phase::LmHead, self.stats.steps);
        dense_matmul_nt(&self.model.lm_head, h, n, logits, &self.workers);
        drop(sg);
        self.stats.steps += n as u64;
        Ok(())
    }
}

/// The pipeline applied at `(layer, site)` for a batched step — `None`
/// when the site is disabled or the engine is dense. Takes the fields
/// (not the engine) so the packed streams stay independently borrowable
/// (shared with the blocked-prefill kernel in `engine::prefill`).
pub(crate) fn site_sp<'a>(
    sparsity: &'a crate::engine::decode::NativeSparsity,
    enabled: &[bool; 7],
    layer: usize,
    site: usize,
) -> Option<&'a crate::sparsity::Sparsifier> {
    if enabled[site] {
        sparsity.site(layer, site)
    } else {
        None
    }
}
