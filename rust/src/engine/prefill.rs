//! Blocked prefill: prompt ingestion as batched multi-row matmuls
//! (DESIGN.md §2.13).
//!
//! [`NativeEngine::prefill`](crate::engine::NativeEngine::prefill) feeds a
//! prompt one `step` at a time, so every one of the seven sparsified sites
//! runs once per position as an independent matvec — a 4k-token prompt is
//! 4k sequential GEMVs per site. This module applies the `StepBatch`
//! trick along the **sequence axis**: a block of B consecutive prompt
//! positions becomes B rows of one
//! [`apply_site_batch`](crate::engine::decode) call, so each site streams
//! its weight rows once per block instead of once per position (and the
//! packed path packs all B rows into one [`PackedNM`] stream via the
//! pooled per-row selection kernels).
//!
//! **Bitwise identity is structural.** Attention is the only op that
//! crosses positions, and it is causal: position `p` reads K/V rows
//! `0..=p` only. Running a block layer-major is therefore valid — for
//! layer `l` the block's K/V rows are written in ascending position order
//! ([`KvCache::write_row_at`]) before each position's
//! [`attention_paged`](crate::engine::decode) reads them, and every other
//! op (rmsnorm, rope, the site matmuls, SwiGLU) is per-position with
//! per-row kernels identical to the single-lane step. No lm head runs on
//! non-final positions (part of the speedup); the final prompt token goes
//! through the ordinary [`NativeEngine::step`](crate::engine::NativeEngine),
//! which loads next-token logits exactly as sequential prefill's last
//! step does. `rust/tests/prefill_blocked.rs` pins logits, KV bytes and
//! stats counters equal to the per-token oracle across patterns, block
//! sizes and page geometries.
//!
//! The body-only entry ([`NativeEngine::prefill_body`]) is what resumable
//! serving prefill uses: `NativeBackend` feeds at most one bounded block
//! per scheduler tick (continuous batching), so a long prompt admits
//! incrementally instead of monopolizing a replica's decode lanes.

use crate::engine::batch::site_sp;
use crate::engine::decode::{
    add_assign, apply_site_batch, attention_paged, pick, rmsnorm_into, rope_in_place, silu,
    NativeEngine,
};
use crate::engine::kv::{KvCache, KvPagePool};
use crate::util::trace::{self, Phase};
use anyhow::Result;

/// Reusable position-major scratch for one blocked-prefill chunk
/// (`[block × width]` buffers, the sequence-axis twin of `StepBatch`'s
/// lane-major scratch). Owned by the engine and retained across chunks
/// and calls, so steady-state blocked prefill allocates nothing once the
/// largest block size has been seen.
#[derive(Debug, Default)]
pub struct PrefillBlock {
    // `[block × d_model]` working buffers…
    x: Vec<f32>,
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctx: Vec<f32>,
    out_d: Vec<f32>,
    // …and `[block × ffn]`.
    gate: Vec<f32>,
    up: Vec<f32>,
    fbuf: Vec<f32>,
    probs: Vec<f32>,
}

impl PrefillBlock {
    fn resize(&mut self, n: usize, d_model: usize, ffn: usize) {
        for buf in [
            &mut self.x,
            &mut self.h,
            &mut self.q,
            &mut self.k,
            &mut self.v,
            &mut self.ctx,
            &mut self.out_d,
        ] {
            buf.resize(n * d_model, 0.0);
        }
        for buf in [&mut self.gate, &mut self.up, &mut self.fbuf] {
            buf.resize(n * ffn, 0.0);
        }
    }
}

impl NativeEngine {
    /// Blocked prefill: extend the cache over `tokens` in chunks of up to
    /// `block` positions (each chunk one multi-row matmul per site, no lm
    /// head), then run the final token through the ordinary
    /// [`NativeEngine::step`] so next-token logits load exactly as
    /// sequential prefill leaves them. Bitwise logits-identical to
    /// [`NativeEngine::prefill`](crate::engine::NativeEngine::prefill) by
    /// construction; `block == 0` is treated as 1. No-op on an empty
    /// slice.
    pub fn prefill_blocked(
        &mut self,
        kv: &mut KvCache,
        pool: &mut KvPagePool,
        tokens: &[u32],
        block: usize,
    ) -> Result<()> {
        let Some((&last, body)) = tokens.split_last() else {
            return Ok(());
        };
        self.prefill_body(kv, pool, body, block)?;
        self.step(kv, pool, last)
    }

    /// The blocked body kernel: extend the cache over `tokens` without
    /// computing any logits — what resumable serving prefill
    /// (`NativeBackend`) calls once per bounded tick. Validates up front
    /// (every token in vocabulary, the whole slice fits the cache), so
    /// the chunk kernel itself is infallible and a failed call leaves the
    /// cache untouched.
    pub fn prefill_body(
        &mut self,
        kv: &mut KvCache,
        pool: &mut KvPagePool,
        tokens: &[u32],
        block: usize,
    ) -> Result<()> {
        let vocab = self.config().vocab;
        anyhow::ensure!(
            kv.len() + tokens.len() <= kv.capacity(),
            "prefill of {} tokens overflows the KV cache ({} cached, capacity {})",
            tokens.len(),
            kv.len(),
            kv.capacity()
        );
        for t in tokens {
            anyhow::ensure!((*t as usize) < vocab, "token {t} out of vocabulary ({vocab})");
        }
        for chunk in tokens.chunks(block.max(1)) {
            let sg = trace::span_id(Phase::PrefillBlock, chunk.len() as u64);
            self.prefill_chunk(kv, pool, chunk);
            drop(sg);
        }
        Ok(())
    }

    /// One block of B positions, layer-major: per layer, the q/k/v sites
    /// run as one B-row matmul, then each position (ascending) applies
    /// rope, writes its K/V rows and attends over `0..=pos` — its own
    /// block's earlier rows are already written — then wo/gate/up/down
    /// run as B-row matmuls. The block commits once (`advance_n`) and
    /// counts B steps, so stats totals match the per-token path exactly.
    fn prefill_chunk(&mut self, kv: &mut KvCache, pool: &mut KvPagePool, chunk: &[u32]) {
        let NativeEngine {
            model,
            sparsity,
            enabled,
            packed_d,
            packed_f,
            rope_freqs,
            act,
            stats,
            workers,
            pblock,
            ..
        } = self;
        let cfg = &model.cfg;
        let (d, ffn, n) = (cfg.d_model, cfg.ffn, chunk.len());
        let (hd, nh) = (cfg.head_dim(), cfg.n_heads);
        let base = kv.len();
        pblock.resize(n, d, ffn);
        let PrefillBlock { x, h, q, k, v, ctx, out_d, gate, up, fbuf, probs } = pblock;
        for (i, t) in chunk.iter().enumerate() {
            x[i * d..(i + 1) * d].copy_from_slice(model.embed.row(*t as usize));
        }
        for (l, layer) in model.layers.iter().enumerate() {
            // Attention block: batched q/k/v sites over the B positions,
            // then per-position rope + positional cache write + causal
            // attention (in-block rows written ascending before use).
            for i in 0..n {
                rmsnorm_into(&x[i * d..(i + 1) * d], &layer.norm1, &mut h[i * d..(i + 1) * d]);
            }
            let s0 = site_sp(sparsity, enabled, l, 0);
            let p0 = pick(s0, packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteQ, stats.steps);
            apply_site_batch(&layer.wq, h, n, s0, p0, act, q, stats, workers);
            drop(sg);
            let s1 = site_sp(sparsity, enabled, l, 1);
            let p1 = pick(s1, packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteK, stats.steps);
            apply_site_batch(&layer.wk, h, n, s1, p1, act, k, stats, workers);
            drop(sg);
            let s2 = site_sp(sparsity, enabled, l, 2);
            let p2 = pick(s2, packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteV, stats.steps);
            apply_site_batch(&layer.wv, h, n, s2, p2, act, v, stats, workers);
            drop(sg);
            let sg = trace::span_id(Phase::Attention, stats.steps);
            for i in 0..n {
                let pos = base + i;
                rope_in_place(&mut q[i * d..(i + 1) * d], nh, hd, pos, rope_freqs);
                rope_in_place(&mut k[i * d..(i + 1) * d], nh, hd, pos, rope_freqs);
                kv.write_row_at(pool, l, pos, &k[i * d..(i + 1) * d], &v[i * d..(i + 1) * d]);
                attention_paged(
                    &q[i * d..(i + 1) * d],
                    kv,
                    l,
                    pos + 1,
                    nh,
                    hd,
                    probs,
                    &mut ctx[i * d..(i + 1) * d],
                );
            }
            drop(sg);
            let s3 = site_sp(sparsity, enabled, l, 3);
            let p3 = pick(s3, packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteO, stats.steps);
            apply_site_batch(&layer.wo, ctx, n, s3, p3, act, out_d, stats, workers);
            drop(sg);
            add_assign(x, out_d);

            // FFN block (SwiGLU): batched gate/up/down sites.
            for i in 0..n {
                rmsnorm_into(&x[i * d..(i + 1) * d], &layer.norm2, &mut h[i * d..(i + 1) * d]);
            }
            let s4 = site_sp(sparsity, enabled, l, 4);
            let p4 = pick(s4, packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteGate, stats.steps);
            apply_site_batch(&layer.wgate, h, n, s4, p4, act, gate, stats, workers);
            drop(sg);
            let s5 = site_sp(sparsity, enabled, l, 5);
            let p5 = pick(s5, packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteUp, stats.steps);
            apply_site_batch(&layer.wup, h, n, s5, p5, act, up, stats, workers);
            drop(sg);
            for ((f, g), u) in fbuf.iter_mut().zip(gate.iter()).zip(up.iter()) {
                *f = silu(*g) * u;
            }
            let s6 = site_sp(sparsity, enabled, l, 6);
            let p6 = pick(s6, packed_f.as_mut());
            let sg = trace::span_id(Phase::SiteDown, stats.steps);
            apply_site_batch(&layer.wdown, fbuf, n, s6, p6, act, out_d, stats, workers);
            drop(sg);
            add_assign(x, out_d);
        }
        kv.advance_n(n);
        stats.steps += n as u64;
    }
}
