//! Prefill and generation loops over the native step kernel.
//!
//! Everything here is a composition of [`NativeEngine::step`]; the
//! KV-cached incremental path and the full-context reference path run the
//! *same* per-position code, so token-identical greedy outputs are a
//! structural property of the cache bookkeeping — exactly what
//! `rust/tests/native_decode.rs` stresses (truncation, reset, eviction,
//! stop-token placement). The full-context loop re-prefills the whole row
//! for every generated token, so its per-token cost grows with context
//! while the cached loop's stays flat: `benches/decode.rs` measures both
//! into `BENCH_decode.json`.
//!
//! Threading is inherited, not re-implemented: every loop here composes
//! [`NativeEngine::step`], whose site matmuls and lm head already run on
//! the engine's worker pool ([`NativeEngine::set_threads`]) — and the
//! weight-row partitioning is bitwise-invariant, so prefill/generate
//! outputs are identical at any thread count.
//!
//! Two context-edge policies exist side by side:
//! [`NativeEngine::generate_greedy`] keeps the PJRT budget rule (the
//! token that fills the context is emitted, then the session ends — the
//! parity oracle for `Coordinator::generate_refs`), while
//! [`NativeEngine::generate_greedy_sliding`] is the serving rule
//! (DESIGN.md §2.10): a full session drops its oldest page-aligned block
//! ([`window_start`]) and re-anchors instead of ending — the sequential
//! reference the batched `NativeBackend` sessions are pinned against.

use crate::engine::decode::NativeEngine;
use crate::engine::kv::{window_start, KvCache, KvPagePool};
use anyhow::Result;

impl NativeEngine {
    /// Feed `tokens` through the step kernel, extending the cache. Leaves
    /// next-token logits loaded; no-op on an empty slice.
    pub fn prefill(
        &mut self,
        kv: &mut KvCache,
        pool: &mut KvPagePool,
        tokens: &[u32],
    ) -> Result<()> {
        for t in tokens {
            self.step(kv, pool, *t)?;
        }
        Ok(())
    }

    /// Reference full-context forward: reset the cache and replay the
    /// whole row. One call of this per generated token is the
    /// full-context baseline the PJRT path implements.
    pub fn full_context(
        &mut self,
        kv: &mut KvCache,
        pool: &mut KvPagePool,
        tokens: &[u32],
    ) -> Result<()> {
        kv.reset(pool);
        self.prefill(kv, pool, tokens)
    }

    /// KV-cached greedy generation: prefill the prompt once, then one
    /// step per emitted token. Stops on a stop token, the `max_new`
    /// budget, or a full context (mirroring `Coordinator::generate_refs`:
    /// the token that fills the context is still emitted).
    pub fn generate_greedy(
        &mut self,
        kv: &mut KvCache,
        pool: &mut KvPagePool,
        prompt: &[u32],
        max_new: usize,
        stop: &[u32],
    ) -> Result<Vec<u32>> {
        self.generate_greedy_with_block(kv, pool, prompt, max_new, stop, 0)
    }

    /// [`NativeEngine::generate_greedy`] with the prompt fed through
    /// blocked prefill ([`NativeEngine::prefill_blocked`]) when
    /// `block >= 1`; `block == 0` keeps the per-token oracle. Outputs are
    /// bitwise-identical either way (the blocked kernel's structural
    /// invariant) — `nmsparse decode --prefill-block` and the CI prefill
    /// smoke pin it.
    pub fn generate_greedy_with_block(
        &mut self,
        kv: &mut KvCache,
        pool: &mut KvPagePool,
        prompt: &[u32],
        max_new: usize,
        stop: &[u32],
        block: usize,
    ) -> Result<Vec<u32>> {
        let max_seq = self.config().max_seq;
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        // Left-crop long prompts (keep the most recent context), like the
        // PJRT path's `pack_rows`.
        let prompt = &prompt[prompt.len().saturating_sub(max_seq)..];
        kv.reset(pool);
        if block == 0 {
            self.prefill(kv, pool, prompt)?;
        } else {
            self.prefill_blocked(kv, pool, prompt, block)?;
        }
        let mut out = Vec::new();
        for _ in 0..max_new {
            let tok = self.argmax_token();
            out.push(tok);
            // Same termination rule as the full-context loop (and PJRT's
            // `generate_refs`): the token that fills the context is still
            // emitted, then the session ends.
            if stop.contains(&tok) || prompt.len() + out.len() >= max_seq || out.len() >= max_new {
                break;
            }
            self.step(kv, pool, tok)?;
        }
        Ok(out)
    }

    /// Full-context greedy reference: identical outputs to
    /// [`NativeEngine::generate_greedy`], at one whole-row forward per
    /// token — the equivalence oracle and the cost baseline.
    pub fn generate_greedy_full(
        &mut self,
        kv: &mut KvCache,
        pool: &mut KvPagePool,
        prompt: &[u32],
        max_new: usize,
        stop: &[u32],
    ) -> Result<Vec<u32>> {
        let max_seq = self.config().max_seq;
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let prompt = &prompt[prompt.len().saturating_sub(max_seq)..];
        let mut row = prompt.to_vec();
        let mut out = Vec::new();
        for _ in 0..max_new {
            self.full_context(kv, pool, &row)?;
            let tok = self.argmax_token();
            out.push(tok);
            row.push(tok);
            if stop.contains(&tok) || row.len() >= max_seq {
                break;
            }
        }
        Ok(out)
    }

    /// Sliding-window greedy generation — the serving-session rule: a row
    /// that outgrows the context drops its oldest page-aligned block
    /// ([`window_start`] on `pool`'s page grid) and re-anchors at
    /// position 0 (a page-granular crop + re-prefill; RoPE positions are
    /// absolute, so retained pages cannot be reused across a slide), then
    /// keeps generating to the `max_new` budget instead of ending. This
    /// sequential loop is the reference the batched
    /// `NativeBackend::decode_step_sessions` path is pinned against —
    /// the rule is a pure function of the row length, so the two can
    /// never disagree on where a window starts.
    pub fn generate_greedy_sliding(
        &mut self,
        kv: &mut KvCache,
        pool: &mut KvPagePool,
        prompt: &[u32],
        max_new: usize,
        stop: &[u32],
    ) -> Result<Vec<u32>> {
        let max_seq = self.config().max_seq;
        let pt = pool.page_tokens();
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let mut row = prompt.to_vec();
        let mut anchor = 0usize;
        kv.reset(pool);
        let mut out = Vec::new();
        for _ in 0..max_new {
            let ws = window_start(row.len(), max_seq, pt);
            // Same reconcile as the batched backend (`>=`: a cache fed
            // through the whole row is stale and rebuilds; unreachable
            // in this loop, where the row grows every iteration).
            if ws != anchor || anchor + kv.len() >= row.len() {
                kv.reset(pool);
                anchor = ws;
            }
            let fed = anchor + kv.len();
            for t in fed..row.len() {
                self.step(kv, pool, row[t])?;
            }
            let tok = self.argmax_token();
            out.push(tok);
            row.push(tok);
            if stop.contains(&tok) {
                break;
            }
        }
        Ok(out)
    }

    /// Sum of continuation logprobs over span `[start, end)` of `tokens`:
    /// `sum_t log p(tokens[t] | tokens[:t])` — the native twin of
    /// `Coordinator::score_rows` for one row (the caller crops/re-bases
    /// long rows the same way).
    pub fn score_span(
        &mut self,
        kv: &mut KvCache,
        pool: &mut KvPagePool,
        tokens: &[u32],
        span: (usize, usize),
    ) -> Result<f64> {
        let (s, e) = span;
        anyhow::ensure!(s >= 1, "span must start at >= 1 (token 0 has no context)");
        anyhow::ensure!(
            e <= tokens.len() && s < e,
            "bad span ({s},{e}) for row len {}",
            tokens.len()
        );
        anyhow::ensure!(
            tokens.len() <= self.config().max_seq,
            "row of {} tokens exceeds the engine context ({}) — crop before scoring",
            tokens.len(),
            self.config().max_seq
        );
        kv.reset(pool);
        let mut total = 0.0f64;
        // After stepping tokens[..t+1], logits predict tokens[t+1].
        for t in 0..e - 1 {
            self.step(kv, pool, tokens[t])?;
            let nxt = tokens[t + 1];
            if t + 1 >= s {
                anyhow::ensure!(
                    (nxt as usize) < self.config().vocab,
                    "token {nxt} out of vocabulary"
                );
                total += self.logprob_of(nxt);
            }
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::decode::NativeSparsity;
    use crate::engine::model::EngineConfig;
    use crate::sparsity::Pattern;

    fn tiny_engine(pattern: Pattern) -> NativeEngine {
        let cfg = EngineConfig {
            vocab: 48,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            ffn: 64,
            max_seq: 24,
        };
        NativeEngine::synthetic(&cfg, 42, NativeSparsity::act(pattern)).unwrap()
    }

    #[test]
    fn prefill_then_step_extends_cache() {
        let mut e = tiny_engine(Pattern::NM { n: 8, m: 16 });
        let mut pool = e.new_kv_pool();
        let mut kv = pool.new_cache();
        e.prefill(&mut kv, &mut pool, &[1, 2, 3]).unwrap();
        assert_eq!(kv.len(), 3);
        let tok = e.argmax_token();
        assert!((tok as usize) < e.config().vocab);
        e.step(&mut kv, &mut pool, tok).unwrap();
        assert_eq!(kv.len(), 4);
        assert_eq!(e.stats().steps, 4);
        // Paged storage: only the pages the 4 positions need are held.
        assert_eq!(kv.pages_held(), 4usize.div_ceil(pool.page_tokens()));
    }

    #[test]
    fn cached_equals_full_context_greedy() {
        for pattern in [Pattern::Dense, Pattern::NM { n: 2, m: 4 }, Pattern::NM { n: 8, m: 16 }] {
            let mut e = tiny_engine(pattern);
            let mut pool = e.new_kv_pool();
            let mut kv = pool.new_cache();
            let prompt = [3u32, 14, 7, 20];
            let cached = e.generate_greedy(&mut kv, &mut pool, &prompt, 10, &[]).unwrap();
            let full = e.generate_greedy_full(&mut kv, &mut pool, &prompt, 10, &[]).unwrap();
            assert_eq!(cached, full, "{pattern}");
            assert_eq!(cached.len(), 10);
        }
    }

    #[test]
    fn generation_stops_on_context_budget_and_stop() {
        let mut e = tiny_engine(Pattern::NM { n: 8, m: 16 });
        let mut pool = e.new_kv_pool();
        let mut kv = pool.new_cache();
        // Budget.
        let out = e.generate_greedy(&mut kv, &mut pool, &[5, 6], 3, &[]).unwrap();
        assert_eq!(out.len(), 3);
        // Stop token: generate once, then replay with that token as stop.
        let free = e.generate_greedy(&mut kv, &mut pool, &[5, 6], 8, &[]).unwrap();
        let stop = free[2];
        let stopped = e.generate_greedy(&mut kv, &mut pool, &[5, 6], 8, &[stop]).unwrap();
        let cut = stopped.iter().position(|t| *t == stop).unwrap();
        assert_eq!(&stopped[..=cut], &free[..=cut]);
        assert_eq!(cut + 1, stopped.len());
        // Context: prompts at (or cropped to) the context edge emit
        // exactly one token — the PJRT `generate_refs` budget rule — and
        // both loops agree on it.
        for extra in [-1i64, 0, 5] {
            let len = (e.config().max_seq as i64 + extra) as u32;
            let long: Vec<u32> = (0..len).map(|i| i % 40).collect();
            let cached = e.generate_greedy(&mut kv, &mut pool, &long, 8, &[]).unwrap();
            let full = e.generate_greedy_full(&mut kv, &mut pool, &long, 8, &[]).unwrap();
            assert_eq!(cached, full, "extra={extra}");
            assert_eq!(cached.len(), 1, "extra={extra}");
        }
    }

    #[test]
    fn sliding_generation_outlives_the_context_budget() {
        let mut e = tiny_engine(Pattern::NM { n: 8, m: 16 });
        let max_seq = e.config().max_seq;
        let mut pool = e.new_kv_pool_with(4);
        let mut kv = pool.new_cache();
        // A prompt near the edge: the budget rule emits one token, the
        // sliding rule keeps going to the full budget.
        let prompt: Vec<u32> = (0..max_seq as u32 - 2).map(|i| i % 40).collect();
        let budget = e.generate_greedy(&mut kv, &mut pool, &prompt, 6, &[]).unwrap();
        assert_eq!(budget.len(), 2, "budget rule: fills context, then ends");
        let slid = e.generate_greedy_sliding(&mut kv, &mut pool, &prompt, 6, &[]).unwrap();
        assert_eq!(slid.len(), 6, "sliding rule: generation continues");
        // Until the first slide, the two rules see identical windows.
        assert_eq!(&slid[..2], &budget[..]);
        // Manual reference: per emitted token, crop the row at the
        // page-granular window start and run one full-context forward.
        let mut row = prompt.clone();
        for (i, want) in slid.iter().enumerate() {
            let ws = window_start(row.len(), max_seq, pool.page_tokens());
            e.full_context(&mut kv, &mut pool, &row[ws..]).unwrap();
            assert_eq!(e.argmax_token(), *want, "token {i}");
            row.push(*want);
        }
        // The cache never exceeds the window, so pages stay bounded.
        assert!(kv.pages_held() <= max_seq.div_ceil(pool.page_tokens()));
    }

    #[test]
    fn score_span_matches_manual_logprob_sum() {
        let mut e = tiny_engine(Pattern::NM { n: 2, m: 4 });
        let mut pool = e.new_kv_pool();
        let mut kv = pool.new_cache();
        let tokens = [4u32, 9, 13, 2, 30];
        let span = (2, 5);
        let got = e.score_span(&mut kv, &mut pool, &tokens, span).unwrap();
        // Manual replay.
        let mut manual = 0.0f64;
        kv.reset(&mut pool);
        for t in 0..tokens.len() - 1 {
            e.step(&mut kv, &mut pool, tokens[t]).unwrap();
            if t + 1 >= span.0 {
                manual += e.logprob_of(tokens[t + 1]);
            }
        }
        assert_eq!(got, manual);
        assert!(got < 0.0, "logprobs are negative: {got}");
        // Bad spans are errors.
        assert!(e.score_span(&mut kv, &mut pool, &tokens, (0, 2)).is_err());
        assert!(e.score_span(&mut kv, &mut pool, &tokens, (3, 3)).is_err());
        assert!(e.score_span(&mut kv, &mut pool, &tokens, (1, 9)).is_err());
    }

    #[test]
    fn packed_and_dense_paths_agree_bitwise() {
        let cfg = EngineConfig {
            vocab: 48,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            ffn: 64,
            max_seq: 24,
        };
        let pattern = Pattern::NM { n: 8, m: 16 };
        let mut packed =
            NativeEngine::synthetic(&cfg, 9, NativeSparsity::act(pattern)).unwrap();
        let mut dense = NativeEngine::synthetic(
            &cfg,
            9,
            NativeSparsity::act(pattern).with_force_dense(true),
        )
        .unwrap();
        assert!(packed.uses_packed());
        assert!(!dense.uses_packed());
        let mut pool_a = packed.new_kv_pool();
        let mut pool_b = dense.new_kv_pool();
        let mut kva = pool_a.new_cache();
        let mut kvb = pool_b.new_cache();
        packed.prefill(&mut kva, &mut pool_a, &[1, 2, 3, 4, 5]).unwrap();
        dense.prefill(&mut kvb, &mut pool_b, &[1, 2, 3, 4, 5]).unwrap();
        let a: Vec<u32> = packed.logits().iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = dense.logits().iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "compressed-domain GEMV must be bitwise-equal");
        // And the packed engine actually moved fewer activation bytes.
        let (sp, sd) = (packed.stats(), dense.stats());
        assert_eq!(sp.dense_activation_bytes, sd.dense_activation_bytes);
        assert!(sp.moved_activation_bytes < sd.moved_activation_bytes);
        assert!(sp.bytes_reduction() > 1.5, "{}", sp.bytes_reduction());
    }

    #[test]
    fn threaded_generation_is_token_and_logit_identical() {
        // The forward loops inherit the pool through step(); weight-row
        // partitioning must leave greedy decode byte-for-byte unchanged.
        let mut single = tiny_engine(Pattern::NM { n: 8, m: 16 });
        let mut pooled = tiny_engine(Pattern::NM { n: 8, m: 16 }).with_threads(3);
        let mut pa = single.new_kv_pool();
        let mut pb = pooled.new_kv_pool();
        let mut kva = pa.new_cache();
        let mut kvb = pb.new_cache();
        let prompt = [3u32, 1, 4, 1, 5];
        let a = single.generate_greedy(&mut kva, &mut pa, &prompt, 8, &[]).unwrap();
        let b = pooled.generate_greedy(&mut kvb, &mut pb, &prompt, 8, &[]).unwrap();
        assert_eq!(a, b, "threads must not change emitted tokens");
        let la: Vec<u32> = single.logits().iter().map(|v| v.to_bits()).collect();
        let lb: Vec<u32> = pooled.logits().iter().map(|v| v.to_bits()).collect();
        assert_eq!(la, lb, "threads must not change final logits bits");
    }
}
