//! Native decode engine: KV-cached incremental decoding over packed N:M
//! activations (DESIGN.md §2.9–§2.10).
//!
//! The PJRT path re-runs a full-context forward for every generated token
//! (the artifact executables are fixed-shape); this subsystem is the
//! serving-native alternative — a pure-rust CPU transformer that prefills
//! a prompt once and then decodes one token per step against per-session
//! paged KV storage, applying the paper's N:M activation sparsification
//! at the seven linear sites on every step and executing the sparse
//! matvecs in the compressed domain over
//! [`PackedNM`](crate::sparsity::PackedNM) streams:
//!
//! - [`model`]: weights + configuration — artifact checkpoints load via
//!   [`NativeModel::from_store`] (same tensor names as `aot.py`); CI and
//!   benches use the seeded deterministic [`NativeModel::synthetic`];
//! - [`kv`]: paged KV storage — fixed-size pages checked out of a shared
//!   [`KvPagePool`] (peak bytes track live context, not
//!   `sessions × max_seq`), the LRU [`SessionKvPool`] of per-session
//!   slots, and the page-granular sliding-window rule
//!   ([`kv::window_start`]);
//! - [`decode`]: the per-token step kernel ([`NativeEngine::step`]), the
//!   per-(layer, site) [`NativeSparsity`] table (S-PTS/L-PTS/Amber
//!   vectors from methodparams), and the [`DecodeStats`] byte counters
//!   behind `BENCH_decode.json`;
//! - [`batch`]: the batched session-stepping API — a reusable
//!   [`StepBatch`] of `{session, token}` lanes advanced by
//!   [`NativeEngine::step_batch`], each sparsified site running as one
//!   packed multi-row matmul across all lanes — partitioned by weight
//!   rows over the engine's persistent [`WorkerPool`] (§2.11) — bitwise
//!   token-identical to sequential per-session stepping at any thread
//!   count;
//! - [`forward`]: prefill, the full-context reference loop (the
//!   equivalence oracle: token-identical by construction), greedy
//!   generation under both context-edge rules (PJRT budget rule and the
//!   serving sliding-window rule), and span scoring;
//! - [`prefill`]: blocked prefill (§2.13) — prompt ingestion as
//!   position-major multi-row site matmuls, bitwise logits-identical to
//!   the per-token loop, with a body-only entry for the resumable
//!   bounded-block serving prefill in `NativeBackend`.
//!
//! Consumers: `coordinator::server::NativeBackend` (`--backend native` in
//! `nmsparse serve`/`loadgen` — one `StepBatch` per scheduler tick),
//! `EnginePool::native_engine` + `Coordinator::generate_refs`
//! (artifact-backed native decode), `nmsparse decode` (single-lane and
//! `--lanes` batched smoke), and `benches/decode.rs`.

pub mod batch;
pub mod decode;
pub mod forward;
pub mod kv;
pub mod model;
pub mod prefill;

pub use batch::{Lane, StepBatch};
pub use decode::{DecodeStats, NativeEngine, NativeSparsity};
pub use prefill::PrefillBlock;
pub use kv::{window_start, KvCache, KvPagePool, SessionKvPool, SessionSlot};
pub use model::{EngineConfig, NativeModel, SITES};
// The engine's hot-loop pool (re-exported so engine consumers and tests
// need not reach into util:: for the threading surface).
pub use crate::util::threadpool::WorkerPool;
