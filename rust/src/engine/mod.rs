//! Native decode engine: KV-cached incremental decoding over packed N:M
//! activations (DESIGN.md §2.9).
//!
//! The PJRT path re-runs a full-context forward for every generated token
//! (the artifact executables are fixed-shape); this subsystem is the
//! serving-native alternative — a pure-rust CPU transformer that prefills
//! a prompt once and then decodes one token per step against a
//! per-session [`KvCache`], applying the paper's N:M activation
//! sparsification at the seven linear sites on every step and executing
//! the sparse matvecs in the compressed domain over [`PackedNM`] streams:
//!
//! - [`model`]: weights + configuration — artifact checkpoints load via
//!   [`NativeModel::from_store`] (same tensor names as `aot.py`); CI and
//!   benches use the seeded deterministic [`NativeModel::synthetic`];
//! - [`kv`]: the per-session KV cache and the LRU [`SessionKvPool`] the
//!   serving backend keys by scheduler session id;
//! - [`decode`]: the per-token step kernel ([`NativeEngine::step`]) and
//!   the [`DecodeStats`] byte counters behind `BENCH_decode.json`;
//! - [`forward`]: prefill, the full-context reference loop (the
//!   equivalence oracle: token-identical by construction, pinned under
//!   cache eviction/truncation by `rust/tests/native_decode.rs`), greedy
//!   generation and span scoring.
//!
//! Consumers: `coordinator::server::NativeBackend` (`--backend native` in
//! `nmsparse serve`/`loadgen`), `EnginePool::native_engine` +
//! `Coordinator::generate_refs` (artifact-backed native decode), and
//! `benches/decode.rs`.

pub mod decode;
pub mod forward;
pub mod kv;
pub mod model;

pub use decode::{DecodeStats, NativeEngine, NativeSparsity};
pub use kv::{KvCache, SessionKvPool};
pub use model::{EngineConfig, NativeModel, SITES};
