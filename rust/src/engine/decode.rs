//! Native decode engine: the per-token step kernel.
//!
//! [`NativeEngine::step`] runs one token through the transformer against a
//! [`KvCache`] — the per-step cost is the layer matmuls plus attention
//! over the cached positions, instead of the full-context forward the
//! PJRT path re-runs per generated token. The paper's N:M activation
//! sparsification sits exactly where `python/compile/model.py` puts it:
//! on the *input* of each of the seven linear sites (q/k/v/o/gate/up/
//! down). For selection-only pipelines the step never materializes the
//! sparsified row densely — the fused [`Sparsifier`] emits a [`PackedNM`]
//! stream during selection and the matvec runs in the compressed domain
//! ([`PackedNM::matmul_nt_into`], the same `row_dot` kernel as
//! [`PackedNM::matvec_into`]), so the bytes-moved numbers in
//! [`DecodeStats`] come from the stream that actually fed the GEMV.
//!
//! The packed and dense paths are bitwise-equal by construction: dropped
//! elements are exactly `0.0`, the kept products are accumulated in the
//! same ascending-column order, and `acc + ±0.0` never changes an f32
//! accumulation that started at `+0.0` — `rust/tests/native_decode.rs`
//! pins this.

use crate::coordinator::methods::MethodConfig;
use crate::engine::kv::KvCache;
use crate::engine::model::{EngineConfig, NativeModel, SITES};
use crate::sparsity::{PackedNM, Pattern, Scratch, Sparsifier};
use crate::util::tensor::Tensor;
use anyhow::{bail, Context, Result};

/// How (and whether) the engine sparsifies site inputs.
#[derive(Clone, Debug)]
pub struct NativeSparsity {
    /// `None` = dense forward (the ORIG baseline).
    sparsifier: Option<Sparsifier>,
    disabled_sites: Vec<String>,
    /// Test/bench knob: run the sparsified-dense path even when the
    /// pipeline could emit a packed stream.
    force_dense: bool,
}

impl NativeSparsity {
    /// Dense (no sparsification).
    pub fn dense() -> NativeSparsity {
        NativeSparsity { sparsifier: None, disabled_sites: Vec::new(), force_dense: false }
    }

    /// Plain magnitude (ACT) sparsification at `pattern` on every site.
    pub fn act(pattern: Pattern) -> NativeSparsity {
        let sparsifier = match pattern {
            Pattern::Dense => None,
            p => Some(Sparsifier::new(p)),
        };
        NativeSparsity { sparsifier, disabled_sites: Vec::new(), force_dense: false }
    }

    /// Realize a [`MethodConfig`] natively. Supported: ORIG/dense, ACT,
    /// D-PTS, VAR (and their site exemptions). Methods needing per-site
    /// calibration vectors (S-PTS/L-PTS/CLACT/Amber/LS) or an R-Sparse
    /// variant are kernel-path-only and error here rather than silently
    /// downgrading.
    pub fn from_method(cfg: &MethodConfig) -> Result<NativeSparsity> {
        if cfg.rank.is_some() {
            bail!("method '{}' is an R-Sparse variant — not representable natively", cfg.id);
        }
        let pattern = cfg.pattern()?;
        let sparsifier = match pattern {
            Pattern::Dense => None,
            _ => Some(cfg.sparsifier(None, None).with_context(|| {
                format!(
                    "native engine cannot realize method '{}' (per-site calibration \
                     vectors are kernel-path-only)",
                    cfg.id
                )
            })?),
        };
        Ok(NativeSparsity {
            sparsifier,
            disabled_sites: cfg.disabled_sites.clone(),
            force_dense: false,
        })
    }

    /// Disable the compressed-domain path (dense sparsified matvecs).
    pub fn with_force_dense(mut self, on: bool) -> NativeSparsity {
        self.force_dense = on;
        self
    }

    pub fn pattern(&self) -> Pattern {
        self.sparsifier.as_ref().map(|s| s.pattern()).unwrap_or(Pattern::Dense)
    }

    pub fn sparsifier(&self) -> Option<&Sparsifier> {
        self.sparsifier.as_ref()
    }
}

/// Running byte/step counters for the decode loop. `dense_activation_bytes`
/// is what a dense engine would have moved through the sparsified sites;
/// `moved_activation_bytes` is what this engine actually moved (packed
/// payload + raw `u32` metadata words on the compressed path). The ratio
/// is the measured activation-I/O reduction `BENCH_decode.json` reports.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DecodeStats {
    /// Tokens stepped (prefill + decode).
    pub steps: u64,
    /// Site linears executed.
    pub site_rows: u64,
    pub dense_activation_bytes: u64,
    pub moved_activation_bytes: u64,
}

impl DecodeStats {
    pub fn reset(&mut self) {
        *self = DecodeStats::default();
    }

    /// dense / moved (1.0 when nothing has run).
    pub fn bytes_reduction(&self) -> f64 {
        if self.moved_activation_bytes == 0 {
            1.0
        } else {
            self.dense_activation_bytes as f64 / self.moved_activation_bytes as f64
        }
    }
}

/// The native engine: model weights + sparsification config + all scratch
/// buffers for one step. Steady state allocates nothing — every buffer is
/// sized at construction.
pub struct NativeEngine {
    model: NativeModel,
    sparsity: NativeSparsity,
    /// Per-site sparsification enables, indexed like [`SITES`].
    enabled: [bool; 7],
    /// Compressed stream for `d_model`-wide site inputs (None off the
    /// packed path or when the pattern cannot hold that width).
    packed_d: Option<PackedNM>,
    /// Compressed stream for the `ffn`-wide `down` input.
    packed_f: Option<PackedNM>,
    /// RoPE inverse frequencies, `[head_dim/2]` — shared by every head,
    /// precomputed once (a `powf` per element per step would dominate
    /// the very step cost `BENCH_decode.json` measures).
    rope_freqs: Vec<f32>,
    scratch: Scratch,
    // Step buffers (residual stream, norms, projections, FFN, outputs).
    x: Vec<f32>,
    h: Vec<f32>,
    act: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctx: Vec<f32>,
    site_out_d: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    fbuf: Vec<f32>,
    probs: Vec<f32>,
    logits: Vec<f32>,
    stats: DecodeStats,
}

const ROPE_BASE: f32 = 10000.0;

impl NativeEngine {
    pub fn new(model: NativeModel, sparsity: NativeSparsity) -> Result<NativeEngine> {
        let cfg = model.cfg.clone();
        anyhow::ensure!(
            cfg.n_heads > 0 && cfg.d_model % cfg.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            cfg.d_model,
            cfg.n_heads
        );
        anyhow::ensure!(cfg.head_dim() % 2 == 0, "RoPE needs an even head_dim");
        anyhow::ensure!(cfg.max_seq > 0, "max_seq must be positive");
        let enabled = site_enables(&sparsity);
        // Enabled sparsified sites must fit the pattern's block geometry.
        if let Some(sp) = sparsity.sparsifier() {
            if let Pattern::NM { m, .. } = sp.pattern() {
                for (i, site) in SITES.iter().enumerate() {
                    let din = cfg.site_in_dim(site);
                    anyhow::ensure!(
                        !enabled[i] || din % m as usize == 0,
                        "site '{site}' width {din} is not a multiple of M={m}"
                    );
                }
            }
        }
        let use_packed = match sparsity.sparsifier() {
            Some(sp) => sp.is_packable() && !sparsity.force_dense,
            None => false,
        };
        let needs_d = enabled[..6].iter().any(|e| *e); // q k v o gate up
        let needs_f = enabled[6]; // down
        let mk = |cols: usize| {
            sparsity.sparsifier().map(|sp| PackedNM::new(sp.pattern(), cols))
        };
        let (packed_d, packed_f) = if use_packed {
            (
                if needs_d { mk(cfg.d_model) } else { None },
                if needs_f { mk(cfg.ffn) } else { None },
            )
        } else {
            (None, None)
        };
        let half = cfg.head_dim() / 2;
        let rope_freqs: Vec<f32> =
            (0..half).map(|i| ROPE_BASE.powf(-(i as f32) / half as f32)).collect();
        Ok(NativeEngine {
            rope_freqs,
            x: vec![0.0; cfg.d_model],
            h: vec![0.0; cfg.d_model],
            act: Vec::with_capacity(cfg.ffn.max(cfg.d_model)),
            q: vec![0.0; cfg.d_model],
            k: vec![0.0; cfg.d_model],
            v: vec![0.0; cfg.d_model],
            ctx: vec![0.0; cfg.d_model],
            site_out_d: vec![0.0; cfg.d_model],
            gate: vec![0.0; cfg.ffn],
            up: vec![0.0; cfg.ffn],
            fbuf: vec![0.0; cfg.ffn],
            probs: Vec::with_capacity(cfg.max_seq),
            logits: vec![0.0; cfg.vocab],
            scratch: Scratch::new(),
            stats: DecodeStats::default(),
            model,
            sparsity,
            enabled,
            packed_d,
            packed_f,
        })
    }

    /// Seeded synthetic engine (no artifacts) — CI, benches, tests.
    pub fn synthetic(
        cfg: &EngineConfig,
        seed: u64,
        sparsity: NativeSparsity,
    ) -> Result<NativeEngine> {
        NativeEngine::new(NativeModel::synthetic(cfg, seed), sparsity)
    }

    pub fn config(&self) -> &EngineConfig {
        &self.model.cfg
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    pub fn sparsity(&self) -> &NativeSparsity {
        &self.sparsity
    }

    /// Is the compressed-domain matvec path active?
    pub fn uses_packed(&self) -> bool {
        self.packed_d.is_some() || self.packed_f.is_some()
    }

    /// A fresh KV cache sized for this engine.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(&self.model.cfg)
    }

    pub fn stats(&self) -> DecodeStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats.reset();
    }

    /// Next-token logits after the last [`NativeEngine::step`].
    pub fn logits(&self) -> &[f32] {
        &self.logits
    }

    /// Greedy token from the current logits (first index on ties — the
    /// same rule as `Coordinator`'s argmax).
    pub fn argmax_token(&self) -> u32 {
        let mut best = 0usize;
        for (i, x) in self.logits.iter().enumerate() {
            if *x > self.logits[best] {
                best = i;
            }
        }
        best as u32
    }

    /// `log p(token)` under the current logits (f64 log-softmax).
    pub fn logprob_of(&self, token: u32) -> f64 {
        let max = self.logits.iter().fold(f32::NEG_INFINITY, |a, b| a.max(*b)) as f64;
        let sum: f64 = self.logits.iter().map(|v| ((*v as f64) - max).exp()).sum();
        (self.logits[token as usize] as f64) - max - sum.ln()
    }

    /// Advance one token: consume `token` at the cache's next position and
    /// leave next-token logits in [`NativeEngine::logits`]. Errors when the
    /// cache is full or the token is out of vocabulary.
    pub fn step(&mut self, kv: &mut KvCache, token: u32) -> Result<()> {
        let NativeEngine {
            model,
            sparsity,
            enabled,
            packed_d,
            packed_f,
            rope_freqs,
            scratch,
            x,
            h,
            act,
            q,
            k,
            v,
            ctx,
            site_out_d,
            gate,
            up,
            fbuf,
            probs,
            logits,
            stats,
        } = self;
        let cfg = &model.cfg;
        anyhow::ensure!(
            !kv.is_full(),
            "KV cache full: context length {} reached",
            kv.capacity()
        );
        anyhow::ensure!(
            (token as usize) < cfg.vocab,
            "token {token} out of vocabulary ({})",
            cfg.vocab
        );
        let pos = kv.len();
        let sp = sparsity.sparsifier();
        x.copy_from_slice(model.embed.row(token as usize));
        for (l, layer) in model.layers.iter().enumerate() {
            // Attention block.
            rmsnorm_into(x, &layer.norm1, h);
            apply_site(&layer.wq, h, sp, enabled[0], packed_d.as_mut(), scratch, act, q, stats);
            apply_site(&layer.wk, h, sp, enabled[1], packed_d.as_mut(), scratch, act, k, stats);
            apply_site(&layer.wv, h, sp, enabled[2], packed_d.as_mut(), scratch, act, v, stats);
            rope_in_place(q, cfg.n_heads, cfg.head_dim(), pos, rope_freqs);
            rope_in_place(k, cfg.n_heads, cfg.head_dim(), pos, rope_freqs);
            kv.write_row(l, k, v);
            attention_into(
                q,
                kv.keys(l, pos + 1),
                kv.values(l, pos + 1),
                pos + 1,
                cfg.n_heads,
                cfg.head_dim(),
                probs,
                ctx,
            );
            let pd = packed_d.as_mut();
            apply_site(&layer.wo, ctx, sp, enabled[3], pd, scratch, act, site_out_d, stats);
            add_assign(x, site_out_d);

            // FFN block (SwiGLU).
            rmsnorm_into(x, &layer.norm2, h);
            let pg = packed_d.as_mut();
            apply_site(&layer.wgate, h, sp, enabled[4], pg, scratch, act, gate, stats);
            let pu = packed_d.as_mut();
            apply_site(&layer.wup, h, sp, enabled[5], pu, scratch, act, up, stats);
            for ((f, g), u) in fbuf.iter_mut().zip(gate.iter()).zip(up.iter()) {
                *f = silu(*g) * u;
            }
            let pf = packed_f.as_mut();
            apply_site(&layer.wdown, fbuf, sp, enabled[6], pf, scratch, act, site_out_d, stats);
            add_assign(x, site_out_d);
        }
        kv.advance();
        rmsnorm_into(x, &model.final_norm, h);
        dense_matvec(&model.lm_head, h, logits);
        stats.steps += 1;
        Ok(())
    }
}

/// Which sites sparsify, in [`SITES`] order.
fn site_enables(sparsity: &NativeSparsity) -> [bool; 7] {
    let mut enabled = [sparsity.sparsifier.is_some(); 7];
    for (i, site) in SITES.iter().enumerate() {
        if sparsity.disabled_sites.iter().any(|d| d == site) {
            enabled[i] = false;
        }
    }
    enabled
}

/// One (possibly sparsified) linear site: `out[o] = w.row(o) · s(input)`.
/// The compressed path packs the row during selection and runs the GEMV
/// over the stream; the dense path sparsifies a copy in place. Byte
/// counters record what actually moved.
#[allow(clippy::too_many_arguments)]
fn apply_site(
    w: &Tensor,
    input: &[f32],
    sp: Option<&Sparsifier>,
    enabled: bool,
    packed: Option<&mut PackedNM>,
    scratch: &mut Scratch,
    act: &mut Vec<f32>,
    out: &mut [f32],
    stats: &mut DecodeStats,
) {
    let din = input.len();
    debug_assert_eq!(w.cols(), din);
    debug_assert_eq!(w.rows(), out.len());
    stats.site_rows += 1;
    stats.dense_activation_bytes += (din * 4) as u64;
    match (sp, enabled) {
        (Some(sp), true) => match packed {
            Some(packed) => {
                packed.clear();
                sp.pack_row_into(input, packed, scratch);
                stats.moved_activation_bytes +=
                    (packed.values().len() * 4 + packed.meta_words().len() * 4) as u64;
                packed.matmul_nt_into(w, out, 1);
            }
            None => {
                act.clear();
                act.extend_from_slice(input);
                sp.sparsify_row(act, scratch);
                stats.moved_activation_bytes += (din * 4) as u64;
                dense_matvec(w, act, out);
            }
        },
        _ => {
            stats.moved_activation_bytes += (din * 4) as u64;
            dense_matvec(w, input, out);
        }
    }
}

/// RMSNorm with the python model's epsilon (1e-6), f64 mean accumulation.
fn rmsnorm_into(x: &[f32], g: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(x.len(), out.len());
    let ms = x.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>() / x.len() as f64;
    let r = (1.0 / (ms + 1e-6).sqrt()) as f32;
    for ((o, v), gg) in out.iter_mut().zip(x).zip(g) {
        *o = *v * r * *gg;
    }
}

/// Rotary position embedding at one position (split-half convention,
/// matching `python/compile/model.py::rope`). `freqs` is the engine's
/// precomputed `[head_dim/2]` inverse-frequency table.
fn rope_in_place(x: &mut [f32], n_heads: usize, head_dim: usize, pos: usize, freqs: &[f32]) {
    let half = head_dim / 2;
    debug_assert_eq!(freqs.len(), half);
    for head in 0..n_heads {
        let o = head * head_dim;
        for (i, freq) in freqs.iter().enumerate() {
            let ang = pos as f32 * freq;
            let (sin, cos) = ang.sin_cos();
            let a = x[o + i];
            let b = x[o + i + half];
            x[o + i] = a * cos - b * sin;
            x[o + i + half] = a * sin + b * cos;
        }
    }
}

/// Causal attention for one query over `rows` cached positions.
#[allow(clippy::too_many_arguments)]
fn attention_into(
    q: &[f32],
    keys: &[f32],
    vals: &[f32],
    rows: usize,
    n_heads: usize,
    head_dim: usize,
    probs: &mut Vec<f32>,
    out: &mut [f32],
) {
    let d = n_heads * head_dim;
    let scale = 1.0 / (head_dim as f32).sqrt();
    for head in 0..n_heads {
        let off = head * head_dim;
        let qh = &q[off..off + head_dim];
        probs.clear();
        let mut maxs = f32::NEG_INFINITY;
        for j in 0..rows {
            let kh = &keys[j * d + off..j * d + off + head_dim];
            let s = dot(qh, kh) * scale;
            probs.push(s);
            maxs = maxs.max(s);
        }
        let mut denom = 0.0f32;
        for p in probs.iter_mut() {
            *p = (*p - maxs).exp();
            denom += *p;
        }
        let inv = 1.0 / denom;
        let oh = &mut out[off..off + head_dim];
        oh.iter_mut().for_each(|o| *o = 0.0);
        for (j, p) in probs.iter().enumerate() {
            let wj = p * inv;
            let vh = &vals[j * d + off..j * d + off + head_dim];
            for (o, vv) in oh.iter_mut().zip(vh) {
                *o += wj * vv;
            }
        }
    }
}

/// Dense GEMV: `out[o] = w.row(o) · x` — the baseline the packed path is
/// bitwise-equal to on selection-only pipelines.
pub(crate) fn dense_matvec(w: &Tensor, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(w.cols(), x.len());
    debug_assert_eq!(w.rows(), out.len());
    let cols = w.cols();
    for (o, row) in out.iter_mut().zip(w.data.chunks_exact(cols)) {
        *o = dot(row, x);
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[inline]
fn add_assign(x: &mut [f32], y: &[f32]) {
    for (a, b) in x.iter_mut().zip(y) {
        *a += b;
    }
}
