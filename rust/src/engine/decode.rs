//! Native decode engine: the per-token step kernel.
//!
//! [`NativeEngine::step`] runs one token through the transformer against a
//! paged [`KvCache`] — the per-step cost is the layer matmuls plus
//! attention over the cached positions, instead of the full-context
//! forward the PJRT path re-runs per generated token. The paper's N:M
//! activation sparsification sits exactly where `python/compile/model.py`
//! puts it: on the *input* of each of the seven linear sites (q/k/v/o/
//! gate/up/down). For selection-only pipelines the step never materializes
//! the sparsified row densely — the fused [`Sparsifier`] emits a
//! [`PackedNM`] stream during selection and the matvec runs in the
//! compressed domain ([`PackedNM::matmul_nt_into`]), so the bytes-moved
//! numbers in [`DecodeStats`] come from the stream that actually fed the
//! GEMV. [`NativeEngine::step_batch`](crate::engine::StepBatch) is the
//! multi-session form: the same seven sites as one multi-row matmul
//! across every lane (`engine/batch.rs`).
//!
//! [`NativeSparsity`] carries either one shared pipeline (ACT/D-PTS/VAR)
//! or a **per-(layer, site) table** built from calibrated methodparams
//! vectors ([`NativeSparsity::from_method_with_params`]): S-PTS/L-PTS eta
//! shifts and Amber channel norms load straight from the artifacts store,
//! so calibrated methods run on the native path, not just PJRT. Shifted
//! pipelines are not selection-only and take the sparsified-dense path;
//! packable sites still stream compressed.
//!
//! The packed and dense paths are bitwise-equal by construction: dropped
//! elements are exactly `0.0`, the kept products are accumulated in the
//! same ascending-column order, and `acc + ±0.0` never changes an f32
//! accumulation that started at `+0.0` — `rust/tests/native_decode.rs`
//! pins this.

use crate::coordinator::methods::MethodConfig;
use crate::engine::kv::{KvCache, KvPagePool};
use crate::engine::model::{EngineConfig, NativeModel, SITES};
use crate::runtime::Manifest;
use crate::sparsity::{PackedNM, Pattern, Scratch, Sparsifier};
use crate::util::tensor::{Tensor, TensorStore};
use crate::util::threadpool::{DisjointSliceMut, WorkerPool};
use crate::util::trace::{self, Phase};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// THE artifacts-or-synthetic loading policy, shared by the serving
/// backend (`NativeBackend::open`) and `nmsparse decode` so the two can
/// never drift: when `artifacts` holds a manifest, load the checkpoint
/// (this method's weight transform applied) at the manifest's dimensions
/// and draw per-site calibration vectors from the methodparams store
/// ([`NativeSparsity::from_method_with_params`] — a missing or corrupt
/// store is a loud error); otherwise build the seeded synthetic model at
/// [`EngineConfig::tiny`] dimensions, where only vector-free methods
/// work. Returns `(model, sparsity, origin)` with `origin` one of
/// `"artifacts"` / `"synthetic"`.
pub fn load_native_parts(
    artifacts: &Path,
    mcfg: &MethodConfig,
    seed: u64,
) -> Result<(NativeModel, NativeSparsity, &'static str)> {
    if artifacts.join("io_manifest.json").exists() {
        let manifest = Manifest::load(artifacts)?;
        let cfg = EngineConfig::from_dims(&manifest.dims);
        let weights = mcfg.transformed_weights(&TensorStore::load(&artifacts.join("ckpt"))?)?;
        let methodparams = TensorStore::load(&artifacts.join("methodparams"))
            .context("loading methodparams")?;
        let sparsity = NativeSparsity::from_method_with_params(mcfg, &methodparams, &cfg)?;
        Ok((NativeModel::from_store(&weights, &cfg)?, sparsity, "artifacts"))
    } else {
        let sparsity = NativeSparsity::from_method(mcfg)?;
        Ok((NativeModel::synthetic(&EngineConfig::tiny(), seed), sparsity, "synthetic"))
    }
}

/// How (and whether) the engine sparsifies site inputs.
#[derive(Clone, Debug)]
pub struct NativeSparsity {
    pattern: Pattern,
    /// Shared pipeline for every enabled site (`None` = dense forward,
    /// the ORIG baseline).
    shared: Option<Sparsifier>,
    /// Per-(layer, site) pipelines from calibrated method vectors,
    /// indexed `layer * 7 + site`; `None` entries are dense. Empty unless
    /// built by [`NativeSparsity::from_method_with_params`].
    per_site: Vec<Option<Sparsifier>>,
    disabled_sites: Vec<String>,
    /// Test/bench knob: run the sparsified-dense path even when the
    /// pipeline could emit a packed stream.
    force_dense: bool,
}

impl NativeSparsity {
    /// Dense (no sparsification).
    pub fn dense() -> NativeSparsity {
        NativeSparsity {
            pattern: Pattern::Dense,
            shared: None,
            per_site: Vec::new(),
            disabled_sites: Vec::new(),
            force_dense: false,
        }
    }

    /// Plain magnitude (ACT) sparsification at `pattern` on every site.
    pub fn act(pattern: Pattern) -> NativeSparsity {
        let shared = match pattern {
            Pattern::Dense => None,
            p => Some(Sparsifier::new(p)),
        };
        NativeSparsity {
            pattern,
            shared,
            per_site: Vec::new(),
            disabled_sites: Vec::new(),
            force_dense: false,
        }
    }

    /// Realize a [`MethodConfig`] natively without calibration data.
    /// Supported: ORIG/dense, ACT, D-PTS, VAR (and their site
    /// exemptions). Methods needing per-site calibration vectors
    /// (S-PTS/L-PTS/Amber) load through
    /// [`NativeSparsity::from_method_with_params`]; CLACT (data-dependent
    /// column energies), LS diagonal scales and R-Sparse variants are
    /// kernel-path-only and error rather than silently downgrading.
    pub fn from_method(cfg: &MethodConfig) -> Result<NativeSparsity> {
        if cfg.rank.is_some() {
            bail!("method '{}' is an R-Sparse variant — not representable natively", cfg.id);
        }
        let pattern = cfg.pattern()?;
        let shared = match pattern {
            Pattern::Dense => None,
            _ => Some(cfg.sparsifier(None, None).with_context(|| {
                format!(
                    "native engine cannot realize method '{}' without its calibration \
                     vectors (load them via NativeSparsity::from_method_with_params)",
                    cfg.id
                )
            })?),
        };
        Ok(NativeSparsity {
            pattern,
            shared,
            per_site: Vec::new(),
            disabled_sites: cfg.disabled_sites.clone(),
            force_dense: false,
        })
    }

    /// Realize a [`MethodConfig`] natively, drawing per-(layer, site)
    /// calibration vectors from a methodparams store: S-PTS/L-PTS eta
    /// shifts (`{eta_family}.l{l}.{site}`) and Amber channel norms
    /// (`{cscale_family}.l{l}.{site}`), validated against each site's
    /// input width. Methods without such families fall back to
    /// [`NativeSparsity::from_method`]; missing store entries are errors,
    /// never silent downgrades to ACT.
    pub fn from_method_with_params(
        cfg: &MethodConfig,
        methodparams: &TensorStore,
        engine_cfg: &EngineConfig,
    ) -> Result<NativeSparsity> {
        if cfg.rank.is_some() {
            bail!("method '{}' is an R-Sparse variant — not representable natively", cfg.id);
        }
        let pattern = cfg.pattern()?;
        let needs_eta = cfg.shift_mode as i64 == 2;
        let needs_cscale = cfg.cscale_family.is_some();
        if matches!(pattern, Pattern::Dense) || (!needs_eta && !needs_cscale) {
            return NativeSparsity::from_method(cfg);
        }
        // Borrowed lookups — Sparsifier construction copies what it
        // keeps, so no transient per-site clones of the store tensors.
        fn family<'a>(
            store: &'a TensorStore,
            method_id: &str,
            fam: &Option<String>,
            l: usize,
            site: &str,
            din: usize,
        ) -> Result<&'a [f32]> {
            let fam = fam.as_ref().with_context(|| {
                format!("method '{method_id}' sets a calibrated mode but names no param family")
            })?;
            let name = format!("{fam}.l{l}.{site}");
            let t = store
                .get(&name)
                .with_context(|| format!("method '{method_id}' needs tensor '{name}'"))?;
            anyhow::ensure!(
                t.data.len() == din,
                "methodparams tensor '{name}' has {} elements, site '{site}' is {din} wide",
                t.data.len()
            );
            Ok(&t.data)
        }
        let mut per_site = Vec::with_capacity(engine_cfg.n_layers * SITES.len());
        for l in 0..engine_cfg.n_layers {
            for site in SITES {
                if cfg.disabled_sites.iter().any(|d| d == site) {
                    per_site.push(None);
                    continue;
                }
                let din = engine_cfg.site_in_dim(site);
                let eta = if needs_eta {
                    Some(family(methodparams, &cfg.id, &cfg.eta_family, l, site, din)?)
                } else {
                    None
                };
                let cs = if needs_cscale {
                    Some(family(methodparams, &cfg.id, &cfg.cscale_family, l, site, din)?)
                } else {
                    None
                };
                per_site.push(Some(cfg.sparsifier(eta, cs)?));
            }
        }
        Ok(NativeSparsity {
            pattern,
            shared: None,
            per_site,
            disabled_sites: cfg.disabled_sites.clone(),
            force_dense: false,
        })
    }

    /// Disable the compressed-domain path (dense sparsified matvecs).
    pub fn with_force_dense(mut self, on: bool) -> NativeSparsity {
        self.force_dense = on;
        self
    }

    pub fn pattern(&self) -> Pattern {
        self.pattern
    }

    /// Is any sparsification configured at all?
    pub fn is_sparse(&self) -> bool {
        self.shared.is_some() || self.per_site.iter().any(|s| s.is_some())
    }

    /// Does this configuration carry per-(layer, site) calibrated
    /// pipelines (vs one shared pipeline)?
    pub fn is_per_site(&self) -> bool {
        !self.per_site.is_empty()
    }

    /// The pipeline applied at `(layer, site_idx)` — [`SITES`] order.
    /// `None` means that site runs dense.
    pub fn site(&self, layer: usize, site_idx: usize) -> Option<&Sparsifier> {
        if self.per_site.is_empty() {
            self.shared.as_ref()
        } else {
            self.per_site[layer * SITES.len() + site_idx].as_ref()
        }
    }

    pub(crate) fn force_dense(&self) -> bool {
        self.force_dense
    }
}

/// Running byte/step counters for the decode loop. `dense_activation_bytes`
/// is what a dense engine would have moved through the sparsified sites;
/// `moved_activation_bytes` is what this engine actually moved (packed
/// payload + raw `u32` metadata words on the compressed path). The ratio
/// is the measured activation-I/O reduction `BENCH_decode.json` reports.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DecodeStats {
    /// Tokens stepped (prefill + decode; batched lanes count one each).
    pub steps: u64,
    /// Site linear rows executed.
    pub site_rows: u64,
    pub dense_activation_bytes: u64,
    pub moved_activation_bytes: u64,
}

impl DecodeStats {
    pub fn reset(&mut self) {
        *self = DecodeStats::default();
    }

    /// dense / moved (1.0 when nothing has run).
    pub fn bytes_reduction(&self) -> f64 {
        if self.moved_activation_bytes == 0 {
            1.0
        } else {
            self.dense_activation_bytes as f64 / self.moved_activation_bytes as f64
        }
    }
}

/// The native engine: model weights + sparsification config + all scratch
/// buffers for one single-lane step. Steady state allocates nothing —
/// every buffer is sized at construction (batched lanes carry their own
/// scratch in [`StepBatch`](crate::engine::StepBatch)).
pub struct NativeEngine {
    pub(crate) model: NativeModel,
    pub(crate) sparsity: NativeSparsity,
    /// Per-site sparsification enables, indexed like [`SITES`].
    pub(crate) enabled: [bool; 7],
    /// Compressed stream for `d_model`-wide site inputs (None off the
    /// packed path or when the pattern cannot hold that width). Grows to
    /// the widest lane count seen, then steady.
    pub(crate) packed_d: Option<PackedNM>,
    /// Compressed stream for the `ffn`-wide `down` input.
    pub(crate) packed_f: Option<PackedNM>,
    /// RoPE inverse frequencies, `[head_dim/2]` — shared by every head,
    /// precomputed once (a `powf` per element per step would dominate
    /// the very step cost `BENCH_decode.json` measures).
    pub(crate) rope_freqs: Vec<f32>,
    pub(crate) scratch: Scratch,
    /// Single-row scratch for the sparsified-dense path (shared with the
    /// batched stepper: lanes sparsify one row at a time).
    pub(crate) act: Vec<f32>,
    // Single-lane step buffers (residual stream, norms, projections, FFN).
    x: Vec<f32>,
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctx: Vec<f32>,
    site_out_d: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    fbuf: Vec<f32>,
    probs: Vec<f32>,
    logits: Vec<f32>,
    /// Position-major scratch for blocked prefill (`engine::prefill`) —
    /// retained across chunks like the step buffers above.
    pub(crate) pblock: crate::engine::prefill::PrefillBlock,
    pub(crate) stats: DecodeStats,
    /// The engine's one worker set: spawned at construction (default one,
    /// i.e. fully inline), parked on a condvar between ticks, shared by
    /// every site matmul, the lm head, and per-lane pack/sparsify fan-out
    /// (DESIGN.md §2.11). Partitioning is by output rows, so results are
    /// bitwise identical at any width.
    pub(crate) workers: WorkerPool,
}

const ROPE_BASE: f32 = 10000.0;

impl NativeEngine {
    pub fn new(model: NativeModel, sparsity: NativeSparsity) -> Result<NativeEngine> {
        let cfg = model.cfg.clone();
        anyhow::ensure!(
            cfg.n_heads > 0 && cfg.d_model % cfg.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            cfg.d_model,
            cfg.n_heads
        );
        anyhow::ensure!(cfg.head_dim() % 2 == 0, "RoPE needs an even head_dim");
        anyhow::ensure!(cfg.max_seq > 0, "max_seq must be positive");
        let enabled = site_enables(&sparsity);
        // Enabled sparsified sites must fit the pattern's block geometry.
        if let Pattern::NM { m, .. } = sparsity.pattern() {
            for (i, site) in SITES.iter().enumerate() {
                let din = cfg.site_in_dim(site);
                anyhow::ensure!(
                    !enabled[i] || din % m as usize == 0,
                    "site '{site}' width {din} is not a multiple of M={m}"
                );
            }
        }
        // A site streams compressed when its pipeline is selection-only
        // (per-site tables may mix: an eta-shifted site goes dense while
        // an Amber-scaled one packs).
        let mut packable = [false; 7];
        for (i, p) in packable.iter_mut().enumerate() {
            *p = enabled[i]
                && (0..cfg.n_layers)
                    .any(|l| sparsity.site(l, i).is_some_and(Sparsifier::is_packable));
        }
        let force_dense = sparsity.force_dense();
        let needs_d = !force_dense && packable[..6].iter().any(|&p| p); // q k v o gate up
        let needs_f = !force_dense && packable[6]; // down
        let mk = |cols: usize| Some(PackedNM::new(sparsity.pattern(), cols));
        let packed_d = if needs_d { mk(cfg.d_model) } else { None };
        let packed_f = if needs_f { mk(cfg.ffn) } else { None };
        let half = cfg.head_dim() / 2;
        let rope_freqs: Vec<f32> =
            (0..half).map(|i| ROPE_BASE.powf(-(i as f32) / half as f32)).collect();
        Ok(NativeEngine {
            rope_freqs,
            x: vec![0.0; cfg.d_model],
            h: vec![0.0; cfg.d_model],
            act: Vec::with_capacity(cfg.ffn.max(cfg.d_model)),
            q: vec![0.0; cfg.d_model],
            k: vec![0.0; cfg.d_model],
            v: vec![0.0; cfg.d_model],
            ctx: vec![0.0; cfg.d_model],
            site_out_d: vec![0.0; cfg.d_model],
            gate: vec![0.0; cfg.ffn],
            up: vec![0.0; cfg.ffn],
            fbuf: vec![0.0; cfg.ffn],
            probs: Vec::with_capacity(cfg.max_seq),
            logits: vec![0.0; cfg.vocab],
            pblock: crate::engine::prefill::PrefillBlock::default(),
            scratch: Scratch::new(),
            stats: DecodeStats::default(),
            workers: WorkerPool::new(1),
            model,
            sparsity,
            enabled,
            packed_d,
            packed_f,
        })
    }

    /// Seeded synthetic engine (no artifacts) — CI, benches, tests.
    pub fn synthetic(
        cfg: &EngineConfig,
        seed: u64,
        sparsity: NativeSparsity,
    ) -> Result<NativeEngine> {
        NativeEngine::new(NativeModel::synthetic(cfg, seed), sparsity)
    }

    pub fn config(&self) -> &EngineConfig {
        &self.model.cfg
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    pub fn sparsity(&self) -> &NativeSparsity {
        &self.sparsity
    }

    /// Is the compressed-domain matvec path active?
    pub fn uses_packed(&self) -> bool {
        self.packed_d.is_some() || self.packed_f.is_some()
    }

    /// A page pool sized for this engine at the default page granularity.
    pub fn new_kv_pool(&self) -> KvPagePool {
        let cfg = &self.model.cfg;
        KvPagePool::new(cfg, KvPagePool::default_page_tokens(cfg.max_seq))
    }

    /// A page pool with an explicit page size (tests pin page-boundary
    /// and sliding-window behavior with tiny pages).
    pub fn new_kv_pool_with(&self, page_tokens: usize) -> KvPagePool {
        KvPagePool::new(&self.model.cfg, page_tokens)
    }

    /// Resize the worker pool (min 1; 1 = fully inline). Threading only
    /// changes wall time, never bits: every output row is one whole dot
    /// computed by exactly one worker (`rust/tests/step_batch.rs` pins
    /// logits identical across thread counts).
    pub fn set_threads(&mut self, threads: usize) {
        let threads = threads.max(1);
        if self.workers.threads() != threads {
            self.workers = WorkerPool::new(threads);
        }
    }

    /// Builder form of [`NativeEngine::set_threads`].
    pub fn with_threads(mut self, threads: usize) -> NativeEngine {
        self.set_threads(threads);
        self
    }

    /// Current worker count (caller thread included).
    pub fn threads(&self) -> usize {
        self.workers.threads()
    }

    pub fn stats(&self) -> DecodeStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats.reset();
    }

    /// Next-token logits after the last [`NativeEngine::step`].
    pub fn logits(&self) -> &[f32] {
        &self.logits
    }

    /// Greedy token from the current logits (first index on ties — the
    /// same rule as `Coordinator`'s argmax).
    pub fn argmax_token(&self) -> u32 {
        argmax(&self.logits)
    }

    /// `log p(token)` under the current logits (f64 log-softmax).
    pub fn logprob_of(&self, token: u32) -> f64 {
        let max = self.logits.iter().fold(f32::NEG_INFINITY, |a, b| a.max(*b)) as f64;
        let sum: f64 = self.logits.iter().map(|v| ((*v as f64) - max).exp()).sum();
        (self.logits[token as usize] as f64) - max - sum.ln()
    }

    /// Advance one token: consume `token` at the cache's next position and
    /// leave next-token logits in [`NativeEngine::logits`]. Errors when the
    /// cache is full or the token is out of vocabulary.
    pub fn step(&mut self, kv: &mut KvCache, pool: &mut KvPagePool, token: u32) -> Result<()> {
        let NativeEngine {
            model,
            sparsity,
            enabled,
            packed_d,
            packed_f,
            rope_freqs,
            scratch,
            x,
            h,
            act,
            q,
            k,
            v,
            ctx,
            site_out_d,
            gate,
            up,
            fbuf,
            probs,
            logits,
            stats,
            workers,
        } = self;
        let cfg = &model.cfg;
        anyhow::ensure!(
            !kv.is_full(),
            "KV cache full: context length {} reached",
            kv.capacity()
        );
        anyhow::ensure!(
            (token as usize) < cfg.vocab,
            "token {token} out of vocabulary ({})",
            cfg.vocab
        );
        let pos = kv.len();
        x.copy_from_slice(model.embed.row(token as usize));
        for (l, layer) in model.layers.iter().enumerate() {
            let sp = |i: usize| if enabled[i] { sparsity.site(l, i) } else { None };
            // Attention block.
            rmsnorm_into(x, &layer.norm1, h);
            let (s0, s1, s2) = (sp(0), sp(1), sp(2));
            let p0 = pick(s0, packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteQ, stats.steps);
            apply_site(&layer.wq, h, s0, p0, scratch, act, q, stats, workers);
            drop(sg);
            let p1 = pick(s1, packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteK, stats.steps);
            apply_site(&layer.wk, h, s1, p1, scratch, act, k, stats, workers);
            drop(sg);
            let p2 = pick(s2, packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteV, stats.steps);
            apply_site(&layer.wv, h, s2, p2, scratch, act, v, stats, workers);
            drop(sg);
            let sg = trace::span_id(Phase::Attention, stats.steps);
            rope_in_place(q, cfg.n_heads, cfg.head_dim(), pos, rope_freqs);
            rope_in_place(k, cfg.n_heads, cfg.head_dim(), pos, rope_freqs);
            kv.write_row(pool, l, k, v);
            attention_paged(q, kv, l, pos + 1, cfg.n_heads, cfg.head_dim(), probs, ctx);
            drop(sg);
            let s3 = sp(3);
            let pd = pick(s3, packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteO, stats.steps);
            apply_site(&layer.wo, ctx, s3, pd, scratch, act, site_out_d, stats, workers);
            drop(sg);
            add_assign(x, site_out_d);

            // FFN block (SwiGLU).
            rmsnorm_into(x, &layer.norm2, h);
            let s4 = sp(4);
            let pg = pick(s4, packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteGate, stats.steps);
            apply_site(&layer.wgate, h, s4, pg, scratch, act, gate, stats, workers);
            drop(sg);
            let s5 = sp(5);
            let pu = pick(s5, packed_d.as_mut());
            let sg = trace::span_id(Phase::SiteUp, stats.steps);
            apply_site(&layer.wup, h, s5, pu, scratch, act, up, stats, workers);
            drop(sg);
            for ((f, g), u) in fbuf.iter_mut().zip(gate.iter()).zip(up.iter()) {
                *f = silu(*g) * u;
            }
            let s6 = sp(6);
            let pf = pick(s6, packed_f.as_mut());
            let sg = trace::span_id(Phase::SiteDown, stats.steps);
            apply_site(&layer.wdown, fbuf, s6, pf, scratch, act, site_out_d, stats, workers);
            drop(sg);
            add_assign(x, site_out_d);
        }
        kv.advance();
        rmsnorm_into(x, &model.final_norm, h);
        // The lm head is the single largest matmul of a step (vocab rows):
        // run it through the pool too. rows == 1 keeps it bitwise equal to
        // the dense_matvec it replaced.
        let sg = trace::span_id(Phase::LmHead, stats.steps);
        dense_matmul_nt(&model.lm_head, h, 1, logits, workers);
        drop(sg);
        stats.steps += 1;
        Ok(())
    }
}

/// First index of the maximum (the `Coordinator` tie-break rule).
pub(crate) fn argmax(xs: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best as u32
}

/// Which sites sparsify, in [`SITES`] order.
fn site_enables(sparsity: &NativeSparsity) -> [bool; 7] {
    let mut enabled = [sparsity.is_sparse(); 7];
    for (i, site) in SITES.iter().enumerate() {
        if sparsity.disabled_sites.iter().any(|d| d == site) {
            enabled[i] = false;
        }
    }
    enabled
}

/// The packed stream to use for a site: only selection-only pipelines can
/// stream compressed; everything else (shifted, VAR, dense) goes through
/// the dense matvec.
#[inline]
pub(crate) fn pick<'a>(
    sp: Option<&Sparsifier>,
    packed: Option<&'a mut PackedNM>,
) -> Option<&'a mut PackedNM> {
    match sp {
        Some(s) if s.is_packable() => packed,
        _ => None,
    }
}

/// One (possibly sparsified) linear site: `out[o] = w.row(o) · s(input)`.
/// The compressed path packs the row during selection and runs the GEMV
/// over the stream; the dense path sparsifies a copy in place. Byte
/// counters record what actually moved. The matmul itself runs on the
/// engine's worker pool, partitioned by weight rows (bitwise invariant).
#[allow(clippy::too_many_arguments)]
pub(crate) fn apply_site(
    w: &Tensor,
    input: &[f32],
    sp: Option<&Sparsifier>,
    packed: Option<&mut PackedNM>,
    scratch: &mut Scratch,
    act: &mut Vec<f32>,
    out: &mut [f32],
    stats: &mut DecodeStats,
    wp: &WorkerPool,
) {
    let din = input.len();
    debug_assert_eq!(w.cols(), din);
    debug_assert_eq!(w.rows(), out.len());
    stats.site_rows += 1;
    stats.dense_activation_bytes += (din * 4) as u64;
    match sp {
        Some(sp) => match packed {
            Some(packed) => {
                packed.clear();
                let sg = trace::span(Phase::Pack);
                sp.pack_row_into(input, packed, scratch);
                drop(sg);
                stats.moved_activation_bytes +=
                    (packed.values().len() * 4 + packed.meta_words().len() * 4) as u64;
                packed.matmul_nt_into(w, out, wp);
            }
            None => {
                act.clear();
                act.extend_from_slice(input);
                let sg = trace::span(Phase::Sparsify);
                sp.sparsify_row(act, scratch);
                drop(sg);
                stats.moved_activation_bytes += (din * 4) as u64;
                dense_matmul_nt(w, act, 1, out, wp);
            }
        },
        None => {
            stats.moved_activation_bytes += (din * 4) as u64;
            dense_matmul_nt(w, input, 1, out, wp);
        }
    }
}

/// The batched-lane form of [`apply_site`]: `lanes` input rows (lane-major
/// `[lanes, din]`) through one site as a single multi-row matmul. On the
/// compressed path every lane's row is packed by the per-row selection
/// kernel — rows fanned out across the pool (`pack_rows_pool`) — into one
/// stream and the GEMM runs once over all lanes, partitioned by weight
/// rows (see [`PackedNM::matmul_nt_into`]); the dense paths sparsify per
/// lane on the pool (`sparsify_rows_pool`) with the identical per-row
/// kernels, then run the pooled dense GEMM. Every lane's output is the
/// same whole-row dot as a single-lane [`apply_site`], so batched,
/// sequential, and any thread count are all bitwise-equal.
#[allow(clippy::too_many_arguments)]
pub(crate) fn apply_site_batch(
    w: &Tensor,
    inputs: &[f32],
    lanes: usize,
    sp: Option<&Sparsifier>,
    packed: Option<&mut PackedNM>,
    act: &mut Vec<f32>,
    out: &mut [f32],
    stats: &mut DecodeStats,
    wp: &WorkerPool,
) {
    let din = w.cols();
    let w_rows = w.rows();
    debug_assert_eq!(inputs.len(), lanes * din);
    debug_assert_eq!(out.len(), lanes * w_rows);
    stats.site_rows += lanes as u64;
    stats.dense_activation_bytes += (lanes * din * 4) as u64;
    match sp {
        Some(sp) => match packed {
            Some(packed) => {
                let sg = trace::span(Phase::Pack);
                sp.pack_rows_pool(inputs, din, packed, wp);
                drop(sg);
                stats.moved_activation_bytes +=
                    (packed.values().len() * 4 + packed.meta_words().len() * 4) as u64;
                packed.matmul_nt_into(w, out, wp);
            }
            None => {
                act.clear();
                act.extend_from_slice(inputs);
                let sg = trace::span(Phase::Sparsify);
                sp.sparsify_rows_pool(act, din, wp);
                drop(sg);
                stats.moved_activation_bytes += (lanes * din * 4) as u64;
                dense_matmul_nt(w, act, lanes, out, wp);
            }
        },
        None => {
            stats.moved_activation_bytes += (lanes * din * 4) as u64;
            dense_matmul_nt(w, inputs, lanes, out, wp);
        }
    }
}

/// RMSNorm with the python model's epsilon (1e-6), f64 mean accumulation.
pub(crate) fn rmsnorm_into(x: &[f32], g: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(x.len(), out.len());
    let ms = x.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>() / x.len() as f64;
    let r = (1.0 / (ms + 1e-6).sqrt()) as f32;
    for ((o, v), gg) in out.iter_mut().zip(x).zip(g) {
        *o = *v * r * *gg;
    }
}

/// Rotary position embedding at one position (split-half convention,
/// matching `python/compile/model.py::rope`). `freqs` is the engine's
/// precomputed `[head_dim/2]` inverse-frequency table.
pub(crate) fn rope_in_place(
    x: &mut [f32],
    n_heads: usize,
    head_dim: usize,
    pos: usize,
    freqs: &[f32],
) {
    let half = head_dim / 2;
    debug_assert_eq!(freqs.len(), half);
    for head in 0..n_heads {
        let o = head * head_dim;
        for (i, freq) in freqs.iter().enumerate() {
            let ang = pos as f32 * freq;
            let (sin, cos) = ang.sin_cos();
            let a = x[o + i];
            let b = x[o + i + half];
            x[o + i] = a * cos - b * sin;
            x[o + i + half] = a * sin + b * cos;
        }
    }
}

/// Causal attention for one query over `rows` cached positions, read as
/// per-page contiguous slabs from the paged cache. Positions are visited
/// in order across segments, so scores and the weighted value sum
/// accumulate exactly as they did over one contiguous buffer.
pub(crate) fn attention_paged(
    q: &[f32],
    kv: &KvCache,
    layer: usize,
    rows: usize,
    n_heads: usize,
    head_dim: usize,
    probs: &mut Vec<f32>,
    out: &mut [f32],
) {
    let d = n_heads * head_dim;
    let scale = 1.0 / (head_dim as f32).sqrt();
    for head in 0..n_heads {
        let off = head * head_dim;
        let qh = &q[off..off + head_dim];
        probs.clear();
        let mut maxs = f32::NEG_INFINITY;
        for seg in kv.key_segments(layer, rows) {
            for krow in seg.chunks_exact(d) {
                let s = dot(qh, &krow[off..off + head_dim]) * scale;
                probs.push(s);
                maxs = maxs.max(s);
            }
        }
        let mut denom = 0.0f32;
        for p in probs.iter_mut() {
            *p = (*p - maxs).exp();
            denom += *p;
        }
        let inv = 1.0 / denom;
        let oh = &mut out[off..off + head_dim];
        oh.iter_mut().for_each(|o| *o = 0.0);
        let mut j = 0usize;
        for seg in kv.value_segments(layer, rows) {
            for vrow in seg.chunks_exact(d) {
                let wj = probs[j] * inv;
                j += 1;
                for (o, vv) in oh.iter_mut().zip(&vrow[off..off + head_dim]) {
                    *o += wj * vv;
                }
            }
        }
        debug_assert_eq!(j, rows);
    }
}

/// Dense GEMV: `out[o] = w.row(o) · x` — the baseline the packed path is
/// bitwise-equal to on selection-only pipelines.
pub(crate) fn dense_matvec(w: &Tensor, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(w.cols(), x.len());
    debug_assert_eq!(w.rows(), out.len());
    let cols = w.cols();
    for (o, row) in out.iter_mut().zip(w.data.chunks_exact(cols)) {
        *o = dot(row, x);
    }
}

/// Batched dense linear over `rows` lane inputs (`xs` is `[rows, cols]`
/// row-major): `out[r * w.rows() + o] = w.row(o) · xs[r]`, partitioned
/// across the worker pool by **weight-row ranges** and iterated
/// weight-row-major within a range so one weight row serves every lane
/// while hot — the dense-site / lm-head form of the batched step. Each
/// output is one whole ascending-index dot computed by exactly one worker
/// (the same dot as [`dense_matvec`]), so single-threaded, pooled, and
/// GEMV results are all bitwise-equal (DESIGN.md §2.11).
pub(crate) fn dense_matmul_nt(
    w: &Tensor,
    xs: &[f32],
    rows: usize,
    out: &mut [f32],
    wp: &WorkerPool,
) {
    let cols = w.cols();
    let w_rows = w.rows();
    debug_assert_eq!(xs.len(), rows * cols);
    debug_assert_eq!(out.len(), rows * w_rows);
    if rows == 0 || w_rows == 0 {
        return;
    }
    if wp.threads() == 1 || w_rows == 1 {
        for o in 0..w_rows {
            let wrow = w.row(o);
            for r in 0..rows {
                out[r * w_rows + o] = dot(wrow, &xs[r * cols..(r + 1) * cols]);
            }
        }
        return;
    }
    let shared = DisjointSliceMut::new(out);
    wp.run_ranges(w_rows, |lo, hi| {
        for o in lo..hi {
            let wrow = w.row(o);
            for r in 0..rows {
                // SAFETY: weight-row ranges are disjoint across parts, so
                // element r*w_rows+o has exactly one writer.
                unsafe { shared.write(r * w_rows + o, dot(wrow, &xs[r * cols..(r + 1) * cols])) };
            }
        }
    });
}

#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

#[inline]
pub(crate) fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[inline]
pub(crate) fn add_assign(x: &mut [f32], y: &[f32]) {
    for (a, b) in x.iter_mut().zip(y) {
        *a += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn pooled_dense_matmul_matches_matvec_oracle_bitwise() {
        // Weight-row partitioning must be invisible: every output element
        // is one whole dot, so any pool width reproduces the per-lane
        // dense_matvec bits exactly — including pool widths that do not
        // divide the weight-row count.
        let mut rng = Rng::new(17);
        let (w_rows, cols, lanes) = (13usize, 32usize, 5usize);
        let w = Tensor::from_vec(
            &[w_rows, cols],
            (0..w_rows * cols).map(|_| rng.normal() as f32).collect(),
        );
        let xs: Vec<f32> = (0..lanes * cols).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0.0f32; lanes * w_rows];
        for r in 0..lanes {
            let row = &xs[r * cols..(r + 1) * cols];
            let mut out = vec![0.0f32; w_rows];
            dense_matvec(&w, row, &mut out);
            want[r * w_rows..(r + 1) * w_rows].copy_from_slice(&out);
        }
        for threads in [1usize, 2, 4, 7] {
            let wp = WorkerPool::new(threads);
            let mut got = vec![0.0f32; lanes * w_rows];
            dense_matmul_nt(&w, &xs, lanes, &mut got, &wp);
            let same = got
                .iter()
                .zip(&want)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "threads={threads}");
        }
    }

    #[test]
    fn set_threads_rebuilds_only_on_change() {
        let mut e = NativeEngine::synthetic(
            &EngineConfig::tiny(),
            3,
            NativeSparsity::act(Pattern::NM { n: 8, m: 16 }),
        )
        .expect("engine");
        assert_eq!(e.threads(), 1);
        e.set_threads(0); // clamps to 1
        assert_eq!(e.threads(), 1);
        e.set_threads(3);
        assert_eq!(e.threads(), 3);
        let e = e.with_threads(2);
        assert_eq!(e.threads(), 2);
    }
}
