//! Paged per-session KV storage for the native decode engine.
//!
//! PR 4's cache pinned `n_layers × max_seq × d_model` buffers per session
//! — a replica serving 64 mostly-short sessions held 64 full-context
//! allocations. This module replaces that with **paged allocation**
//! (DESIGN.md §2.10): KV rows live in fixed-size [`KvPage`]s of
//! `page_tokens` positions, checked out of a shared [`KvPagePool`] as a
//! session's context grows and recycled (O(1) per page, free-list push)
//! the moment [`KvCache::truncate`] / [`KvCache::reset`] / eviction lets
//! them go. Peak KV bytes therefore track *live context*, not
//! `sessions × max_seq` — the pool counts it ([`KvPagePool::peak_bytes`]).
//!
//! Page layout: one page holds `page_tokens` consecutive positions for
//! *every* layer — `k[(layer * page_tokens + slot) * d_model + i]` — so
//! attention over one layer reads one contiguous slab per page
//! ([`KvCache::key_segments`]). A position's rows are written during that
//! token's step and then immutable; `len` alone tracks validity, so a
//! recycled page's stale contents are never observable.
//!
//! The pool also owns the **sliding-window rule** ([`window_start`]): a
//! session whose row outgrows `max_seq` drops its oldest page-aligned
//! block and re-anchors at position 0 (RoPE positions are absolute, so a
//! slide is a page-granular crop + re-prefill — the native twin of the
//! PJRT path's left-crop, amortized over `page_tokens` tokens). The rule
//! is a pure function of the row length, so an evicted session recomputes
//! the same window and re-prefills transparently.

use crate::engine::model::EngineConfig;

/// One fixed-size block of KV storage: `page_tokens` positions × every
/// layer, for both K and V. Buffers are allocated once and recycled
/// through the [`KvPagePool`] free list, never shrunk.
#[derive(Debug)]
pub struct KvPage {
    /// `[n_layers * page_tokens * d_model]` keys (post-RoPE).
    k: Vec<f32>,
    /// `[n_layers * page_tokens * d_model]` values.
    v: Vec<f32>,
}

/// Shared page allocator + recycler for every cache of one engine
/// geometry (one per replica backend). `take`/`put` are O(1) free-list
/// ops; fresh pages are allocated only when the free list is empty, so
/// steady-state serving reuses a working set proportional to live
/// context.
#[derive(Debug)]
pub struct KvPagePool {
    d_model: usize,
    n_layers: usize,
    page_tokens: usize,
    max_seq: usize,
    free: Vec<KvPage>,
    /// Pages currently held by caches.
    outstanding: usize,
    /// High-water mark of `outstanding` — the proportionality witness.
    peak: usize,
    /// Pages served from the free list (recycles).
    recycled: u64,
    /// Fresh page allocations ever made.
    allocated: u64,
}

impl KvPagePool {
    /// Default page size for a context budget: coarse enough that window
    /// slides stay rare, fine enough that short sessions hold little.
    pub fn default_page_tokens(max_seq: usize) -> usize {
        (max_seq / 4).clamp(1, 32).min(max_seq.max(1))
    }

    pub fn new(cfg: &EngineConfig, page_tokens: usize) -> KvPagePool {
        let page_tokens = page_tokens.clamp(1, cfg.max_seq.max(1));
        KvPagePool {
            d_model: cfg.d_model,
            n_layers: cfg.n_layers,
            page_tokens,
            max_seq: cfg.max_seq,
            free: Vec::new(),
            outstanding: 0,
            peak: 0,
            recycled: 0,
            allocated: 0,
        }
    }

    /// Position capacity of one page.
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// The engine context budget this pool serves.
    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Resident bytes of one page (K + V, f32).
    pub fn page_bytes(&self) -> usize {
        2 * self.n_layers * self.page_tokens * self.d_model * 4
    }

    /// Pages currently checked out by caches.
    pub fn outstanding_pages(&self) -> usize {
        self.outstanding
    }

    /// High-water mark of checked-out pages.
    pub fn peak_pages(&self) -> usize {
        self.peak
    }

    /// Bytes currently checked out by caches.
    pub fn outstanding_bytes(&self) -> usize {
        self.outstanding * self.page_bytes()
    }

    /// High-water mark of checked-out bytes — what "peak KV proportional
    /// to live context" is asserted against.
    pub fn peak_bytes(&self) -> usize {
        self.peak * self.page_bytes()
    }

    /// Pages served from the free list instead of a fresh allocation.
    pub fn recycled(&self) -> u64 {
        self.recycled
    }

    /// Fresh page allocations ever made (free + outstanding).
    pub fn pages_allocated(&self) -> u64 {
        self.allocated
    }

    /// A fresh empty cache bound to this pool's geometry.
    pub fn new_cache(&self) -> KvCache {
        KvCache {
            d_model: self.d_model,
            n_layers: self.n_layers,
            page_tokens: self.page_tokens,
            max_seq: self.max_seq,
            len: 0,
            pages: Vec::new(),
        }
    }

    /// First window position for a row of `row_len` tokens under this
    /// pool's page grid — see [`window_start`].
    pub fn window_start(&self, row_len: usize) -> usize {
        window_start(row_len, self.max_seq, self.page_tokens)
    }

    fn take_page(&mut self) -> KvPage {
        self.outstanding += 1;
        self.peak = self.peak.max(self.outstanding);
        match self.free.pop() {
            Some(page) => {
                self.recycled += 1;
                page
            }
            None => {
                self.allocated += 1;
                let n = self.n_layers * self.page_tokens * self.d_model;
                KvPage { k: vec![0.0; n], v: vec![0.0; n] }
            }
        }
    }

    fn put_page(&mut self, page: KvPage) {
        debug_assert!(self.outstanding > 0, "page released twice");
        self.outstanding -= 1;
        self.free.push(page);
    }
}

/// First retained position of a session row under the sliding-window
/// rule: rows within the context budget keep everything; longer rows drop
/// the oldest tokens in whole-page steps, so the retained window length
/// stays in `(max_seq - page_tokens, max_seq]`. Pure function of the row
/// length — an evicted session recomputes the same window.
pub fn window_start(row_len: usize, max_seq: usize, page_tokens: usize) -> usize {
    if row_len <= max_seq {
        0
    } else {
        (row_len - max_seq).div_ceil(page_tokens) * page_tokens
    }
}

/// KV storage for one decode session: an ordered list of pages checked
/// out of the [`KvPagePool`], plus `len` (the committed positions).
/// Methods that can change the page set take the pool so recycling is
/// immediate; dropping a cache without resetting it frees the memory but
/// skips the recycle (fine for one-shot tools, avoided on serving paths).
#[derive(Debug)]
pub struct KvCache {
    d_model: usize,
    n_layers: usize,
    page_tokens: usize,
    max_seq: usize,
    len: usize,
    pages: Vec<KvPage>,
}

impl KvCache {
    /// Cached positions (tokens already committed).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Position capacity (the engine's `max_seq`).
    pub fn capacity(&self) -> usize {
        self.max_seq
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.max_seq
    }

    /// Pages currently held.
    pub fn pages_held(&self) -> usize {
        self.pages.len()
    }

    /// Resident bytes of the held pages (measured from the buffers, so
    /// it can never drift from the pool's page geometry).
    pub fn bytes(&self) -> usize {
        self.pages.iter().map(|p| (p.k.len() + p.v.len()) * 4).sum()
    }

    /// Forget everything, returning every page to the pool.
    pub fn reset(&mut self, pool: &mut KvPagePool) {
        self.truncate(pool, 0);
    }

    /// Roll back to the first `len` positions (no-op if already shorter),
    /// returning pages past the new end to the pool — O(1) per released
    /// page. Positions ≥ `len` will be overwritten by subsequent steps.
    pub fn truncate(&mut self, pool: &mut KvPagePool, len: usize) {
        self.len = self.len.min(len);
        let needed = self.len.div_ceil(self.page_tokens);
        while self.pages.len() > needed {
            pool.put_page(self.pages.pop().expect("pages.len() > needed"));
        }
    }

    /// Write the current position's K and V rows for `layer`, checking a
    /// page out of the pool at page boundaries. Every layer must be
    /// written before [`KvCache::advance`] commits the position. Panics
    /// when full — the engine checks before stepping.
    pub fn write_row(&mut self, pool: &mut KvPagePool, layer: usize, k_row: &[f32], v_row: &[f32]) {
        assert!(self.len < self.max_seq, "KV cache full");
        assert_eq!(k_row.len(), self.d_model);
        assert_eq!(v_row.len(), self.d_model);
        let (page, slot) = (self.len / self.page_tokens, self.len % self.page_tokens);
        if page == self.pages.len() {
            let fresh = pool.take_page();
            debug_assert_eq!(
                fresh.k.len(),
                self.n_layers * self.page_tokens * self.d_model,
                "cache used with a pool of different page geometry"
            );
            self.pages.push(fresh);
        }
        let base = (layer * self.page_tokens + slot) * self.d_model;
        let p = &mut self.pages[page];
        p.k[base..base + self.d_model].copy_from_slice(k_row);
        p.v[base..base + self.d_model].copy_from_slice(v_row);
    }

    /// Commit the current position (call once per token, after every
    /// layer's [`KvCache::write_row`]).
    pub fn advance(&mut self) {
        assert!(self.len < self.max_seq, "KV cache full");
        debug_assert!(
            self.len / self.page_tokens < self.pages.len(),
            "advance before any write_row at this position"
        );
        self.len += 1;
    }

    /// Positional form of [`KvCache::write_row`] for blocked prefill:
    /// write the K/V rows of `layer` at uncommitted position `pos`
    /// (`len <= pos < len + block`). Within one layer the block's
    /// positions must be written in ascending order so pages check out
    /// sequentially; [`KvCache::advance_n`] commits the whole block once
    /// every layer of every position is written. Bit-identical storage to
    /// a `write_row`/`advance` loop — only the commit granularity differs.
    pub fn write_row_at(
        &mut self,
        pool: &mut KvPagePool,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        assert!(pos < self.max_seq, "KV cache full");
        assert!(pos >= self.len, "position {pos} already committed (len {})", self.len);
        assert_eq!(k_row.len(), self.d_model);
        assert_eq!(v_row.len(), self.d_model);
        let (page, slot) = (pos / self.page_tokens, pos % self.page_tokens);
        if page == self.pages.len() {
            let fresh = pool.take_page();
            debug_assert_eq!(
                fresh.k.len(),
                self.n_layers * self.page_tokens * self.d_model,
                "cache used with a pool of different page geometry"
            );
            self.pages.push(fresh);
        }
        assert!(page < self.pages.len(), "block positions must be written in ascending order");
        let base = (layer * self.page_tokens + slot) * self.d_model;
        let p = &mut self.pages[page];
        p.k[base..base + self.d_model].copy_from_slice(k_row);
        p.v[base..base + self.d_model].copy_from_slice(v_row);
    }

    /// Commit `n` in-flight positions at once — the blocked-prefill twin
    /// of [`KvCache::advance`], called after every layer × position
    /// [`KvCache::write_row_at`] of the block.
    pub fn advance_n(&mut self, n: usize) {
        assert!(self.len + n <= self.max_seq, "KV cache full");
        debug_assert!(
            n == 0 || (self.len + n - 1) / self.page_tokens < self.pages.len(),
            "advance_n past the written rows"
        );
        self.len += n;
    }

    /// The valid key rows of `layer` as per-page contiguous slabs, in
    /// position order — attention at position `t` passes `rows = t + 1`
    /// (its own row was just written, `len` still `t`). Each slab is
    /// `min(page_tokens, remaining) × d_model`.
    pub fn key_segments(&self, layer: usize, rows: usize) -> impl Iterator<Item = &[f32]> + '_ {
        self.segments(layer, rows, false)
    }

    /// The valid value rows of `layer` (see [`KvCache::key_segments`]).
    pub fn value_segments(&self, layer: usize, rows: usize) -> impl Iterator<Item = &[f32]> + '_ {
        self.segments(layer, rows, true)
    }

    fn segments(
        &self,
        layer: usize,
        rows: usize,
        values: bool,
    ) -> impl Iterator<Item = &[f32]> + '_ {
        let (pt, d) = (self.page_tokens, self.d_model);
        debug_assert!(rows <= self.pages.len() * pt, "reading unwritten rows");
        let n_pages = rows.div_ceil(pt);
        (0..n_pages).map(move |p| {
            let take = (rows - p * pt).min(pt);
            let base = layer * pt * d;
            let page = &self.pages[p];
            let buf = if values { &page.v } else { &page.k };
            &buf[base..base + take * d]
        })
    }
}

/// LRU pool of per-session cache slots, keyed by the scheduler's session
/// id. Bounded: admitting session `cap + 1` evicts the least-recently-
/// used slot, returning its pages to the shared [`KvPagePool`]. An
/// evicted session that steps again re-prefills its window from the row
/// — slower, never wrong (`rust/tests/step_batch.rs` pins token identity
/// at cap 1 with interleaved sessions).
#[derive(Debug)]
pub struct SessionKvPool {
    cap: usize,
    /// `(session id, slot)`, least-recently-used first.
    entries: Vec<(u64, SessionSlot)>,
    evictions: u64,
}

/// One session's cache plus the window position it is anchored at:
/// `kv` holds positions `anchor..anchor + kv.len()` of the session row.
/// A slide (or a rebind after eviction) resets the cache and moves the
/// anchor; the backend reconciles `anchor` against [`window_start`]
/// before every step.
#[derive(Debug)]
pub struct SessionSlot {
    pub anchor: usize,
    pub kv: KvCache,
}

impl SessionKvPool {
    pub fn new(cap: usize) -> SessionKvPool {
        SessionKvPool { cap: cap.max(1), entries: Vec::new(), evictions: 0 }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Resident-slot bound — batched steps must chunk lanes to this.
    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn contains(&self, id: u64) -> bool {
        self.entries.iter().any(|(e, _)| *e == id)
    }

    /// Sessions evicted to make room so far.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// The session's slot, created (or rebound from the evicted LRU
    /// entry, its pages recycled) on miss; the entry becomes
    /// most-recently-used.
    pub fn get_or_create(&mut self, pages: &mut KvPagePool, id: u64) -> &mut SessionSlot {
        if let Some(i) = self.entries.iter().position(|(e, _)| *e == id) {
            let entry = self.entries.remove(i);
            self.entries.push(entry);
        } else if self.entries.len() < self.cap {
            self.entries.push((id, SessionSlot { anchor: 0, kv: pages.new_cache() }));
        } else {
            // Evict the LRU entry: pages go back to the pool, the slot is
            // rebound to the new session.
            let (_, mut slot) = self.entries.remove(0);
            slot.kv.reset(pages);
            slot.anchor = 0;
            self.evictions += 1;
            self.entries.push((id, slot));
        }
        &mut self.entries.last_mut().expect("just pushed").1
    }

    /// Borrow a resident session's slot without touching LRU order —
    /// what [`NativeEngine::step_batch`](crate::engine::NativeEngine)
    /// uses mid-step (residency is the caller's contract).
    pub fn get_mut(&mut self, id: u64) -> Option<&mut SessionSlot> {
        self.entries.iter_mut().find(|(e, _)| *e == id).map(|(_, s)| s)
    }

    /// Drop a finished session's slot, recycling its pages.
    pub fn remove(&mut self, pages: &mut KvPagePool, id: u64) {
        if let Some(i) = self.entries.iter().position(|(e, _)| *e == id) {
            let (_, mut slot) = self.entries.remove(i);
            slot.kv.reset(pages);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> EngineConfig {
        EngineConfig {
            vocab: 16,
            d_model: 4,
            n_layers: 2,
            n_heads: 1,
            ffn: 8,
            max_seq: 6,
        }
    }

    fn pool_pt(page_tokens: usize) -> KvPagePool {
        KvPagePool::new(&cfg(), page_tokens)
    }

    /// All key rows of `layer` flattened back to one dense buffer.
    fn flat_keys(kv: &KvCache, layer: usize, rows: usize) -> Vec<f32> {
        kv.key_segments(layer, rows).flatten().copied().collect()
    }

    #[test]
    fn write_advance_read_roundtrip_across_pages() {
        let mut pool = pool_pt(2);
        let mut kv = pool.new_cache();
        assert!(kv.is_empty() && !kv.is_full());
        for pos in 0..5 {
            let krow = [pos as f32; 4];
            let vrow = [pos as f32 + 100.0; 4];
            kv.write_row(&mut pool, 0, &krow, &vrow);
            kv.write_row(&mut pool, 1, &[pos as f32 + 50.0; 4], &[0.0; 4]);
            // Before advance, the in-flight row is readable as rows = len + 1.
            assert_eq!(flat_keys(&kv, 0, pos + 1)[pos * 4], pos as f32);
            kv.advance();
        }
        assert_eq!(kv.len(), 5);
        assert_eq!(kv.pages_held(), 3); // ceil(5 / 2)
        assert_eq!(pool.outstanding_pages(), 3);
        // Layers are disjoint slabs; segments cover rows in order.
        let k0 = flat_keys(&kv, 0, 5);
        let k1 = flat_keys(&kv, 1, 5);
        for pos in 0..5 {
            assert_eq!(k0[pos * 4..pos * 4 + 4], [pos as f32; 4]);
            assert_eq!(k1[pos * 4..pos * 4 + 4], [pos as f32 + 50.0; 4]);
        }
        let v0: Vec<f32> = kv.value_segments(0, 5).flatten().copied().collect();
        assert_eq!(v0[0], 100.0);
        assert_eq!(v0[16], 104.0);
    }

    #[test]
    fn truncate_recycles_pages_and_reuse_is_allocation_free() {
        let mut pool = pool_pt(2);
        let mut kv = pool.new_cache();
        for pos in 0..6 {
            kv.write_row(&mut pool, 0, &[pos as f32; 4], &[0.0; 4]);
            kv.write_row(&mut pool, 1, &[0.0; 4], &[0.0; 4]);
            kv.advance();
        }
        assert!(kv.is_full());
        assert_eq!(pool.pages_allocated(), 3);
        kv.truncate(&mut pool, 3); // keeps ceil(3/2) = 2 pages
        assert_eq!(kv.len(), 3);
        assert_eq!(kv.pages_held(), 2);
        assert_eq!(pool.outstanding_pages(), 2);
        kv.truncate(&mut pool, 9); // no-op: cannot extend
        assert_eq!(kv.len(), 3);
        // Old prefix survives truncation.
        assert_eq!(flat_keys(&kv, 0, 3)[8], 2.0);
        // Regrow: the released page comes back from the free list.
        for pos in 3..6 {
            kv.write_row(&mut pool, 0, &[pos as f32 * 10.0; 4], &[0.0; 4]);
            kv.write_row(&mut pool, 1, &[0.0; 4], &[0.0; 4]);
            kv.advance();
        }
        assert_eq!(pool.pages_allocated(), 3, "no fresh allocation on regrow");
        assert_eq!(pool.recycled(), 1);
        assert_eq!(flat_keys(&kv, 0, 6)[20], 50.0);
        kv.reset(&mut pool);
        assert!(kv.is_empty());
        assert_eq!(kv.pages_held(), 0);
        assert_eq!(pool.outstanding_pages(), 0);
        assert_eq!(pool.peak_pages(), 3);
    }

    #[test]
    fn write_row_at_blocks_match_sequential_writes() {
        // The blocked write path (layer-major over a block, advance_n once)
        // must leave the exact bytes of the per-token write_row/advance
        // loop — the storage half of the blocked-prefill invariant.
        let mut pool_a = pool_pt(2);
        let mut pool_b = pool_pt(2);
        let mut seq = pool_a.new_cache();
        let mut blk = pool_b.new_cache();
        let row = |pos: usize, layer: usize, val: bool| {
            let x = (pos * 10 + layer) as f32 + if val { 0.5 } else { 0.0 };
            [x; 4]
        };
        for pos in 0..5 {
            for layer in 0..2 {
                seq.write_row(&mut pool_a, layer, &row(pos, layer, false), &row(pos, layer, true));
            }
            seq.advance();
        }
        // Blocked twin: positions 0..3 as one block, 3..5 as another.
        for (start, end) in [(0usize, 3usize), (3, 5)] {
            for layer in 0..2 {
                for pos in start..end {
                    blk.write_row_at(
                        &mut pool_b,
                        layer,
                        pos,
                        &row(pos, layer, false),
                        &row(pos, layer, true),
                    );
                }
            }
            blk.advance_n(end - start);
            assert_eq!(blk.len(), end);
        }
        assert_eq!(blk.pages_held(), seq.pages_held());
        for layer in 0..2 {
            assert_eq!(flat_keys(&blk, layer, 5), flat_keys(&seq, layer, 5));
            let va: Vec<f32> = seq.value_segments(layer, 5).flatten().copied().collect();
            let vb: Vec<f32> = blk.value_segments(layer, 5).flatten().copied().collect();
            assert_eq!(va, vb);
        }
    }

    #[test]
    #[should_panic(expected = "KV cache full")]
    fn advance_n_past_capacity_panics() {
        let mut pool = pool_pt(3);
        let mut kv = pool.new_cache();
        kv.write_row_at(&mut pool, 0, 0, &[0.0; 4], &[0.0; 4]);
        kv.advance_n(7);
    }

    #[test]
    #[should_panic(expected = "KV cache full")]
    fn write_past_capacity_panics() {
        let mut pool = pool_pt(3);
        let mut kv = pool.new_cache();
        for _ in 0..7 {
            kv.write_row(&mut pool, 0, &[0.0; 4], &[0.0; 4]);
            kv.write_row(&mut pool, 1, &[0.0; 4], &[0.0; 4]);
            kv.advance();
        }
    }

    #[test]
    fn window_start_is_page_granular() {
        // Within budget: no slide.
        assert_eq!(window_start(0, 8, 4), 0);
        assert_eq!(window_start(8, 8, 4), 0);
        // One token over: slide one whole page.
        assert_eq!(window_start(9, 8, 4), 4);
        assert_eq!(window_start(12, 8, 4), 4);
        assert_eq!(window_start(13, 8, 4), 8);
        // Window length stays in (max_seq - page_tokens, max_seq].
        for row_len in 1..200usize {
            let ws = window_start(row_len, 8, 4);
            let w = row_len - ws;
            assert!(w <= 8 && (row_len <= 8 || w > 8 - 4), "row_len {row_len}");
            assert_eq!(ws % 4, 0, "page-aligned start");
        }
        // page_tokens = 1 degenerates to an exact crop.
        assert_eq!(window_start(11, 8, 1), 3);
    }

    #[test]
    fn session_pool_lru_eviction_recycles_pages() {
        let mut pages = pool_pt(2);
        let mut pool = SessionKvPool::new(2);
        let s1 = pool.get_or_create(&mut pages, 1);
        s1.kv.write_row(&mut pages, 0, &[1.0; 4], &[0.0; 4]);
        s1.kv.write_row(&mut pages, 1, &[0.0; 4], &[0.0; 4]);
        s1.kv.advance();
        pool.get_or_create(&mut pages, 2);
        pool.get_or_create(&mut pages, 1); // touch 1: now 2 is LRU
        assert_eq!(pool.len(), 2);
        pool.get_or_create(&mut pages, 3); // evicts 2
        assert_eq!(pool.evictions(), 1);
        assert!(pool.contains(1) && pool.contains(3) && !pool.contains(2));
        // Session 1 kept its state; the rebound slot starts empty.
        assert_eq!(pool.get_or_create(&mut pages, 1).kv.len(), 1);
        assert_eq!(pool.get_or_create(&mut pages, 3).kv.len(), 0);
        assert_eq!(pool.get_or_create(&mut pages, 3).anchor, 0);
        pool.remove(&mut pages, 1);
        assert!(!pool.contains(1));
        assert_eq!(pool.len(), 1);
        assert_eq!(pages.outstanding_pages(), 0, "removed session's pages recycled");
        assert!(pool.get_mut(9).is_none());
    }

    #[test]
    fn peak_tracks_live_context_not_capacity() {
        // 8 short sessions against a max_seq-6 geometry: peak pages stay
        // proportional to the 1 live position each, far under 8 × 3 pages.
        let mut pages = pool_pt(2);
        let mut pool = SessionKvPool::new(8);
        for id in 0..8u64 {
            let slot = pool.get_or_create(&mut pages, id);
            slot.kv.write_row(&mut pages, 0, &[0.0; 4], &[0.0; 4]);
            slot.kv.write_row(&mut pages, 1, &[0.0; 4], &[0.0; 4]);
            slot.kv.advance();
        }
        assert_eq!(pages.outstanding_pages(), 8);
        assert_eq!(pages.peak_pages(), 8);
        let pinned_pages = 8 * 6usize.div_ceil(2);
        assert!(pages.peak_pages() * 3 <= pinned_pages, "paged ≪ pinned");
        assert!(pages.peak_bytes() < pinned_pages * pages.page_bytes());
    }
}
