//! Per-session KV cache for the native decode engine.
//!
//! Memory layout (see DESIGN.md §2.9): one contiguous f32 buffer per
//! projection, indexed `[layer][position][d_model]` —
//! `k[(l * max_seq + pos) * d_model + i]`. A position's K/V rows for
//! every layer are written during that token's step and become immutable;
//! attention at position `t` reads the `t + 1` leading rows of its
//! layer's span. `len` alone tracks validity, so [`KvCache::reset`] and
//! [`KvCache::truncate`] are O(1) bookkeeping (no zeroing), and a cache
//! evicted from the [`SessionKvPool`] is rebound to a new session by
//! resetting — buffers are never freed in steady state.

use crate::engine::model::EngineConfig;

/// KV storage for one decode session.
#[derive(Clone, Debug)]
pub struct KvCache {
    d_model: usize,
    max_seq: usize,
    len: usize,
    /// `[n_layers * max_seq * d_model]` keys (post-RoPE).
    k: Vec<f32>,
    /// `[n_layers * max_seq * d_model]` values.
    v: Vec<f32>,
}

impl KvCache {
    pub fn new(cfg: &EngineConfig) -> KvCache {
        let n = cfg.n_layers * cfg.max_seq * cfg.d_model;
        KvCache {
            d_model: cfg.d_model,
            max_seq: cfg.max_seq,
            len: 0,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    /// Cached positions (tokens already processed).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Position capacity (the engine's `max_seq`).
    pub fn capacity(&self) -> usize {
        self.max_seq
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.max_seq
    }

    /// Forget everything (O(1) — validity is tracked by `len`).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Roll back to the first `len` positions (no-op if already shorter).
    /// Positions ≥ `len` will be overwritten by subsequent steps.
    pub fn truncate(&mut self, len: usize) {
        self.len = self.len.min(len);
    }

    /// Write the current position's K and V rows for `layer`. Every layer
    /// must be written before [`KvCache::advance`] moves to the next
    /// position. Panics when full — the engine checks before stepping.
    pub fn write_row(&mut self, layer: usize, k_row: &[f32], v_row: &[f32]) {
        assert!(self.len < self.max_seq, "KV cache full");
        assert_eq!(k_row.len(), self.d_model);
        assert_eq!(v_row.len(), self.d_model);
        let base = (layer * self.max_seq + self.len) * self.d_model;
        self.k[base..base + self.d_model].copy_from_slice(k_row);
        self.v[base..base + self.d_model].copy_from_slice(v_row);
    }

    /// Commit the current position (call once per token, after every
    /// layer's [`KvCache::write_row`]).
    pub fn advance(&mut self) {
        assert!(self.len < self.max_seq, "KV cache full");
        self.len += 1;
    }

    /// The valid key rows of `layer`, including the in-flight position:
    /// `rows` rows of `d_model` — attention at position `t` passes
    /// `rows = t + 1` (its own row was just written, `len` still `t`).
    pub fn keys(&self, layer: usize, rows: usize) -> &[f32] {
        debug_assert!(rows <= self.max_seq);
        let base = layer * self.max_seq * self.d_model;
        &self.k[base..base + rows * self.d_model]
    }

    /// The valid value rows of `layer` (see [`KvCache::keys`]).
    pub fn values(&self, layer: usize, rows: usize) -> &[f32] {
        debug_assert!(rows <= self.max_seq);
        let base = layer * self.max_seq * self.d_model;
        &self.v[base..base + rows * self.d_model]
    }

    /// Resident footprint of the cache buffers in bytes.
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }
}

/// LRU pool of per-session caches, keyed by the scheduler's session id.
/// Bounded: admitting session `cap + 1` evicts the least-recently-used
/// cache and rebinds its buffers (reset, no reallocation). An evicted
/// session that steps again is re-prefilled from its row — slower, never
/// wrong (`rust/tests/native_decode.rs` pins token identity under cap 1).
#[derive(Debug)]
pub struct SessionKvPool {
    cfg: EngineConfig,
    cap: usize,
    /// `(session id, cache)`, least-recently-used first.
    entries: Vec<(u64, KvCache)>,
    evictions: u64,
}

impl SessionKvPool {
    pub fn new(cfg: &EngineConfig, cap: usize) -> SessionKvPool {
        SessionKvPool {
            cfg: cfg.clone(),
            cap: cap.max(1),
            entries: Vec::new(),
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.entries.iter().any(|(e, _)| *e == id)
    }

    /// Sessions evicted to make room so far.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// The session's cache, created (or rebound from the evicted LRU
    /// entry) on miss; the entry becomes most-recently-used.
    pub fn get_or_create(&mut self, id: u64) -> &mut KvCache {
        if let Some(i) = self.entries.iter().position(|(e, _)| *e == id) {
            let entry = self.entries.remove(i);
            self.entries.push(entry);
        } else if self.entries.len() < self.cap {
            self.entries.push((id, KvCache::new(&self.cfg)));
        } else {
            // Evict the LRU entry, reusing its buffers for the new session.
            let (_, mut cache) = self.entries.remove(0);
            cache.reset();
            self.evictions += 1;
            self.entries.push((id, cache));
        }
        &mut self.entries.last_mut().expect("just pushed").1
    }

    /// Drop a finished session's cache (buffers are freed; live sessions
    /// keep theirs).
    pub fn remove(&mut self, id: u64) {
        self.entries.retain(|(e, _)| *e != id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> EngineConfig {
        EngineConfig {
            vocab: 16,
            d_model: 4,
            n_layers: 2,
            n_heads: 1,
            ffn: 8,
            max_seq: 3,
        }
    }

    #[test]
    fn write_advance_read_roundtrip() {
        let mut kv = KvCache::new(&cfg());
        assert!(kv.is_empty() && !kv.is_full());
        kv.write_row(0, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        kv.write_row(1, &[9.0; 4], &[10.0; 4]);
        // Before advance, the in-flight row is readable as rows = len + 1.
        assert_eq!(kv.keys(0, 1), &[1.0, 2.0, 3.0, 4.0]);
        kv.advance();
        kv.write_row(0, &[11.0; 4], &[12.0; 4]);
        kv.advance();
        assert_eq!(kv.len(), 2);
        assert_eq!(&kv.keys(0, 2)[4..], &[11.0; 4]);
        assert_eq!(kv.values(1, 1), &[10.0; 4]);
        // Layers are disjoint spans.
        assert_eq!(kv.keys(1, 1), &[9.0; 4]);
    }

    #[test]
    fn full_and_truncate_semantics() {
        let mut kv = KvCache::new(&cfg());
        for i in 0..3 {
            kv.write_row(0, &[i as f32; 4], &[0.0; 4]);
            kv.write_row(1, &[0.0; 4], &[0.0; 4]);
            kv.advance();
        }
        assert!(kv.is_full());
        kv.truncate(1);
        assert_eq!(kv.len(), 1);
        assert!(!kv.is_full());
        assert_eq!(kv.keys(0, 1), &[0.0; 4]);
        kv.truncate(5); // no-op: cannot extend
        assert_eq!(kv.len(), 1);
        kv.reset();
        assert!(kv.is_empty());
    }

    #[test]
    #[should_panic(expected = "KV cache full")]
    fn advance_past_capacity_panics() {
        let mut kv = KvCache::new(&cfg());
        for _ in 0..4 {
            kv.advance();
        }
    }

    #[test]
    fn pool_lru_eviction_and_rebind() {
        let mut pool = SessionKvPool::new(&cfg(), 2);
        pool.get_or_create(1).advance();
        pool.get_or_create(2);
        pool.get_or_create(1); // touch 1: now 2 is LRU
        assert_eq!(pool.len(), 2);
        pool.get_or_create(3); // evicts 2
        assert_eq!(pool.evictions(), 1);
        assert!(pool.contains(1) && pool.contains(3) && !pool.contains(2));
        // Session 1 kept its state; the rebound cache starts empty.
        assert_eq!(pool.get_or_create(1).len(), 1);
        assert_eq!(pool.get_or_create(3).len(), 0);
        pool.remove(1);
        assert!(!pool.contains(1));
        assert_eq!(pool.len(), 1);
    }
}
