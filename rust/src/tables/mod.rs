//! Paper-table reproduction harness: `nmsparse table <id>`.
//!
//! One function per table/figure in the paper's evaluation (see DESIGN.md §4
//! for the experiment index). Each prints the same rows the paper reports,
//! side-by-side with the paper's published value where the paper gives one
//! (we claim *shape* — orderings and rough ratios — not absolute numbers:
//! the substrate is a 2.7M-param SynthLang model, not a 7B LLM).
//!
//! Results are also dumped as JSON under `--out` for
//! `tools/results_to_md.py`.

pub mod paper_ref;

use crate::coordinator::methods::{table2_methods, table8_methods, MethodConfig};
use crate::coordinator::Coordinator;
use crate::evalharness::{self, ifeval::eval_ifeval, TaskResult};
use crate::hwmodel;
use crate::sparsity::Pattern;
use crate::synthlang::corpus::Corpus;
use crate::synthlang::tasks::{self, IfevalSet, TaskSet};
use crate::synthlang::vocab::Vocab;
use crate::util::cli::{usage, Args, OptSpec};

use crate::util::table_fmt::{acc, pct, ppl as fmt_ppl, Table};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::path::PathBuf;

pub use paper_ref as reference;

/// Shared state for table generation: coordinator, task data and caches.
pub struct TableCtx {
    pub coord: Coordinator,
    pub data: PathBuf,
    pub limit: usize,
    pub ifeval_limit: usize,
    pub max_new: usize,
    pub windows: usize,
    pub vocab: Vocab,
    task_cache: HashMap<String, TaskSet>,
    result_cache: HashMap<String, (Vec<TaskResult>, f64)>,
    ppl_cache: HashMap<String, f64>,
}

impl TableCtx {
    pub fn open(artifacts: &str, data: &str, limit: usize) -> Result<TableCtx> {
        Ok(TableCtx {
            coord: Coordinator::open(&PathBuf::from(artifacts))?,
            data: PathBuf::from(data),
            limit,
            ifeval_limit: 48,
            max_new: 10,
            windows: 16,
            vocab: Vocab::synthlang(),
            task_cache: HashMap::new(),
            result_cache: HashMap::new(),
            ppl_cache: HashMap::new(),
        })
    }

    pub fn task(&mut self, name: &str) -> Result<TaskSet> {
        if let Some(t) = self.task_cache.get(name) {
            return Ok(t.clone());
        }
        let t = TaskSet::load(&self.data.join("tasks").join(format!("{name}.json")))?;
        self.task_cache.insert(name.to_string(), t.clone());
        Ok(t)
    }

    pub fn core_tasks(&mut self) -> Result<Vec<TaskSet>> {
        tasks::CORE_TASKS.iter().map(|n| self.task(n)).collect()
    }

    pub fn extended_tasks(&mut self) -> Result<Vec<TaskSet>> {
        tasks::CORE_TASKS
            .iter()
            .chain(tasks::EXTENDED_TASKS)
            .map(|n| self.task(n))
            .collect()
    }

    pub fn ifeval_set(&self) -> Result<IfevalSet> {
        IfevalSet::load(&self.data.join("tasks").join("synth_ifeval.json"))
    }

    /// Evaluate a method on the core suite (cached by engine key + suite).
    pub fn eval_core(&mut self, cfg: &MethodConfig) -> Result<(Vec<TaskResult>, f64)> {
        let key = format!("core|{}|{}", cfg.engine_key(), self.limit);
        if let Some(r) = self.result_cache.get(&key) {
            return Ok(r.clone());
        }
        let suite = self.core_tasks()?;
        let r = evalharness::eval_suite(&self.coord, cfg, &suite, self.limit)?;
        self.result_cache.insert(key, r.clone());
        Ok(r)
    }

    /// Evaluate on core + extended.
    pub fn eval_extended(&mut self, cfg: &MethodConfig) -> Result<(Vec<TaskResult>, f64)> {
        let key = format!("ext|{}|{}", cfg.engine_key(), self.limit);
        if let Some(r) = self.result_cache.get(&key) {
            return Ok(r.clone());
        }
        let suite = self.extended_tasks()?;
        let r = evalharness::eval_suite(&self.coord, cfg, &suite, self.limit)?;
        self.result_cache.insert(key, r.clone());
        Ok(r)
    }

    /// Avg relative drop (%) of `cfg` vs the dense baseline on core tasks.
    pub fn drop_core(&mut self, cfg: &MethodConfig) -> Result<f64> {
        let (base, _) = self.eval_core(&MethodConfig::dense())?;
        let (res, _) = self.eval_core(cfg)?;
        Ok(evalharness::avg_relative_drop(&base, &res))
    }

    /// Validation perplexity (cached).
    pub fn ppl(&mut self, cfg: &MethodConfig) -> Result<f64> {
        let key = cfg.engine_key();
        if let Some(p) = self.ppl_cache.get(&key) {
            return Ok(*p);
        }
        let stream = Corpus::read_tokens(&self.data.join("corpus_valid.tokens"))?;
        let p = self.coord.perplexity(cfg, &stream, self.windows)?;
        self.ppl_cache.insert(key, p);
        Ok(p)
    }
}

/// `nmsparse table <id>` entry point.
pub fn cmd_table(rest: Vec<String>) -> Result<()> {
    #[rustfmt::skip]
    let specs = vec![
        OptSpec { name: "artifacts", takes_value: true, default: Some("artifacts"), help: "artifacts dir" },
        OptSpec { name: "data", takes_value: true, default: Some("artifacts/data"), help: "data dir" },
        OptSpec { name: "examples", takes_value: true, default: Some("64"), help: "examples per task" },
        OptSpec { name: "ifeval-examples", takes_value: true, default: Some("48"), help: "ifeval prompts" },
        OptSpec { name: "out", takes_value: true, default: Some("results"), help: "JSON output dir" },
        OptSpec { name: "help", takes_value: false, default: None, help: "show help" },
    ];
    let a = Args::parse(rest, &specs)?;
    if a.flag("help") || a.positional.is_empty() {
        let about = "Regenerate a paper table/figure.\nIds: fig1 fig2 table2 table3 table4 \
                     table5 table6 table7 table8 table10 table11 table12 table14 serving all";
        print!("{}", usage("table <id>", about, &specs));
        return Ok(());
    }
    let id = a.positional[0].clone();
    let mut ctx = TableCtx::open(&a.get("artifacts"), &a.get("data"), a.get_usize("examples")?)?;
    ctx.ifeval_limit = a.get_usize("ifeval-examples")?;
    let out_dir = PathBuf::from(a.get("out"));
    std::fs::create_dir_all(&out_dir)?;

    let ids: Vec<&str> = if id == "all" {
        vec![
            "table6", "serving", "fig1", "fig2", "table2", "table4", "table8",
            "table3", "table5", "table11", "table12", "table14",
        ]
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        let t0 = std::time::Instant::now();
        let table = generate(&mut ctx, id)?;
        println!("{}", table.render());
        println!(
            "[{} regenerated in {:.1}s | {} so far]\n",
            id,
            t0.elapsed().as_secs_f64(),
            ctx.coord.stats.summary()
        );
        std::fs::write(out_dir.join(format!("{id}.json")), table.to_json().pretty())?;
    }
    Ok(())
}

/// Generate one table by id.
pub fn generate(ctx: &mut TableCtx, id: &str) -> Result<Table> {
    match id {
        "fig1" | "table10" => fig1_unstructured_act_vs_wt(ctx),
        "fig2" | "table7" => fig2_pattern_sweep(ctx),
        "table2" => table2_methods_grid(ctx),
        "table3" => table3_ifeval(ctx),
        "table4" => table4_unstructured_methods(ctx),
        "table5" | "table13" => table5_layer_sensitivity(ctx),
        "table6" => Ok(table6_hw_complexity()),
        "serving" => Ok(table_serving()),
        "table8" => table8_combinations(ctx),
        "table11" => table11_full(ctx, Pattern::NM { n: 2, m: 4 }),
        "table12" => table11_full(ctx, Pattern::NM { n: 8, m: 16 }),
        "table14" => table14_vs_quant(ctx),
        other => bail!("unknown table id '{other}'"),
    }
}

// ---------------------------------------------------------------- fig 1/10

/// Figure 1 / Table 10: unstructured activation vs weight sparsity at
/// matched levels.
fn fig1_unstructured_act_vs_wt(ctx: &mut TableCtx) -> Result<Table> {
    let mut t = Table::new(
        "Figure 1 / Table 10 — unstructured ACT (activations) vs WT (weights)",
        &[
            "sparsity", "target", "ppl", "ArcE", "BoolQ", "PIQA", "Wino", "drop%",
            "paper drop% (L3.1)",
        ],
    );
    let (base, _) = ctx.eval_core(&MethodConfig::dense())?;
    let base_ppl = ctx.ppl(&MethodConfig::dense())?;
    t.row(row_cells("0", "orig", base_ppl, &base, 0.0, ""));
    for &sp in &[20u32, 50, 70, 90] {
        let pattern = Pattern::Unstructured { keep_pct: 100 - sp };
        for target in ["act", "wt"] {
            let cfg = if target == "act" {
                let mut c = MethodConfig::act(pattern);
                c.id = format!("{sp}% ACT");
                c
            } else {
                let mut c = MethodConfig::wt(pattern);
                c.id = format!("{sp}% WT");
                c
            };
            let (res, _) = ctx.eval_core(&cfg)?;
            let drop = evalharness::avg_relative_drop(&base, &res);
            let p = ctx.ppl(&cfg)?;
            let paper = paper_ref::fig1_drop(sp, target);
            t.row(row_cells(
                &format!("{sp}%"),
                target,
                p,
                &res,
                drop,
                &paper,
            ));
        }
    }
    t.note =
        "expected shape: ACT degrades far less than WT at 50%/70%; both collapse by 90%".into();
    Ok(t)
}

fn row_cells(
    sparsity: &str,
    target: &str,
    p: f64,
    res: &[TaskResult],
    drop: f64,
    paper: &str,
) -> Vec<String> {
    let mut cells = vec![sparsity.to_string(), target.to_string(), fmt_ppl(p)];
    for r in res {
        cells.push(acc(r.accuracy));
    }
    cells.push(pct(drop));
    cells.push(paper.to_string());
    cells
}

// ---------------------------------------------------------------- fig 2/7

/// Figure 2 / Table 7: sparsity-pattern sweep with magnitude pruning.
fn fig2_pattern_sweep(ctx: &mut TableCtx) -> Result<Table> {
    let mut t = Table::new(
        "Figure 2 / Table 7 — pattern flexibility sweep (magnitude/ACT pruning)",
        &["pattern", "ArcE", "BoolQ", "PIQA", "Wino", "drop%", "paper drop%"],
    );
    let (base, _) = ctx.eval_core(&MethodConfig::dense())?;
    let row = |label: &str, res: &[TaskResult], drop: f64, paper: &str| {
        let mut cells = vec![label.to_string()];
        for r in res {
            cells.push(acc(r.accuracy));
        }
        cells.push(pct(drop));
        cells.push(paper.to_string());
        cells
    };
    t.rows.push(row("orig", &base, 0.0, "-"));
    for key in ["2:4", "4:8", "8:16", "16:32", "u50", "u70"] {
        let pattern = Pattern::parse(key)?;
        let mut cfg = MethodConfig::act(pattern);
        cfg.id = key.to_string();
        let (res, _) = ctx.eval_core(&cfg)?;
        let drop = evalharness::avg_relative_drop(&base, &res);
        t.rows
            .push(row(key, &res, drop, &paper_ref::fig2_drop(key)));
    }
    t.note =
        "expected shape: monotone 2:4 > 4:8 > 8:16 > 16:32 ≥ u50 drops; u70 collapses".into();
    Ok(t)
}

// ---------------------------------------------------------------- table 2

/// Table 2: avg drop per method at 2:4 and 8:16 (+ u50 / WT references).
fn table2_methods_grid(ctx: &mut TableCtx) -> Result<Table> {
    let mut t = Table::new(
        "Table 2 — avg relative drop (%) per method x pattern (core tasks)",
        &["target", "pattern", "method", "drop%", "paper drop%"],
    );
    let mut push = |ctx: &mut TableCtx, target: &str, pat: &str, cfg: &MethodConfig| -> Result<()> {
        let drop = ctx.drop_core(cfg)?;
        t.row(vec![
            target.into(),
            pat.into(),
            cfg.id.clone(),
            pct(drop),
            paper_ref::table2_drop(pat, &cfg.id),
        ]);
        Ok(())
    };
    // u50 ACT reference row.
    let u50 = Pattern::Unstructured { keep_pct: 50 };
    push(ctx, "Act", "u50", &MethodConfig::act(u50))?;
    for pat_key in ["2:4", "8:16"] {
        let pattern = Pattern::parse(pat_key)?;
        push(ctx, "Wt", pat_key, &MethodConfig::wt(pattern))?;
        for name in table2_methods() {
            let cfg = MethodConfig::by_name(name, pattern)?;
            push(ctx, "Act", pat_key, &cfg)?;
        }
    }
    t.note = "paper values are 4-model averages; ours are one SynthLang model — compare shape"
        .into();
    Ok(t)
}

// ---------------------------------------------------------------- table 3

/// Table 3: IFEval prompt-level strict/loose under 2:4 and 8:16.
fn table3_ifeval(ctx: &mut TableCtx) -> Result<Table> {
    let mut t = Table::new(
        "Table 3 — instruction following (IFEval analog), PS/PL",
        &["method", "2:4 PS/PL", "8:16 PS/PL", "paper 8:16 PS/PL (L3.1)"],
    );
    let set = ctx.ifeval_set()?;
    let vocab = ctx.vocab.clone();
    let orig = eval_ifeval(
        &ctx.coord,
        &MethodConfig::dense(),
        &set,
        &vocab,
        ctx.ifeval_limit,
        ctx.max_new,
    )?;
    t.row(vec![
        "ORIG".into(),
        format!("{:.4}/{:.4}", orig.strict, orig.loose),
        format!("{:.4}/{:.4}", orig.strict, orig.loose),
        paper_ref::table3_ps_pl("ORIG"),
    ]);
    for name in ["S-PTS", "D-PTS", "R-Sparse(64)", "VAR"] {
        let mut cells = vec![name.to_string()];
        for pat_key in ["2:4", "8:16"] {
            let cfg = MethodConfig::by_name(name, Pattern::parse(pat_key)?)?;
            let r = eval_ifeval(&ctx.coord, &cfg, &set, &vocab, ctx.ifeval_limit, ctx.max_new)?;
            cells.push(format!("{:.4}/{:.4}", r.strict, r.loose));
        }
        cells.push(paper_ref::table3_ps_pl(name));
        t.row(cells);
    }
    t.note = "expected shape: generative scores drop much harder than QA; 8:16 >> 2:4".into();
    Ok(t)
}

// ---------------------------------------------------------------- table 4

/// Table 4: methods under unstructured 50%/70%.
fn table4_unstructured_methods(ctx: &mut TableCtx) -> Result<Table> {
    let mut t = Table::new(
        "Table 4 — unstructured 50% / 70% methods (Llama3.1 analog)",
        &["level", "method", "ArcE", "BoolQ", "PIQA", "Wino", "drop%", "paper drop%"],
    );
    let (base, _) = ctx.eval_core(&MethodConfig::dense())?;
    for keep in [50u32, 30] {
        let sp = 100 - keep;
        let pattern = Pattern::Unstructured { keep_pct: keep };
        for name in ["ACT", "D-PTS", "VAR", "CLACT", "Amber-Pruner"] {
            let cfg = MethodConfig::by_name(name, pattern)?;
            let (res, _) = ctx.eval_core(&cfg)?;
            let drop = evalharness::avg_relative_drop(&base, &res);
            let mut cells = vec![format!("u{sp}"), name.to_string()];
            for r in &res {
                cells.push(acc(r.accuracy));
            }
            cells.push(pct(drop));
            cells.push(paper_ref::table4_drop(sp, name));
            t.row(cells);
        }
    }
    t.note = "expected shape: VAR best at u70; methods clustered at u50".into();
    Ok(t)
}

// ---------------------------------------------------------------- table 5/13

/// Table 5/13: layer-subset sensitivity with LS+L-PTS (+VAR) at 8:16.
fn table5_layer_sensitivity(ctx: &mut TableCtx) -> Result<Table> {
    let mut t = Table::new(
        "Table 5 / 13 — 8:16 layer sensitivity (extended suite)",
        &["method", "layers", "ppl", "avg acc", "drop%", "paper drop%"],
    );
    let pattern = Pattern::NM { n: 8, m: 16 };
    let (base, base_mean) = ctx.eval_extended(&MethodConfig::dense())?;
    let _ = base_mean;
    // "all" = every site; subsets name the sites that STAY sparsified.
    let subsets: [(&str, Vec<&str>); 3] = [
        ("all", vec![]),
        ("key,out,gate,down", vec!["q", "v", "up"]),
        ("key,value,gate,down", vec!["q", "o", "up"]),
    ];
    for method in ["LS+L-PTS", "LS+L-PTS+VAR"] {
        for (label, disabled) in &subsets {
            let cfg = MethodConfig::by_name(method, pattern)?
                .with_disabled_sites(disabled);
            let (res, mean) = ctx.eval_extended(&cfg)?;
            let drop = evalharness::avg_relative_drop(&base, &res);
            let p = ctx.ppl(&cfg)?;
            t.row(vec![
                method.to_string(),
                label.to_string(),
                fmt_ppl(p),
                acc(mean),
                pct(drop),
                paper_ref::table5_drop(method, label),
            ]);
        }
    }
    t.note = "expected shape: sparsifying fewer sites (esp. exempting up/out) lowers the drop"
        .into();
    Ok(t)
}

// ---------------------------------------------------------------- table 6

/// Table 6 + Appendix A: hardware complexity + EDP break-even (analytic).
fn table6_hw_complexity() -> Table {
    let mut t = Table::new(
        "Table 6 / Appendix A — microarchitectural complexity & EDP break-even",
        &["dimension", "2:4", "8:16", "reference"],
    );
    let a24 = hwmodel::assess(Pattern::NM { n: 2, m: 4 });
    let a816 = hwmodel::assess(Pattern::NM { n: 8, m: 16 });
    t.row(vec![
        "metadata bits/elt".into(),
        format!("{} ({:.3})", a24.metadata_rating, a24.metadata_bits_per_elt),
        format!("{} ({:.3})", a816.metadata_rating, a816.metadata_bits_per_elt),
        "paper: 0.75 vs 0.875 (+16.7%)".into(),
    ]);
    t.row(vec![
        "controller logic".into(),
        format!("{} ({}-bit rank)", a24.controller_rating, a24.controller_bits),
        format!("{} ({}-bit rank)", a816.controller_rating, a816.controller_bits),
        "paper: 2-bit decoders vs 14-bit unpacking".into(),
    ]);
    t.row(vec![
        "memory bandwidth".into(),
        a24.bandwidth_rating.to_string(),
        a816.bandwidth_rating.to_string(),
        "paper: Low vs Low-Med".into(),
    ]);
    t.row(vec![
        "NRE cost tier".into(),
        a24.nre_rating.to_string(),
        a816.nre_rating.to_string(),
        "paper: Low (mature IP) vs Medium".into(),
    ]);
    t.row(vec![
        "incr. die area".into(),
        format!("{:.2}%", hwmodel::incremental_die_area_pct(Pattern::NM { n: 2, m: 4 })),
        format!("{:.2}%", hwmodel::incremental_die_area_pct(Pattern::NM { n: 8, m: 16 })),
        "paper: < 2% for 8:16".into(),
    ]);
    // Measured activation I/O (written by `cargo bench -- substrate`):
    // bytes-per-row of the packed compressed stream, replacing the
    // theoretical bits_per_element story when available.
    let packed = load_packed_bench(std::path::Path::new(PACKED_BENCH_FILE));
    match &packed {
        Some(rows) => {
            let find = |pat: &str| rows.iter().find(|r| r.pattern == pat);
            let cell = |pat: &str| {
                find(pat)
                    .map(|r| {
                        format!(
                            "{:.0} B/row (r={:.2})",
                            r.packed_bytes_per_row, r.measured_bandwidth_reduction
                        )
                    })
                    .unwrap_or_else(|| "-".into())
            };
            t.row(vec![
                "act I/O (measured, packed)".into(),
                cell("2:4"),
                cell("8:16"),
                format!(
                    "dense {:.0} B/row; values + measured combinadic metadata",
                    find("8:16")
                        .or_else(|| find("2:4"))
                        .map(|r| r.dense_bytes_per_row)
                        .unwrap_or(0.0)
                ),
            ]);
            if let Some(r) = find("8:16") {
                if r.codec_word_speedup > 0.0 {
                    t.row(vec![
                        "codec word-path speedup".into(),
                        "-".into(),
                        format!("{:.1}x vs per-bit", r.codec_word_speedup),
                        "gate: >= 5x at 8:16".into(),
                    ]);
                }
            }
        }
        None => {
            t.row(vec![
                "act I/O (theoretical)".into(),
                format!("{:.3} meta bits/elt", a24.metadata_bits_per_elt),
                format!("{:.3} meta bits/elt", a816.metadata_bits_per_elt),
                "no BENCH_packed.json — run `cargo bench -- substrate`".into(),
            ]);
        }
    }
    // Measured software sparsify overhead (written by `cargo bench -- tables`)
    // grounds the model's alpha when available.
    if let Some(measured) = load_measured_overhead(std::path::Path::new(OVERHEAD_BENCH_FILE)) {
        let find = |pat: &str| {
            measured
                .iter()
                .find(|(p, _)| p == pat)
                .map(|(_, f)| format!("{:.3}", f))
                .unwrap_or_else(|| "-".into())
        };
        t.row(vec![
            "sw sparsify overhead α (measured)".into(),
            find("2:4"),
            find("8:16"),
            "paper model: alpha = 0.3".into(),
        ]);
    }
    // EDP with the measured bandwidth ratio when the packed bench ran;
    // the paper's theoretical r = 2.0 otherwise.
    let edp = match packed
        .as_ref()
        .and_then(|rows| rows.iter().find(|r| r.pattern == "8:16"))
    {
        Some(r) => hwmodel::EdpModel::paper_default()
            .with_measured_bandwidth(r.dense_bytes_per_row, r.packed_bytes_per_row),
        None => hwmodel::EdpModel::paper_default(),
    };
    t.row(vec![
        "EDP improvement".into(),
        "-".into(),
        format!(
            "{:.3}x (r={:.2}{})",
            edp.edp_improvement(),
            edp.bandwidth_reduction,
            if packed.is_some() { ", measured" } else { ", theoretical" }
        ),
        "paper: r*eta/(1+alpha) = 1.31 at r=2.0".into(),
    ]);
    t.row(vec![
        "break-even k".into(),
        "-".into(),
        format!(
            "> {:.2} (conservative {:.1})",
            edp.breakeven_k() / edp.edp_improvement() * 1.31,
            hwmodel::EdpModel::CONSERVATIVE_K
        ),
        "paper: k > 1.31, conservative 1.6".into(),
    ]);
    t.note = "Appendix A model; act-I/O row and EDP's r are measured from BENCH_packed.json \
              when present (theoretical bits_per_element / r=1/density otherwise)"
        .into();
    t
}

// ------------------------------------------------- measured sw overhead

/// Where `cargo bench -- tables` drops the measured per-pattern software
/// sparsify-overhead fractions (see `rust/benches/tables.rs`).
pub const OVERHEAD_BENCH_FILE: &str = "BENCH_sparsify_overhead.json";

/// Load measured `(pattern, overhead_frac)` pairs — the fused pipeline's
/// per-forward cost as a fraction of end-to-end forward time. Returns
/// `None` when the bench has not been run (callers print the analytic
/// default instead).
pub fn load_measured_overhead(path: &std::path::Path) -> Option<Vec<(String, f64)>> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = crate::util::json::parse(&text).ok()?;
    let pats = match j.get("patterns") {
        Some(crate::util::json::Json::Obj(m)) => m,
        _ => return None,
    };
    let mut out = Vec::with_capacity(pats.len());
    for (name, v) in pats {
        let frac = v.get("overhead_frac").and_then(|x| x.as_f64())?;
        out.push((name.clone(), frac));
    }
    Some(out)
}

// ------------------------------------------------- measured packed I/O

/// Where `cargo bench -- substrate` drops the measured packed-stream
/// numbers (see `rust/benches/substrate.rs`): per-pattern bytes-per-row of
/// the compressed activation representation, pack/unpack throughput,
/// packed-vs-dense GEMV rates and word-vs-bit codec rates.
pub const PACKED_BENCH_FILE: &str = "BENCH_packed.json";

/// One pattern's measured packed-stream numbers from [`PACKED_BENCH_FILE`].
#[derive(Clone, Debug, PartialEq)]
pub struct PackedBenchRow {
    pub pattern: String,
    /// Dense f32 bytes per activation row (the baseline).
    pub dense_bytes_per_row: f64,
    /// Measured packed bytes per row: kept values + encoded metadata.
    pub packed_bytes_per_row: f64,
    /// dense / packed — the bandwidth-reduction ratio `r` the EDP model
    /// consumes in place of the theoretical 1/density.
    pub measured_bandwidth_reduction: f64,
    /// Word-level codec throughput over the seed per-bit path (roundtrip).
    pub codec_word_speedup: f64,
    /// Packed GEMV rows/sec over dense GEMV rows/sec.
    pub packed_gemv_speedup: f64,
}

/// Load the measured packed-stream rows. `None` when the bench has not
/// been run — callers fall back to theoretical `bits_per_element`.
pub fn load_packed_bench(path: &std::path::Path) -> Option<Vec<PackedBenchRow>> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = crate::util::json::parse(&text).ok()?;
    let pats = match j.get("patterns") {
        Some(crate::util::json::Json::Obj(m)) => m,
        _ => return None,
    };
    let mut out = Vec::with_capacity(pats.len());
    for (name, v) in pats {
        let f = |key: &str| v.get(key).and_then(|x| x.as_f64());
        out.push(PackedBenchRow {
            pattern: name.clone(),
            dense_bytes_per_row: f("dense_bytes_per_row")?,
            packed_bytes_per_row: f("packed_bytes_per_row")?,
            measured_bandwidth_reduction: f("measured_bandwidth_reduction")?,
            codec_word_speedup: f("codec_word_speedup").unwrap_or(0.0),
            packed_gemv_speedup: f("packed_gemv_speedup").unwrap_or(0.0),
        });
    }
    Some(out)
}

// ------------------------------------------------- measured serving perf

/// Where `cargo bench -- serving` / `nmsparse loadgen` drop the measured
/// multi-replica serving numbers (see `rust/src/launcher/loadgen.rs`).
pub const SERVING_BENCH_FILE: &str = "BENCH_serving.json";

/// The measured serving summary from [`SERVING_BENCH_FILE`].
#[derive(Clone, Debug, PartialEq)]
pub struct ServingBenchSummary {
    pub mode: String,
    pub backend: String,
    pub replicas: f64,
    pub requests: f64,
    pub served: f64,
    pub rejected: f64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub batch_occupancy: f64,
    pub rejection_rate: f64,
}

/// Load the measured serving summary. `None` when the loadgen/bench has
/// not been run — callers render a pointer at the command instead.
pub fn load_serving_bench(path: &std::path::Path) -> Option<ServingBenchSummary> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = crate::util::json::parse(&text).ok()?;
    let f = |key: &str| j.get(key).and_then(|x| x.as_f64());
    let s = |key: &str| j.get(key).and_then(|x| x.as_str()).map(|x| x.to_string());
    let lat = j.get("latency_ms")?;
    let lf = |key: &str| lat.get(key).and_then(|x| x.as_f64());
    Some(ServingBenchSummary {
        mode: s("mode")?,
        backend: s("backend")?,
        replicas: f("replicas")?,
        requests: f("requests")?,
        served: f("served")?,
        rejected: f("rejected")?,
        throughput_rps: f("throughput_rps")?,
        p50_ms: lf("p50")?,
        p95_ms: lf("p95")?,
        p99_ms: lf("p99")?,
        batch_occupancy: f("batch_occupancy")?,
        rejection_rate: f("rejection_rate")?,
    })
}

/// Where `nmsparse loadgen --sweep` drops the latency-vs-offered-rate
/// curve (one open-loop run per rate).
pub const SERVING_SWEEP_FILE: &str = "BENCH_serving_sweep.json";

/// One measured point of the offered-rate sweep.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepPointSummary {
    pub rate_rps: f64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub rejection_rate: f64,
}

/// Load the sweep curve; `None` when the sweep has not been run.
pub fn load_serving_sweep(path: &std::path::Path) -> Option<Vec<SweepPointSummary>> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = crate::util::json::parse(&text).ok()?;
    let points = j.get("points")?.as_arr()?;
    let mut out = Vec::with_capacity(points.len());
    for p in points {
        let f = |key: &str| p.get(key).and_then(|x| x.as_f64());
        let lat = p.get("latency_ms")?;
        let lf = |key: &str| lat.get(key).and_then(|x| x.as_f64());
        out.push(SweepPointSummary {
            rate_rps: f("rate_rps")?,
            throughput_rps: f("throughput_rps")?,
            p50_ms: lf("p50")?,
            p95_ms: lf("p95")?,
            p99_ms: lf("p99")?,
            rejection_rate: f("rejection_rate")?,
        });
    }
    Some(out)
}

/// `nmsparse table serving` — the measured multi-replica serving profile.
/// Purely a consumer of [`SERVING_BENCH_FILE`]; needs no artifacts.
fn table_serving() -> Table {
    let mut t = Table::new(
        "Serving — multi-replica ServerCore under load (measured)",
        &["metric", "value", "source"],
    );
    match load_serving_bench(std::path::Path::new(SERVING_BENCH_FILE)) {
        Some(m) => {
            let src = format!("{} backend, {} mode", m.backend, m.mode);
            t.row(vec![
                "throughput".into(),
                format!("{:.1} req/s", m.throughput_rps),
                src.clone(),
            ]);
            t.row(vec![
                "latency p50 / p95 / p99".into(),
                format!("{:.2} / {:.2} / {:.2} ms", m.p50_ms, m.p95_ms, m.p99_ms),
                "server-side histogram (util::stats)".into(),
            ]);
            t.row(vec![
                "batch occupancy".into(),
                format!("{:.2}", m.batch_occupancy),
                "packing_efficiency over dispatched slots".into(),
            ]);
            t.row(vec![
                "rejection rate".into(),
                format!("{:.3}", m.rejection_rate),
                format!("admission cap; {} of {} shed", m.rejected, m.requests),
            ]);
            t.row(vec![
                "replicas".into(),
                format!("{:.0}", m.replicas),
                format!("{:.0} served", m.served),
            ]);
            t.note = "run `nmsparse loadgen` or `cargo bench -- serving` to refresh".into();
        }
        None => {
            t.row(vec![
                "serving profile".into(),
                "-".into(),
                "no BENCH_serving.json — run `nmsparse loadgen`".into(),
            ]);
        }
    }
    // Latency-vs-offered-rate curve, when the sweep has been run.
    match load_serving_sweep(std::path::Path::new(SERVING_SWEEP_FILE)) {
        Some(points) => {
            for p in &points {
                t.row(vec![
                    format!("sweep @ {:.0} req/s", p.rate_rps),
                    format!(
                        "{:.1} served/s | p50/p95/p99 {:.2}/{:.2}/{:.2} ms",
                        p.throughput_rps, p.p50_ms, p.p95_ms, p.p99_ms
                    ),
                    format!("rejection {:.3}", p.rejection_rate),
                ]);
            }
        }
        None => {
            t.row(vec![
                "rate sweep".into(),
                "-".into(),
                "no BENCH_serving_sweep.json — run `nmsparse loadgen --sweep r1,r2,...`".into(),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------- table 8

/// Table 8: combinations at 8:16.
fn table8_combinations(ctx: &mut TableCtx) -> Result<Table> {
    let mut t = Table::new(
        "Table 8 — combined methods at 8:16 (avg drop %, core tasks)",
        &["method", "drop%", "paper avg drop%"],
    );
    let pattern = Pattern::NM { n: 8, m: 16 };
    for name in table8_methods() {
        let cfg = MethodConfig::by_name(name, pattern)?;
        let drop = ctx.drop_core(&cfg)?;
        t.row(vec![name.to_string(), pct(drop), paper_ref::table8_drop(name)]);
    }
    // Singles for comparison, as the paper discusses.
    t.separator();
    for name in ["S-PTS", "VAR", "CLACT", "Amber-Pruner"] {
        let cfg = MethodConfig::by_name(name, pattern)?;
        let drop = ctx.drop_core(&cfg)?;
        t.row(vec![format!("(single) {name}"), pct(drop), paper_ref::table2_drop("8:16", name)]);
    }
    t.note = "paper finding: no combination beats the best single method".into();
    Ok(t)
}

// ---------------------------------------------------------------- table 11/12

/// Table 11 (2:4) / Table 12 (8:16): the full per-method table with ppl.
fn table11_full(ctx: &mut TableCtx, pattern: Pattern) -> Result<Table> {
    let title = format!(
        "Table {} — full semi-structured {} results",
        if pattern == (Pattern::NM { n: 2, m: 4 }) { "11" } else { "12" },
        pattern
    );
    let mut t = Table::new(&title, &["method", "ppl", "ArcE", "BoolQ", "PIQA", "Wino", "drop%"]);
    let (base, _) = ctx.eval_core(&MethodConfig::dense())?;
    let base_ppl = ctx.ppl(&MethodConfig::dense())?;
    let mut orig_cells = vec!["ORIG".to_string(), fmt_ppl(base_ppl)];
    for r in &base {
        orig_cells.push(acc(r.accuracy));
    }
    orig_cells.push(pct(0.0));
    t.row(orig_cells);
    let mut push = |ctx: &mut TableCtx, cfg: &MethodConfig| -> Result<()> {
        let (res, _) = ctx.eval_core(cfg)?;
        let drop = evalharness::avg_relative_drop(&base, &res);
        let p = ctx.ppl(cfg)?;
        let mut cells = vec![cfg.id.clone(), fmt_ppl(p)];
        for r in &res {
            cells.push(acc(r.accuracy));
        }
        cells.push(pct(drop));
        t.row(cells);
        Ok(())
    };
    push(ctx, &MethodConfig::wt(pattern))?;
    for name in table2_methods() {
        push(ctx, &MethodConfig::by_name(name, pattern)?)?;
    }
    for name in table8_methods() {
        push(ctx, &MethodConfig::by_name(name, pattern)?)?;
    }
    Ok(t)
}

// ---------------------------------------------------------------- table 14

/// Table 14: activation sparsity vs int8 quantization.
fn table14_vs_quant(ctx: &mut TableCtx) -> Result<Table> {
    let mut t = Table::new(
        "Table 14 — activation sparsity vs quantization",
        &["method", "ArcE", "BoolQ", "PIQA", "Wino", "drop%"],
    );
    let (base, _) = ctx.eval_core(&MethodConfig::dense())?;
    let mut push = |ctx: &mut TableCtx, label: &str, cfg: &MethodConfig| -> Result<()> {
        let (res, _) = ctx.eval_core(cfg)?;
        let drop = evalharness::avg_relative_drop(&base, &res);
        let mut cells = vec![label.to_string()];
        for r in &res {
            cells.push(acc(r.accuracy));
        }
        cells.push(pct(drop));
        t.row(cells);
        Ok(())
    };
    push(ctx, "Baseline (dense)", &MethodConfig::dense())?;
    push(ctx, "int8 weights (ours, PTQ)", &MethodConfig::quant8())?;
    let u50 = Pattern::Unstructured { keep_pct: 50 };
    let p816 = Pattern::NM { n: 8, m: 16 };
    let spts_u50 = MethodConfig::by_name("S-PTS", u50).map(|mut c| {
        c.eta_family = Some("spts_eta".into());
        c
    })?;
    push(ctx, "50% unstruct + S-PTS", &spts_u50)?;
    push(ctx, "50% unstruct + VAR", &MethodConfig::by_name("VAR", u50)?)?;
    push(ctx, "8:16 + ACT", &MethodConfig::by_name("ACT", p816)?)?;
    push(ctx, "8:16 + Amber-Pruner", &MethodConfig::by_name("Amber-Pruner", p816)?)?;
    push(ctx, "8:16 + D-PTS", &MethodConfig::by_name("D-PTS", p816)?)?;
    push(ctx, "8:16 + VAR", &MethodConfig::by_name("VAR", p816)?)?;
    t.note = "expected shape: int8 ~lossless; u50 methods close behind; 8:16 modest drops".into();
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_overhead_loader_roundtrip() {
        let dir = std::env::temp_dir().join(format!("nmsparse-ovh-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_sparsify_overhead.json");
        std::fs::write(
            &path,
            r#"{"forward_s": 0.5,
                "patterns": {
                  "2:4":  {"overhead_frac": 0.12, "sparsify_s_per_forward": 0.06},
                  "8:16": {"overhead_frac": 0.20, "sparsify_s_per_forward": 0.10}
                }}"#,
        )
        .unwrap();
        let got = load_measured_overhead(&path).unwrap();
        assert_eq!(got.len(), 2);
        assert!(got.contains(&("2:4".to_string(), 0.12)));
        assert!(got.contains(&("8:16".to_string(), 0.20)));
        assert!(load_measured_overhead(std::path::Path::new("/definitely/not/here.json"))
            .is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table6_renders_without_artifacts() {
        // Fully analytic table — must not require engines (and must fall
        // back gracefully when no BENCH_packed.json is in cwd).
        let t = table6_hw_complexity();
        assert!(t.rows.len() >= 7);
    }

    #[test]
    fn serving_table_renders_without_bench_file() {
        // Pure consumer table — must render a pointer row when no
        // BENCH_serving.json is in cwd (and never require artifacts).
        let t = table_serving();
        assert!(!t.rows.is_empty());
    }

    #[test]
    fn serving_bench_loader_roundtrip() {
        let dir = std::env::temp_dir().join(format!("nmsparse-serving-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serving.json");
        std::fs::write(
            &path,
            r#"{"suite": "serving", "mode": "mixed", "backend": "synthetic",
                "replicas": 2, "queue_cap": 64, "requests": 512,
                "served": 500, "rejected": 12, "errors": 0,
                "wall_s": 1.5, "throughput_rps": 333.3,
                "latency_ms": {"mean": 4.0, "p50": 3.1, "p95": 9.9, "p99": 14.2, "max": 20.0},
                "batch_occupancy": 0.82, "rejection_rate": 0.023}"#,
        )
        .unwrap();
        let m = load_serving_bench(&path).unwrap();
        assert_eq!(m.mode, "mixed");
        assert_eq!(m.replicas, 2.0);
        assert!((m.throughput_rps - 333.3).abs() < 1e-9);
        assert!(m.p50_ms <= m.p95_ms && m.p95_ms <= m.p99_ms);
        assert!((m.rejection_rate - 0.023).abs() < 1e-12);
        // Missing file and missing required field both yield None.
        assert!(load_serving_bench(std::path::Path::new("/definitely/not/here.json")).is_none());
        std::fs::write(&path, r#"{"mode": "mixed", "backend": "synthetic"}"#).unwrap();
        assert!(load_serving_bench(&path).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serving_sweep_loader_roundtrip() {
        let dir = std::env::temp_dir().join(format!("nmsparse-sweep-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serving_sweep.json");
        std::fs::write(
            &path,
            r#"{"suite": "serving_sweep", "mode": "mixed", "backend": "synthetic",
                "replicas": 2, "queue_cap": 32, "requests_per_point": 64,
                "points": [
                  {"rate_rps": 100.0, "served": 64, "rejected": 0,
                   "throughput_rps": 99.1, "rejection_rate": 0.0,
                   "batch_occupancy": 0.4,
                   "latency_ms": {"mean": 2.0, "p50": 1.5, "p95": 4.0, "p99": 6.0, "max": 9.0}},
                  {"rate_rps": 400.0, "served": 60, "rejected": 4,
                   "throughput_rps": 350.0, "rejection_rate": 0.0625,
                   "batch_occupancy": 0.7,
                   "latency_ms": {"mean": 5.0, "p50": 4.0, "p95": 11.0, "p99": 15.0, "max": 22.0}}
                ]}"#,
        )
        .unwrap();
        let points = load_serving_sweep(&path).unwrap();
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].rate_rps, 100.0);
        assert!((points[1].rejection_rate - 0.0625).abs() < 1e-12);
        assert!(points[1].p50_ms <= points[1].p95_ms);
        // Missing file and malformed points both yield None.
        assert!(load_serving_sweep(std::path::Path::new("/definitely/not/here.json")).is_none());
        std::fs::write(&path, r#"{"points": [{"rate_rps": 1.0}]}"#).unwrap();
        assert!(load_serving_sweep(&path).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_bench_loader_roundtrip() {
        let dir = std::env::temp_dir().join(format!("nmsparse-packed-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_packed.json");
        std::fs::write(
            &path,
            r#"{"rows": 256, "hidden": 1024,
                "patterns": {
                  "2:4":  {"dense_bytes_per_row": 4096.0, "packed_bytes_per_row": 2432.0,
                           "measured_bandwidth_reduction": 1.684,
                           "codec_word_speedup": 6.1, "packed_gemv_speedup": 1.7},
                  "8:16": {"dense_bytes_per_row": 4096.0, "packed_bytes_per_row": 2296.0,
                           "measured_bandwidth_reduction": 1.784}
                }}"#,
        )
        .unwrap();
        let rows = load_packed_bench(&path).unwrap();
        assert_eq!(rows.len(), 2);
        let r816 = rows.iter().find(|r| r.pattern == "8:16").unwrap();
        assert_eq!(r816.packed_bytes_per_row, 2296.0);
        assert_eq!(r816.codec_word_speedup, 0.0); // optional field defaulted
        let r24 = rows.iter().find(|r| r.pattern == "2:4").unwrap();
        assert!((r24.measured_bandwidth_reduction - 1.684).abs() < 1e-12);
        assert_eq!(r24.codec_word_speedup, 6.1);
        // Missing file and missing required field both yield None.
        assert!(load_packed_bench(std::path::Path::new("/definitely/not/here.json")).is_none());
        std::fs::write(&path, r#"{"patterns": {"2:4": {"dense_bytes_per_row": 1.0}}}"#).unwrap();
        assert!(load_packed_bench(&path).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
