//! Published values from the paper, for side-by-side "paper" columns.
//!
//! These constants are the *shape reference*: our substrate is a 2.7M-param
//! SynthLang model, so absolute values differ; orderings and rough ratios
//! are what EXPERIMENTS.md compares.

/// Figure 1 / Table 10 average drops (%) for Llama3.1-8B-Instruct rows
/// (Table 10 reports per-model; we quote Llama2-7B-chat's, the most
/// complete series).
pub fn fig1_drop(sparsity_pct: u32, target: &str) -> String {
    let v = match (sparsity_pct, target) {
        (20, "act") => Some(-0.33),
        (20, "wt") => Some(0.68),
        (50, "act") => Some(2.32),
        (50, "wt") => Some(11.10),
        (70, "act") => Some(19.62),
        (70, "wt") => Some(43.44),
        (90, "act") => Some(43.39),
        (90, "wt") => Some(43.39),
        _ => None,
    };
    v.map(|x| format!("{x:.2}%")).unwrap_or_else(|| "-".into())
}

/// Figure 2 / Table 7 drops (Llama3.1-8B-Instruct, magnitude pruning).
pub fn fig2_drop(pattern: &str) -> String {
    match pattern {
        "2:4" => "14.35%".into(),
        "4:8" => "9.29%".into(),
        "8:16" => "7.38%".into(),
        "16:32" => "5.40%".into(),
        "u50" => "4.30%".into(),
        "u70" => "25.32%".into(),
        _ => "-".into(),
    }
}

/// Table 2 average drops (4-model averages).
pub fn table2_drop(pattern: &str, method: &str) -> String {
    let m = method.to_ascii_lowercase();
    let v: Option<f64> = match pattern {
        "u50" => match m.as_str() {
            "act" => Some(3.82),
            _ => None,
        },
        "2:4" => match m.as_str() {
            "wt" => Some(24.49),
            "act" => Some(9.67),
            "clact" => Some(7.79),
            "amber-pruner" => Some(7.85),
            "var" => Some(6.09),
            "d-pts" => Some(5.84),
            "s-pts" => Some(4.29),
            "l-pts" => Some(8.79),
            "r-sparse(64)" => Some(7.70),
            "r-sparse(128)" => Some(8.05),
            _ => None,
        },
        "8:16" => match m.as_str() {
            "wt" => Some(17.68),
            "act" => Some(5.47),
            "clact" => Some(2.29),
            "amber-pruner" => Some(1.56),
            "var" => Some(3.30),
            "d-pts" => Some(2.07),
            "s-pts" => Some(0.61),
            "l-pts" => Some(5.32),
            "r-sparse(64)" => Some(1.52),
            "r-sparse(128)" => Some(2.63),
            _ => None,
        },
        _ => None,
    };
    v.map(|x| format!("{x:.2}%")).unwrap_or_else(|| "-".into())
}

/// Table 3 PS/PL (Llama3.1-8B, 8:16 column).
pub fn table3_ps_pl(method: &str) -> String {
    match method {
        "ORIG" => "0.4455/0.4861".into(),
        "S-PTS" => "0.2995/0.3327".into(),
        "D-PTS" => "0.2828/0.3198".into(),
        "R-Sparse(64)" => "0.2089/0.2311".into(),
        "VAR" => "0.3161/0.3586".into(),
        _ => "-".into(),
    }
}

/// Table 4 drops (Llama3.1-8B-Instruct, unstructured).
pub fn table4_drop(sparsity_pct: u32, method: &str) -> String {
    let v = match (sparsity_pct, method) {
        (50, "ACT") => Some(4.450),
        (50, "D-PTS") => Some(3.600),
        (50, "VAR") => Some(3.470),
        (50, "CLACT") => Some(3.890),
        (50, "Amber-Pruner") => Some(4.450),
        (70, "ACT") => Some(25.320),
        (70, "D-PTS") => Some(25.680),
        (70, "VAR") => Some(22.660),
        (70, "CLACT") => Some(27.670),
        (70, "Amber-Pruner") => Some(30.680),
        _ => None,
    };
    v.map(|x| format!("{x:.2}%")).unwrap_or_else(|| "-".into())
}

/// Table 5 drops (Llama3.1-8B, 8:16 layer subsets).
pub fn table5_drop(method: &str, layers: &str) -> String {
    let v = match (method, layers) {
        ("LS+L-PTS", "all") => Some(10.90),
        ("LS+L-PTS", "key,out,gate,down") => Some(5.43),
        ("LS+L-PTS", "key,value,gate,down") => Some(3.56),
        ("LS+L-PTS+VAR", "all") => Some(10.60),
        ("LS+L-PTS+VAR", "key,out,gate,down") => Some(4.64),
        ("LS+L-PTS+VAR", "key,value,gate,down") => Some(3.36),
        _ => None,
    };
    v.map(|x| format!("{x:.2}%")).unwrap_or_else(|| "-".into())
}

/// Table 8 average drops (combination methods at 8:16).
pub fn table8_drop(method: &str) -> String {
    let v = match method {
        "CLACT+PTS" => Some(2.40),
        "CLACT+VAR" => Some(2.82),
        "Amber-Pruner+PTS" => Some(2.57),
        "Amber-Pruner+VAR" => Some(2.34),
        "L-PTS+VAR" => Some(5.07),
        _ => None,
    };
    v.map(|x| format!("{x:.2}%")).unwrap_or_else(|| "-".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_orderings_hold_internally() {
        // The references themselves encode the paper's headline claims:
        // 16:32 ~2.7x better than 2:4 (abstract).
        let d24: f64 = 14.35;
        let d1632: f64 = 5.40;
        assert!(d24 / d1632 > 2.5 && d24 / d1632 < 3.0);
        // 8:16 about half the 2:4 drop ("twice the accuracy retention").
        let d816: f64 = 7.38;
        assert!(d24 / d816 > 1.8);
        // ACT beats WT at matched pattern (Table 2).
        assert!(24.49 > 9.67);
        assert!(17.68 > 5.47);
    }

    #[test]
    fn lookups_return_dash_for_unknown() {
        assert_eq!(fig2_drop("3:7"), "-");
        assert_eq!(table2_drop("8:16", "nope"), "-");
        assert_eq!(table3_ps_pl("nope"), "-");
    }

    #[test]
    fn known_lookups_format() {
        assert_eq!(fig2_drop("8:16"), "7.38%");
        assert_eq!(table2_drop("8:16", "S-PTS"), "0.61%");
        assert_eq!(table8_drop("L-PTS+VAR"), "5.07%");
        assert_eq!(table5_drop("LS+L-PTS", "all"), "10.90%");
        assert_eq!(fig1_drop(50, "wt"), "11.10%");
        assert_eq!(table4_drop(70, "VAR"), "22.66%");
    }
}
