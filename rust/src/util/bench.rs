//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Used by every target in `rust/benches/` (registered with
//! `harness = false`). Provides adaptive iteration-count calibration,
//! warmup, robust statistics and throughput reporting, plus a `--filter`
//! CLI like libtest's.

use crate::util::json::Json;
use crate::util::stats::{fmt_duration_s, TimingStats};
use std::time::Instant;

/// A benchmark suite: named measurements printed in a fixed-width report.
pub struct BenchSuite {
    name: String,
    filter: Option<String>,
    results: Vec<BenchResult>,
    /// Target measurement time per benchmark (seconds).
    pub target_time_s: f64,
    /// Measured-sample count.
    pub samples: usize,
}

/// One benchmark's outcome.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub stats: TimingStats,
    pub iters_per_sample: usize,
    /// Optional items-per-iteration for throughput reporting.
    pub throughput_items: Option<f64>,
}

impl BenchSuite {
    /// Create a suite; reads `--filter <substr>` / `--quick` from argv and
    /// ignores libtest flags cargo may pass (e.g. `--bench`).
    pub fn new(name: &str) -> BenchSuite {
        let argv: Vec<String> = std::env::args().collect();
        let mut filter = None;
        let mut target_time_s = 1.0;
        let mut i = 1;
        while i < argv.len() {
            match argv[i].as_str() {
                "--filter" if i + 1 < argv.len() => {
                    filter = Some(argv[i + 1].clone());
                    i += 1;
                }
                "--quick" => target_time_s = 0.2,
                _ => {
                    // Tolerate unknown flags (cargo bench passes --bench);
                    // bare substrings act as a filter, like libtest.
                    if !argv[i].starts_with('-') {
                        filter = Some(argv[i].clone());
                    }
                }
            }
            i += 1;
        }
        println!("== bench suite: {name} ==");
        BenchSuite {
            name: name.to_string(),
            filter,
            results: Vec::new(),
            target_time_s,
            samples: 20,
        }
    }

    fn skip(&self, bench_name: &str) -> bool {
        match &self.filter {
            Some(f) => !bench_name.contains(f.as_str()),
            None => false,
        }
    }

    /// Benchmark a closure. Iteration count per sample is auto-calibrated so
    /// each sample takes ≥ ~1ms, then `samples` samples fill `target_time_s`.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) {
        self.bench_with_items(name, None, f)
    }

    /// Benchmark with a throughput denominator (items processed per
    /// iteration — tokens, bytes, requests...).
    pub fn bench_with_items<F: FnMut()>(&mut self, name: &str, items: Option<f64>, mut f: F) {
        if self.skip(name) {
            return;
        }
        // Calibrate: how many iters take >= 1ms?
        let mut iters = 1usize;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed().as_secs_f64();
            if dt >= 1e-3 || iters >= (1 << 24) {
                break;
            }
            iters *= 2;
        }
        // Decide sample iters so total ≈ target_time_s over self.samples.
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let per_iter = (t0.elapsed().as_secs_f64() / iters as f64).max(1e-12);
        let sample_iters = (((self.target_time_s / self.samples as f64) / per_iter).ceil())
            .clamp(1.0, 1e8) as usize;
        // Warmup + measure.
        for _ in 0..sample_iters.min(1000) {
            f();
        }
        let mut durs = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..sample_iters {
                f();
            }
            let total = t0.elapsed();
            // f64 division: integer Duration division truncates sub-ns
            // per-iter times to zero for very fast benchmarks.
            durs.push(std::time::Duration::from_secs_f64(
                total.as_secs_f64() / sample_iters as f64,
            ));
        }
        let stats = TimingStats::from_durations(&durs);
        let result = BenchResult {
            name: name.to_string(),
            stats,
            iters_per_sample: sample_iters,
            throughput_items: items,
        };
        println!("{}", format_result(&result));
        self.results.push(result);
    }

    /// All results measured so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Throughput (items/sec) of a named benchmark, if it ran with items.
    pub fn rate_of(&self, name: &str) -> Option<f64> {
        self.results
            .iter()
            .find(|r| r.name == name)
            .and_then(|r| r.throughput_items.map(|items| items / r.stats.mean_s.max(1e-12)))
    }

    /// Machine-readable dump of every measurement — bench targets write
    /// this (plus any derived fields) to `BENCH_<suite>.json` files so CI
    /// and the hardware model can cite measured baselines.
    pub fn to_json(&self) -> Json {
        let mut results = Vec::with_capacity(self.results.len());
        for r in &self.results {
            let mut e = Json::obj();
            e.insert("name", r.name.as_str().into());
            e.insert("mean_s", r.stats.mean_s.into());
            e.insert("p50_s", r.stats.p50_s.into());
            e.insert("p95_s", r.stats.p95_s.into());
            e.insert("iters_per_sample", r.iters_per_sample.into());
            if let Some(items) = r.throughput_items {
                e.insert("items_per_iter", items.into());
                e.insert("items_per_sec", (items / r.stats.mean_s.max(1e-12)).into());
            }
            results.push(e);
        }
        let mut j = Json::obj();
        j.insert("suite", self.name.as_str().into());
        j.insert("results", Json::Arr(results));
        j
    }

    /// Print the closing summary (called on drop as well).
    pub fn finish(&self) {
        println!(
            "== {}: {} benchmarks done ==",
            self.name,
            self.results.len()
        );
    }
}

fn format_result(r: &BenchResult) -> String {
    let mut line = format!(
        "{:<44} {:>12}/iter  p50 {:>12}  p95 {:>12}",
        r.name,
        fmt_duration_s(r.stats.mean_s),
        fmt_duration_s(r.stats.p50_s),
        fmt_duration_s(r.stats.p95_s),
    );
    if let Some(items) = r.throughput_items {
        let per_sec = items / r.stats.mean_s.max(1e-12);
        line.push_str(&format!("  {:>14}", format_rate(per_sec)));
    }
    line
}

fn format_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2} G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} K/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.2} /s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut suite = BenchSuite {
            name: "t".into(),
            filter: None,
            results: vec![],
            target_time_s: 0.02,
            samples: 3,
        };
        let mut acc = 0u64;
        suite.bench_with_items("volatile-sum", Some(100.0), || {
            // Real side effect so the optimizer cannot delete the loop.
            acc = acc.wrapping_add(std::hint::black_box(17u64));
            std::hint::black_box(&acc);
        });
        assert_eq!(suite.results().len(), 1);
        assert!(suite.results()[0].stats.mean_s >= 0.0);
        assert!(suite.results()[0].iters_per_sample >= 1);
    }

    #[test]
    fn filter_skips() {
        let mut suite = BenchSuite {
            name: "t".into(),
            filter: Some("match-me".into()),
            results: vec![],
            target_time_s: 0.01,
            samples: 2,
        };
        suite.bench("other", || {});
        assert!(suite.results().is_empty());
        suite.bench("match-me-exactly", || {});
        assert_eq!(suite.results().len(), 1);
    }

    #[test]
    fn json_dump_has_rates() {
        let mut suite = BenchSuite {
            name: "jt".into(),
            filter: None,
            results: vec![],
            target_time_s: 0.01,
            samples: 2,
        };
        let mut acc = 0u64;
        suite.bench_with_items("with-items", Some(64.0), || {
            acc = acc.wrapping_add(std::hint::black_box(3u64));
            std::hint::black_box(&acc);
        });
        suite.bench("no-items", || {
            std::hint::black_box(1 + 1);
        });
        let j = suite.to_json();
        assert_eq!(j.get("suite").and_then(|s| s.as_str()), Some("jt"));
        let rs = j.get("results").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(rs.len(), 2);
        assert!(rs[0].get("items_per_sec").is_some());
        assert!(rs[1].get("items_per_sec").is_none());
        assert!(suite.rate_of("with-items").unwrap() > 0.0);
        assert!(suite.rate_of("no-items").is_none());
        assert!(suite.rate_of("missing").is_none());
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(format_rate(2.5e9), "2.50 G/s");
        assert_eq!(format_rate(2.5e6), "2.50 M/s");
        assert_eq!(format_rate(2.5e3), "2.50 K/s");
        assert_eq!(format_rate(2.5), "2.50 /s");
    }
}
