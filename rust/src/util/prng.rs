//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so this module is the project's
//! randomness substrate: a SplitMix64 seeder feeding an xoshiro256** core,
//! plus the distribution helpers the data generators and benchmarks need.
//! Everything is deterministic given a seed — dataset generation, weight
//! pruning tie-breaks and benchmark workloads are all reproducible.

/// SplitMix64 step — used to expand a single `u64` seed into the four-word
/// xoshiro state. Public because tests and the python side (train.py mirrors
/// it for corpus-parity checks) rely on the exact constants.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Canonical byte-wise FNV-1a (64-bit): the project's label/stream hash.
/// Used to derive per-tensor synthetic-weight streams
/// (`engine::NativeModel::synthetic`) and the decode smoke's output hash
/// (`nmsparse decode`) — one definition, so a constant typo cannot split
/// the two. (`Rng::fork` predates this helper with a slightly different
/// multiplier; its output feeds existing corpora, so it stays as is.)
#[inline]
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h = (h ^ *b as u64).wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// xoshiro256** PRNG. Small, fast, and good enough for synthetic-data and
/// benchmark workloads (not cryptographic).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a named sub-component. Streams with
    /// different labels are decorrelated even for equal parent seeds.
    pub fn fork(&mut self, label: &str) -> Rng {
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        Rng::new(self.next_u64() ^ h)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. `n` must be non-zero.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping (Lemire); bias is negligible
        // for our n << 2^64 workloads.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Choose a reference from a slice uniformly.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm), in
    /// random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        self.shuffle(&mut chosen);
        chosen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_known_vectors() {
        // Offset basis for empty input; classic FNV-1a test vector for "a".
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a64(b"ab"), fnv1a64(b"ba"));
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn uniformity_rough() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(17);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(23);
        for _ in 0..100 {
            let k = r.range(1, 20);
            let s = r.sample_indices(50, k);
            assert_eq!(s.len(), k);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), k, "indices distinct");
        }
    }

    #[test]
    fn fork_decorrelates() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        let mut fa = a.fork("alpha");
        let mut fb = b.fork("beta");
        let same = (0..64).filter(|_| fa.next_u64() == fb.next_u64()).count();
        assert_eq!(same, 0);
    }
}
