//! Zero-dependency observability: runtime-gated per-phase spans, a
//! metrics registry (counters + gauges) and two exports — an aggregated
//! per-phase breakdown (the `phases` block in `BENCH_*.json` and the
//! serve `{"op":"stats"}` reply) and Chrome trace-event JSON for
//! `--trace <path>` (loadable in Perfetto). See DESIGN.md §2.14.
//!
//! Three runtime levels ([`TraceLevel`], one process-wide atomic):
//!
//! - **Off** (default): [`span`] reads one relaxed atomic and returns a
//!   disarmed guard — no clock read, no TLS touch, no allocation
//!   (`rust/tests/trace.rs` pins the zero-allocation property).
//! - **Metrics**: every finished span folds into per-thread per-phase
//!   aggregates (count, total ns, log-bucketed [`Histogram`]) — bounded
//!   memory, no event storage.
//! - **Full**: aggregates plus the span event itself into a per-thread
//!   bounded ring ([`RING_CAP`] events, drop-oldest) for Chrome export.
//!
//! The hot path takes no locks and (past one-time sink setup) performs
//! no allocation: spans land in `thread_local!` sinks, which flush into
//! the process-wide accumulator when their thread exits (TLS `Drop`) or
//! explicitly via [`flush_thread`]/[`snapshot`]. Spans are *complete*
//! records written at guard drop, i.e. in end order — so a parent span
//! is always recorded (and ring-evicted) after its children, and an
//! unwinding backend call still closes every span it opened: a restarted
//! replica cannot orphan an open span by construction.
//!
//! Instrumentation never changes bits: guards only read the clock and
//! write thread-local state, so `decode --check` hashes with tracing on
//! vs. off are pinned identical (CI smoke + `rust/tests/trace.rs`).

use crate::util::json::Json;
use crate::util::stats::{fmt_duration_s, Histogram};
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

// ------------------------------------------------------------------ level

/// How much the tracing substrate records (process-wide).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum TraceLevel {
    /// Record nothing; spans are disarmed without reading the clock.
    Off = 0,
    /// Per-phase aggregates only (counts, totals, histograms).
    Metrics = 1,
    /// Aggregates plus ring-buffered span events for Chrome export.
    Full = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(0);

pub fn set_level(l: TraceLevel) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Raise the level to at least `l`, never lowering it — `loadgen` turns
/// Metrics on for its `phases` report without clobbering `--trace`.
pub fn ensure(l: TraceLevel) {
    LEVEL.fetch_max(l as u8, Ordering::Relaxed);
}

pub fn level() -> TraceLevel {
    match LEVEL.load(Ordering::Relaxed) {
        0 => TraceLevel::Off,
        1 => TraceLevel::Metrics,
        _ => TraceLevel::Full,
    }
}

#[inline]
pub fn enabled() -> bool {
    LEVEL.load(Ordering::Relaxed) != 0
}

// ------------------------------------------------------------------ clock

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process trace epoch (first clock use) — one
/// monotonic timebase shared by every thread, so cross-thread spans in
/// one export are comparable.
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

// ------------------------------------------------------------------ phases

/// One timed pipeline phase (DESIGN.md §2.14 taxonomy).
///
/// `site_matmul_*`, `attention` and `lm_head` are the *leaf* engine
/// phases: on any one thread their spans are disjoint in time, so their
/// totals sum to at most wall × recording-threads
/// (`tools/check_bench_json.py` gates exactly that). `sparsify`/`pack`
/// nest inside their site span, `tick_build`/`prefill_block` are parent
/// spans, and `queue_wait` overlaps across concurrently staged requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Phase {
    /// Server-side admission → dispatch wait of one staged request.
    QueueWait = 0,
    /// One replica scheduler flush: drain admissions, build the tick.
    TickBuild = 1,
    /// One bounded blocked-prefill chunk (all sites, all positions).
    PrefillBlock = 2,
    SiteQ = 3,
    SiteK = 4,
    SiteV = 5,
    SiteO = 6,
    SiteGate = 7,
    SiteUp = 8,
    SiteDown = 9,
    /// In-place sparsification feeding a dense site matmul.
    Sparsify = 10,
    /// Compressed-domain packing feeding a packed site matmul.
    Pack = 11,
    /// Rope + KV row write + causal attention for a layer's positions.
    Attention = 12,
    LmHead = 13,
    /// Delivering one finished request's reply + stats accounting.
    Reply = 14,
    /// Engine/variant construction (`coordinator::pool` load log).
    EngineBuild = 15,
}

pub const PHASE_COUNT: usize = 16;

/// Every phase, in discriminant order (export + aggregation iterate this).
pub const ALL_PHASES: [Phase; PHASE_COUNT] = [
    Phase::QueueWait,
    Phase::TickBuild,
    Phase::PrefillBlock,
    Phase::SiteQ,
    Phase::SiteK,
    Phase::SiteV,
    Phase::SiteO,
    Phase::SiteGate,
    Phase::SiteUp,
    Phase::SiteDown,
    Phase::Sparsify,
    Phase::Pack,
    Phase::Attention,
    Phase::LmHead,
    Phase::Reply,
    Phase::EngineBuild,
];

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::QueueWait => "queue_wait",
            Phase::TickBuild => "tick_build",
            Phase::PrefillBlock => "prefill_block",
            Phase::SiteQ => "site_matmul_q",
            Phase::SiteK => "site_matmul_k",
            Phase::SiteV => "site_matmul_v",
            Phase::SiteO => "site_matmul_o",
            Phase::SiteGate => "site_matmul_gate",
            Phase::SiteUp => "site_matmul_up",
            Phase::SiteDown => "site_matmul_down",
            Phase::Sparsify => "sparsify",
            Phase::Pack => "pack",
            Phase::Attention => "attention",
            Phase::LmHead => "lm_head",
            Phase::Reply => "reply",
            Phase::EngineBuild => "engine_build",
        }
    }

    /// The span phase for site index `i` in `SITES` order
    /// (q k v o gate up down).
    pub fn site(i: usize) -> Phase {
        match i {
            0 => Phase::SiteQ,
            1 => Phase::SiteK,
            2 => Phase::SiteV,
            3 => Phase::SiteO,
            4 => Phase::SiteGate,
            5 => Phase::SiteUp,
            _ => Phase::SiteDown,
        }
    }
}

// ------------------------------------------------------------ thread sinks

/// Per-thread span ring capacity (drop-oldest beyond this).
pub const RING_CAP: usize = 4096;

/// One finished span, as flushed to the global accumulator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceSpan {
    /// Trace-local recording-thread id (dense small integers, not OS tids).
    pub tid: u64,
    pub phase: Phase,
    /// Request-scoped id ([`next_id`]) where known, else 0.
    pub id: u64,
    pub start_ns: u64,
    pub dur_ns: u64,
}

struct ThreadSink {
    tid: u64,
    /// Drop-oldest event ring: grows to [`RING_CAP`] then wraps at `head`
    /// (once full, `head` is both the next write slot and the oldest).
    ring: Vec<TraceSpan>,
    head: usize,
    dropped: u64,
    count: [u64; PHASE_COUNT],
    total_ns: [u64; PHASE_COUNT],
    hist: Vec<Histogram>,
}

impl ThreadSink {
    fn new() -> ThreadSink {
        static NEXT_TID: AtomicU64 = AtomicU64::new(1);
        ThreadSink {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            ring: Vec::with_capacity(RING_CAP),
            head: 0,
            dropped: 0,
            count: [0; PHASE_COUNT],
            total_ns: [0; PHASE_COUNT],
            hist: vec![Histogram::new(); PHASE_COUNT],
        }
    }

    fn has_data(&self) -> bool {
        !self.ring.is_empty() || self.count.iter().any(|c| *c > 0)
    }

    fn record(&mut self, full: bool, phase: Phase, id: u64, start_ns: u64, dur_ns: u64) {
        let p = phase as usize;
        self.count[p] += 1;
        self.total_ns[p] += dur_ns;
        self.hist[p].record(dur_ns as f64 * 1e-9);
        if !full {
            return;
        }
        let span = TraceSpan { tid: self.tid, phase, id, start_ns, dur_ns };
        if self.ring.len() < RING_CAP {
            self.ring.push(span);
        } else {
            self.ring[self.head] = span;
            self.head = (self.head + 1) % RING_CAP;
            self.dropped += 1;
        }
    }

    /// Move everything into `g` and reset this sink (keeping its tid).
    fn flush_into(&mut self, g: &mut Global) {
        if !self.has_data() {
            return;
        }
        g.recorders += 1;
        for p in 0..PHASE_COUNT {
            g.count[p] += self.count[p];
            g.total_ns[p] += self.total_ns[p];
            g.hist[p].merge(&self.hist[p]);
            self.count[p] = 0;
            self.total_ns[p] = 0;
            self.hist[p] = Histogram::new();
        }
        g.dropped += self.dropped;
        self.dropped = 0;
        // Rotate a wrapped ring so the drain below is oldest-first.
        if self.ring.len() >= RING_CAP && self.head != 0 {
            self.ring.rotate_left(self.head);
            self.head = 0;
        }
        g.spans.append(&mut self.ring);
    }
}

/// Flushes a dying thread's sink into the global accumulator.
struct SinkCell(ThreadSink);

impl Drop for SinkCell {
    fn drop(&mut self) {
        if let Ok(mut g) = global().lock() {
            self.0.flush_into(&mut g);
        }
    }
}

thread_local! {
    static SINK: RefCell<Option<SinkCell>> = const { RefCell::new(None) };
}

fn record(phase: Phase, id: u64, start_ns: u64, dur_ns: u64) {
    let lvl = LEVEL.load(Ordering::Relaxed);
    if lvl == 0 {
        return;
    }
    let full = lvl >= TraceLevel::Full as u8;
    // A destroyed TLS slot (thread teardown) silently drops the span.
    let _ = SINK.try_with(|cell| {
        let mut cell = cell.borrow_mut();
        let sink = &mut cell.get_or_insert_with(|| SinkCell(ThreadSink::new())).0;
        sink.record(full, phase, id, start_ns, dur_ns);
    });
}

// ------------------------------------------------------------------ spans

/// RAII span: times from construction to drop. Disarmed — no clock read,
/// no TLS touch — when tracing is off.
pub struct SpanGuard {
    phase: Phase,
    id: u64,
    start_ns: u64,
    armed: bool,
}

#[inline]
pub fn span(phase: Phase) -> SpanGuard {
    span_id(phase, 0)
}

#[inline]
pub fn span_id(phase: Phase, id: u64) -> SpanGuard {
    if !enabled() {
        return SpanGuard { phase, id, start_ns: 0, armed: false };
    }
    SpanGuard { phase, id, start_ns: now_ns(), armed: true }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.armed {
            let end = now_ns();
            record(self.phase, self.id, self.start_ns, end.saturating_sub(self.start_ns));
        }
    }
}

/// Record an already-measured duration as a span ending now — the
/// queue-wait path measures from a staged `Instant`, not a live guard.
pub fn record_duration(phase: Phase, id: u64, d: Duration) {
    if !enabled() {
        return;
    }
    let dur = d.as_nanos() as u64;
    record(phase, id, now_ns().saturating_sub(dur), dur);
}

/// Time `f` through the span API *and* hand the wall time back — the one
/// sanctioned "time a phase" helper (it replaced `stats::time_once` and
/// the ad-hoc `Instant::now()` pairs in `coordinator::pool`).
pub fn timed<R>(phase: Phase, f: impl FnOnce() -> R) -> (R, Duration) {
    let t0 = Instant::now();
    let r = f();
    let d = t0.elapsed();
    record_duration(phase, 0, d);
    (r, d)
}

/// Process-unique request-scoped span id, threaded from admission through
/// the replica worker (and across replica rebuilds: a retried request
/// keeps its id) into queue-wait and reply spans.
pub fn next_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

// --------------------------------------------------------------- global

struct Global {
    count: [u64; PHASE_COUNT],
    total_ns: [u64; PHASE_COUNT],
    hist: Vec<Histogram>,
    spans: Vec<TraceSpan>,
    dropped: u64,
    /// Sink flushes that carried data — an upper bound on the number of
    /// concurrently recording threads (the `phases` sum gate uses it).
    recorders: u64,
}

impl Global {
    fn new() -> Global {
        Global {
            count: [0; PHASE_COUNT],
            total_ns: [0; PHASE_COUNT],
            hist: vec![Histogram::new(); PHASE_COUNT],
            spans: Vec::new(),
            dropped: 0,
            recorders: 0,
        }
    }
}

fn global() -> &'static Mutex<Global> {
    static GLOBAL: OnceLock<Mutex<Global>> = OnceLock::new();
    GLOBAL.get_or_init(|| Mutex::new(Global::new()))
}

fn lock_global() -> MutexGuard<'static, Global> {
    global().lock().unwrap_or_else(|e| e.into_inner())
}

/// Flush the calling thread's sink into the global accumulator (exiting
/// threads flush automatically via TLS drop).
pub fn flush_thread() {
    let _ = SINK.try_with(|cell| {
        if let Some(sc) = cell.borrow_mut().as_mut() {
            sc.0.flush_into(&mut lock_global());
        }
    });
}

/// Clear the global accumulator and the calling thread's sink. Sinks on
/// other *live* threads keep their unflushed data — callers reset between
/// runs whose recording threads (replicas, engines) have already joined.
pub fn reset() {
    let _ = SINK.try_with(|cell| {
        if let Some(sc) = cell.borrow_mut().as_mut() {
            let mut scratch = Global::new();
            sc.0.flush_into(&mut scratch);
        }
    });
    *lock_global() = Global::new();
}

// ------------------------------------------------------------- snapshot

/// Aggregate of one phase across all flushed sinks.
#[derive(Clone, Debug)]
pub struct PhaseAgg {
    pub phase: Phase,
    pub count: u64,
    pub total_ns: u64,
    pub hist: Histogram,
}

/// The per-phase breakdown at one point in time ([`snapshot`]).
#[derive(Clone, Debug, Default)]
pub struct PhaseSnapshot {
    /// Phases with at least one span, in [`ALL_PHASES`] order.
    pub phases: Vec<PhaseAgg>,
    pub dropped_spans: u64,
    pub recorders: u64,
}

/// Flush the calling thread, then copy the global per-phase aggregates.
pub fn snapshot() -> PhaseSnapshot {
    flush_thread();
    let g = lock_global();
    let mut phases = Vec::new();
    for (p, phase) in ALL_PHASES.iter().enumerate() {
        if g.count[p] > 0 {
            phases.push(PhaseAgg {
                phase: *phase,
                count: g.count[p],
                total_ns: g.total_ns[p],
                hist: g.hist[p].clone(),
            });
        }
    }
    PhaseSnapshot { phases, dropped_spans: g.dropped, recorders: g.recorders }
}

impl PhaseSnapshot {
    pub fn is_empty(&self) -> bool {
        self.phases.is_empty()
    }

    /// The `phases` block consumed by `BENCH_decode.json` /
    /// `BENCH_serving.json` / the `stats` op and validated by
    /// `tools/check_bench_json.py`: wall clock, recorder bound, drop
    /// accounting, and per-phase `{count, total_ms, p50_ms, p95_ms}`.
    pub fn to_json(&self, wall_s: f64) -> Json {
        let mut breakdown = Json::obj();
        for a in &self.phases {
            let mut e = Json::obj();
            e.insert("count", (a.count as f64).into());
            e.insert("total_ms", (a.total_ns as f64 / 1e6).into());
            e.insert("p50_ms", (a.hist.percentile(50.0) * 1e3).into());
            e.insert("p95_ms", (a.hist.percentile(95.0) * 1e3).into());
            breakdown.insert(a.phase.name(), e);
        }
        let mut j = Json::obj();
        j.insert("wall_ms", (wall_s * 1e3).into());
        j.insert("recorders", (self.recorders as f64).into());
        j.insert("dropped_spans", (self.dropped_spans as f64).into());
        j.insert("breakdown", breakdown);
        j
    }

    /// One-line top-phases summary for loadgen/decode CLI output.
    pub fn summary(&self) -> String {
        if self.phases.is_empty() {
            return "phases: none recorded".to_string();
        }
        let mut by_total: Vec<&PhaseAgg> = self.phases.iter().collect();
        by_total.sort_by(|a, b| b.total_ns.cmp(&a.total_ns));
        let parts: Vec<String> = by_total
            .iter()
            .take(5)
            .map(|a| {
                format!(
                    "{} {} (n={} p95={})",
                    a.phase.name(),
                    fmt_duration_s(a.total_ns as f64 / 1e9),
                    a.count,
                    fmt_duration_s(a.hist.percentile(95.0)),
                )
            })
            .collect();
        format!("phases: {}", parts.join(", "))
    }
}

// --------------------------------------------------------- chrome export

/// Flush the calling thread and drain every flushed span event.
pub fn take_spans() -> Vec<TraceSpan> {
    flush_thread();
    std::mem::take(&mut lock_global().spans)
}

/// Export-track offset for queue-wait spans: their synthesized start
/// (`now - wait`, [`record_duration`]) reaches back before the dispatch
/// tick that records them, and concurrently staged requests overlap
/// freely — so they render on a separate per-thread track instead of
/// breaking the recording thread's nesting.
pub const WAIT_TRACK_OFFSET: u64 = 10_000;

/// Chrome trace-event JSON (Perfetto-loadable): complete (`"ph":"X"`)
/// events with fractional-microsecond timestamps, sorted by `(tid, ts)`
/// so per-track timestamps are monotone (`tools/check_trace_json.py`
/// validates pairing/nesting on exactly this format). Queue-wait spans
/// land on `tid + WAIT_TRACK_OFFSET` (see above). Ties on `(tid, ts)`
/// order longest-duration first: spans are recorded at guard *drop*
/// (child before parent), so on a coarse clock a parent sharing its
/// first child's start timestamp would otherwise sort after the child
/// and read as a straddle to any laminarity check.
pub fn chrome_trace_json(spans: &[TraceSpan]) -> Json {
    let tid_of = |s: &TraceSpan| match s.phase {
        Phase::QueueWait => s.tid + WAIT_TRACK_OFFSET,
        _ => s.tid,
    };
    let mut sorted: Vec<&TraceSpan> = spans.iter().collect();
    sorted.sort_by(|a, b| {
        (tid_of(a), a.start_ns, std::cmp::Reverse(a.dur_ns))
            .cmp(&(tid_of(b), b.start_ns, std::cmp::Reverse(b.dur_ns)))
    });
    let mut events = Json::Arr(Vec::new());
    for s in sorted {
        let mut e = Json::obj();
        e.insert("name", s.phase.name().into());
        e.insert("cat", "nmsparse".into());
        e.insert("ph", "X".into());
        e.insert("ts", (s.start_ns as f64 / 1e3).into());
        e.insert("dur", (s.dur_ns as f64 / 1e3).into());
        e.insert("pid", 1.0.into());
        e.insert("tid", (tid_of(s) as f64).into());
        let mut args = Json::obj();
        args.insert("id", (s.id as f64).into());
        e.insert("args", args);
        events.push(e);
    }
    let mut j = Json::obj();
    j.insert("traceEvents", events);
    j.insert("displayTimeUnit", "ms".into());
    j
}

/// Drain all span events and write them as Chrome trace JSON to `path`.
/// Returns the number of events written.
pub fn write_chrome_trace(path: &std::path::Path) -> Result<usize> {
    let spans = take_spans();
    let doc = chrome_trace_json(&spans);
    std::fs::write(path, doc.pretty())
        .with_context(|| format!("writing Chrome trace to {}", path.display()))?;
    Ok(spans.len())
}

// ------------------------------------------------------ metrics registry

#[derive(Clone, Copy, PartialEq, Eq)]
enum MetricKind {
    Counter,
    Gauge,
}

/// Monotonic counter handle (always-on, one relaxed `fetch_add` per
/// event; callers cache the handle off the hot path).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value gauge handle (relaxed store; `set_max` for peaks).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn set_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

fn metrics() -> &'static Mutex<BTreeMap<String, (MetricKind, Arc<AtomicU64>)>> {
    static METRICS: OnceLock<Mutex<BTreeMap<String, (MetricKind, Arc<AtomicU64>)>>> =
        OnceLock::new();
    METRICS.get_or_init(|| Mutex::new(BTreeMap::new()))
}

fn metric(name: &str, kind: MetricKind) -> Arc<AtomicU64> {
    let mut m = metrics().lock().unwrap_or_else(|e| e.into_inner());
    let entry = m
        .entry(name.to_string())
        .or_insert_with(|| (kind, Arc::new(AtomicU64::new(0))));
    Arc::clone(&entry.1)
}

/// Look up (registering on first use) the named monotonic counter.
pub fn counter(name: &str) -> Counter {
    Counter(metric(name, MetricKind::Counter))
}

/// Look up (registering on first use) the named gauge.
pub fn gauge(name: &str) -> Gauge {
    Gauge(metric(name, MetricKind::Gauge))
}

/// Every registered metric as a flat `{name: value}` object (BTreeMap
/// order, so serialization is deterministic) — the `metrics` block of the
/// serve `{"op":"stats"}` reply.
pub fn metrics_json() -> Json {
    let m = metrics().lock().unwrap_or_else(|e| e.into_inner());
    let mut j = Json::obj();
    for (name, (_, v)) in m.iter() {
        j.insert(name, (v.load(Ordering::Relaxed) as f64).into());
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    // Global-state behavior (rings, flush ordering, identity) is pinned in
    // `rust/tests/trace.rs`, a separate process — unit tests here stick to
    // the pure pieces so they cannot race the loadgen tests that enable
    // Metrics in this same test binary.

    #[test]
    fn phase_names_and_site_mapping() {
        assert_eq!(Phase::QueueWait.name(), "queue_wait");
        assert_eq!(Phase::site(0), Phase::SiteQ);
        assert_eq!(Phase::site(6), Phase::SiteDown);
        assert_eq!(Phase::site(99), Phase::SiteDown);
        assert_eq!(ALL_PHASES.len(), PHASE_COUNT);
        for (i, p) in ALL_PHASES.iter().enumerate() {
            assert_eq!(*p as usize, i, "discriminants must be dense");
        }
        let mut names: Vec<&str> = ALL_PHASES.iter().map(|p| p.name()).collect();
        names.dedup();
        assert_eq!(names.len(), PHASE_COUNT, "phase names must be unique");
    }

    #[test]
    fn snapshot_json_shape() {
        let mut hist = Histogram::new();
        for ms in [1.0, 2.0, 8.0] {
            hist.record(ms * 1e-3);
        }
        let snap = PhaseSnapshot {
            phases: vec![PhaseAgg {
                phase: Phase::Attention,
                count: 3,
                total_ns: 11_000_000,
                hist,
            }],
            dropped_spans: 2,
            recorders: 1,
        };
        let j = snap.to_json(0.5);
        assert_eq!(j.req("wall_ms").unwrap().as_f64().unwrap(), 500.0);
        assert_eq!(j.req("recorders").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.req("dropped_spans").unwrap().as_f64().unwrap(), 2.0);
        let att = j.req("breakdown").unwrap().req("attention").unwrap();
        assert_eq!(att.req("count").unwrap().as_f64().unwrap(), 3.0);
        assert!((att.req("total_ms").unwrap().as_f64().unwrap() - 11.0).abs() < 1e-9);
        let p50 = att.req("p50_ms").unwrap().as_f64().unwrap();
        let p95 = att.req("p95_ms").unwrap().as_f64().unwrap();
        assert!(p50 <= p95, "p50 {p50} must be <= p95 {p95}");
        assert!(snap.summary().contains("attention"));
        assert!(!snap.is_empty());
        assert!(PhaseSnapshot::default().summary().contains("none"));
    }

    #[test]
    fn chrome_export_sorted_per_tid() {
        let mk = |tid, start_ns, dur_ns| TraceSpan {
            tid,
            phase: Phase::Pack,
            id: 7,
            start_ns,
            dur_ns,
        };
        // Deliberately unsorted input across two tids.
        let spans = [mk(2, 50, 5), mk(1, 30, 10), mk(2, 10, 20), mk(1, 90, 1)];
        let j = chrome_trace_json(&spans);
        let events = j.req("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 4);
        let mut last: Option<(f64, f64)> = None;
        for e in events {
            let tid = e.req("tid").unwrap().as_f64().unwrap();
            let ts = e.req("ts").unwrap().as_f64().unwrap();
            assert_eq!(e.req("ph").unwrap().as_str().unwrap(), "X");
            assert_eq!(e.req("name").unwrap().as_str().unwrap(), "pack");
            assert_eq!(e.req("args").unwrap().req("id").unwrap().as_f64().unwrap(), 7.0);
            if let Some((lt, lts)) = last {
                assert!(tid > lt || (tid == lt && ts >= lts), "(tid, ts) must ascend");
            }
            last = Some((tid, ts));
        }
    }

    #[test]
    fn metrics_registry_counters_and_gauges() {
        let c = counter("test.trace_unit.counter");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Second lookup shares the same cell.
        assert_eq!(counter("test.trace_unit.counter").get(), 5);
        let g = gauge("test.trace_unit.gauge");
        g.set(9);
        g.set_max(3);
        assert_eq!(g.get(), 9);
        g.set_max(12);
        assert_eq!(g.get(), 12);
        let j = metrics_json();
        assert_eq!(j.req("test.trace_unit.counter").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(j.req("test.trace_unit.gauge").unwrap().as_f64().unwrap(), 12.0);
    }

    #[test]
    fn disarmed_guard_is_inert() {
        // Whatever the current level, a disarmed guard records nothing on
        // drop — constructed directly so this cannot race other tests.
        let g = SpanGuard { phase: Phase::LmHead, id: 0, start_ns: 0, armed: false };
        drop(g);
        // timed() always returns the measured wall time.
        let (v, d) = timed(Phase::EngineBuild, || 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0 || d.is_zero());
    }
}
