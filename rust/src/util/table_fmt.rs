//! ASCII table rendering for the paper-table reproduction harness.
//!
//! Every `nmsparse table <id>` command prints its rows through this module
//! so the output matches the paper's row/column structure and can also be
//! dumped as JSON/markdown for EXPERIMENTS.md.

use crate::util::json::Json;

/// A simple table: header + rows of strings, plus a title and footnote.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub note: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            note: String::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    /// Separator row rendered as a dashed line.
    pub fn separator(&mut self) {
        self.rows.push(vec!["--".to_string(); self.header.len()]);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n", self.title));
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            s
        };
        let rule: String = {
            let mut s = String::from("|");
            for wi in &w {
                s.push_str(&format!("{}|", "-".repeat(wi + 2)));
            }
            s
        };
        out.push_str(&line(&self.header, &w));
        out.push('\n');
        out.push_str(&rule);
        out.push('\n');
        for row in &self.rows {
            if row.iter().all(|c| c == "--") {
                out.push_str(&rule);
            } else {
                out.push_str(&line(row, &w));
            }
            out.push('\n');
        }
        if !self.note.is_empty() {
            out.push_str(&format!("note: {}\n", self.note));
        }
        out
    }

    /// Machine-readable form for EXPERIMENTS.md tooling.
    pub fn to_json(&self) -> Json {
        let mut t = Json::obj();
        t.insert("title", self.title.clone().into());
        t.insert("header", self.header.clone().into());
        t.insert(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| c.as_str().into()).collect()))
                    .collect(),
            ),
        );
        if !self.note.is_empty() {
            t.insert("note", self.note.clone().into());
        }
        t
    }
}

/// Format a fraction as the paper does: `0.7268` style accuracy cell.
pub fn acc(x: f64) -> String {
    format!("{x:.4}")
}

/// Format a drop percentage as the paper does: `14.35%` / `-6.46%`.
pub fn pct(x: f64) -> String {
    format!("{x:.2}%")
}

/// Format perplexity; paper writes `OUT` for divergent (>1e3) values.
pub fn ppl(x: f64) -> String {
    if !x.is_finite() || x > 1e3 {
        "OUT".to_string()
    } else {
        format!("{x:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "Drop"]);
        t.row(vec!["ACT".into(), pct(9.666)]);
        t.row(vec!["S-PTS".into(), pct(-4.43)]);
        let r = t.render();
        assert!(r.contains("### Demo"));
        assert!(r.contains("| ACT"));
        assert!(r.contains("9.67%"));
        assert!(r.contains("-4.43%"));
        // All data lines have the same width.
        let widths: Vec<usize> = r.lines().skip(1).map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn ppl_out_sentinel() {
        assert_eq!(ppl(1e6), "OUT");
        assert_eq!(ppl(f64::INFINITY), "OUT");
        assert_eq!(ppl(8.31), "8.31");
    }

    #[test]
    fn json_shape() {
        let mut t = Table::new("T", &["c1"]);
        t.row(vec!["v".into()]);
        let j = t.to_json();
        assert_eq!(j.get("title").unwrap().as_str(), Some("T"));
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn separator_renders_rule() {
        let mut t = Table::new("", &["a"]);
        t.row(vec!["1".into()]);
        t.separator();
        t.row(vec!["2".into()]);
        let rules = t.render().lines().filter(|l| l.starts_with("|-")).count();
        assert_eq!(rules, 2);
    }
}
