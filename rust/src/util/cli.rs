//! Command-line argument parsing (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands; generates usage text from declared options. Only what the
//! `nmsparse` launcher and the examples need — deliberately small.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Declarative option spec used for help text and validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// Parsed arguments: positionals + key/value options + boolean flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    specs: Vec<OptSpec>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]) against
    /// a set of declared option specs. Unknown `--options` are rejected so
    /// typos fail loudly.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, specs: &[OptSpec]) -> Result<Args> {
        let mut args = Args {
            specs: specs.to_vec(),
            ..Default::default()
        };
        let spec_for = |name: &str| specs.iter().find(|s| s.name == name);
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let Some(spec) = spec_for(&key) else {
                    bail!("unknown option --{key} (see --help)");
                };
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => match it.next() {
                            Some(v) => v,
                            None => bail!("option --{key} requires a value"),
                        },
                    };
                    args.opts.insert(key, val);
                } else {
                    if inline_val.is_some() {
                        bail!("flag --{key} does not take a value");
                    }
                    args.flags.push(key);
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// True if the boolean flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// String option with declared/explicit default.
    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.opts.get(name) {
            return v.clone();
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default)
            .unwrap_or("")
            .to_string()
    }

    /// Option present on the command line (not defaulted)?
    pub fn given(&self, name: &str) -> bool {
        self.opts.contains_key(name)
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        let v = self.get(name);
        v.parse::<usize>()
            .map_err(|_| anyhow::anyhow!("option --{name}: '{v}' is not an unsigned integer"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        let v = self.get(name);
        v.parse::<u64>()
            .map_err(|_| anyhow::anyhow!("option --{name}: '{v}' is not a u64"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let v = self.get(name);
        v.parse::<f64>()
            .map_err(|_| anyhow::anyhow!("option --{name}: '{v}' is not a number"))
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        let v = self.get(name);
        if v.is_empty() {
            vec![]
        } else {
            v.split(',').map(|s| s.trim().to_string()).collect()
        }
    }
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{about}\n\nUsage: nmsparse {cmd} [options]\n\nOptions:\n");
    for spec in specs {
        let left = if spec.takes_value {
            format!("--{} <v>", spec.name)
        } else {
            format!("--{}", spec.name)
        };
        let default = spec
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  {left:<24} {}{default}\n", spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "steps", takes_value: true, default: Some("100"), help: "steps" },
            OptSpec { name: "out", takes_value: true, default: None, help: "output" },
            OptSpec { name: "verbose", takes_value: false, default: None, help: "chatty" },
        ]
    }

    fn parse(v: &[&str]) -> Result<Args> {
        Args::parse(v.iter().map(|s| s.to_string()), &specs())
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = parse(&["run", "--steps", "5", "--verbose", "extra"]).unwrap();
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.get_usize("steps").unwrap(), 5);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["--steps=7"]).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 7);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 100);
        assert_eq!(a.get("out"), "");
        assert!(!a.given("steps"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse(&["--bogus"]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&["--steps"]).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(parse(&["--verbose=1"]).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["--steps", "abc"]).unwrap();
        assert!(a.get_usize("steps").is_err());
    }

    #[test]
    fn list_option() {
        let mut sp = specs();
        sp.push(OptSpec { name: "methods", takes_value: true, default: Some(""), help: "m" });
        let a = Args::parse(
            ["--methods", "act, var ,spts"].iter().map(|s| s.to_string()),
            &sp,
        )
        .unwrap();
        assert_eq!(a.get_list("methods"), vec!["act", "var", "spts"]);
    }

    #[test]
    fn usage_contains_options() {
        let u = usage("demo", "Demo command", &specs());
        assert!(u.contains("--steps"));
        assert!(u.contains("[default: 100]"));
    }
}
