//! Minimal JSON codec.
//!
//! The offline build has no `serde`, so the project carries its own JSON
//! substrate. It is used for the weights manifest written by `aot.py`, the
//! SynthLang dataset files, experiment configs, and machine-readable table
//! output. Supports the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null); numbers are held as `f64` which is
//! sufficient for every producer in this repo.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so that
/// serialization is deterministic — table outputs diff cleanly run-to-run.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ----
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs<I: IntoIterator<Item = (&'static str, Json)>>(pairs: I) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in pairs {
            m.insert(k.to_string(), v);
        }
        Json::Obj(m)
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj.get(key)` that errors with the key name — manifest parsing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn insert(&mut self, key: &str, value: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        } else {
            panic!("insert on non-object json value");
        }
    }

    pub fn push(&mut self, value: Json) {
        if let Json::Arr(v) = self {
            v.push(value);
        } else {
            panic!("push on non-array json value");
        }
    }

    // ---- serialization ----

    /// Compact single-line serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty-printed serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document from text.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Handle surrogate pairs.
                            if (0xD800..0xDC00).contains(&cp) {
                                self.pos += 5;
                                if self.peek() != Some(b'\\') {
                                    return Err(self.err("lone high surrogate"));
                                }
                                self.pos += 1;
                                if self.peek() != Some(b'u') {
                                    return Err(self.err("lone high surrogate"));
                                }
                                if self.pos + 4 >= self.bytes.len() {
                                    return Err(self.err("bad \\u escape"));
                                }
                                let hex2 = std::str::from_utf8(
                                    &self.bytes[self.pos + 1..self.pos + 5],
                                )
                                .map_err(|_| self.err("bad \\u escape"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad \\u escape"))?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("bad low surrogate"));
                                }
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(
                                    char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                                );
                                self.pos += 4; // the final hex digits; +1 below
                            } else {
                                s.push(
                                    char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                                );
                                self.pos += 4;
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// Convenience From impls keep table-generation code terse.
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, false, null], "c": "hi\nthere", "d": -2.5e3}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("d").unwrap().as_f64(), Some(-2500.0));
        let reparsed = parse(&v.dump()).unwrap();
        assert_eq!(v, reparsed);
    }

    #[test]
    fn pretty_roundtrip() {
        let mut o = Json::obj();
        o.insert("name", "q_proj".into());
        o.insert("shape", vec![256usize, 256].into());
        let r = parse(&o.pretty()).unwrap();
        assert_eq!(o, r);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let r = parse(&v.dump()).unwrap();
        assert_eq!(v, r);
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        let v = parse(r#""A😀""#).unwrap();
        assert_eq!(v.as_str(), Some("A😀"));
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn all_control_chars_roundtrip_escaped() {
        // Every char below 0x20 must be emitted in \uXXXX (or short-escape)
        // form and parse back identically — tenant ids and synthlang text
        // can carry arbitrary bytes.
        let raw: String = (1u8..0x20).map(|b| b as char).collect();
        let v = Json::Str(raw.clone());
        let dumped = v.dump();
        assert!(
            dumped.bytes().all(|b| (0x20..0x7f).contains(&b)),
            "control chars leaked into dump: {dumped:?}"
        );
        assert_eq!(parse(&dumped).unwrap().as_str(), Some(raw.as_str()));
    }

    #[test]
    fn rejects_truncated_escapes() {
        // Truncated or malformed \u escapes must error, never panic
        // (the low-surrogate path used to slice out of bounds).
        for src in [
            r#""\u"#,
            r#""\u00"#,
            r#""\u00""#,
            r#""\ud83d"#,
            r#""\ud83d""#,
            r#""\ud83d\"#,
            r#""\ud83d\u"#,
            r#""\ud83d\ud8"#,
            r#""\ud83dA""#,
            r#""\udc00""#,
            r#""\uzzzz""#,
        ] {
            assert!(parse(src).is_err(), "accepted truncated escape {src:?}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01x").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("[1] extra").is_err());
    }

    #[test]
    fn nested_structure() {
        let src = r#"{"layers": [{"w": [[1,2],[3,4]], "ok": true}]}"#;
        let v = parse(src).unwrap();
        let layers = v.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 1);
        assert_eq!(layers[0].get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn integers_serialize_without_point() {
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.5).dump(), "3.5");
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        assert!(parse(&s).is_ok());
    }
}
