//! Property-based testing mini-framework.
//!
//! `proptest` is not available offline, so this module provides the shape of
//! it that the invariant tests need: seeded generators, a `forall` runner
//! that reports the failing case and its seed, and integer shrinking. Used
//! by the sparsity, metadata, coordinator and synthlang test suites.

use crate::util::prng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Seed can be pinned via NMSPARSE_PROP_SEED for reproducing failures.
        let seed = std::env::var("NMSPARSE_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xA5A5_5A5A);
        Config { cases: 128, seed }
    }
}

/// Run `prop` against `cases` values drawn by `gen`. On failure, attempts a
/// simple halving shrink via `shrink` (pass `|_| vec![]` to disable) and
/// panics with the minimal failing input's Debug representation + seed.
pub fn forall<T, G, P, S>(cfg: &Config, mut gen: G, prop: P, shrink: S)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> bool,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if prop(&input) {
            continue;
        }
        // Shrink: breadth-first over candidate reductions.
        let mut minimal = input.clone();
        let mut frontier = shrink(&minimal);
        let mut budget = 1000;
        while let Some(cand) = frontier.pop() {
            if budget == 0 {
                break;
            }
            budget -= 1;
            if !prop(&cand) {
                minimal = cand.clone();
                frontier = shrink(&minimal);
            }
        }
        panic!(
            "property failed at case {case} (seed {}):\n  original: {:?}\n  minimal:  {:?}",
            cfg.seed, input, minimal
        );
    }
}

/// `forall` without shrinking — most of our invariants have small inputs
/// already.
pub fn forall_simple<T, G, P>(cfg: &Config, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    forall(cfg, gen, prop, |_| vec![]);
}

/// Generate a vector of f32s with a mix of magnitudes, signs, zeros and
/// ties — the adversarial distribution for selection/pruning code.
pub fn gen_activations(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len)
        .map(|_| {
            match rng.below(10) {
                0 => 0.0,                                  // exact zeros
                1 => 1.0,                                  // ties
                2 => -1.0,                                 // sign-symmetric ties
                3 => (rng.normal() * 100.0) as f32,        // outliers
                _ => rng.normal() as f32,                  // bulk
            }
        })
        .collect()
}

/// Shrinker for `Vec<f32>`: halves the vector and zeroes elements.
pub fn shrink_vec_f32(v: &Vec<f32>) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
    }
    for i in 0..v.len().min(8) {
        if v[i] != 0.0 {
            let mut w = v.clone();
            w[i] = 0.0;
            out.push(w);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let cfg = Config { cases: 64, seed: 1 };
        forall_simple(
            &cfg,
            |rng| rng.below(1000),
            |x| *x < 1000,
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        let cfg = Config { cases: 64, seed: 2 };
        forall_simple(&cfg, |rng| rng.below(100), |x| *x < 50);
    }

    #[test]
    fn shrinking_reduces_input() {
        let cfg = Config { cases: 32, seed: 3 };
        let result = std::panic::catch_unwind(|| {
            forall(
                &cfg,
                |rng| {
                    let n = rng.range(4, 64);
                    (0..n).map(|i| i as f32).collect::<Vec<f32>>()
                },
                |v| v.len() < 4, // always fails
                shrink_vec_f32,
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal"));
    }

    #[test]
    fn gen_activations_has_structure() {
        let mut rng = Rng::new(9);
        let v = gen_activations(&mut rng, 10_000);
        let zeros = v.iter().filter(|x| **x == 0.0).count();
        let big = v.iter().filter(|x| x.abs() > 10.0).count();
        assert!(zeros > 100, "zeros present");
        assert!(big > 100, "outliers present");
    }
}
