//! Dense f32 tensors + the on-disk weights format shared with `aot.py`.
//!
//! The python compile path serializes the trained checkpoint as a flat
//! little-endian f32 blob (`weights.bin`) plus a JSON manifest describing
//! name/shape/offset of each array. Rust loads those into `Tensor`s, mutates
//! them (weight pruning, quantization baselines) and feeds them to PJRT as
//! literals. Keeping the format trivial avoids any protobuf/npz dependency.

use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

/// A dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn scalar(x: f32) -> Tensor {
        Tensor {
            shape: vec![],
            data: vec![x],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Number of rows for a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[0]
    }

    /// Number of columns for a 2-D tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[1]
    }

    /// Borrow row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    /// Mutably borrow row `i` of a 2-D tensor.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Fraction of exactly-zero elements.
    pub fn zero_fraction(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|x| **x == 0.0).count() as f64 / self.data.len() as f64
    }

    /// L2 norm of the whole tensor.
    pub fn l2(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
    }

    /// Mean absolute value.
    pub fn mean_abs(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|x| x.abs() as f64).sum::<f64>() / self.data.len() as f64
    }

    /// Max |a - b| against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max)
    }
}

/// A named collection of tensors — the checkpoint / method-parameter store.
#[derive(Clone, Debug, Default)]
pub struct TensorStore {
    map: BTreeMap<String, Tensor>,
}

impl TensorStore {
    pub fn new() -> TensorStore {
        TensorStore::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .with_context(|| format!("tensor '{name}' not in store"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.map
            .get_mut(name)
            .with_context(|| format!("tensor '{name}' not in store (mut)"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.map.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = (&str, &mut Tensor)> {
        self.map.iter_mut().map(|(k, v)| (k.as_str(), v))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Load `<stem>.bin` + `<stem>.json` (manifest) written by `aot.py`
    /// (or by [`TensorStore::save`]).
    pub fn load(stem: &Path) -> Result<TensorStore> {
        let manifest_path = stem.with_extension("json");
        let bin_path = stem.with_extension("bin");
        let manifest_text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = json::parse(&manifest_text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", manifest_path.display()))?;
        let mut blob = Vec::new();
        std::fs::File::open(&bin_path)
            .with_context(|| format!("opening {}", bin_path.display()))?
            .read_to_end(&mut blob)?;
        let entries = manifest
            .req("tensors")?
            .as_arr()
            .context("manifest 'tensors' not an array")?;
        let mut store = TensorStore::new();
        for e in entries {
            let name = e.req("name")?.as_str().context("tensor name")?.to_string();
            let shape: Vec<usize> = e
                .req("shape")?
                .as_arr()
                .context("tensor shape")?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect();
            let offset = e.req("offset")?.as_usize().context("tensor offset")?;
            let n: usize = shape.iter().product();
            let bytes = &blob
                .get(offset..offset + 4 * n)
                .with_context(|| format!("blob too short for tensor '{name}'"))?;
            let mut data = Vec::with_capacity(n);
            for chunk in bytes.chunks_exact(4) {
                data.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
            }
            store.insert(&name, Tensor::from_vec(&shape, data));
        }
        if store.is_empty() {
            bail!("manifest {} contained no tensors", manifest_path.display());
        }
        Ok(store)
    }

    /// Save as `<stem>.bin` + `<stem>.json` in the same format `aot.py` emits.
    pub fn save(&self, stem: &Path) -> Result<()> {
        let mut blob: Vec<u8> = Vec::new();
        let mut entries = Vec::new();
        for (name, t) in self.iter() {
            let offset = blob.len();
            for x in &t.data {
                blob.extend_from_slice(&x.to_le_bytes());
            }
            let mut e = Json::obj();
            e.insert("name", name.into());
            e.insert("shape", t.shape.clone().into());
            e.insert("offset", offset.into());
            entries.push(e);
        }
        let mut manifest = Json::obj();
        manifest.insert("tensors", Json::Arr(entries));
        manifest.insert("format", "nmsparse-flat-f32-le-v1".into());
        if let Some(parent) = stem.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::File::create(stem.with_extension("bin"))?.write_all(&blob)?;
        std::fs::write(stem.with_extension("json"), manifest.pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1., 2., 3.]);
    }

    #[test]
    fn zero_fraction() {
        let t = Tensor::from_vec(&[4], vec![0., 1., 0., 2.]);
        assert_eq!(t.zero_fraction(), 0.5);
    }

    #[test]
    fn store_roundtrip_via_disk() {
        let dir = std::env::temp_dir().join(format!("nmsparse-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let stem = dir.join("ckpt");
        let mut s = TensorStore::new();
        s.insert("a.w", Tensor::from_vec(&[2, 2], vec![1., -2., 3.5, 0.]));
        s.insert("b", Tensor::from_vec(&[3], vec![9., 8., 7.]));
        s.insert("scalar", Tensor::scalar(4.25));
        s.save(&stem).unwrap();
        let loaded = TensorStore::load(&stem).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded.get("a.w").unwrap(), s.get("a.w").unwrap());
        assert_eq!(loaded.get("scalar").unwrap().data, vec![4.25]);
        assert_eq!(loaded.num_params(), 8);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_tensor_is_error() {
        let s = TensorStore::new();
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.5, 2.0]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-9);
    }
}
