//! Infrastructure substrates built from scratch for the offline environment:
//! PRNG, JSON, tensors + checkpoint I/O, thread pool, CLI parsing, summary
//! statistics, a property-testing mini-framework, a micro-bench harness,
//! table rendering, and the tracing/metrics substrate.

pub mod bench;
pub mod cli;
pub mod json;
pub mod miniprop;
pub mod prng;
pub mod stats;
pub mod table_fmt;
pub mod tensor;
pub mod threadpool;
pub mod trace;
