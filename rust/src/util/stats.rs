//! Summary statistics + wall-clock measurement helpers.
//!
//! Shared by the eval harness (accuracy aggregation), the hardware model
//! (distribution summaries) and the bench harness (robust timing stats).

use std::time::{Duration, Instant};

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let f = rank - lo as f64;
        v[lo] * (1.0 - f) + v[hi] * f
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Relative drop in percent: how much worse `value` is than `baseline`.
/// Matches the paper's "Avg drop (%)": positive = degradation, negative =
/// improvement over the dense baseline.
pub fn relative_drop_pct(baseline: f64, value: f64) -> f64 {
    if baseline == 0.0 {
        return 0.0;
    }
    (baseline - value) / baseline * 100.0
}

/// Aggregate timing statistics for a set of measured runs.
#[derive(Clone, Debug)]
pub struct TimingStats {
    pub n: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub max_s: f64,
}

impl TimingStats {
    pub fn from_durations(ds: &[Duration]) -> TimingStats {
        let xs: Vec<f64> = ds.iter().map(|d| d.as_secs_f64()).collect();
        TimingStats {
            n: xs.len(),
            mean_s: mean(&xs),
            std_s: stddev(&xs),
            min_s: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            p50_s: percentile(&xs, 50.0),
            p95_s: percentile(&xs, 95.0),
            max_s: xs.iter().cloned().fold(0.0, f64::max),
        }
    }

    /// Human-readable one-liner, auto-scaled units.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={} p50={} p95={} min={} max={}",
            self.n,
            fmt_duration_s(self.mean_s),
            fmt_duration_s(self.p50_s),
            fmt_duration_s(self.p95_s),
            fmt_duration_s(self.min_s),
            fmt_duration_s(self.max_s),
        )
    }
}

/// Format seconds with an appropriate unit.
pub fn fmt_duration_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Time a closure once.
pub fn time_once<R, F: FnOnce() -> R>(f: F) -> (R, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

/// Run `f` repeatedly: `warmup` unmeasured runs then `iters` measured runs.
pub fn time_many<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> TimingStats {
    for _ in 0..warmup {
        f();
    }
    let mut ds = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        ds.push(t0.elapsed());
    }
    TimingStats::from_durations(&ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 50.0);
        assert_eq!(median(&xs), 30.0);
        assert!((percentile(&xs, 25.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn drop_pct_signs() {
        assert!((relative_drop_pct(0.8, 0.72) - 10.0).abs() < 1e-9);
        assert!(relative_drop_pct(0.8, 0.88) < 0.0); // improvement
        assert_eq!(relative_drop_pct(0.0, 1.0), 0.0);
    }

    #[test]
    fn timing_runs() {
        let stats = time_many(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(stats.n, 5);
        assert!(stats.mean_s >= 0.0);
        assert!(stats.min_s <= stats.max_s);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration_s(2.5), "2.500s");
        assert!(fmt_duration_s(0.002).ends_with("ms"));
        assert!(fmt_duration_s(2e-6).ends_with("us"));
        assert!(fmt_duration_s(5e-9).ends_with("ns"));
    }
}
