//! Summary statistics + wall-clock measurement helpers.
//!
//! Shared by the eval harness (accuracy aggregation), the hardware model
//! (distribution summaries) and the bench harness (robust timing stats).

use std::time::Duration;

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let f = rank - lo as f64;
        v[lo] * (1.0 - f) + v[hi] * f
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Relative drop in percent: how much worse `value` is than `baseline`.
/// Matches the paper's "Avg drop (%)": positive = degradation, negative =
/// improvement over the dense baseline.
pub fn relative_drop_pct(baseline: f64, value: f64) -> f64 {
    if baseline == 0.0 {
        return 0.0;
    }
    (baseline - value) / baseline * 100.0
}

/// Aggregate timing statistics for a set of measured runs.
#[derive(Clone, Debug)]
pub struct TimingStats {
    pub n: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub max_s: f64,
}

impl TimingStats {
    pub fn from_durations(ds: &[Duration]) -> TimingStats {
        let xs: Vec<f64> = ds.iter().map(|d| d.as_secs_f64()).collect();
        TimingStats {
            n: xs.len(),
            mean_s: mean(&xs),
            std_s: stddev(&xs),
            min_s: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            p50_s: percentile(&xs, 50.0),
            p95_s: percentile(&xs, 95.0),
            max_s: xs.iter().cloned().fold(0.0, f64::max),
        }
    }

    /// Human-readable one-liner, auto-scaled units.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={} p50={} p95={} min={} max={}",
            self.n,
            fmt_duration_s(self.mean_s),
            fmt_duration_s(self.p50_s),
            fmt_duration_s(self.p95_s),
            fmt_duration_s(self.min_s),
            fmt_duration_s(self.max_s),
        )
    }
}

/// Format seconds with an appropriate unit.
pub fn fmt_duration_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Reusable log-bucketed latency histogram for the serving path.
///
/// Buckets are geometric: 8 per octave (each spans a ×2^(1/8) ≈ 9% range)
/// from 1 µs to ~4.4 ks, so percentile error is bounded by bucket width
/// while `record` stays allocation-free and O(1). Designed for the
/// [`crate::coordinator::server::ServerCore`] per-request latency stats
/// (`{"op":"stats"}` and `BENCH_serving.json`):
///
/// - **Monotone percentiles**: `p <= q` implies
///   `percentile(p) <= percentile(q)` (cumulative-count search over fixed
///   buckets, clamped to the observed `[min, max]`).
/// - **Associative, commutative merge**: counts add element-wise and the
///   duration sum is saturating integer nanoseconds, so merging per-replica
///   histograms in any grouping yields identical stats (property-tested).
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum_ns: u64,
    min_s: f64,
    max_s: f64,
}

/// Smallest bucketed latency (seconds); everything below lands in bucket 0.
const HIST_MIN_S: f64 = 1e-6;
/// Buckets per octave (factor-of-two range).
const HIST_PER_OCTAVE: f64 = 8.0;
/// 8/octave × 32 octaves ≈ 1 µs .. 4.4 ks.
const HIST_BUCKETS: usize = 256;

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; HIST_BUCKETS],
            total: 0,
            sum_ns: 0,
            min_s: f64::INFINITY,
            max_s: 0.0,
        }
    }

    fn bucket_of(s: f64) -> usize {
        if s <= HIST_MIN_S {
            return 0; // `record` clamps, so s is finite and >= 0 here
        }
        let idx = ((s / HIST_MIN_S).log2() * HIST_PER_OCTAVE).floor() as usize;
        idx.min(HIST_BUCKETS - 1)
    }

    /// Geometric midpoint of a bucket (seconds).
    fn bucket_mid(idx: usize) -> f64 {
        HIST_MIN_S * 2f64.powf((idx as f64 + 0.5) / HIST_PER_OCTAVE)
    }

    /// Record one latency in seconds. Negative/NaN values clamp to 0.
    pub fn record(&mut self, seconds: f64) {
        let s = if seconds.is_finite() && seconds > 0.0 { seconds } else { 0.0 };
        self.counts[Self::bucket_of(s)] += 1;
        self.total += 1;
        self.sum_ns = self.sum_ns.saturating_add((s * 1e9).round() as u64);
        self.min_s = self.min_s.min(s);
        self.max_s = self.max_s.max(s);
    }

    /// Record one latency from a `Duration`.
    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_secs_f64());
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Mean recorded latency in seconds (0 when empty).
    pub fn mean_s(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum_ns as f64 / 1e9 / self.total as f64
    }

    pub fn min_s(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min_s
        }
    }

    pub fn max_s(&self) -> f64 {
        self.max_s
    }

    /// Approximate percentile (seconds), `p` in [0, 100]. Returns the
    /// geometric midpoint of the bucket holding the rank-`ceil(p/100·n)`
    /// sample, clamped to the observed `[min, max]` so no percentile ever
    /// leaves the observed range.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let p = p.clamp(0.0, 100.0);
        let rank = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_mid(i).clamp(self.min_s, self.max_s);
            }
        }
        self.max_s
    }

    /// Merge another histogram into this one (associative + commutative).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        if other.total > 0 {
            self.min_s = self.min_s.min(other.min_s);
            self.max_s = self.max_s.max(other.max_s);
        }
    }

    /// One-line summary used by the serve log and loadgen report.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={} p50={} p95={} p99={} max={}",
            self.total,
            fmt_duration_s(self.mean_s()),
            fmt_duration_s(self.percentile(50.0)),
            fmt_duration_s(self.percentile(95.0)),
            fmt_duration_s(self.percentile(99.0)),
            fmt_duration_s(self.max_s()),
        )
    }
}

// NOTE: one-off wall-clock helpers (`time_once`/`time_many`) used to live
// here; phase timing now goes through `util::trace` (`trace::timed` /
// span guards) so there is exactly one way to time a phase. `TimingStats`
// stays: it is the *aggregation* type the bench harness (`util::bench`)
// builds from its own measured durations.

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 50.0);
        assert_eq!(median(&xs), 30.0);
        assert!((percentile(&xs, 25.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn drop_pct_signs() {
        assert!((relative_drop_pct(0.8, 0.72) - 10.0).abs() < 1e-9);
        assert!(relative_drop_pct(0.8, 0.88) < 0.0); // improvement
        assert_eq!(relative_drop_pct(0.0, 1.0), 0.0);
    }

    #[test]
    fn timing_stats_from_durations() {
        let ds: Vec<Duration> = (1..=5).map(Duration::from_millis).collect();
        let stats = TimingStats::from_durations(&ds);
        assert_eq!(stats.n, 5);
        assert!((stats.mean_s - 3e-3).abs() < 1e-12);
        assert!(stats.min_s <= stats.p50_s && stats.p50_s <= stats.p95_s);
        assert!(stats.p95_s <= stats.max_s);
        assert!(stats.summary().starts_with("n=5"));
    }

    #[test]
    fn histogram_basics() {
        let mut h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean_s(), 0.0);
        for ms in [1.0, 2.0, 3.0, 4.0, 100.0] {
            h.record(ms * 1e-3);
        }
        assert_eq!(h.count(), 5);
        assert!((h.min_s() - 1e-3).abs() < 1e-12);
        assert!((h.max_s() - 0.1).abs() < 1e-12);
        // Bucket resolution is ~9%, so percentiles land near the samples.
        assert!((h.percentile(50.0) - 3e-3).abs() < 3e-4);
        assert!(h.percentile(0.0) >= h.min_s());
        assert!(h.percentile(100.0) <= h.max_s());
        assert!(h.mean_s() > 0.0);
        assert!(h.summary().starts_with("n=5"));
        // Degenerate inputs clamp instead of poisoning the buckets.
        h.record(-1.0);
        h.record(f64::NAN);
        assert_eq!(h.count(), 7);
        assert_eq!(h.min_s(), 0.0);
    }

    #[test]
    fn histogram_percentiles_track_known_distribution() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-4); // 0.1ms .. 100ms uniform
        }
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        assert!((p50 - 0.05).abs() / 0.05 < 0.10, "p50={p50}");
        assert!((p95 - 0.095).abs() / 0.095 < 0.10, "p95={p95}");
        assert!((p99 - 0.099).abs() / 0.099 < 0.10, "p99={p99}");
        assert!(p50 <= p95 && p95 <= p99);
    }

    #[test]
    fn prop_histogram_percentile_monotone() {
        // For any sample set and any pair p <= q, percentile(p) <=
        // percentile(q), and all percentiles stay within [min, max].
        let cfg = crate::util::miniprop::Config { cases: 128, ..Default::default() };
        crate::util::miniprop::forall_simple(
            &cfg,
            |rng: &mut crate::util::prng::Rng| {
                let n = rng.range(1, 60);
                let samples: Vec<f64> =
                    (0..n).map(|_| rng.f64() * 10f64.powi(rng.range(0, 7) as i32 - 4)).collect();
                let ps: Vec<f64> = (0..8).map(|_| rng.f64() * 100.0).collect();
                (samples, ps)
            },
            |(samples, ps)| {
                let mut h = Histogram::new();
                for s in samples {
                    h.record(*s);
                }
                let mut sorted = ps.clone();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let vals: Vec<f64> = sorted.iter().map(|p| h.percentile(*p)).collect();
                vals.windows(2).all(|w| w[0] <= w[1])
                    && vals.iter().all(|v| *v >= h.min_s() && *v <= h.max_s())
            },
        );
    }

    #[test]
    fn prop_histogram_merge_associative_commutative() {
        // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c) and a ⊕ b == b ⊕ a, exactly —
        // the invariant that makes per-replica stats aggregation safe.
        let cfg = crate::util::miniprop::Config { cases: 96, ..Default::default() };
        crate::util::miniprop::forall_simple(
            &cfg,
            |rng: &mut crate::util::prng::Rng| {
                let mut parts: Vec<Vec<f64>> = Vec::new();
                for _ in 0..3 {
                    let n = rng.range(0, 20);
                    parts.push((0..n).map(|_| rng.f64() * 0.5).collect());
                }
                parts
            },
            |parts| {
                let hs: Vec<Histogram> = parts
                    .iter()
                    .map(|p| {
                        let mut h = Histogram::new();
                        for s in p {
                            h.record(*s);
                        }
                        h
                    })
                    .collect();
                let mut left = hs[0].clone();
                left.merge(&hs[1]);
                left.merge(&hs[2]);
                let mut bc = hs[1].clone();
                bc.merge(&hs[2]);
                let mut right = hs[0].clone();
                right.merge(&bc);
                let mut ba = hs[1].clone();
                ba.merge(&hs[0]);
                let mut ab = hs[0].clone();
                ab.merge(&hs[1]);
                left == right && ab == ba
            },
        );
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration_s(2.5), "2.500s");
        assert!(fmt_duration_s(0.002).ends_with("ms"));
        assert!(fmt_duration_s(2e-6).ends_with("us"));
        assert!(fmt_duration_s(5e-9).ends_with("ns"));
    }
}
