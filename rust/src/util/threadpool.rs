//! A small fixed-size thread pool.
//!
//! The offline build has no tokio; the coordinator's parallelism needs are
//! CPU-bound fan-out (evaluate many batches, generate many examples), for
//! which a plain worker pool over an MPMC channel is the right tool anyway.
//! Includes a `scope`-style parallel map used by the eval harness.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed worker pool. Jobs are executed in submission order per the shared
/// queue; `wait_idle` blocks until every submitted job has completed.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    /// Create a pool with `n` workers (min 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending: Arc<(Mutex<usize>, Condvar)> = Arc::new((Mutex::new(0), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(
                thread::Builder::new()
                    .name(format!("nmsparse-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cv) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            tx: Some(tx),
            workers,
            pending,
        }
    }

    /// Pool sized to available parallelism.
    pub fn with_default_size() -> ThreadPool {
        ThreadPool::new(default_threads())
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool receiver gone");
    }

    /// Block until all submitted jobs have finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit on recv error
        for w in self.workers.drain(..) {
            w.join().ok();
        }
    }
}

/// Available parallelism (≥ 1) — the default worker count for the parallel
/// helpers below.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Parallel in-place map over disjoint chunks of `data`: `f(chunk_index,
/// chunk)` is called for every `chunk_len`-sized chunk (the last may be
/// shorter), spread across up to `threads` scoped workers. Chunks are
/// assigned contiguously so each worker touches one memory span; the call
/// blocks until every chunk is done. Used by the fused sparsification
/// pipeline's row-parallel batch driver.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    if data.is_empty() {
        return;
    }
    let n_chunks = (data.len() + chunk_len - 1) / chunk_len;
    let threads = threads.max(1).min(n_chunks);
    if threads == 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let chunks_per_worker = (n_chunks + threads - 1) / threads;
    thread::scope(|scope| {
        let mut rest = data;
        let mut first_chunk = 0usize;
        while !rest.is_empty() {
            let take = (chunks_per_worker * chunk_len).min(rest.len());
            let (span, tail) = rest.split_at_mut(take);
            rest = tail;
            let f = &f;
            let base = first_chunk;
            scope.spawn(move || {
                for (i, chunk) in span.chunks_mut(chunk_len).enumerate() {
                    f(base + i, chunk);
                }
            });
            first_chunk += chunks_per_worker;
        }
    });
}

/// Lockstep dual-slice variant of [`par_chunks_mut`]: splits `a` into
/// `a_chunk`-sized chunks and `b` into `b_chunk`-sized chunks (same chunk
/// count required — the last chunk of each may be shorter) and calls
/// `f(chunk_index, a_chunk, b_chunk)` for each pair across up to `threads`
/// scoped workers. Used by the packed-stream emitter, whose kept-values and
/// metadata-words outputs are two parallel row-blocked arrays.
pub fn par_chunks2_mut<A, B, F>(
    a: &mut [A],
    a_chunk: usize,
    b: &mut [B],
    b_chunk: usize,
    threads: usize,
    f: F,
) where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert!(a_chunk > 0 && b_chunk > 0, "chunk lengths must be positive");
    let n_chunks = (a.len() + a_chunk - 1) / a_chunk;
    assert_eq!(
        n_chunks,
        (b.len() + b_chunk - 1) / b_chunk,
        "slices disagree on chunk count"
    );
    if a.is_empty() && b.is_empty() {
        return;
    }
    let threads = threads.max(1).min(n_chunks);
    if threads == 1 {
        for (i, (ca, cb)) in a.chunks_mut(a_chunk).zip(b.chunks_mut(b_chunk)).enumerate() {
            f(i, ca, cb);
        }
        return;
    }
    let chunks_per_worker = (n_chunks + threads - 1) / threads;
    thread::scope(|scope| {
        let mut rest_a = a;
        let mut rest_b = b;
        let mut first_chunk = 0usize;
        while !rest_a.is_empty() || !rest_b.is_empty() {
            let take_a = (chunks_per_worker * a_chunk).min(rest_a.len());
            let take_b = (chunks_per_worker * b_chunk).min(rest_b.len());
            let (span_a, tail_a) = rest_a.split_at_mut(take_a);
            let (span_b, tail_b) = rest_b.split_at_mut(take_b);
            rest_a = tail_a;
            rest_b = tail_b;
            let f = &f;
            let base = first_chunk;
            scope.spawn(move || {
                for (i, (ca, cb)) in span_a
                    .chunks_mut(a_chunk)
                    .zip(span_b.chunks_mut(b_chunk))
                    .enumerate()
                {
                    f(base + i, ca, cb);
                }
            });
            first_chunk += chunks_per_worker;
        }
    });
}

/// Parallel map: applies `f` to every item, preserving order, using `threads`
/// workers via scoped threads (no 'static bound on inputs).
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                // Each index is written exactly once; the mutex only guards
                // the Vec header, contention is negligible vs work done.
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(r);
            });
        }
    });
    out.into_iter().map(|r| r.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = par_map(&items, 8, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_chunks_mut_covers_all_chunks() {
        let mut data: Vec<u64> = vec![0; 103]; // deliberately not a multiple
        par_chunks_mut(&mut data, 10, 4, |ci, chunk| {
            for v in chunk.iter_mut() {
                *v = ci as u64 + 1;
            }
        });
        // Every element written, with its chunk's 1-based index.
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, (i / 10) as u64 + 1, "element {i}");
        }
    }

    #[test]
    fn par_chunks_mut_single_thread_and_empty() {
        let mut data: Vec<u8> = vec![0; 7];
        par_chunks_mut(&mut data, 3, 1, |_ci, chunk| {
            for v in chunk.iter_mut() {
                *v += 1;
            }
        });
        assert_eq!(data, vec![1; 7]);
        let mut empty: Vec<u8> = vec![];
        par_chunks_mut(&mut empty, 3, 4, |_, _| panic!("no chunks expected"));
    }

    #[test]
    fn par_chunks2_mut_lockstep_coverage() {
        // 7 chunks of (3, 2): last chunk of each is short.
        let mut a: Vec<u64> = vec![0; 20];
        let mut b: Vec<u64> = vec![0; 13];
        par_chunks2_mut(&mut a, 3, &mut b, 2, 4, |ci, ca, cb| {
            for v in ca.iter_mut() {
                *v = ci as u64 + 1;
            }
            for v in cb.iter_mut() {
                *v = (ci as u64 + 1) * 100;
            }
        });
        for (i, v) in a.iter().enumerate() {
            assert_eq!(*v, (i / 3) as u64 + 1, "a[{i}]");
        }
        for (i, v) in b.iter().enumerate() {
            assert_eq!(*v, ((i / 2) as u64 + 1) * 100, "b[{i}]");
        }
        // Single-thread path and empty inputs.
        let mut a: Vec<u8> = vec![0; 4];
        let mut b: Vec<u8> = vec![0; 2];
        par_chunks2_mut(&mut a, 2, &mut b, 1, 1, |_ci, ca, cb| {
            ca.iter_mut().for_each(|v| *v += 1);
            cb.iter_mut().for_each(|v| *v += 1);
        });
        assert_eq!(a, vec![1; 4]);
        assert_eq!(b, vec![1; 2]);
        let mut ea: Vec<u8> = vec![];
        let mut eb: Vec<u8> = vec![];
        par_chunks2_mut(&mut ea, 1, &mut eb, 1, 4, |_, _, _| panic!("no chunks"));
    }

    #[test]
    #[should_panic(expected = "chunk count")]
    fn par_chunks2_mut_rejects_mismatched_chunk_counts() {
        let mut a: Vec<u8> = vec![0; 10];
        let mut b: Vec<u8> = vec![0; 2];
        par_chunks2_mut(&mut a, 2, &mut b, 1, 2, |_, _, _| {});
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn par_map_empty() {
        let items: Vec<usize> = vec![];
        let out = par_map(&items, 4, |x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_drop_joins_cleanly() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
